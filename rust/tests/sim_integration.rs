//! Integration tests: scheduler × cluster simulator — the paper's
//! qualitative claims must hold as invariants of the composed system.

use sbs::cluster::sim::{DecodePlacement, SchedMode, SimConfig, Simulation};
use sbs::config;
use sbs::scheduler::baseline::ImmediatePolicy;
use sbs::scheduler::staggered::StaggeredConfig;
use sbs::workload::{LengthDist, PrefixSpec, WorkloadSpec};

fn quick(load_qps: f64, staggered: bool, seed: u64) -> SimConfig {
    let mut cfg = config::fig6a(1.0, staggered, seed);
    cfg.workload = WorkloadSpec::paper_short(load_qps, 40.0, seed);
    cfg.warmup = 8.0;
    cfg
}

#[test]
fn all_requests_complete_under_both_schedulers() {
    for staggered in [true, false] {
        let r = Simulation::run(&quick(60.0, staggered, 3));
        assert_eq!(r.completed, r.offered, "staggered={staggered}");
        assert_eq!(r.report.rejected, 0);
    }
}

#[test]
fn sbs_eliminates_device_side_queueing() {
    // §3.2: the core mechanism. Device-side wait under SBS must be an
    // order of magnitude below the immediate baseline at moderate load.
    let sbs = Simulation::run(&quick(100.0, true, 5));
    let imm = Simulation::run(&quick(100.0, false, 5));
    let (ds, di) = (
        sbs.report.device_queue.mean(),
        imm.report.device_queue.mean(),
    );
    assert!(
        ds < di / 3.0,
        "device queue: SBS {ds:.4}s vs immediate {di:.4}s"
    );
}

#[test]
fn sbs_improves_mean_ttft_at_moderate_load() {
    let sbs = Simulation::run(&quick(100.0, true, 7));
    let imm = Simulation::run(&quick(100.0, false, 7));
    let (ts, ti) = (sbs.report.ttft.mean(), imm.report.ttft.mean());
    assert!(
        ts < ti,
        "TTFT: SBS {:.1}ms vs immediate {:.1}ms",
        ts * 1e3,
        ti * 1e3
    );
}

#[test]
fn sbs_reduces_straggler_waste() {
    let sbs = Simulation::run(&quick(100.0, true, 9));
    let imm = Simulation::run(&quick(100.0, false, 9));
    assert!(
        sbs.straggler_waste_s < imm.straggler_waste_s,
        "waste: SBS {:.1} vs immediate {:.1} DP-s",
        sbs.straggler_waste_s,
        imm.straggler_waste_s
    );
}

#[test]
fn iqr_placement_tightens_kv_dispersion() {
    let mut base = config::fig7(30.0, false, 11);
    base.workload.duration = 120.0;
    base.warmup = 40.0;
    let mut sbs = base.clone();
    sbs.decode = DecodePlacement::IqrLex(Default::default());
    let rb = Simulation::run(&base);
    let rs = Simulation::run(&sbs);
    let (_, sigma_b) = rb.kv_band();
    let (_, sigma_s) = rs.kv_band();
    assert!(
        sigma_s < sigma_b,
        "KV σ: IQR {sigma_s:.0} vs random {sigma_b:.0}"
    );
}

#[test]
fn flow_control_engages_beyond_saturation() {
    // Far beyond capacity the staggered scheduler must shed load rather
    // than queue unboundedly.
    let mut cfg = quick(400.0, true, 13);
    cfg.workload.duration = 30.0;
    let r = Simulation::run(&cfg);
    assert!(r.report.rejected > 0, "expected rejections at 400 QPS");
    // Survivor TTFT stays bounded (the point of overload protection).
    assert!(r.report.ttft.percentile(99.0) < 10.0);
}

#[test]
fn cache_aware_pbaa_cuts_effective_prefill() {
    let mk = |aware: bool| {
        let mut cfg = quick(80.0, true, 17);
        cfg.workload.prefix = Some(PrefixSpec {
            groups: 8,
            zipf_s: 1.2,
            prefix_len: LengthDist::Uniform { lo: 256, hi: 900 },
            participation: 0.9,
        });
        if let SchedMode::Staggered(sc) = &mut cfg.mode {
            sc.pbaa.cache_aware = aware;
        }
        Simulation::run(&cfg)
    };
    let cold = mk(false);
    let warm = mk(true);
    // Same offered tokens; cache hits mean fewer computed prefill tokens.
    assert!(
        warm.report.throughput.prefill_tokens < cold.report.throughput.prefill_tokens,
        "computed prefill: warm {} vs cold {}",
        warm.report.throughput.prefill_tokens,
        cold.report.throughput.prefill_tokens
    );
}

#[test]
fn static_interval_underperforms_adaptive_when_miscalibrated() {
    let mk = |adaptive: bool| {
        let mut cfg = quick(100.0, true, 19);
        if let SchedMode::Staggered(StaggeredConfig { interval, .. }) = &mut cfg.mode {
            interval.adaptive = adaptive;
            interval.t_default = 1.2; // 3–4× the true pass time
        }
        Simulation::run(&cfg)
    };
    let adaptive = mk(true);
    let fixed = mk(false);
    assert!(
        adaptive.report.ttft.mean() < fixed.report.ttft.mean(),
        "adaptive {:.1}ms vs static {:.1}ms",
        adaptive.report.ttft.mean() * 1e3,
        fixed.report.ttft.mean() * 1e3
    );
}

#[test]
fn deterministic_replay_is_bit_exact() {
    let cfg = quick(60.0, true, 21);
    let trace = cfg.workload.generate();
    let a = Simulation::run_trace(&cfg, trace.clone());
    let b = Simulation::run_trace(&cfg, trace);
    assert_eq!(a.prefill_passes, b.prefill_passes);
    assert_eq!(a.decode_steps, b.decode_steps);
    assert!((a.report.ttft.mean() - b.report.ttft.mean()).abs() < 1e-15);
}

#[test]
fn jsq_beats_round_robin_for_immediate_dispatch() {
    // Sanity on the baselines themselves: state-aware immediate policies
    // should not be worse than blind RR.
    let rr = Simulation::run(&{
        let mut c = quick(120.0, false, 23);
        c.mode = SchedMode::Immediate(ImmediatePolicy::RoundRobin);
        c
    });
    let jsq = Simulation::run(&{
        let mut c = quick(120.0, false, 23);
        c.mode = SchedMode::Immediate(ImmediatePolicy::JoinShortestQueue);
        c
    });
    assert!(jsq.report.ttft.mean() <= rr.report.ttft.mean() * 1.15);
}

#[test]
fn watchdog_preserves_liveness_under_signal_loss() {
    // §4.1.2 safety path at system level: with 25% of EndForward signals
    // silently dropped, the watchdog's forced resets must keep the
    // cluster serving — every request still completes.
    let mut cfg = quick(80.0, true, 31);
    cfg.fault_lose_endforward = 0.25;
    let r = Simulation::run(&cfg);
    assert!(r.lost_signals > 0, "fault injection must actually fire");
    assert_eq!(r.completed, r.offered, "liveness under signal loss");
    // Latency degrades but stays bounded (graceful degradation).
    let healthy = Simulation::run(&quick(80.0, true, 31));
    assert!(r.report.ttft.mean() < healthy.report.ttft.mean() * 25.0);
}
