//! Integration tests for the concurrent TCP serving frontend over the
//! mock-engine cluster: interleaved streaming across ≥ 4 connections,
//! `BUSY` load shedding under overload, and the drain-on-`SHUTDOWN` path.
//! No artifacts or `pjrt` feature required.

use sbs::cluster::workers::{AdmissionConfig, EngineSpec, RealClusterConfig};
use sbs::engine::mock::MockEngineConfig;
use sbs::scheduler::flow::FlowPolicy;
use sbs::testing::net::{LineClient, Reply, TestServer};
use std::time::Duration;

fn mock_cfg() -> RealClusterConfig {
    RealClusterConfig {
        engine: EngineSpec::Mock(MockEngineConfig::default()),
        ..Default::default()
    }
}

#[test]
fn four_concurrent_clients_stream_interleaved() {
    let server = TestServer::start(mock_cfg());
    let mut handles = Vec::new();
    for i in 0..4 {
        let addr = server.addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = LineClient::connect(&addr).expect("connect");
            let prompt = format!("client {i} {}", "x".repeat(40));
            let out = c.gen(24, &prompt).expect("gen");
            let _ = c.send("QUIT");
            out
        }));
    }
    let outs: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (i, o) in outs.iter().enumerate() {
        assert!(!o.busy, "client {i} unexpectedly BUSY under light load");
        assert_eq!(o.tokens.len(), 24, "client {i} token count");
        assert!(o.done.is_some(), "client {i} missing DONE");
        let done = o.done.as_deref().unwrap();
        assert!(done.contains("ttft_ms="), "DONE carries ttft: {done}");
    }
    // Streaming must interleave across connections: some client receives
    // its first token while another client's stream is still open.
    let mut overlap = false;
    for a in &outs {
        for b in &outs {
            let (fa, la) = (a.tok_times[0], *a.tok_times.last().unwrap());
            let fb = b.tok_times[0];
            if fb > fa && fb < la {
                overlap = true;
            }
        }
    }
    assert!(overlap, "expected interleaved token streams across connections");
    server.shutdown().expect("clean shutdown");
}

#[test]
fn overload_returns_busy_then_recovers() {
    let mut cfg = mock_cfg();
    cfg.admission = AdmissionConfig {
        max_inflight: 2,
        policy: FlowPolicy::RejectOverloaded,
        ..Default::default()
    };
    // Slow decode so admitted jobs hold the in-flight window open while
    // the burst lands.
    cfg.engine = EngineSpec::Mock(MockEngineConfig {
        t_decode_step: 0.01,
        ..Default::default()
    });
    let server = TestServer::start(cfg);
    let mut handles = Vec::new();
    for i in 0..8 {
        let addr = server.addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = LineClient::connect(&addr).expect("connect");
            let out = c.gen(32, &format!("burst client {i}")).expect("gen");
            let _ = c.send("QUIT");
            out
        }));
    }
    let outs: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let busy = outs.iter().filter(|o| o.busy).count();
    let done = outs.iter().filter(|o| o.done.is_some()).count();
    assert!(busy > 0, "8-deep burst over a 2-slot window must shed load");
    assert!(done > 0, "admitted requests must still complete");
    // Recovery: once the burst drains, a fresh request is admitted.
    let mut c = LineClient::connect(&server.addr).expect("connect");
    let mut recovered = false;
    for _ in 0..100 {
        let out = c.gen(4, "post-burst probe").expect("gen");
        if !out.busy {
            assert_eq!(out.tokens.len(), 4);
            recovered = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    assert!(recovered, "server must admit again after the overload drains");
    let _ = c.send("QUIT");
    server.shutdown().expect("clean shutdown");
}

#[test]
fn shutdown_drains_inflight_generation() {
    let server = TestServer::start(mock_cfg());
    let addr = server.addr.clone();
    let (first_tok_tx, first_tok_rx) = std::sync::mpsc::channel();
    let client = std::thread::spawn(move || {
        let mut c = LineClient::connect(&addr).expect("connect");
        c.send("GEN 64 drain me across the shutdown boundary").expect("send");
        let mut tokens = 0u32;
        let mut done = false;
        loop {
            match c.recv().expect("recv") {
                Some(Reply::Tok { .. }) => {
                    tokens += 1;
                    if tokens == 1 {
                        first_tok_tx.send(()).unwrap();
                    }
                }
                Some(Reply::Done { .. }) => {
                    done = true;
                    break;
                }
                _ => break,
            }
        }
        (tokens, done)
    });
    // Wait until the generation is demonstrably in flight, then ask the
    // server to shut down mid-stream.
    first_tok_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("first token before shutdown");
    server.shutdown().expect("drain shutdown");
    let (tokens, done) = client.join().unwrap();
    assert!(done, "in-flight generation must complete through shutdown");
    assert_eq!(tokens, 64, "no tokens may be dropped by the drain");
}
