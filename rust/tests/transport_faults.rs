//! Deterministic fault injection against the transport layer, driven by
//! the scripted loopback harness in `sbs::testing::net` (no real shard
//! processes, no timing races): truncated/corrupt/reordered `KvSegment`
//! streams, mid-handoff peer death, codec-mismatch handshakes, the
//! decode shard's direct-transfer peer listener under the same abuse,
//! and the v4 multiplexed per-job streams (interleaved handoffs, stale
//! streams after relay fallback, split frames, two-in-flight death).
//!
//! The invariant under test everywhere: every fault ends in a **clean
//! reject-or-fallback** — a terminal event per affected job (failed or
//! evicted), never a hang, never a leaked pending-table entry, and a
//! surviving connection where the fault is job-scoped rather than
//! stream-scoped.

use sbs::cluster::shard::{run_shard, ShardConfig};
use sbs::cluster::workers::EngineSpec;
use sbs::engine::mock::MockEngineConfig;
use sbs::engine::sampler::Sampling;
use sbs::engine::PrefillOutcome;
use sbs::metrics::RequestMetrics;
use sbs::scheduler::types::SloClass;
use sbs::testing::net::{accept_peer, FakeShard, ShardConn};
use sbs::transport::peer::PeerMux;
use sbs::transport::proto::{
    self, DirectTarget, Frame, FrameReader, KvHalf, ShardRole, StreamId, PROTO_VERSION,
};
use sbs::transport::remote::{connect_prefill_shard, connect_shard, RemoteShardConfig};
use sbs::transport::{
    AdmitJob, DecodeTransport, KvCodec, KvWireCounters, PrefillSinks, PrefillTransport,
    PrefillWork, ShardSinks,
};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::{Duration, Instant};

const TICK: Duration = Duration::from_secs(10);

/// Channel-backed prefill sinks: every upstream event lands in a
/// receiver the test can assert on (or assert the absence of).
struct PrefillEvents {
    prefilled: Receiver<(u64, Box<PrefillOutcome>)>,
    failed: Receiver<u64>,
    evicted: Receiver<Vec<u64>>,
    handoff: Receiver<u64>,
}

fn prefill_sinks() -> (PrefillSinks, PrefillEvents) {
    let (p_tx, prefilled) = channel();
    let (f_tx, failed) = channel();
    let (e_tx, evicted) = channel();
    let (h_tx, handoff) = channel();
    (
        PrefillSinks {
            on_prefilled: Box::new(move |id, outcome, _max_new, _class, _m| {
                let _ = p_tx.send((id, outcome));
            }),
            on_handoff: Box::new(move |id, _exec| {
                let _ = h_tx.send(id);
            }),
            on_failed: Box::new(move |id| {
                let _ = f_tx.send(id);
            }),
            on_end_forward: Box::new(|_, _, _| {}),
            on_evicted: Box::new(move |ids| {
                let _ = e_tx.send(ids);
            }),
            on_trace: Box::new(|_, _| {}),
        },
        PrefillEvents {
            prefilled,
            failed,
            evicted,
            handoff,
        },
    )
}

fn work(id: u64, prompt_len: usize, max_new: u32) -> PrefillWork {
    PrefillWork {
        id,
        prompt: vec![7; prompt_len],
        max_new,
        class: SloClass::Standard,
        metrics: RequestMetrics::arrive(0.0, prompt_len as u32),
        target: None,
    }
}

/// Block until the script sees the dispatch for `id` (skipping pings).
fn await_dispatch(sc: &mut ShardConn, id: u64) -> anyhow::Result<()> {
    sc.recv_until(TICK, |f| {
        matches!(f, Frame::PrefillDispatch { jobs, .. } if jobs.iter().any(|j| j.id == id))
    })?;
    Ok(())
}

// ---- handshake faults ---------------------------------------------------

#[test]
fn codec_mismatch_handshake_is_refused() {
    // The shard acks `lz` against a scheduler that asked for `raw`: the
    // byte accounting (and lossiness expectations) would silently skew,
    // so the connect must fail loudly.
    let shard = FakeShard::serve(FakeShard::ack(ShardRole::Prefill, KvCodec::Lz), |_, _| Ok(()));
    let (sinks, _ev) = prefill_sinks();
    let err = connect_prefill_shard(RemoteShardConfig::new(&shard.addr), sinks, Arc::default())
        .expect_err("codec mismatch must refuse the handshake");
    assert!(format!("{err:#}").contains("codec"), "{err:#}");
}

#[test]
fn version_mismatch_handshake_is_refused() {
    let ack = Frame::HelloAck {
        version: PROTO_VERSION - 1,
        role: ShardRole::Prefill,
        units: 1,
        slots: 1,
        kv_wire: KvCodec::Raw,
        peer_port: 0,
    };
    let shard = FakeShard::serve(ack, |_, _| Ok(()));
    let (sinks, _ev) = prefill_sinks();
    let err = connect_prefill_shard(RemoteShardConfig::new(&shard.addr), sinks, Arc::default())
        .expect_err("version mismatch must refuse the handshake");
    assert!(format!("{err:#}").contains("protocol"), "{err:#}");
}

#[test]
fn wrong_role_handshake_is_refused() {
    let shard = FakeShard::serve(FakeShard::ack(ShardRole::Decode, KvCodec::Raw), |_, _| Ok(()));
    let (sinks, _ev) = prefill_sinks();
    assert!(
        connect_prefill_shard(RemoteShardConfig::new(&shard.addr), sinks, Arc::default()).is_err(),
        "a decode shard must not join a prefill pool"
    );
}

// ---- KV stream faults (relay path) --------------------------------------

#[test]
fn mid_handoff_shard_death_evicts_cleanly() {
    // The shard starts streaming a job's KV, then dies mid-handoff: the
    // pending entry (and its partial assembly) must come back as one
    // eviction — not a hang, not a stuck ledger entry, and never a
    // completed handoff.
    let shard = FakeShard::serve(FakeShard::ack(ShardRole::Prefill, KvCodec::Raw), |mut sc, _| {
        await_dispatch(&mut sc, 1)?;
        sc.send(&Frame::KvSegment {
            id: 1,
            half: KvHalf::K,
            offset: 0,
            total: 1000,
            data: vec![0.5; 200], // 800 elements never arrive
        })?;
        sc.kill();
        Ok(())
    });
    let (sinks, ev) = prefill_sinks();
    let mut units =
        connect_prefill_shard(RemoteShardConfig::new(&shard.addr), sinks, Arc::default()).unwrap();
    units[0].dispatch(vec![work(1, 16, 4)]).map_err(|_| ()).unwrap();

    let evicted = ev.evicted.recv_timeout(TICK).expect("death must evict, not hang");
    assert_eq!(evicted, vec![1], "exactly the in-flight job is evicted");
    assert!(ev.prefilled.try_recv().is_err(), "a dead handoff must not commit");
    assert!(ev.failed.try_recv().is_err(), "evicted, not failed — one terminal only");
    // A second eviction for the same id would double-release upstream.
    assert!(ev.evicted.try_recv().is_err(), "no duplicate eviction");
    units[0].detach();
}

#[test]
fn corrupt_segment_fails_job_but_connection_survives() {
    // A segment whose offset+len overruns its declared total is a
    // job-scoped fault: that job fails terminally, the connection (and
    // the next job) keeps working.
    let shard = FakeShard::serve(FakeShard::ack(ShardRole::Prefill, KvCodec::Raw), |mut sc, _| {
        await_dispatch(&mut sc, 1)?;
        sc.send(&Frame::KvSegment {
            id: 1,
            half: KvHalf::K,
            offset: 90,
            total: 100,
            data: vec![0.5; 20], // 90 + 20 > 100
        })?;
        await_dispatch(&mut sc, 2)?;
        sc.send(&Frame::PrefillDone {
            id: 2,
            first_token: 0x41,
            kv_len: 8,
            exec_time: 0.01,
        })?;
        // Hold the connection until the client detaches.
        let _ = sc.recv_until(Duration::from_secs(30), |_| false);
        Ok(())
    });
    let (sinks, ev) = prefill_sinks();
    let mut units =
        connect_prefill_shard(RemoteShardConfig::new(&shard.addr), sinks, Arc::default()).unwrap();
    units[0].dispatch(vec![work(1, 16, 4)]).map_err(|_| ()).unwrap();
    assert_eq!(ev.failed.recv_timeout(TICK).expect("corrupt KV fails the job"), 1);

    units[0].dispatch(vec![work(2, 8, 4)]).map_err(|_| ()).unwrap();
    let (id, outcome) = ev.prefilled.recv_timeout(TICK).expect("connection must survive");
    assert_eq!(id, 2);
    assert_eq!(outcome.first_token, 0x41);
    assert!(ev.evicted.try_recv().is_err(), "no eviction for a job-scoped fault");
    units[0].detach();
}

#[test]
fn absurd_total_fails_job_before_allocating() {
    // `total` claims more elements than MAX_FRAME could ever carry: the
    // client must fail the job instead of pre-sizing a giant buffer.
    let shard = FakeShard::serve(FakeShard::ack(ShardRole::Prefill, KvCodec::Raw), |mut sc, _| {
        await_dispatch(&mut sc, 5)?;
        sc.send(&Frame::KvSegment {
            id: 5,
            half: KvHalf::V,
            offset: 0,
            total: proto::MAX_FRAME / 4 + 1,
            data: vec![1.0; 4],
        })?;
        let _ = sc.recv_until(Duration::from_secs(30), |_| false);
        Ok(())
    });
    let (sinks, ev) = prefill_sinks();
    let mut units =
        connect_prefill_shard(RemoteShardConfig::new(&shard.addr), sinks, Arc::default()).unwrap();
    units[0].dispatch(vec![work(5, 16, 4)]).map_err(|_| ()).unwrap();
    assert_eq!(ev.failed.recv_timeout(TICK).expect("absurd total fails the job"), 5);
    units[0].detach();
}

#[test]
fn garbage_frame_kills_connection_and_evicts_pending() {
    // A structurally broken frame (unknown tag behind a valid length
    // prefix) desyncs the stream permanently: the reader must declare
    // the connection dead and evict every pending job.
    let shard = FakeShard::serve(FakeShard::ack(ShardRole::Prefill, KvCodec::Raw), |mut sc, _| {
        await_dispatch(&mut sc, 7)?;
        // v4 header: [len=5][stream=0], then payload with unknown tag 250.
        sc.send_raw(&[5, 0, 0, 0, 0, 0, 0, 0, 250, 1, 2, 3, 4])?;
        // Keep the socket open: the *decode error* alone must kill it.
        let _ = sc.recv_until(Duration::from_secs(30), |_| false);
        Ok(())
    });
    let (sinks, ev) = prefill_sinks();
    let mut units =
        connect_prefill_shard(RemoteShardConfig::new(&shard.addr), sinks, Arc::default()).unwrap();
    units[0].dispatch(vec![work(7, 16, 4)]).map_err(|_| ()).unwrap();
    let evicted = ev.evicted.recv_timeout(TICK).expect("garbage must evict, not hang");
    assert_eq!(evicted, vec![7]);
    units[0].detach();
}

#[test]
fn truncated_frame_then_death_evicts_cleanly() {
    // The connection dies mid-frame (half a length-prefixed frame on the
    // wire): partial bytes must not wedge the reader — death is death.
    let shard = FakeShard::serve(FakeShard::ack(ShardRole::Prefill, KvCodec::Raw), |mut sc, _| {
        await_dispatch(&mut sc, 9)?;
        let mut buf = Vec::new();
        proto::kv_segment_frame_into(
            &mut buf,
            KvCodec::Raw,
            proto::job_stream(9),
            9,
            KvHalf::K,
            0,
            64,
            &vec![1.0f32; 64],
        );
        sc.send_raw(&buf[..buf.len() / 2])?;
        sc.kill();
        Ok(())
    });
    let (sinks, ev) = prefill_sinks();
    let mut units =
        connect_prefill_shard(RemoteShardConfig::new(&shard.addr), sinks, Arc::default()).unwrap();
    units[0].dispatch(vec![work(9, 16, 4)]).map_err(|_| ()).unwrap();
    assert_eq!(ev.evicted.recv_timeout(TICK).expect("truncation + death must evict"), vec![9]);
    units[0].detach();
}

#[test]
fn reordered_coded_segments_reassemble_exactly() {
    // Out-of-order lz-coded chunks for both halves must assemble into
    // the exact caches (the relay path's correctness under the codec
    // layer + interleaving).
    let k: Vec<f32> = (0..900).map(|i| ((i / 7) as f32) * 0.125).collect();
    let v: Vec<f32> = (0..500).map(|i| -((i / 5) as f32) * 0.25).collect();
    let (k2, v2) = (k.clone(), v.clone());
    let shard = FakeShard::serve(
        FakeShard::ack(ShardRole::Prefill, KvCodec::Lz),
        move |mut sc, proposed| {
            assert_eq!(proposed, KvCodec::Lz, "scheduler proposed the lz codec");
            await_dispatch(&mut sc, 3)?;
            let mut buf = Vec::new();
            // V first, then K's second chunk before its first.
            for (half, data, ranges) in [
                (KvHalf::V, &v2, vec![(0usize, 500usize)]),
                (KvHalf::K, &k2, vec![(512, 900), (0, 512)]),
            ] {
                for (a, b) in ranges {
                    proto::kv_segment_frame_into(
                        &mut buf,
                        KvCodec::Lz,
                        proto::job_stream(3),
                        3,
                        half,
                        a as u32,
                        data.len() as u32,
                        &data[a..b],
                    );
                    sc.send_raw(&buf)?;
                }
            }
            sc.send(&Frame::PrefillDone {
                id: 3,
                first_token: 0x2A,
                kv_len: 24,
                exec_time: 0.02,
            })?;
            let _ = sc.recv_until(Duration::from_secs(30), |_| false);
            Ok(())
        },
    );
    let (sinks, ev) = prefill_sinks();
    let relay_kv: Arc<KvWireCounters> = Arc::default();
    let mut cfg = RemoteShardConfig::new(&shard.addr);
    cfg.kv_wire = KvCodec::Lz;
    let mut units = connect_prefill_shard(cfg, sinks, relay_kv.clone()).unwrap();
    units[0].dispatch(vec![work(3, 24, 4)]).map_err(|_| ()).unwrap();
    let (id, outcome) = ev.prefilled.recv_timeout(TICK).expect("handoff must commit");
    assert_eq!(id, 3);
    assert_eq!(outcome.k, k, "K must reassemble bit-exactly through lz");
    assert_eq!(outcome.v, v, "V must reassemble bit-exactly through lz");
    let (wire, raw) = relay_kv.snapshot();
    assert_eq!(raw, 4 * (900 + 500), "raw accounting counts every element");
    assert!(
        (wire as f64) < 0.6 * raw as f64,
        "structured KV must shrink ≥40% on the wire: {wire}/{raw}"
    );
    units[0].detach();
}

// ---- decode-side faults -------------------------------------------------

/// Channel-backed decode sinks.
struct DecodeEvents {
    evicted: Receiver<Vec<u64>>,
}

fn decode_sinks(tokens: Arc<AtomicU32>, dones: Arc<AtomicU32>) -> (ShardSinks, DecodeEvents) {
    let (e_tx, evicted) = channel();
    (
        ShardSinks {
            on_token: Box::new(move |_, _, _| {
                tokens.fetch_add(1, Ordering::SeqCst);
            }),
            on_done: Box::new(move |_, _, _| {
                dones.fetch_add(1, Ordering::SeqCst);
            }),
            on_rejected: Box::new(|_| {}),
            on_evicted: Box::new(move |ids| {
                let _ = e_tx.send(ids);
            }),
            on_stats: Box::new(|_, _, _| {}),
            on_trace: Box::new(|_, _| {}),
            on_migrated: Box::new(|_, _| {}),
        },
        DecodeEvents { evicted },
    )
}

#[test]
fn decode_shard_death_evicts_direct_registrations_too() {
    // A decode pre-placement registered with `expect_direct` (made at
    // dispatch time, before any KV moved) must be swept by the same
    // eviction as ordinary admits when the shard dies.
    let shard = FakeShard::serve(FakeShard::ack(ShardRole::Decode, KvCodec::Raw), |mut sc, _| {
        // Wait for the scheduler's first ping, then die.
        sc.recv_until(TICK, |f| matches!(f, Frame::Ping { .. }))?;
        sc.kill();
        Ok(())
    });
    let (sinks, ev) = decode_sinks(Arc::new(AtomicU32::new(0)), Arc::new(AtomicU32::new(0)));
    let mut units =
        connect_shard(RemoteShardConfig::new(&shard.addr), sinks, Arc::default()).unwrap();
    units[0].expect_direct(42, RequestMetrics::arrive(0.0, 16));
    let evicted = ev.evicted.recv_timeout(TICK).expect("shard death must evict");
    assert_eq!(evicted, vec![42], "the direct registration is swept");
    units[0].detach();
}

// ---- mid-migration shard death -------------------------------------------

/// Channel-backed decode sinks with every rescue-relevant event exposed.
struct MigrationEvents {
    tokens: Receiver<(u64, u32, i32)>,
    done: Receiver<u64>,
    evicted: Receiver<Vec<u64>>,
    /// `(id, extraction delivered)` per `on_migrated` call.
    migrated: Receiver<(u64, bool)>,
}

fn migration_sinks() -> (ShardSinks, MigrationEvents) {
    let (t_tx, tokens) = channel();
    let (d_tx, done) = channel();
    let (e_tx, evicted) = channel();
    let (m_tx, migrated) = channel();
    (
        ShardSinks {
            on_token: Box::new(move |id, index, token| {
                let _ = t_tx.send((id, index, token));
            }),
            on_done: Box::new(move |id, _, _| {
                let _ = d_tx.send(id);
            }),
            on_rejected: Box::new(|_| {}),
            on_evicted: Box::new(move |ids| {
                let _ = e_tx.send(ids);
            }),
            on_stats: Box::new(|_, _, _| {}),
            on_trace: Box::new(|_, _| {}),
            on_migrated: Box::new(move |id, seq| {
                let _ = m_tx.send((id, seq.is_some()));
            }),
        },
        MigrationEvents {
            tokens,
            done,
            evicted,
            migrated,
        },
    )
}

fn resident_job(id: u64) -> AdmitJob {
    AdmitJob {
        id,
        outcome: Box::new(PrefillOutcome {
            first_token: 0x41,
            len: 4,
            k: vec![0.5; 16],
            v: vec![0.25; 16],
            exec_time: 0.01,
            passes: 1,
        }),
        max_new: 8,
        class: SloClass::Interactive,
        resume: Vec::new(),
        metrics: RequestMetrics::arrive(0.0, 16),
    }
}

#[test]
fn source_shard_death_mid_migration_evicts_once_no_double_delivery() {
    // The scheduler asks a decode shard to extract a resident sequence;
    // the shard streams half the KV behind the coming MigrateAck and
    // dies. The move must collapse to the ordinary death path: exactly
    // one terminal (eviction) for the sequence, the partial extraction
    // assembly dropped, no migration result ever delivered — and never
    // a hang.
    let shard = FakeShard::serve(FakeShard::ack(ShardRole::Decode, KvCodec::Raw), |mut sc, _| {
        // Skip the Admit (and pings); the Migrate is the death cue.
        sc.recv_until(TICK, |f| matches!(f, Frame::Migrate { id: 60, .. }))?;
        sc.send(&Frame::KvSegment {
            id: 60,
            half: KvHalf::K,
            offset: 0,
            total: 400,
            data: vec![0.5; 100], // 300 elements never arrive
        })?;
        sc.kill();
        Ok(())
    });
    let (sinks, ev) = migration_sinks();
    let mut units =
        connect_shard(RemoteShardConfig::new(&shard.addr), sinks, Arc::default()).unwrap();
    units[0].admit(resident_job(60)).map_err(|_| ()).unwrap();
    assert!(units[0].extract(60), "extract is deliverable while the shard lives");

    let evicted = ev.evicted.recv_timeout(TICK).expect("death must evict, not hang");
    assert_eq!(evicted, vec![60], "exactly the mid-move sequence is evicted");
    assert!(
        ev.migrated.try_recv().is_err(),
        "a migration cut short by death must not deliver an extraction"
    );
    assert!(ev.done.try_recv().is_err(), "no completion for a sequence that died mid-move");
    assert!(ev.evicted.try_recv().is_err(), "one terminal only — no double delivery");
    units[0].detach();
}

#[test]
fn destination_shard_death_after_resumed_admit_is_single_terminal() {
    // The destination side of a live migration is an Admit carrying the
    // resume history. The shard echoes one post-resume token (proving
    // the history crossed the wire and the emission index continued
    // past it) and dies: the sequence must end in exactly one terminal
    // (eviction) — never a Done, never a replay of the resume prefix.
    let shard = FakeShard::serve(FakeShard::ack(ShardRole::Decode, KvCodec::Raw), |mut sc, _| {
        let admit = sc.recv_until(TICK, |f| matches!(f, Frame::Admit { id: 61, .. }))?;
        if let Frame::Admit { id, resume, .. } = admit {
            // Only a faithfully-transferred history earns the token the
            // test asserts on; a mangled resume fails loudly below.
            if resume == vec![0x41, 0x42, 0x43] {
                sc.send(&Frame::Token {
                    id,
                    index: resume.len() as u32,
                    token: 0x44,
                })?;
            }
        }
        sc.kill();
        Ok(())
    });
    let (sinks, ev) = migration_sinks();
    let mut units =
        connect_shard(RemoteShardConfig::new(&shard.addr), sinks, Arc::default()).unwrap();
    let mut job = resident_job(61);
    job.resume = vec![0x41, 0x42, 0x43];
    units[0].admit(job).map_err(|_| ()).unwrap();

    let (id, index, token) =
        ev.tokens.recv_timeout(TICK).expect("resume must survive the wire verbatim");
    assert_eq!((id, token), (61, 0x44));
    assert_eq!(index, 3, "emission resumes past the transferred history");
    let evicted = ev.evicted.recv_timeout(TICK).expect("destination death must evict");
    assert_eq!(evicted, vec![61]);
    assert!(ev.done.try_recv().is_err(), "no Done for a sequence the destination lost");
    assert!(ev.evicted.try_recv().is_err(), "one terminal only — ledger releases once");
    units[0].detach();
}

// ---- direct-transfer peer listener (real decode shard) ------------------

fn fast_mock() -> EngineSpec {
    EngineSpec::Mock(MockEngineConfig {
        t_prefill_base: 0.0,
        t_prefill_per_token: 0.0,
        t_decode_step: 0.001,
        chunk: 128,
        jitter: 0.0,
        kv_elems_per_token: 4,
    })
}

/// Minimal scheduler-side client for a real in-thread decode shard.
struct RawClient {
    w: TcpStream,
    rd: TcpStream,
    reader: FrameReader,
}

impl RawClient {
    fn connect(addr: std::net::SocketAddr) -> RawClient {
        let conn = TcpStream::connect(addr).unwrap();
        conn.set_nodelay(true).unwrap();
        conn.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        RawClient {
            w: conn.try_clone().unwrap(),
            rd: conn,
            reader: FrameReader::new(),
        }
    }

    fn send(&mut self, f: &Frame) {
        proto::write_frame(&mut self.w, f).unwrap();
    }

    /// Best-effort send: the peer may already have closed the socket
    /// (exactly what some fault scripts provoke).
    fn try_send(&mut self, f: &Frame) {
        let _ = proto::write_frame(&mut self.w, f);
    }

    fn send_raw(&mut self, bytes: &[u8]) {
        use std::io::Write;
        self.w.write_all(bytes).unwrap();
    }

    fn recv(&mut self, timeout: Duration) -> Frame {
        let deadline = Instant::now() + timeout;
        loop {
            match self.reader.poll(&mut self.rd) {
                Ok(Some(f)) => return f,
                Ok(None) => assert!(Instant::now() < deadline, "no frame within {timeout:?}"),
                Err(e) => panic!("receive failed: {e}"),
            }
        }
    }
}

/// Start a 1-unit decode shard in-thread; returns its scheduler client
/// (already handshaken), the peer port, and the shard join handle.
fn start_decode_shard() -> (RawClient, u16, std::thread::JoinHandle<anyhow::Result<()>>) {
    let cfg = ShardConfig {
        role: ShardRole::Decode,
        units: 1,
        batch: 4,
        engine: fast_mock(),
        sampling: Sampling::Greedy,
        seed: 3,
    };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let shard = std::thread::spawn(move || run_shard(cfg, listener));
    let mut c = RawClient::connect(addr);
    c.send(&Frame::Hello {
        version: PROTO_VERSION,
        kv_wire: KvCodec::Lz,
    });
    let peer_port = match c.recv(TICK) {
        Frame::HelloAck { peer_port, .. } => peer_port,
        other => panic!("expected HelloAck, got {other:?}"),
    };
    assert_ne!(peer_port, 0, "decode shards advertise their peer listener");
    (c, peer_port, shard)
}

fn peer_connect(port: u16, codec: KvCodec) -> RawClient {
    let mut p = RawClient::connect(format!("127.0.0.1:{port}").parse().unwrap());
    p.send(&Frame::PeerHello {
        version: PROTO_VERSION,
        kv_wire: codec,
    });
    match p.recv(TICK) {
        Frame::PeerHelloAck { version } => assert_eq!(version, PROTO_VERSION),
        other => panic!("expected PeerHelloAck, got {other:?}"),
    }
    p
}

#[test]
fn direct_peer_handoff_admits_and_emits_ordered_stream() {
    let (mut sched, peer_port, shard) = start_decode_shard();
    let mut peer = peer_connect(peer_port, KvCodec::Lz);

    // Stream a job's KV directly, commit, and expect the ack.
    let k: Vec<f32> = (0..640).map(|i| ((i / 7) as f32) * 0.125).collect();
    let mut buf = Vec::new();
    for (half, data) in [(KvHalf::K, &k), (KvHalf::V, &k)] {
        proto::kv_segment_frame_into(
            &mut buf,
            KvCodec::Lz,
            proto::job_stream(77),
            77,
            half,
            0,
            data.len() as u32,
            data,
        );
        peer.send_raw(&buf);
    }
    peer.send(&Frame::HandoffCommit {
        unit: 0,
        id: 77,
        first_token: 0x55,
        kv_len: 160,
        max_new: 3,
        class: SloClass::Interactive,
        exec_time: 0.01,
    });
    match peer.recv(TICK) {
        Frame::HandoffAck { id } => assert_eq!(id, 77),
        other => panic!("expected HandoffAck, got {other:?}"),
    }

    // The scheduler connection sees token 0 first, then the decode
    // steps, then Done — one ordered stream, indices contiguous.
    let mut next_index = 0u32;
    let done = loop {
        match sched.recv(TICK) {
            Frame::Token { id, index, token } => {
                assert_eq!(id, 77);
                assert_eq!(index, next_index, "stream must stay ordered from index 0");
                if index == 0 {
                    assert_eq!(token, 0x55, "index 0 is the prefill-produced token");
                }
                next_index += 1;
            }
            Frame::Done { id, tokens } => {
                assert_eq!(id, 77);
                break tokens;
            }
            other => panic!("unexpected frame {other:?}"),
        }
    };
    assert_eq!(done.len(), 4, "first token + 3 decoded");
    assert_eq!(done[0], 0x55);

    // The shard's inbound-KV accounting covered the peer stream.
    sched.send(&Frame::StatsRequest);
    let stats = loop {
        match sched.recv(TICK) {
            Frame::StatsReply {
                kv_wire_bytes,
                kv_raw_bytes,
                ..
            } => break (kv_wire_bytes, kv_raw_bytes),
            _ => continue,
        }
    };
    assert_eq!(stats.1, 4 * 2 * 640, "raw bytes = both halves");
    assert!(stats.0 > 0 && stats.0 < stats.1, "lz wire bytes shrink: {stats:?}");

    sched.send(&Frame::Stop);
    loop {
        if matches!(sched.recv(TICK), Frame::Bye) {
            break;
        }
    }
    shard.join().unwrap().unwrap();
}

#[test]
fn peer_death_mid_handoff_leaves_decode_shard_clean() {
    let (mut sched, peer_port, shard) = start_decode_shard();

    // A peer streams half a job's KV and dies: nothing was admitted, so
    // the shard must drop the partial assembly and keep serving.
    {
        let mut peer = peer_connect(peer_port, KvCodec::Raw);
        peer.send(&Frame::KvSegment {
            id: 9,
            half: KvHalf::K,
            offset: 0,
            total: 400,
            data: vec![1.0; 100],
        });
        drop(peer); // abrupt close
    }

    // A malformed peer segment poisons only that *job* (the connection
    // — and any sibling handoffs multiplexed on it — survives).
    {
        let mut peer = peer_connect(peer_port, KvCodec::Raw);
        peer.send(&Frame::KvSegment {
            id: 10,
            half: KvHalf::K,
            offset: 390,
            total: 400,
            data: vec![1.0; 100], // overruns the declared total
        });
        // The poisoned job's commit is swallowed: no admit, and the ack
        // is withheld so the sender's timeout routes the job to relay.
        peer.try_send(&Frame::HandoffCommit {
            unit: 0,
            id: 10,
            first_token: 1,
            kv_len: 4,
            max_new: 2,
            class: SloClass::Standard,
            exec_time: 0.0,
        });
    }

    // The same id then arrives via the ordinary relay Admit — the shard
    // serves it without interference from the dead peer's leftovers.
    sched.send(&Frame::Admit {
        unit: 0,
        id: 9,
        first_token: 0x30,
        kv_len: 4,
        max_new: 2,
        class: SloClass::Standard,
        resume: Vec::new(),
        k: Vec::new(),
        v: Vec::new(),
    });
    let done = loop {
        match sched.recv(TICK) {
            Frame::Token { id, .. } => assert!(id == 9, "only job 9 may emit (got {id})"),
            Frame::Done { id, tokens } => {
                assert_eq!(id, 9);
                break tokens;
            }
            Frame::Rejected { id } => panic!("job {id} rejected"),
            other => panic!("unexpected frame {other:?}"),
        }
    };
    assert_eq!(done.len(), 3, "relay admit serves normally after peer faults");

    sched.send(&Frame::Stop);
    loop {
        if matches!(sched.recv(TICK), Frame::Bye) {
            break;
        }
    }
    shard.join().unwrap().unwrap();
}

// ---- multiplexed peer streams (v4 stream framing) ------------------------

/// Drain the scheduler stream until `Done` has arrived for every id in
/// `want`, asserting no other job ever emits.
fn await_dones(sched: &mut RawClient, want: &[u64]) {
    let mut pending: Vec<u64> = want.to_vec();
    while !pending.is_empty() {
        match sched.recv(TICK) {
            Frame::Token { id, .. } => {
                assert!(want.contains(&id), "token from unexpected job {id}")
            }
            Frame::Done { id, .. } => {
                assert!(want.contains(&id), "done from unexpected job {id}");
                pending.retain(|&p| p != id);
            }
            Frame::Rejected { id } => panic!("job {id} rejected"),
            other => panic!("unexpected frame {other:?}"),
        }
    }
}

fn stop_shard(mut sched: RawClient, shard: std::thread::JoinHandle<anyhow::Result<()>>) {
    sched.send(&Frame::Stop);
    loop {
        if matches!(sched.recv(TICK), Frame::Bye) {
            break;
        }
    }
    shard.join().unwrap().unwrap();
}

#[test]
fn interleaved_handoffs_with_split_frames_share_one_connection() {
    // Two handoffs in flight on one peer connection, their frames
    // alternating at frame granularity on distinct streams — and every
    // frame of one stream arriving split across two writes (so the
    // reader always holds a partial frame of stream A when stream B's
    // next frame lands). Both must reassemble exactly and admit.
    let (mut sched, peer_port, shard) = start_decode_shard();
    let mut peer = peer_connect(peer_port, KvCodec::Raw);

    let ka: Vec<f32> = (0..200).map(|i| i as f32).collect();
    let kb: Vec<f32> = (0..120).map(|i| -(i as f32)).collect();
    let (sa, sb) = (proto::job_stream(101), proto::job_stream(102));
    let frames_for = |stream: StreamId, id: u64, data: &[f32]| -> Vec<Vec<u8>> {
        let mut out = Vec::new();
        for half in [KvHalf::K, KvHalf::V] {
            let mid = data.len() / 2;
            for (a, b) in [(0usize, mid), (mid, data.len())] {
                out.push(proto::frame_bytes_on(
                    stream,
                    &Frame::KvSegment {
                        id,
                        half,
                        offset: a as u32,
                        total: data.len() as u32,
                        data: data[a..b].to_vec(),
                    },
                ));
            }
        }
        out
    };
    let a_frames = frames_for(sa, 101, &ka);
    let b_frames = frames_for(sb, 102, &kb);
    for (af, bf) in a_frames.iter().zip(&b_frames) {
        let cut = af.len() / 2; // inside A's payload (header is 8 bytes)
        peer.send_raw(&af[..cut]);
        std::thread::sleep(Duration::from_millis(5)); // force a partial read
        peer.send_raw(&af[cut..]);
        peer.send_raw(bf);
    }
    for (stream, id, kv_len) in [(sa, 101u64, 50u32), (sb, 102, 30)] {
        peer.send_raw(&proto::frame_bytes_on(
            stream,
            &Frame::HandoffCommit {
                unit: 0,
                id,
                first_token: id as i32,
                kv_len,
                max_new: 2,
                class: SloClass::Standard,
                exec_time: 0.0,
            },
        ));
    }
    let mut acked = Vec::new();
    while acked.len() < 2 {
        match peer.recv(TICK) {
            Frame::HandoffAck { id } => acked.push(id),
            other => panic!("expected HandoffAck, got {other:?}"),
        }
    }
    acked.sort_unstable();
    assert_eq!(acked, vec![101, 102], "both interleaved handoffs admit");
    await_dones(&mut sched, &[101, 102]);
    stop_shard(sched, shard);
}

#[test]
fn stale_stream_frames_after_relay_fallback_are_dropped() {
    // A handoff goes bad (poisoned job → withheld ack), the scheduler
    // relay takes the job over — and then frames for the stale stream
    // keep arriving. They must be dropped without disturbing the
    // relay-admitted job, and the *next* handoff on the same connection
    // must work untouched.
    let (mut sched, peer_port, shard) = start_decode_shard();
    let mut peer = peer_connect(peer_port, KvCodec::Raw);

    let s20 = proto::job_stream(20);
    peer.send_raw(&proto::frame_bytes_on(
        s20,
        &Frame::KvSegment {
            id: 20,
            half: KvHalf::K,
            offset: 90,
            total: 100,
            data: vec![1.0; 20], // overrun: poisons job 20
        },
    ));
    peer.send_raw(&proto::frame_bytes_on(
        s20,
        &Frame::HandoffCommit {
            unit: 0,
            id: 20,
            first_token: 2,
            kv_len: 4,
            max_new: 2,
            class: SloClass::Standard,
            exec_time: 0.0,
        },
    ));
    // The prefill side would now time out on the ack and relay; the
    // scheduler admits job 20 the ordinary way.
    sched.send(&Frame::Admit {
        unit: 0,
        id: 20,
        first_token: 0x30,
        kv_len: 4,
        max_new: 2,
        class: SloClass::Standard,
        resume: Vec::new(),
        k: Vec::new(),
        v: Vec::new(),
    });
    // Late frames on the stale stream: dropped (GC'd if never
    // committed), never admitted, never fatal to the connection.
    peer.send_raw(&proto::frame_bytes_on(
        s20,
        &Frame::KvSegment {
            id: 20,
            half: KvHalf::V,
            offset: 0,
            total: 100,
            data: vec![1.0; 50],
        },
    ));
    // A fresh handoff on the same connection works end to end.
    let s21 = proto::job_stream(21);
    peer.send_raw(&proto::frame_bytes_on(
        s21,
        &Frame::KvSegment {
            id: 21,
            half: KvHalf::K,
            offset: 0,
            total: 8,
            data: vec![0.5; 8],
        },
    ));
    peer.send_raw(&proto::frame_bytes_on(
        s21,
        &Frame::KvSegment {
            id: 21,
            half: KvHalf::V,
            offset: 0,
            total: 8,
            data: vec![0.25; 8],
        },
    ));
    peer.send_raw(&proto::frame_bytes_on(
        s21,
        &Frame::HandoffCommit {
            unit: 0,
            id: 21,
            first_token: 7,
            kv_len: 2,
            max_new: 2,
            class: SloClass::Standard,
            exec_time: 0.0,
        },
    ));
    // The first (and only) ack is job 21's — job 20's stayed withheld.
    match peer.recv(TICK) {
        Frame::HandoffAck { id } => assert_eq!(id, 21, "poisoned job 20 must not be acked"),
        other => panic!("expected HandoffAck, got {other:?}"),
    }
    await_dones(&mut sched, &[20, 21]);
    stop_shard(sched, shard);
}

#[test]
fn peer_death_with_two_handoffs_in_flight_drops_both_assemblies() {
    // Mid-handoff death with *two* handoffs multiplexed on the dying
    // connection: neither was committed, so the shard must drop both
    // partial assemblies and serve both ids cleanly via relay after.
    let (mut sched, peer_port, shard) = start_decode_shard();
    {
        let mut peer = peer_connect(peer_port, KvCodec::Raw);
        for id in [31u64, 32] {
            peer.send_raw(&proto::frame_bytes_on(
                proto::job_stream(id),
                &Frame::KvSegment {
                    id,
                    half: KvHalf::K,
                    offset: 0,
                    total: 400,
                    data: vec![1.0; 100], // 300 elements never arrive
                },
            ));
        }
        drop(peer); // abrupt close with both assemblies open
    }
    for id in [31u64, 32] {
        sched.send(&Frame::Admit {
            unit: 0,
            id,
            first_token: 0x30,
            kv_len: 4,
            max_new: 2,
            class: SloClass::Standard,
            resume: Vec::new(),
            k: Vec::new(),
            v: Vec::new(),
        });
    }
    await_dones(&mut sched, &[31, 32]);
    stop_shard(sched, shard);
}

#[test]
fn concurrent_same_peer_handoffs_interleave_on_one_socket() {
    // The acceptance test for stream multiplexing: two concurrent
    // handoffs from one PeerMux to the same peer address must share one
    // socket and *demonstrably interleave* — the small handoff's frames
    // land before the big one's tail, on distinct streams, captured in
    // wire order by the test harness.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    // Small chunks → many frames per handoff, so the round-robin drain
    // has something to alternate between.
    let mux = Arc::new(PeerMux::new(4096, Duration::from_secs(30)));

    let server = std::thread::spawn(move || -> anyhow::Result<Vec<(StreamId, Frame)>> {
        let (mut sc, codec) = accept_peer(&listener, Duration::from_secs(10))?;
        assert_eq!(codec, KvCodec::Raw);
        // Stall reads so the sender's outbound queue backs up: by the
        // time the second handoff enqueues, the first still has a deep
        // backlog for it to interleave into.
        std::thread::sleep(Duration::from_millis(300));
        let cap = sc.capture_streams(Duration::from_secs(60), |cap| {
            cap.iter()
                .filter(|(_, f)| matches!(f, Frame::HandoffCommit { .. }))
                .count()
                == 2
        })?;
        for (_, f) in &cap {
            if let Frame::HandoffCommit { id, .. } = f {
                sc.send(&Frame::HandoffAck { id: *id })?;
            }
        }
        Ok(cap)
    });

    // Big enough that the backlog cannot hide in socket buffers while
    // the server stalls (16 MiB total), against a 4 KiB-elem chunk.
    let outcome = |elems: usize, fill: f32| PrefillOutcome {
        first_token: 1,
        len: elems / 4,
        k: vec![fill; elems],
        v: vec![fill; elems],
        exec_time: 0.0,
        passes: 1,
    };
    let big = outcome(2 * 1024 * 1024, 0.5);
    let small = outcome(2048, 0.25);
    let spawn_handoff = |mux: &Arc<PeerMux>, addr: &str, id: u64, out: PrefillOutcome| {
        let (mux, target) = (
            Arc::clone(mux),
            DirectTarget {
                addr: addr.to_string(),
                unit: 0,
            },
        );
        std::thread::spawn(move || {
            mux.handoff(KvCodec::Raw, &target, id, &out, 4, SloClass::Standard)
        })
    };
    let t_big = spawn_handoff(&mux, &addr, 201, big);
    std::thread::sleep(Duration::from_millis(50));
    let t_small = spawn_handoff(&mux, &addr, 202, small);
    t_big.join().unwrap().expect("big handoff must be acked");
    t_small.join().unwrap().expect("small handoff must be acked");

    let cap = server.join().unwrap().unwrap();
    let stream_of = |id: u64| {
        cap.iter()
            .find_map(|(s, f)| match f {
                Frame::HandoffCommit { id: i, .. } if *i == id => Some(*s),
                _ => None,
            })
            .expect("commit captured")
    };
    let (s_big, s_small) = (stream_of(201), stream_of(202));
    assert_ne!(s_big, s_small, "each handoff rides its own stream");
    // Stream discipline: every segment frame travels on the stream its
    // job's commit used.
    for (s, f) in &cap {
        if let Frame::KvSegment { id, .. } = f {
            assert_eq!(*s, stream_of(*id), "job {id} leaked onto a foreign stream");
        }
    }
    // The interleaving itself: the small handoff completes inside the
    // big one's frame sequence instead of queueing behind it.
    let last_big = cap.iter().rposition(|(s, _)| *s == s_big).unwrap();
    let first_small = cap.iter().position(|(s, _)| *s == s_small).unwrap();
    assert!(
        first_small < last_big,
        "small handoff must interleave into the big one's backlog \
         (first small frame at {first_small}, last big frame at {last_big})"
    );
}

#[test]
fn unknown_unit_peer_commit_is_rejected_to_scheduler() {
    let (mut sched, peer_port, shard) = start_decode_shard();
    let mut peer = peer_connect(peer_port, KvCodec::Raw);
    peer.send(&Frame::HandoffCommit {
        unit: 9, // shard has 1 unit
        id: 55,
        first_token: 1,
        kv_len: 4,
        max_new: 2,
        class: SloClass::Standard,
        exec_time: 0.0,
    });
    // The peer still gets its ack (the handoff reached a terminal
    // owner), and the scheduler stream carries the rejection.
    match peer.recv(TICK) {
        Frame::HandoffAck { id } => assert_eq!(id, 55),
        other => panic!("expected HandoffAck, got {other:?}"),
    }
    loop {
        match sched.recv(TICK) {
            Frame::Rejected { id } => {
                assert_eq!(id, 55);
                break;
            }
            Frame::Token { id, index: 0, .. } if id == 55 => continue, // pre-admit token 0
            other => panic!("unexpected frame {other:?}"),
        }
    }
    sched.send(&Frame::Stop);
    loop {
        if matches!(sched.recv(TICK), Frame::Bye) {
            break;
        }
    }
    shard.join().unwrap().unwrap();
}
