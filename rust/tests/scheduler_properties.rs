//! Property-based tests on scheduler invariants (seeded mini-framework,
//! `sbs::testing`). These are the correctness contracts of Algorithms
//! 1–3 that must hold for *any* workload.

use sbs::scheduler::decode::{lex_less, schedule_batch, DecodeSchedConfig};
use sbs::scheduler::interval::{IntervalConfig, IntervalController};
use sbs::scheduler::pbaa::{allocate, PbaaConfig};
use sbs::scheduler::prefix::{PrefixCacheModel, RadixTree};
use sbs::scheduler::state::DpState;
use sbs::scheduler::types::{DpUnitId, Request};
use sbs::testing::check;
use sbs::util::stats::Iqr;
use sbs::util::Rng;

fn gen_requests(rng: &mut Rng, n: usize, max_len: u32) -> Vec<Request> {
    (0..n)
        .map(|i| {
            Request::new(
                i as u64,
                rng.range_u64(1, max_len as u64) as u32,
                rng.range_u64(1, 512) as u32,
                rng.uniform(0.0, 100.0),
            )
        })
        .collect()
}

fn gen_pool(rng: &mut Rng, n: usize, c_chunk: u32) -> Vec<DpState> {
    (0..n)
        .map(|i| {
            let mut d = DpState::new(DpUnitId::new(0, i as u32), c_chunk);
            // Random pre-existing load.
            d.on_dispatch(rng.range_u64(0, c_chunk as u64 / 2) as u32);
            d
        })
        .collect()
}

#[test]
fn pbaa_never_assigns_to_exhausted_unit() {
    check("pbaa headroom precondition", 200, |g| {
        let n_req = g.len(64);
        let n_dp = g.len(16);
        let reqs = gen_requests(&mut g.rng, n_req, 4000);
        let mut dps = gen_pool(&mut g.rng, n_dp, 3072);
        // Snapshot capacities before allocation.
        let before: Vec<i64> = dps.iter().map(|d| d.c_avail()).collect();
        let out = allocate(&PbaaConfig::default(), vec![], reqs, &mut dps, None);
        // Every assignment went to a unit that had strictly positive
        // headroom at its moment of assignment. Since capacity only
        // decreases within a cycle, a unit that started ≤ 0 can never
        // receive anything.
        for a in &out.assignments {
            let i = a.unit.dp as usize;
            assert!(
                before[i] > 0,
                "unit {i} started with c_avail {} but got request {}",
                before[i],
                a.request.id
            );
        }
    });
}

#[test]
fn pbaa_conserves_requests() {
    check("pbaa conservation", 200, |g| {
        let n_req = g.len(64);
        let n_pend = g.len(16);
        let n_dp = g.len(8);
        let reqs = gen_requests(&mut g.rng, n_req, 4000);
        let pending = gen_requests(&mut g.rng, n_pend, 4000);
        let n_total = reqs.len() + pending.len();
        let mut dps = gen_pool(&mut g.rng, n_dp, 3072);
        let out = allocate(&PbaaConfig::default(), pending, reqs, &mut dps, None);
        assert_eq!(
            out.assignments.len() + out.next_queue.len() + out.overloaded.len(),
            n_total,
            "requests must never be lost or duplicated"
        );
    });
}

#[test]
fn pbaa_legacy_never_starved_by_new() {
    check("pbaa FCFS priority", 150, |g| {
        let n_leg = g.len(16);
        let n_fresh = g.len(16);
        let n_dp = g.len(8);
        let mut legacy = gen_requests(&mut g.rng, n_leg, 2000);
        for (i, r) in legacy.iter_mut().enumerate() {
            r.id = 1_000_000 + i as u64; // tag
        }
        let fresh = gen_requests(&mut g.rng, n_fresh, 2000);
        let mut dps = gen_pool(&mut g.rng, n_dp, 3072);
        let out = allocate(&PbaaConfig::default(), legacy.clone(), fresh, &mut dps, None);
        // If any legacy request failed to place, the capacity it saw was
        // exhausted *before* any new arrival was considered: therefore no
        // new request may occupy a unit that could instead have fit a
        // failed legacy request of smaller-or-equal size... The checkable
        // invariant: every unplaced legacy request is at least as long as
        // the shortest remaining headroom would allow (placement is
        // headroom-gated, not size-gated), so instead verify ordering:
        // legacy requests appear in assignments before any new request of
        // the same cycle touched the same unit's *initial* capacity.
        // Pragmatic check: if some legacy went unplaced, total assigned
        // tokens must have exhausted all units.
        let legacy_unplaced = out
            .next_queue
            .iter()
            .chain(out.overloaded.iter())
            .any(|r| r.id >= 1_000_000);
        if legacy_unplaced {
            assert!(
                dps.iter().all(|d| d.c_avail() <= 0),
                "legacy unplaced while headroom remained: {:?}",
                dps.iter().map(|d| d.c_avail()).collect::<Vec<_>>()
            );
        }
    });
}

#[test]
fn alg3_lexicographic_choice_is_minimal() {
    check("alg3 lex minimality", 200, |g| {
        let n_dp = 1 + g.len(32);
        let mut dps: Vec<DpState> = (0..n_dp)
            .map(|i| {
                let mut d = DpState::new(DpUnitId::new(0, i as u32), 0);
                d.batch = g.rng.range_u64(0, 50) as u32;
                d.kv_tokens = g.rng.range_u64(0, 200_000);
                d
            })
            .collect();
        let snapshot: Vec<(u32, u64)> = dps.iter().map(|d| (d.batch, d.kv_tokens)).collect();
        let kvs: Vec<f64> = snapshot.iter().map(|s| s.1 as f64).collect();
        let threshold = Iqr::of(&kvs).outlier_threshold(1.5);

        let req = Request::new(0, 1000, 100, 0.0);
        let out = schedule_batch(&DecodeSchedConfig::default(), vec![req], &mut dps);
        let chosen = out[0].unit.dp as usize;

        // The chosen unit must be lexicographically minimal among the
        // units within the IQR threshold (or among all if all masked).
        let safe: Vec<usize> = (0..n_dp)
            .filter(|&i| snapshot[i].1 as f64 <= threshold)
            .collect();
        let candidates = if safe.is_empty() {
            (0..n_dp).collect::<Vec<_>>()
        } else {
            safe
        };
        assert!(candidates.contains(&chosen), "chosen unit must be unmasked");
        for &c in &candidates {
            let a = (snapshot[chosen].0, snapshot[chosen].1);
            let b = (snapshot[c].0, snapshot[c].1);
            assert!(a <= b || !lex_strict_less(b, a), "not minimal: chose {a:?} over {b:?}");
        }
    });
}

fn lex_strict_less(a: (u32, u64), b: (u32, u64)) -> bool {
    a.0 < b.0 || (a.0 == b.0 && a.1 < b.1)
}

#[test]
fn alg3_state_updates_are_exact() {
    check("alg3 bookkeeping", 150, |g| {
        let n_dp = 1 + g.len(16);
        let n_req = g.len(64);
        let mut dps: Vec<DpState> = (0..n_dp)
            .map(|i| DpState::new(DpUnitId::new(0, i as u32), 0))
            .collect();
        let reqs = gen_requests(&mut g.rng, n_req, 8000);
        let total_len: u64 = reqs.iter().map(|r| r.total_len() as u64).sum();
        let out = schedule_batch(&DecodeSchedConfig::default(), reqs, &mut dps);
        assert_eq!(out.len(), n_req, "every request placed");
        let batch_sum: u32 = dps.iter().map(|d| d.batch).sum();
        let kv_sum: u64 = dps.iter().map(|d| d.kv_tokens).sum();
        assert_eq!(batch_sum as usize, n_req);
        assert_eq!(kv_sum, total_len);
    });
}

#[test]
fn alg3_balances_batch_sizes_within_one() {
    check("alg3 batch balance (uniform lengths)", 100, |g| {
        let n_dp = 1 + g.len(16);
        let n_req = g.len(128);
        let mut dps: Vec<DpState> = (0..n_dp)
            .map(|i| DpState::new(DpUnitId::new(0, i as u32), 0))
            .collect();
        // Identical lengths: batch counts must end within 1 of each other.
        let reqs: Vec<Request> = (0..n_req).map(|i| Request::new(i as u64, 100, 10, 0.0)).collect();
        schedule_batch(&DecodeSchedConfig::default(), reqs, &mut dps);
        let min = dps.iter().map(|d| d.batch).min().unwrap();
        let max = dps.iter().map(|d| d.batch).max().unwrap();
        assert!(max - min <= 1, "batch spread {min}..{max}");
    });
}

#[test]
fn interval_always_positive_and_bounded() {
    check("Alg1 interval bounds", 200, |g| {
        let n = 1 + g.rng.index(64) as u32;
        let mut c = IntervalController::new(IntervalConfig::default(), n);
        let mut max_sample: f64 = IntervalConfig::default().t_default;
        for _ in 0..g.len(200) {
            let t = g.rng.uniform(0.001, 5.0);
            max_sample = max_sample.max(t);
            c.on_end_forward(t);
            assert!(c.i_opt() > 0.0);
            // I_opt can never exceed the largest plausible cycle time.
            assert!(c.i_opt() <= (max_sample + 1.0) / 1.0);
        }
    });
}

#[test]
fn radix_tree_match_is_consistent_with_inserts() {
    check("radix tree consistency", 150, |g| {
        let mut tree = RadixTree::new(u64::MAX);
        let mut inserted: Vec<Vec<u32>> = Vec::new();
        for _ in 0..g.len(20) {
            let len = 1 + g.rng.index(64);
            let seq: Vec<u32> = if !inserted.is_empty() && g.rng.chance(0.5) {
                // Extend an existing sequence (shared prefix).
                let base = &inserted[g.rng.index(inserted.len())];
                let keep = 1 + g.rng.index(base.len());
                let mut s = base[..keep].to_vec();
                for _ in 0..g.rng.index(32) {
                    s.push(g.rng.next_u64() as u32);
                }
                s
            } else {
                (0..len).map(|_| g.rng.next_u64() as u32).collect()
            };
            tree.insert(&seq);
            inserted.push(seq);
        }
        // Every inserted sequence matches fully.
        for s in &inserted {
            assert_eq!(tree.match_prefix(s) as usize, s.len());
        }
    });
}

#[test]
fn prefix_cache_hit_never_exceeds_request_prefix() {
    check("len_hit bounds", 150, |g| {
        let units = 1 + g.len(8);
        let mut cache = PrefixCacheModel::new(units, u64::MAX);
        for _ in 0..g.len(30) {
            let unit = g.rng.index(units);
            let group = g.rng.range_u64(0, 8);
            let len = 1 + g.rng.index(512) as u32;
            let hit_before = cache.len_hit(unit, group, len);
            assert!(hit_before <= len);
            cache.admit(unit, group, len);
            let hit_after = cache.len_hit(unit, group, len);
            assert_eq!(hit_after, len, "admit must make the prefix fully hot");
        }
    });
}
