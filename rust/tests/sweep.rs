//! Integration tests for the `sbs sweep` experiment harness: document
//! determinism, schema validation, regression comparison, and a live
//! mock-cluster smoke pass — the properties the CI bench gate leans on.

use sbs::json::{self, Json};
use sbs::workload::sweep::{self, LiveOpts, SweepGrid, SweepModes};

/// A DES grid small enough to run in milliseconds but still covering both
/// schedulers and two arrival processes.
fn tiny_grid() -> SweepGrid {
    SweepGrid {
        scheds: vec!["staggered".into(), "immediate".into()],
        arrivals: vec!["poisson".into(), "bursty".into()],
        policies: vec!["load-aware".into()],
        qps: vec![20.0],
        windows: vec![0.0],
        kv_budgets: vec![150_000],
        codecs: vec!["raw".into()],
        shards: vec![2],
        replicas: 2,
        seed: 5,
        duration: 8.0,
        warmup: 2.0,
    }
}

fn des_modes() -> SweepModes {
    SweepModes {
        bench_id: "BENCH_TEST".into(),
        des: true,
        live: None,
    }
}

/// Navigate to a mutable numeric leaf in a parsed document.
fn num_at<'a>(doc: &'a mut Json, path: &[&str]) -> &'a mut f64 {
    let mut cur = doc;
    for key in path {
        let Json::Obj(map) = cur else {
            panic!("expected object at '{key}'");
        };
        cur = map.get_mut(*key).unwrap_or_else(|| panic!("missing '{key}'"));
    }
    match cur {
        Json::Num(x) => x,
        other => panic!("expected number, got {other:?}"),
    }
}

/// Scale one point's summary metric (mean and replicas stay consistent
/// enough for [`sweep::validate`], which only checks presence).
fn scale_metric(doc: &mut Json, point: usize, metric: &str, factor: f64) {
    let Json::Obj(root) = doc else { panic!("doc not an object") };
    let Some(Json::Arr(points)) = root.get_mut("points") else {
        panic!("missing points");
    };
    let pt = &mut points[point];
    *num_at(pt, &["summary", metric, "mean"]) *= factor;
}

#[test]
fn same_grid_same_seed_is_byte_identical() {
    let grid = tiny_grid();
    let a = sweep::run_sweep(&grid, &des_modes()).unwrap();
    let b = sweep::run_sweep(&grid, &des_modes()).unwrap();
    assert_eq!(a.dump(), b.dump(), "sweep output must be deterministic");
    // And a different seed must actually change the document — the
    // determinism above is not just constants.
    let mut reseeded = tiny_grid();
    reseeded.seed = 6;
    let c = sweep::run_sweep(&reseeded, &des_modes()).unwrap();
    assert_ne!(a.dump(), c.dump(), "seed must matter");
}

#[test]
fn emitted_document_round_trips_and_validates() {
    let doc = sweep::run_sweep(&tiny_grid(), &des_modes()).unwrap();
    sweep::validate(&doc).expect("fresh document must validate");
    let back = json::parse(&doc.dump()).expect("document must re-parse");
    assert_eq!(doc, back, "dump/parse must round-trip exactly");
    sweep::validate(&back).expect("round-tripped document must validate");

    // Grid shape: 2 scheds × 2 arrivals = 4 points, 2 replicas each.
    let points = doc.get("points").and_then(Json::as_arr).unwrap();
    assert_eq!(points.len(), 4);
    for pt in points {
        let reps = pt.get("replicas").and_then(Json::as_arr).unwrap();
        assert_eq!(reps.len(), 2);
        let arrival = pt.path(&["params", "arrival"]).and_then(Json::as_str);
        match arrival {
            // The M/M/1 column exists exactly for poisson points.
            Some("poisson") => {
                assert!(pt.f64_at(&["mm1", "rho"]).is_some(), "poisson point lacks mm1")
            }
            _ => assert_eq!(pt.get("mm1"), Some(&Json::Null)),
        }
        // The sweep horizon must actually produce traffic.
        assert!(pt.f64_at(&["summary", "ttft_p99_ms", "mean"]).unwrap() > 0.0);
        for rep in reps {
            assert!(rep.f64_at(&["completed"]).unwrap() > 0.0);
        }
    }
}

#[test]
fn validate_rejects_corruption() {
    let doc = sweep::run_sweep(&tiny_grid(), &des_modes()).unwrap();

    // Wrong schema name.
    let mut bad = doc.clone();
    if let Json::Obj(m) = &mut bad {
        m.insert("schema".into(), Json::from("something-else"));
    }
    assert!(sweep::validate(&bad).is_err());

    // Unsupported version.
    let mut bad = doc.clone();
    if let Json::Obj(m) = &mut bad {
        m.insert("schema_version".into(), Json::from(999u64));
    }
    assert!(sweep::validate(&bad).is_err());

    // Dropped replica (count no longer matches grid.replicas).
    let mut bad = doc.clone();
    if let Json::Obj(m) = &mut bad {
        if let Some(Json::Arr(points)) = m.get_mut("points") {
            if let Json::Obj(pt) = &mut points[0] {
                if let Some(Json::Arr(reps)) = pt.get_mut("replicas") {
                    reps.pop();
                }
            }
        }
    }
    assert!(sweep::validate(&bad).is_err());

    // Missing summary metric.
    let mut bad = doc.clone();
    if let Json::Obj(m) = &mut bad {
        if let Some(Json::Arr(points)) = m.get_mut("points") {
            if let Json::Obj(pt) = &mut points[0] {
                if let Some(Json::Obj(s)) = pt.get_mut("summary") {
                    s.remove("ttft_p99_ms");
                }
            }
        }
    }
    assert!(sweep::validate(&bad).is_err());

    // Empty points array.
    let mut bad = doc;
    if let Json::Obj(m) = &mut bad {
        m.insert("points".into(), Json::Arr(vec![]));
    }
    assert!(sweep::validate(&bad).is_err());
}

#[test]
fn compare_identical_documents_reports_nothing() {
    let doc = sweep::run_sweep(&tiny_grid(), &des_modes()).unwrap();
    let rep = sweep::compare(&doc, &doc, 0.25, 3.0).unwrap();
    assert_eq!(rep.compared, 4);
    assert_eq!(rep.only_old, 0);
    assert_eq!(rep.only_new, 0);
    assert!(rep.regressions.is_empty(), "self-compare regressed: {:?}", rep.regressions);
    assert!(rep.improvements.is_empty());
}

#[test]
fn compare_flags_injected_regression_and_improvement() {
    let old = sweep::run_sweep(&tiny_grid(), &des_modes()).unwrap();

    // sigma = 0 isolates the relative floor, making these assertions
    // independent of the (seed-dependent) replica scatter.

    // 2× TTFT p99 on one point: unambiguous regression at rel 0.25.
    let mut worse = old.clone();
    scale_metric(&mut worse, 0, "ttft_p99_ms", 2.0);
    let rep = sweep::compare(&old, &worse, 0.25, 0.0).unwrap();
    assert_eq!(rep.regressions.len(), 1, "regressions: {:?}", rep.regressions);
    assert!(rep.regressions[0].contains("ttft_p99_ms"));

    // Halving decode throughput regresses on the lower-is-worse axis.
    let mut slower = old.clone();
    scale_metric(&mut slower, 1, "decode_tps", 0.5);
    let rep = sweep::compare(&old, &slower, 0.25, 0.0).unwrap();
    assert_eq!(rep.regressions.len(), 1);
    assert!(rep.regressions[0].contains("decode_tps"));

    // The same deltas in the good direction are improvements, not
    // regressions — direction awareness.
    let rep = sweep::compare(&worse, &old, 0.25, 0.0).unwrap();
    assert!(rep.regressions.is_empty());
    assert_eq!(rep.improvements.len(), 1);

    // A 10% drift stays under the 25% relative floor.
    let mut drift = old.clone();
    scale_metric(&mut drift, 0, "ttft_p99_ms", 1.10);
    let rep = sweep::compare(&old, &drift, 0.25, 0.0).unwrap();
    assert!(rep.regressions.is_empty(), "drift flagged: {:?}", rep.regressions);
}

#[test]
fn compare_noise_term_widens_the_gate() {
    let old = sweep::run_sweep(&tiny_grid(), &des_modes()).unwrap();
    // Two seeds never agree exactly, so every point carries real scatter.
    let points = old.get("points").and_then(Json::as_arr).unwrap();
    let std = points[0].f64_at(&["summary", "ttft_p99_ms", "std"]).unwrap();
    assert!(std > 0.0, "replica scatter expected");

    // A 30% jump clears the 25% floor when sigma is 0...
    let mut worse = old.clone();
    scale_metric(&mut worse, 0, "ttft_p99_ms", 1.30);
    let rep = sweep::compare(&old, &worse, 0.25, 0.0).unwrap();
    assert_eq!(rep.regressions.len(), 1);

    // ...but an absurd sigma makes the noise term dominate and the same
    // delta is absorbed: the gate really is stddev-aware.
    let rep = sweep::compare(&old, &worse, 0.25, 1e12).unwrap();
    assert!(rep.regressions.is_empty(), "noise term ignored: {:?}", rep.regressions);
}

#[test]
fn compare_tracks_grid_membership() {
    let old = sweep::run_sweep(&tiny_grid(), &des_modes()).unwrap();
    let mut shrunk = tiny_grid();
    shrunk.arrivals = vec!["poisson".into()];
    let new = sweep::run_sweep(&shrunk, &des_modes()).unwrap();
    let rep = sweep::compare(&old, &new, 0.25, 3.0).unwrap();
    // The 2 poisson points match; the 2 bursty points only exist on the
    // old side.
    assert_eq!(rep.compared, 2);
    assert_eq!(rep.only_old, 2);
    assert_eq!(rep.only_new, 0);
}

#[test]
fn live_mock_cluster_smoke() {
    // One point, one replica, short horizon: exercises TestServer +
    // loadgen end-to-end through the sweep path.
    let grid = SweepGrid {
        scheds: vec!["staggered".into()],
        arrivals: vec!["poisson".into()],
        policies: vec!["load-aware".into()],
        qps: vec![10.0],
        windows: vec![0.0],
        kv_budgets: vec![150_000],
        codecs: vec!["raw".into()],
        shards: vec![2],
        replicas: 1,
        seed: 11,
        duration: 1.5,
        warmup: 0.0,
    };
    let modes = SweepModes {
        bench_id: "BENCH_LIVE_TEST".into(),
        des: false,
        live: Some(LiveOpts {
            remote_decode: vec![],
            prompt_tokens: 24,
            max_new: 6,
            conns: 4,
        }),
    };
    let doc = sweep::run_sweep(&grid, &modes).unwrap();
    sweep::validate(&doc).expect("live document must validate");
    let points = doc.get("points").and_then(Json::as_arr).unwrap();
    assert_eq!(points.len(), 1);
    let pt = &points[0];
    assert_eq!(pt.path(&["params", "mode"]).and_then(Json::as_str), Some("live"));
    assert_eq!(pt.path(&["params", "kv_wire"]).and_then(Json::as_str), Some("raw"));
    assert_eq!(pt.f64_at(&["params", "local_pool_units"]), Some(2.0));
    let rep = &pt.get("replicas").and_then(Json::as_arr).unwrap()[0];
    assert!(rep.f64_at(&["completed"]).unwrap() > 0.0, "live run completed nothing");
    assert!(rep.f64_at(&["ttft_p99_ms"]).unwrap() > 0.0);
    // The live replica carries the per-stage TTFT decomposition fetched
    // off the server's STATS snapshot.
    assert!(
        rep.f64_at(&["ttft_stages", "requests"]).unwrap_or(0.0) > 0.0,
        "live replica has no finalized stage traces"
    );
}
