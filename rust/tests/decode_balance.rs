//! Integration tests for the multi-DP decode pool.
//!
//! 1. On the live mock-engine cluster with `n_decode = 4`, skewed output
//!    lengths must make load-aware placement (Algorithm 3) beat blind
//!    round-robin on the per-DP busy-time imbalance gauge — the live
//!    counterpart of the paper's Fig. 7 claim.
//! 2. The simulator-style and live-style drivers of the shared dispatch
//!    core must produce identical dispatch decisions from the same event
//!    trace (the refactor's no-divergence guarantee).

use sbs::cluster::dispatch::{
    DecodeJoin, DecodePolicy, DispatchCore, DispatchCoreConfig, EndForwardBacklog, FnAdmission,
    SchedMode,
};
use sbs::cluster::workers::{EngineSpec, Job, RealCluster, RealClusterConfig, RealSchedMode};
use sbs::engine::mock::MockEngineConfig;
use sbs::metrics::DecodePoolStats;
use sbs::scheduler::baseline::ImmediatePolicy;
use sbs::scheduler::staggered::{SchedulerAction, StaggeredConfig};
use sbs::scheduler::types::{DpUnitId, Request, SloClass};
use sbs::testing::scenarios::{skewed_decode_cluster, submit_skewed_jobs};
use sbs::workload::WorkloadSpec;
use std::collections::VecDeque;
use std::time::Duration;

const N_JOBS: u64 = 40;
const N_DECODE: u32 = 4;

/// Run the live mock cluster under `policy` with skewed output lengths:
/// every 4th job generates 150 tokens, the rest 3. Submission order is
/// near-deterministic (single prefill worker, spaced submissions), which
/// exposes round-robin's blindness: with the heavy jobs arriving at a
/// stride that aliases with the pool size, RR piles them onto the same
/// units while load-aware reacts to the live per-DP ledger.
fn run_live(policy: DecodePolicy) -> (DecodePoolStats, usize) {
    let cfg = skewed_decode_cluster(policy, N_DECODE);
    let cluster = RealCluster::start(cfg).expect("cluster start");
    let handle = cluster.handle();
    submit_skewed_jobs(&cluster, N_JOBS, 4, 150, 3);
    let (completions, _report) = cluster.finish().expect("cluster finish");
    (handle.decode_stats(), completions.len())
}

#[test]
fn load_aware_beats_round_robin_on_live_imbalance() {
    let (rr, rr_done) = run_live(DecodePolicy::RoundRobin);
    let (la, la_done) = run_live(DecodePolicy::LoadAware(Default::default()));
    assert_eq!(rr_done, N_JOBS as usize, "round-robin run must drain fully");
    assert_eq!(la_done, N_JOBS as usize, "load-aware run must drain fully");
    for stats in [&rr, &la] {
        assert_eq!(stats.units.len(), N_DECODE as usize);
        assert_eq!(stats.total_placed(), N_JOBS, "every job decodes: {stats:?}");
    }
    assert_eq!(rr.policy, "round-robin");
    assert_eq!(la.policy, "load-aware");
    let (rr_imb, la_imb) = (rr.imbalance(), la.imbalance());
    assert!(
        la_imb < rr_imb,
        "load-aware imbalance {la_imb:.3} must be strictly below round-robin {rr_imb:.3}\n\
         load-aware units: {:?}\nround-robin units: {:?}",
        la.units.iter().map(|u| u.seq_seconds).collect::<Vec<_>>(),
        rr.units.iter().map(|u| u.seq_seconds).collect::<Vec<_>>(),
    );
}

/// Drive one dispatch core through a fixed event trace the way each
/// driver does: the sim style acks + consumes every dispatched token and
/// reports zero backlog at `EndForward`; the live style does nothing
/// between dispatch and `EndForward` and lets the core clear the
/// capacity model wholesale (`ConsumedAll`).
fn drive_trace(live_style: bool) -> (Vec<(u32, Vec<u64>)>, DispatchCore) {
    fn record(
        core: &mut DispatchCore,
        actions: Vec<SchedulerAction>,
        live_style: bool,
        out: &mut Vec<(u32, Vec<u64>)>,
    ) {
        for act in actions {
            if let SchedulerAction::Dispatch(batch) = act {
                if !live_style {
                    for a in &batch.assignments {
                        let eff = a.request.input_tokens - a.cached_tokens;
                        core.on_deliver_ack(a.unit, eff);
                        core.on_prefill_consumed(a.unit, eff);
                    }
                }
                out.push((
                    batch.instance,
                    batch.assignments.iter().map(|a| a.request.id).collect(),
                ));
                // The engine finishes the pass and signals EndForward.
                let backlog = if live_style {
                    EndForwardBacklog::ConsumedAll
                } else {
                    EndForwardBacklog::Remaining(0)
                };
                let t_done = batch.at + 0.08;
                let next = core.on_end_forward(batch.instance, 0.08, backlog, t_done);
                record(core, next, live_style, out);
            }
        }
    }

    let cfg = DispatchCoreConfig {
        mode: SchedMode::Staggered(StaggeredConfig::default()),
        n_prefill: 2,
        dp_prefill: 2,
        c_chunk: 1024,
        n_decode: 2,
        dp_decode: 2,
        decode_policy: DecodePolicy::LoadAware(Default::default()),
        seed: 99,
    };
    let mut core = DispatchCore::new(&cfg);
    let mut decisions = Vec::new();
    let mut t = 0.0;
    for id in 0..24u64 {
        let len = 100 + (id as u32 * 57) % 800;
        let acts = core.on_arrival(Request::new(id, len, 16, t), t);
        record(&mut core, acts, live_style, &mut decisions);
        if id % 3 == 2 {
            t += 0.05;
            let acts = core.on_timer(t);
            record(&mut core, acts, live_style, &mut decisions);
        }
        t += 0.21;
    }
    (decisions, core)
}

#[test]
fn sim_and_live_drivers_make_identical_dispatch_decisions() {
    let (sim_style, mut core_sim) = drive_trace(false);
    let (live_style, mut core_live) = drive_trace(true);
    assert!(!sim_style.is_empty(), "trace must produce dispatches");
    assert_eq!(
        sim_style, live_style,
        "prefill dispatch decisions must match between driver styles"
    );
    // Decode placement goes through the same shared function: identical
    // join sets must land on identical units.
    let joins: Vec<DecodeJoin> = (0..12u64)
        .map(|i| DecodeJoin {
            request_id: 1000 + i,
            kv_tokens: 64 + (i as u32 * 97) % 900,
            remaining_out: 8 + (i as u32 * 13) % 120,
            class: SloClass::Standard,
            deadline: None,
        })
        .collect();
    let place = |core: &mut DispatchCore| -> Vec<(u64, DpUnitId)> {
        core.place_decode(joins.clone(), 10.0, &mut FnAdmission(|_, _| true))
            .placed
            .iter()
            .map(|(j, u)| (j.request_id, *u))
            .collect()
    };
    let pa = place(&mut core_sim);
    let pb = place(&mut core_live);
    assert_eq!(pa.len(), joins.len());
    assert_eq!(pa, pb, "decode placements must match between driver styles");
}

/// The deadline clock anchors at *arrival* (`ClusterHandle::now_s()` at
/// submit), never at dispatch. A deadlined job whose budget is smaller
/// than the prefill pass it queues behind must therefore score as
/// violated even though its own decode takes single-digit milliseconds —
/// a dispatch-anchored clock would trivially meet it. An identical job
/// with a generous budget scores met, and both verdicts accrue on the
/// rescue gauge with rescue disabled (the A/B property the CI rescue
/// smoke gates on).
#[test]
fn deadline_clock_anchors_at_arrival_not_dispatch() {
    let cfg = RealClusterConfig {
        n_prefill: 1,
        n_decode: 1,
        engine: EngineSpec::Mock(MockEngineConfig {
            t_prefill_base: 0.3,
            t_prefill_per_token: 0.0,
            t_decode_step: 0.001,
            chunk: 128,
            jitter: 0.0,
            kv_elems_per_token: 4,
        }),
        mode: RealSchedMode::Immediate(ImmediatePolicy::LeastOutstanding),
        ..Default::default()
    };
    let cluster = RealCluster::start(cfg).expect("cluster start");
    let handle = cluster.handle();

    // 150 ms of budget against a 300 ms prefill pass.
    let tight = handle.next_id();
    cluster.submit(
        Job::new(tight, vec![7; 64], 2)
            .with_class(SloClass::Interactive)
            .with_deadline_ms(150.0),
    );
    cluster.wait_for(tight, Duration::from_secs(30)).expect("tight job completes");

    let loose = handle.next_id();
    cluster.submit(
        Job::new(loose, vec![7; 64], 2)
            .with_class(SloClass::Interactive)
            .with_deadline_ms(30_000.0),
    );
    cluster.wait_for(loose, Duration::from_secs(30)).expect("loose job completes");
    cluster.finish().expect("cluster finish");

    let g = handle.decode_stats().rescue;
    assert!(!g.enabled, "rescue stays off: verdicts must accrue in both A/B arms");
    assert_eq!(
        (g.deadline_met, g.deadline_violated),
        (1, 1),
        "arrival-anchored clock: queueing time counts against the budget ({g:?})"
    );
    assert_eq!(g.preempted + g.migrated, 0, "no rescue actions while disabled");
}

/// Classed counterpart of [`drive_trace`]: a seeded 20/50/30
/// interactive/standard/batch trace against a single prefill instance
/// whose `EndForward` is withheld until every second event, so the core
/// sees genuine backlog and Algorithm 2's overload phase engages
/// (`N_limit = 2`). Returns (shed ids with class, dispatched ids) so the
/// two driver styles can be compared decision-for-decision.
fn drive_classed_overload(live_style: bool) -> (Vec<(u64, SloClass)>, Vec<u64>) {
    fn absorb(
        core: &mut DispatchCore,
        actions: Vec<SchedulerAction>,
        live_style: bool,
        shed: &mut Vec<(u64, SloClass)>,
        placed: &mut Vec<u64>,
        in_flight: &mut VecDeque<u32>,
    ) {
        for act in actions {
            match act {
                SchedulerAction::Dispatch(batch) => {
                    if !live_style {
                        for a in &batch.assignments {
                            let eff = a.request.input_tokens - a.cached_tokens;
                            core.on_deliver_ack(a.unit, eff);
                            core.on_prefill_consumed(a.unit, eff);
                        }
                    }
                    placed.extend(batch.assignments.iter().map(|a| a.request.id));
                    in_flight.push_back(batch.instance);
                }
                SchedulerAction::Reject(r) => shed.push((r.id, r.class)),
                _ => {}
            }
        }
    }

    let mut sc = StaggeredConfig::default();
    sc.pbaa.n_limit = 2;
    let cfg = DispatchCoreConfig {
        mode: SchedMode::Staggered(sc),
        n_prefill: 1,
        dp_prefill: 1,
        c_chunk: 1024,
        n_decode: 1,
        dp_decode: 2,
        decode_policy: DecodePolicy::LoadAware(Default::default()),
        seed: 7,
    };
    let mut wl = WorkloadSpec::paper_short(60.0, 3.0, 7);
    wl.class_mix = Some([0.2, 0.5, 0.3]);

    let mut core = DispatchCore::new(&cfg);
    let mut shed = Vec::new();
    let mut placed = Vec::new();
    let mut in_flight: VecDeque<u32> = VecDeque::new();
    for (i, r) in wl.generate().into_iter().enumerate() {
        let t = r.arrival;
        // Finish at most one outstanding pass every second event: the
        // instance drains at roughly half the offered rate, so pending
        // backlog builds and wait counters climb.
        if i % 2 == 0 {
            if let Some(inst) = in_flight.pop_front() {
                let backlog = if live_style {
                    EndForwardBacklog::ConsumedAll
                } else {
                    EndForwardBacklog::Remaining(0)
                };
                let acts = core.on_end_forward(inst, 0.05, backlog, t);
                absorb(&mut core, acts, live_style, &mut shed, &mut placed, &mut in_flight);
            }
        }
        let acts = core.on_arrival(r, t);
        absorb(&mut core, acts, live_style, &mut shed, &mut placed, &mut in_flight);
    }
    (shed, placed)
}

#[test]
fn sim_and_live_drivers_shed_the_same_classed_requests() {
    let (shed_sim, placed_sim) = drive_classed_overload(false);
    let (shed_live, placed_live) = drive_classed_overload(true);
    assert_eq!(
        placed_sim, placed_live,
        "dispatch decisions must match between driver styles"
    );
    assert_eq!(
        shed_sim, shed_live,
        "shed sets must be identical between driver styles"
    );
    assert!(!shed_sim.is_empty(), "the overload trace must engage flow control");
    assert!(
        shed_sim.iter().any(|(_, c)| *c == SloClass::Batch),
        "batch traffic must shed under sustained overload: {shed_sim:?}"
    );
    assert!(
        shed_sim.iter().all(|(_, c)| *c != SloClass::Interactive),
        "no interactive request may ever be shed: {shed_sim:?}"
    );
    // Nothing is both dispatched and shed.
    for (id, _) in &shed_sim {
        assert!(!placed_sim.contains(id), "request {id} both placed and shed");
    }
}
