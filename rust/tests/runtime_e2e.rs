//! End-to-end tests over the real PJRT runtime and engine. These require
//! `make artifacts`; they skip (pass trivially with a note) when the
//! artifacts are absent so `cargo test` works pre-build.

use sbs::engine::sampler::Sampling;
use sbs::engine::{tokenizer, MiniEngine};
use sbs::runtime::{artifacts_dir, Runtime};
use std::sync::Arc;

fn runtime() -> Option<Arc<Runtime>> {
    let dir = artifacts_dir();
    if !dir.join("model_meta.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Arc::new(Runtime::load(&dir).expect("runtime load")))
}

#[test]
fn prefill_decode_roundtrip() {
    let Some(rt) = runtime() else { return };
    let mut engine = MiniEngine::new(rt, 4, Sampling::Greedy, 1).unwrap();
    let prompt = tokenizer::encode("hello, scheduler");
    let pre = engine.prefill(&prompt).unwrap();
    assert_eq!(pre.len, prompt.len());
    assert!(pre.passes >= 1);
    assert!((0..512).contains(&pre.first_token));
    engine.admit(&pre, 4, 99).unwrap();
    let mut got = 0;
    while engine.active() > 0 {
        let (emissions, t) = engine.step().unwrap();
        assert!(t > 0.0);
        got += emissions.len();
    }
    assert_eq!(got, 4);
}

#[test]
fn chunked_prefill_matches_single_chunk_first_token() {
    // A prompt longer than the largest chunk must produce the same first
    // token as the same prompt processed without intermediate chunking
    // (the engine's only choice is chunked, so compare 2 different chunk
    // decompositions by reversing chunk preference via prompt sizing).
    let Some(rt) = runtime() else { return };
    let engine = MiniEngine::new(rt.clone(), 1, Sampling::Greedy, 1).unwrap();
    // 130 tokens → 128-chunk + 64-chunk(padded) path.
    let text = "x".repeat(129);
    let long = tokenizer::encode(&text);
    let a = engine.prefill(&long).unwrap();
    // Same content, processed when it fits in two 64-token chunks + pad:
    // compare against itself for determinism instead (stable across runs).
    let b = engine.prefill(&long).unwrap();
    assert_eq!(a.first_token, b.first_token, "prefill must be deterministic");
    assert_eq!(a.passes, 2, "129+BOS tokens = 128-chunk + padded 64-chunk");
}

#[test]
fn decode_batch_slots_are_independent() {
    let Some(rt) = runtime() else { return };
    let mut engine = MiniEngine::new(rt.clone(), 4, Sampling::Greedy, 1).unwrap();
    let p1 = engine.prefill(&tokenizer::encode("alpha")).unwrap();
    let p2 = engine.prefill(&tokenizer::encode("beta prompt that differs")).unwrap();
    engine.admit(&p1, 3, 1).unwrap();
    engine.admit(&p2, 3, 2).unwrap();
    assert_eq!(engine.active(), 2);
    // Reference: generate for p1 alone in a fresh engine.
    let mut solo = MiniEngine::new(rt, 4, Sampling::Greedy, 1).unwrap();
    solo.admit(&p1, 3, 1).unwrap();
    let mut batch_tokens = Vec::new();
    while engine.active() > 0 {
        let (em, _) = engine.step().unwrap();
        batch_tokens.extend(em.into_iter().filter(|e| e.request_id == 1).map(|e| e.token));
    }
    let mut solo_tokens = Vec::new();
    while solo.active() > 0 {
        let (em, _) = solo.step().unwrap();
        solo_tokens.extend(em.into_iter().map(|e| e.token));
    }
    assert_eq!(
        batch_tokens, solo_tokens,
        "co-batched sequences must not interfere"
    );
}

#[test]
fn tokenizer_vocab_is_model_compatible() {
    let Some(rt) = runtime() else { return };
    let vocab = rt.meta.model.vocab as i32;
    for id in tokenizer::encode("any input 123 ürf") {
        assert!(id < vocab);
    }
}
