//! Integration tests for the remote shard transports: true
//! multi-process (`sbs worker` children driven over real TCP).
//!
//! 1. **Parity** (extends the PR 2 harness): the same deterministic job
//!    trace through an in-process 2-unit pool and a 2-shard remote pool
//!    must produce identical placement decisions — the transport must be
//!    invisible to the dispatch core.
//! 2. **Shard death**: killing a decode shard mid-run evicts its
//!    sequences (rejected upstream, ledger released — nothing hangs or
//!    leaks) and the dead unit stays *visible* in the gauges.
//! 3. **Reconnect**: a replacement shard on the same address rejoins the
//!    pool without restarting the scheduler.
//! 4. **P/D separation**: a 4-process topology (scheduler + 1 remote
//!    prefill shard + 2 remote decode shards) serves end to end — the
//!    KV handoff and `EndForward` backlog cross the wire — and killing
//!    the prefill shard mid-run rejects its in-flight jobs rather than
//!    leaking or hanging them, with the dead instance loud in `STATS`.

use sbs::cluster::dispatch::DecodePolicy;
use sbs::cluster::workers::{
    Admission, AdmissionConfig, EngineSpec, Job, JobUpdate, RealCluster, RealClusterConfig,
    RealSchedMode,
};
use sbs::engine::mock::MockEngineConfig;
use sbs::engine::sampler::Sampling;
use sbs::scheduler::baseline::ImmediatePolicy;
use sbs::testing::net::{parse_listening_line, wait_for_port};
use sbs::transport::KvCodec;
use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

/// Spawn one `sbs worker` shard process (`role` = `--decode` or
/// `--prefill`) with a deterministic mock engine (2 ms steps, zero
/// jitter); returns the child and the address it announced.
fn spawn_role_worker(role: &str, listen: &str, units: u32, batch: u32) -> (Child, String) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_sbs"))
        .args([
            "worker",
            role,
            "--listen",
            listen,
            "--units",
            &units.to_string(),
            "--batch",
            &batch.to_string(),
            "--engine",
            "mock",
            "--mock-decode-ms",
            "2",
            "--mock-jitter",
            "0",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn sbs worker");
    let stdout = child.stdout.take().expect("worker stdout");
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).expect("read LISTENING line");
    let addr = parse_listening_line(&line).expect("LISTENING announcement");
    wait_for_port(&addr, Duration::from_secs(10)).expect("shard listener accepting");
    (child, addr)
}

/// Decode-shard convenience wrapper (the historical helper).
fn spawn_worker(listen: &str, units: u32, batch: u32) -> (Child, String) {
    spawn_role_worker("--decode", listen, units, batch)
}

/// Wait (bounded) for a shard process to exit on its own; kill on
/// timeout so a failed drain cannot leak processes past the test.
fn reap(mut child: Child, timeout: Duration) -> bool {
    let deadline = Instant::now() + timeout;
    loop {
        match child.try_wait().expect("try_wait") {
            Some(_) => return true,
            None if Instant::now() >= deadline => {
                let _ = child.kill();
                let _ = child.wait();
                return false;
            }
            None => std::thread::sleep(Duration::from_millis(25)),
        }
    }
}

fn det_mock() -> EngineSpec {
    EngineSpec::Mock(MockEngineConfig {
        t_prefill_base: 0.001,
        t_prefill_per_token: 5e-6,
        t_decode_step: 0.002,
        chunk: 512,
        jitter: 0.0,
        kv_elems_per_token: 16,
    })
}

/// Pool config shared by both parity runs; only the decode topology
/// (local units vs remote shards) differs. Immediate prefill dispatch +
/// one prefill worker serializes placements in submission order, and
/// every job outlives the whole submission window, so placement
/// decisions depend *only* on the join sequence — deterministic across
/// runs and transports.
fn parity_cfg(n_local: u32, remote: Vec<String>) -> RealClusterConfig {
    RealClusterConfig {
        n_prefill: 1,
        n_decode: n_local,
        decode_batch: 16,
        c_chunk: 4096,
        mode: RealSchedMode::Immediate(ImmediatePolicy::RoundRobin),
        decode_policy: DecodePolicy::LoadAware(Default::default()),
        sampling: Sampling::Greedy,
        seed: 11,
        engine: det_mock(),
        admission: AdmissionConfig {
            max_inflight: 1024,
            ..Default::default()
        },
        remote_decode: remote,
        ..Default::default()
    }
}

const PARITY_JOBS: u64 = 24;

fn submit_parity_trace(cluster: &RealCluster) {
    for i in 0..PARITY_JOBS {
        // Heterogeneous KV footprints so load-aware placement has real
        // decisions to make; max_new keeps every job resident past the
        // ~240 ms submission window (≥ 150 steps × 2 ms = 300 ms), so no
        // release ever interleaves with a placement and the decision
        // sequence is timing-independent.
        let prompt_len = 16 + (i as usize * 37) % 200;
        let max_new = 150 + (i as u32 % 4) * 60;
        cluster.submit(Job::new(i, vec![7; prompt_len], max_new));
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn run_parity(cfg: RealClusterConfig) -> (Vec<u64>, usize) {
    let cluster = RealCluster::start(cfg).expect("cluster start");
    let handle = cluster.handle();
    submit_parity_trace(&cluster);
    let (completions, _report) = cluster.finish().expect("cluster finish");
    let stats = handle.decode_stats();
    (stats.units.iter().map(|u| u.placed).collect(), completions.len())
}

#[test]
fn remote_pool_matches_inprocess_dispatch_decisions() {
    let (w1, a1) = spawn_worker("127.0.0.1:0", 1, 16);
    let (w2, a2) = spawn_worker("127.0.0.1:0", 1, 16);

    let (local_placed, local_done) = run_parity(parity_cfg(2, Vec::new()));
    let (remote_placed, remote_done) = run_parity(parity_cfg(0, vec![a1, a2]));

    assert_eq!(local_done, PARITY_JOBS as usize, "in-process run must drain");
    assert_eq!(remote_done, PARITY_JOBS as usize, "remote run must drain");
    assert_eq!(local_placed.len(), 2);
    assert_eq!(
        local_placed, remote_placed,
        "the transport must be invisible to placement: in-process pool \
         placed {local_placed:?}, remote pool placed {remote_placed:?}"
    );
    assert!(
        local_placed.iter().all(|&p| p > 0),
        "trace must exercise every unit: {local_placed:?}"
    );

    // The remote run's drain sent Stop to both shards: they must exit
    // cleanly on their own.
    assert!(reap(w1, Duration::from_secs(10)), "shard 1 must drain and exit");
    assert!(reap(w2, Duration::from_secs(10)), "shard 2 must drain and exit");
}

/// Drain one streaming job to its terminal update. Returns `true` for
/// Done, `false` for Rejected.
fn drain_stream(rx: &std::sync::mpsc::Receiver<JobUpdate>, timeout: Duration) -> bool {
    let deadline = Instant::now() + timeout;
    loop {
        let left = deadline
            .checked_duration_since(Instant::now())
            .expect("job stream must terminate (no hang after shard death)");
        match rx.recv_timeout(left) {
            Ok(JobUpdate::Token { .. }) => continue,
            Ok(JobUpdate::Done(_)) => return true,
            Ok(JobUpdate::Rejected { .. }) => return false,
            Err(_) => panic!("job stream must terminate (no hang after shard death)"),
        }
    }
}

#[test]
fn killed_shard_evicts_sequences_and_stays_visible() {
    let (mut worker, addr) = spawn_worker("127.0.0.1:0", 1, 8);
    let cfg = RealClusterConfig {
        n_prefill: 1,
        n_decode: 1,
        decode_batch: 8,
        c_chunk: 4096,
        mode: RealSchedMode::Immediate(ImmediatePolicy::RoundRobin),
        decode_policy: DecodePolicy::LoadAware(Default::default()),
        sampling: Sampling::Greedy,
        seed: 5,
        engine: det_mock(),
        admission: AdmissionConfig {
            max_inflight: 1024,
            ..Default::default()
        },
        remote_decode: vec![addr],
        ..Default::default()
    };
    let cluster = RealCluster::start(cfg).expect("cluster start");
    let handle = cluster.handle();

    // 12 long jobs across 16 slots: load-aware spreads them over both
    // units, so some are resident on the shard when it dies.
    let mut streams = Vec::new();
    for _ in 0..12 {
        match handle.try_submit(vec![7; 24], 300) {
            Admission::Accepted { updates, .. } => streams.push(updates),
            Admission::Busy(r) => panic!("unexpected BUSY: {r:?}"),
        }
        std::thread::sleep(Duration::from_millis(8));
    }
    // Let every job prefill and get placed, then kill the shard cold.
    std::thread::sleep(Duration::from_millis(300));
    let placed_remote_before = {
        let stats = handle.decode_stats();
        stats.units[1].placed
    };
    worker.kill().expect("kill shard");
    worker.wait().expect("reap shard");

    let (mut done, mut rejected) = (0, 0);
    for rx in &streams {
        if drain_stream(rx, Duration::from_secs(60)) {
            done += 1;
        } else {
            rejected += 1;
        }
    }
    assert_eq!(done + rejected, 12, "every stream reaches a terminal state");
    assert!(placed_remote_before > 0, "test premise: the shard owned sequences before dying");
    assert!(rejected > 0, "shard-resident sequences must be rejected");
    assert!(done > 0, "locally-resident sequences must still complete");

    // Nothing leaked: the ledger drains to zero (poll briefly — the last
    // DecodeDone can trail the last router update by a scheduler tick),
    // and the dead unit is visible.
    let deadline = Instant::now() + Duration::from_secs(5);
    let stats = loop {
        let stats = handle.decode_stats();
        if stats.units.iter().all(|u| u.active == 0) {
            break stats;
        }
        assert!(Instant::now() < deadline, "leaked ledger entries: {stats:?}");
        std::thread::sleep(Duration::from_millis(25));
    };
    assert_eq!(stats.units.len(), 2);
    assert_eq!(stats.units_alive(), 1, "dead shard must be reported, not hidden");
    assert!(!stats.units[1].alive, "unit 1 is the shard: {stats:?}");
    let (_completions, _report) = cluster.finish().expect("finish must not hang");
}

#[test]
fn replacement_shard_on_same_address_rejoins_the_pool() {
    let (mut worker, addr) = spawn_worker("127.0.0.1:0", 1, 8);
    let cfg = RealClusterConfig {
        n_prefill: 1,
        n_decode: 1,
        decode_batch: 8,
        c_chunk: 4096,
        mode: RealSchedMode::Immediate(ImmediatePolicy::RoundRobin),
        decode_policy: DecodePolicy::LoadAware(Default::default()),
        sampling: Sampling::Greedy,
        seed: 5,
        engine: det_mock(),
        admission: AdmissionConfig {
            max_inflight: 1024,
            ..Default::default()
        },
        remote_decode: vec![addr.clone()],
        ..Default::default()
    };
    let cluster = RealCluster::start(cfg).expect("cluster start");
    let handle = cluster.handle();

    let wait_alive = |want: usize, what: &str| {
        let deadline = Instant::now() + Duration::from_secs(15);
        loop {
            if handle.decode_stats().units_alive() == want {
                return;
            }
            assert!(
                Instant::now() < deadline,
                "{what}: still {} alive units",
                handle.decode_stats().units_alive()
            );
            std::thread::sleep(Duration::from_millis(50));
        }
    };

    worker.kill().expect("kill shard");
    worker.wait().expect("reap shard");
    wait_alive(1, "scheduler must notice the dead shard");

    // A replacement process on the *same* address: the client's
    // reconnect loop finds it and restores the pool.
    let (replacement, readdr) = spawn_worker(&addr, 1, 8);
    assert_eq!(readdr, addr);
    wait_alive(2, "replacement shard must rejoin");

    // The restored pool serves traffic end to end.
    for i in 0..6u64 {
        cluster.submit(Job::new(1000 + i, vec![7; 24], 4));
    }
    let (completions, _report) = cluster.finish().expect("finish");
    assert_eq!(completions.len(), 6, "restored pool must serve all jobs");
    assert!(reap(replacement, Duration::from_secs(10)), "replacement drains on Stop");
}

/// Fully P/D-separated config: zero local workers on either plane; both
/// phases run in remote shard processes.
fn pd_cfg(prefill: Vec<String>, decode: Vec<String>) -> RealClusterConfig {
    RealClusterConfig {
        n_prefill: 0,
        n_decode: 0,
        decode_batch: 8,
        c_chunk: 4096,
        mode: RealSchedMode::Immediate(ImmediatePolicy::RoundRobin),
        decode_policy: DecodePolicy::LoadAware(Default::default()),
        sampling: Sampling::Greedy,
        seed: 23,
        engine: det_mock(),
        admission: AdmissionConfig {
            max_inflight: 1024,
            ..Default::default()
        },
        remote_prefill: prefill,
        remote_decode: decode,
        ..Default::default()
    }
}

#[test]
fn pd_separated_topology_serves_end_to_end() {
    // 4 OS processes: this scheduler + 1 prefill shard + 2 decode shards.
    let (pf, pf_addr) = spawn_role_worker("--prefill", "127.0.0.1:0", 1, 1);
    let (d1, a1) = spawn_worker("127.0.0.1:0", 1, 8);
    let (d2, a2) = spawn_worker("127.0.0.1:0", 1, 8);

    let cluster = RealCluster::start(pd_cfg(vec![pf_addr.clone()], vec![a1, a2]))
        .expect("P/D cluster start");
    let handle = cluster.handle();
    const JOBS: u64 = 16;
    for i in 0..JOBS {
        cluster.submit(Job::new(i, vec![7; 16 + (i as usize * 13) % 60], 8));
        std::thread::sleep(Duration::from_millis(5));
    }
    let (completions, report) = cluster.finish().expect("P/D cluster finish");
    assert_eq!(completions.len(), JOBS as usize, "every job crosses both wire hops");
    assert_eq!(report.rejected, 0, "nothing may be shed on a healthy topology");
    for c in &completions {
        assert_eq!(c.tokens.len(), 8, "full generation (first token + 7 decoded)");
        assert!(c.metrics.ttft().is_some(), "TTFT observed for job {}", c.id);
    }

    let stats = handle.decode_stats();
    assert_eq!(stats.prefill.len(), 1, "the remote instance is the whole prefill pool");
    assert!(stats.prefill[0].transport.contains("#p0"), "{stats:?}");
    assert!(stats.prefill[0].dispatched > 0, "dispatches crossed the wire: {stats:?}");
    assert_eq!(stats.units.len(), 2);
    assert_eq!(stats.total_placed(), JOBS, "every sequence decoded remotely");

    // The drain sent Stop to all three shards: they exit on their own.
    assert!(reap(pf, Duration::from_secs(10)), "prefill shard must drain and exit");
    assert!(reap(d1, Duration::from_secs(10)), "decode shard 1 must drain and exit");
    assert!(reap(d2, Duration::from_secs(10)), "decode shard 2 must drain and exit");
}

/// Run one P/D cluster (1 prefill shard + 2 decode shards, fresh
/// processes) over a fixed trace under the given codec/route; returns
/// the per-job token streams (sorted by id) and the final pool stats.
fn run_pd_trace(
    kv_wire: KvCodec,
    direct: bool,
) -> (Vec<(u64, Vec<i32>)>, sbs::metrics::DecodePoolStats) {
    let (pf, pf_addr) = spawn_role_worker("--prefill", "127.0.0.1:0", 1, 1);
    let (d1, a1) = spawn_worker("127.0.0.1:0", 1, 8);
    let (d2, a2) = spawn_worker("127.0.0.1:0", 1, 8);
    let cfg = RealClusterConfig {
        kv_wire,
        direct_handoff: direct,
        ..pd_cfg(vec![pf_addr], vec![a1, a2])
    };
    let cluster = RealCluster::start(cfg).expect("P/D cluster start");
    let handle = cluster.handle();
    for i in 0..20u64 {
        cluster.submit(Job::new(i, vec![3 + (i as i32 % 5); 24 + (i as usize * 11) % 80], 6));
        std::thread::sleep(Duration::from_millis(5));
    }
    // Let the last jobs finish *and* a post-traffic StatsReply land (the
    // scheduler polls each decode shard at most 1/s), so the published
    // kv_wire gauge includes the full run's shard counters.
    std::thread::sleep(Duration::from_millis(2200));
    let (completions, _report) = cluster.finish().expect("P/D cluster finish");
    let stats = handle.decode_stats();
    assert!(reap(pf, Duration::from_secs(10)), "prefill shard drains");
    assert!(reap(d1, Duration::from_secs(10)), "decode shard 1 drains");
    assert!(reap(d2, Duration::from_secs(10)), "decode shard 2 drains");
    let mut streams: Vec<(u64, Vec<i32>)> =
        completions.into_iter().map(|c| (c.id, c.tokens)).collect();
    streams.sort_by_key(|(id, _)| *id);
    assert_eq!(streams.len(), 20, "{}-{} run must complete every job",
        kv_wire.name(), if direct { "direct" } else { "relay" });
    (streams, stats)
}

/// The end-to-end parity + byte-accounting claim: the same trace under
/// `raw`/`fp16`/`lz` and relay vs direct transfer produces identical
/// token streams, `lz` cuts the KV wire bytes by ≥40%, and direct
/// transfer leaves the scheduler's relay counters at zero.
#[test]
fn kv_codecs_and_routes_produce_identical_streams_and_lz_shrinks_the_wire() {
    let (raw_direct, _) = run_pd_trace(KvCodec::Raw, true);
    let (fp16_direct, _) = run_pd_trace(KvCodec::Fp16, true);
    let (lz_direct, lz_direct_stats) = run_pd_trace(KvCodec::Lz, true);
    let (lz_relay, lz_relay_stats) = run_pd_trace(KvCodec::Lz, false);

    assert_eq!(raw_direct, fp16_direct, "fp16 must not perturb the token streams");
    assert_eq!(raw_direct, lz_direct, "lz is bit-exact: identical streams");
    assert_eq!(raw_direct, lz_relay, "relay vs direct must be invisible to clients");

    let kv = &lz_direct_stats.kv_wire;
    assert_eq!(kv.codec, "lz");
    assert!(kv.raw_bytes > 0, "the mock engines synthesize KV: {kv:?}");
    assert!(
        (kv.wire_bytes as f64) < 0.6 * kv.raw_bytes as f64,
        "lz must cut the KV wire by ≥40%: {kv:?}"
    );
    assert_eq!(
        kv.relay_wire_bytes, 0,
        "direct transfer must leave the scheduler relay at zero KV bytes: {kv:?}"
    );

    let kv = &lz_relay_stats.kv_wire;
    assert!(
        kv.relay_wire_bytes > 0 && kv.relay_raw_bytes > 0,
        "the relay route must carry the KV through the scheduler: {kv:?}"
    );
    assert!(
        (kv.relay_wire_bytes as f64) < 0.6 * kv.relay_raw_bytes as f64,
        "lz shrinks the relayed KV too: {kv:?}"
    );
}

/// Killing a decode shard mid-run under direct transfer: handoffs aimed
/// at the dead peer fall back (relay re-placement onto the survivor) or
/// terminalize via eviction — every stream ends, nothing leaks.
#[test]
fn direct_transfer_survives_decode_peer_death_with_all_streams_terminal() {
    let (pf, pf_addr) = spawn_role_worker("--prefill", "127.0.0.1:0", 1, 1);
    let (d1, a1) = spawn_worker("127.0.0.1:0", 1, 8);
    let (mut d2, a2) = spawn_worker("127.0.0.1:0", 1, 8);
    let cfg = RealClusterConfig {
        kv_wire: KvCodec::Lz,
        direct_handoff: true,
        ..pd_cfg(vec![pf_addr], vec![a1, a2])
    };
    let cluster = RealCluster::start(cfg).expect("P/D cluster start");
    let handle = cluster.handle();

    let mut streams = Vec::new();
    for _ in 0..24 {
        match handle.try_submit(vec![7; 24], 200) {
            Admission::Accepted { updates, .. } => streams.push(updates),
            Admission::Busy(r) => panic!("unexpected BUSY: {r:?}"),
        }
        std::thread::sleep(Duration::from_millis(4));
    }
    // Kill decode shard 2 while handoffs and long decodes are in flight.
    std::thread::sleep(Duration::from_millis(120));
    d2.kill().expect("kill decode shard");
    d2.wait().expect("reap decode shard");

    let (mut done, mut rejected) = (0, 0);
    for rx in &streams {
        if drain_stream(rx, Duration::from_secs(60)) {
            done += 1;
        } else {
            rejected += 1;
        }
    }
    assert_eq!(done + rejected, 24, "every stream reaches a terminal state");
    assert!(done > 0, "the surviving shard keeps serving");

    // Nothing leaked: the ledger drains to zero and the dead unit stays
    // visible.
    let deadline = Instant::now() + Duration::from_secs(10);
    let stats = loop {
        let stats = handle.decode_stats();
        if stats.units.iter().all(|u| u.active == 0) {
            break stats;
        }
        assert!(Instant::now() < deadline, "leaked ledger entries: {stats:?}");
        std::thread::sleep(Duration::from_millis(25));
    };
    assert_eq!(stats.units_alive(), 1, "dead decode peer reported, not hidden");

    let (_completions, _report) = cluster.finish().expect("finish must not hang");
    assert!(reap(pf, Duration::from_secs(10)), "prefill shard drains");
    assert!(reap(d1, Duration::from_secs(10)), "decode shard 1 drains");
}

#[test]
fn killed_prefill_shard_rejects_inflight_jobs_and_stays_visible() {
    let (mut pf, pf_addr) = spawn_role_worker("--prefill", "127.0.0.1:0", 1, 1);
    let (d1, a1) = spawn_worker("127.0.0.1:0", 1, 8);
    let (d2, a2) = spawn_worker("127.0.0.1:0", 1, 8);

    let cluster =
        RealCluster::start(pd_cfg(vec![pf_addr], vec![a1, a2])).expect("P/D cluster start");
    let handle = cluster.handle();

    // A burst that outruns the single prefill instance (~8.5 ms/job at
    // mock defaults): when the shard dies mid-burst, part of the batch
    // is decoding already, part is still queued on the shard.
    let mut streams = Vec::new();
    for _ in 0..24 {
        match handle.try_submit(vec![7; 24], 200) {
            Admission::Accepted { updates, .. } => streams.push(updates),
            Admission::Busy(r) => panic!("unexpected BUSY: {r:?}"),
        }
        std::thread::sleep(Duration::from_millis(4));
    }
    pf.kill().expect("kill prefill shard");
    pf.wait().expect("reap prefill shard");

    // Every stream must reach a terminal state: jobs already handed off
    // keep decoding to Done; jobs queued on the dead shard (or still
    // scheduler-side with nowhere to dispatch) are rejected — parked
    // work is *rejected, not leaked*.
    let (mut done, mut rejected) = (0, 0);
    for rx in &streams {
        if drain_stream(rx, Duration::from_secs(60)) {
            done += 1;
        } else {
            rejected += 1;
        }
    }
    assert_eq!(done + rejected, 24, "every stream reaches a terminal state");
    assert!(rejected > 0, "jobs in flight at the dead prefill shard must be rejected");
    assert!(done > 0, "jobs handed off before the kill must still complete");

    // Nothing leaked: the decode ledger drains to zero, and the dead
    // prefill instance is reported, not hidden.
    let deadline = Instant::now() + Duration::from_secs(10);
    let stats = loop {
        let stats = handle.decode_stats();
        if stats.units.iter().all(|u| u.active == 0) && stats.prefill_units_alive() == 0 {
            break stats;
        }
        assert!(
            Instant::now() < deadline,
            "ledger must drain and the dead prefill shard must be visible: {stats:?}"
        );
        std::thread::sleep(Duration::from_millis(25));
    };
    assert_eq!(stats.prefill.len(), 1, "dead instance stays in the gauges: {stats:?}");
    assert!(!stats.prefill[0].alive);
    assert_eq!(stats.units_alive(), 2, "decode shards are unaffected");

    let (_completions, _report) = cluster.finish().expect("finish must not hang");
    assert!(reap(d1, Duration::from_secs(10)), "decode shard 1 drains on Stop");
    assert!(reap(d2, Duration::from_secs(10)), "decode shard 2 drains on Stop");
}
