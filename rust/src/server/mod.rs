//! Serving frontend over the real mini-cluster: an in-process batch mode
//! plus a concurrent TCP line protocol, wired through `sbs serve`.
//!
//! ## Line protocol
//!
//! Requests (one per line):
//!
//! * `GEN <max_tokens> [class=<c>] [deadline=<ms>] <prompt...>` —
//!   generate; the response streams. The optional, order-tolerant
//!   annotations attach an SLO class (`interactive | standard | batch`,
//!   default `standard`) and a completion deadline in milliseconds —
//!   a classless line behaves exactly as before.
//! * `STATS` — one-line JSON snapshot of the decode DP pool (per-DP
//!   occupancy + imbalance gauges), plus the `ttft_stages` per-stage
//!   TTFT decomposition and the `ledger_divergence` counter.
//! * `QUIT` — close *this* connection (in-flight work elsewhere is
//!   untouched).
//! * `SHUTDOWN` — stop accepting, drain every in-flight job, exit.
//!
//! Responses:
//!
//! * `TOK <id> <index> <token>` — one generated token as it is produced;
//!   `index 0` arrives the moment prefill completes, so TTFT is
//!   observable on the wire.
//! * `DONE <id> ttft_ms=<..> e2e_ms=<..> tokens=<n> <text>` — terminal.
//! * `STATS <json>` — reply to `STATS`.
//! * `BUSY <queue_full|throttled|rejected>` — load shed by the
//!   [`FlowPolicy`]-governed admission path; retry later.
//! * `ERR <message>` — malformed request.
//!
//! Each connection is served by its own thread over a shared
//! [`ClusterHandle`]; concurrency is across connections (one in-flight
//! `GEN` per connection, pipelining via multiple connections).

use crate::cli::Command;
use crate::cluster::dispatch::{DecodePolicy, RescueConfig};
use crate::cluster::workers::{
    Admission, AdmissionConfig, BusyReason, ClusterHandle, EngineSpec, Job, JobUpdate,
    RealCluster, RealClusterConfig, RealSchedMode,
};
use crate::engine::mock::MockEngineConfig;
use crate::engine::sampler::Sampling;
use crate::engine::tokenizer;
use crate::runtime::artifacts_dir;
use crate::scheduler::baseline::ImmediatePolicy;
use crate::scheduler::flow::FlowPolicy;
use crate::scheduler::types::SloClass;
use anyhow::{anyhow, Result};
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

/// `sbs serve` entrypoint.
pub fn cli_serve(argv: &[String]) -> Result<()> {
    let cmd = Command::new("sbs serve", "serve the nano-MoE model via SBS")
        .opt("artifacts", "artifact directory", Some("artifacts"))
        .opt("engine", "pjrt | mock", Some("pjrt"))
        .opt("prefill", "prefill instances", Some("2"))
        .opt("n-decode", "decode DP workers", Some("1"))
        .opt("batch", "decode batch size per decode worker", Some("4"))
        .opt(
            "scheduler",
            "staggered | round_robin | least_outstanding",
            Some("staggered"),
        )
        .opt(
            "decode-policy",
            "decode placement: load-aware | deadline-aware | round-robin | random",
            Some("load-aware"),
        )
        .opt(
            "remote-decode",
            "comma-separated remote decode shard addrs (sbs worker --decode)",
            None,
        )
        .opt(
            "remote-prefill",
            "comma-separated remote prefill shard addrs (sbs worker --prefill)",
            None,
        )
        .opt(
            "kv-budget",
            "per-DP-unit KV-token admission budget (0 = slots only)",
            Some(crate::config::LIVE_KV_BUDGET_TOKENS_STR),
        )
        .opt(
            "kv-wire",
            "KV handoff wire codec: raw | fp16 | lz",
            Some("raw"),
        )
        .opt(
            "handoff",
            "prefill→decode KV handoff route: direct | relay",
            Some("direct"),
        )
        .opt(
            "rescue",
            "SLO-violation rescue (decode preemption + migration): on | off",
            Some("off"),
        )
        .opt("requests", "batch mode: number of synthetic requests", Some("8"))
        .opt("max-new", "tokens to generate per request", Some("16"))
        .opt(
            "listen",
            "run the TCP server on this addr instead (e.g. 127.0.0.1:7433)",
            None,
        )
        .opt(
            "max-inflight",
            "admission control: max jobs in flight before BUSY",
            Some("256"),
        )
        .opt("flow", "admission policy: throttle | reject", Some("throttle"))
        .opt(
            "trace-out",
            "write per-request TTFT stage traces (Chrome/Perfetto \
             trace_event JSON) to this file on exit",
            None,
        )
        .opt("seed", "rng seed", Some("7"));
    let args = cmd.parse(argv).map_err(|e| anyhow!("{e}"))?;
    let dir = std::path::PathBuf::from(
        args.str_or("artifacts", artifacts_dir().to_str().unwrap_or("artifacts")),
    );
    let mode = match args.str_or("scheduler", "staggered").as_str() {
        "staggered" => RealSchedMode::Staggered(Default::default()),
        "round_robin" => RealSchedMode::Immediate(ImmediatePolicy::RoundRobin),
        "least_outstanding" => RealSchedMode::Immediate(ImmediatePolicy::LeastOutstanding),
        other => return Err(anyhow!("unknown scheduler '{other}'")),
    };
    let engine = match args.str_or("engine", "pjrt").as_str() {
        "pjrt" => EngineSpec::Pjrt { artifacts: dir },
        "mock" => EngineSpec::Mock(MockEngineConfig::default()),
        other => return Err(anyhow!("unknown engine '{other}'")),
    };
    let policy = match args.str_or("flow", "throttle").as_str() {
        "throttle" => FlowPolicy::Throttle,
        "reject" => FlowPolicy::RejectOverloaded,
        other => return Err(anyhow!("unknown flow policy '{other}'")),
    };
    let decode_policy = parse_decode_policy(&args.str_or("decode-policy", "load-aware"), &mode)?;
    let kv_wire_s = args.str_or("kv-wire", "raw");
    let kv_wire = crate::transport::KvCodec::parse(&kv_wire_s)
        .ok_or_else(|| anyhow!("unknown kv-wire codec '{kv_wire_s}' (raw | fp16 | lz)"))?;
    let direct_handoff = match args.str_or("handoff", "direct").as_str() {
        "direct" => true,
        "relay" => false,
        other => return Err(anyhow!("unknown handoff route '{other}' (direct | relay)")),
    };
    let rescue = match args.str_or("rescue", "off").as_str() {
        "on" => RescueConfig::on(),
        "off" => RescueConfig::default(),
        other => return Err(anyhow!("unknown rescue mode '{other}' (on | off)")),
    };
    let remote_decode = args
        .value("remote-decode")
        .map(crate::transport::parse_shard_list)
        .unwrap_or_default();
    let remote_prefill = args
        .value("remote-prefill")
        .map(crate::transport::parse_shard_list)
        .unwrap_or_default();
    let trace_out = args.value("trace-out").map(std::path::PathBuf::from);
    let cfg = RealClusterConfig {
        n_prefill: args.parse_or("prefill", 2u32).map_err(|e| anyhow!("{e}"))?,
        n_decode: args.parse_or("n-decode", 1u32).map_err(|e| anyhow!("{e}"))?,
        decode_batch: args.parse_or("batch", 4u32).map_err(|e| anyhow!("{e}"))?,
        mode,
        decode_policy,
        sampling: Sampling::Greedy,
        seed: args.parse_or("seed", 7u64).map_err(|e| anyhow!("{e}"))?,
        engine,
        admission: AdmissionConfig {
            max_inflight: args
                .parse_or("max-inflight", 256u64)
                .map_err(|e| anyhow!("{e}"))?,
            policy,
            ..Default::default()
        },
        remote_decode,
        remote_prefill,
        kv_budget: args
            .parse_or("kv-budget", crate::config::LIVE_KV_BUDGET_TOKENS)
            .map_err(|e| anyhow!("{e}"))?,
        kv_wire,
        direct_handoff,
        rescue,
        // Per-request Perfetto records are only retained when there is a
        // file to write them to; aggregate stage stats are always on.
        trace_retain: if trace_out.is_some() { TRACE_RETAIN } else { 0 },
        ..Default::default()
    };

    if let Some(addr) = args.value("listen") {
        return serve_tcp(cfg, addr, trace_out);
    }

    // Batch mode: synthetic prompts through the cluster; print report.
    let n: usize = args.parse_or("requests", 8).map_err(|e| anyhow!("{e}"))?;
    let max_new: u32 = args.parse_or("max-new", 16).map_err(|e| anyhow!("{e}"))?;
    let cluster = RealCluster::start(cfg)?;
    let handle = cluster.handle();
    for i in 0..n {
        let prompt = tokenizer::encode(&format!(
            "Request {i}: the staggered batch scheduler buffers requests to \
             form optimal execution batches before dispatch."
        ));
        cluster.submit(Job::new(i as u64, prompt, max_new));
    }
    let (completions, report) = cluster.finish()?;
    for c in completions.iter().take(3) {
        println!(
            "job {}: {} tokens, ttft={:.0}ms",
            c.id,
            c.tokens.len(),
            c.metrics.ttft().unwrap_or(-1.0) * 1e3,
        );
    }
    println!("\n{}", report.render());
    write_trace_out(&handle, trace_out.as_deref());
    Ok(())
}

/// Per-request trace records retained for Perfetto export when
/// `--trace-out` is set (bounds collector memory on long-lived servers).
const TRACE_RETAIN: usize = 65_536;

/// Best-effort `--trace-out` export: a trace that fails to write must
/// never turn a completed serving run into an error.
fn write_trace_out(cluster: &ClusterHandle, path: Option<&std::path::Path>) {
    let Some(path) = path else { return };
    match cluster.write_trace(path) {
        Ok(n) => log::info!("wrote {n} trace records to {}", path.display()),
        Err(e) => log::warn!("trace export to {} failed: {e:#}", path.display()),
    }
}

/// Map a `--decode-policy` string onto a [`DecodePolicy`]. The load-aware
/// policy picks up Algorithm 3's knobs from the staggered scheduler config
/// when one is in force (one `StaggeredConfig` carries the full knob set).
fn parse_decode_policy(s: &str, mode: &RealSchedMode) -> Result<DecodePolicy> {
    let dc = || match mode {
        RealSchedMode::Staggered(sc) => sc.decode.clone(),
        RealSchedMode::Immediate(_) => Default::default(),
    };
    Ok(match s {
        "load-aware" | "load_aware" | "iqr" => DecodePolicy::LoadAware(dc()),
        "deadline-aware" | "deadline_aware" => DecodePolicy::DeadlineAware(dc()),
        "round-robin" | "round_robin" => DecodePolicy::RoundRobin,
        "random" => DecodePolicy::Random,
        other => return Err(anyhow!("unknown decode policy '{other}'")),
    })
}

/// Bind `addr` and run the concurrent TCP server until `SHUTDOWN`.
pub fn serve_tcp(
    cfg: RealClusterConfig,
    addr: &str,
    trace_out: Option<std::path::PathBuf>,
) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    serve_listener_traced(cfg, listener, trace_out)
}

/// Run the concurrent TCP server on an already-bound listener (tests use
/// this with an ephemeral port). One handler thread per connection over a
/// shared [`ClusterHandle`]; `SHUTDOWN` stops the accept loop, joins the
/// handlers, and drains every in-flight cluster job before returning.
pub fn serve_listener(cfg: RealClusterConfig, listener: TcpListener) -> Result<()> {
    serve_listener_traced(cfg, listener, None)
}

/// [`serve_listener`] plus an optional Perfetto `--trace-out` export
/// written after the drain (when every span has reached the collector).
pub fn serve_listener_traced(
    cfg: RealClusterConfig,
    listener: TcpListener,
    trace_out: Option<std::path::PathBuf>,
) -> Result<()> {
    let addr = listener.local_addr()?;
    log::info!("listening on {addr}");
    let cluster = RealCluster::start(cfg)?;
    let shutdown = Arc::new(AtomicBool::new(false));
    // Non-blocking accept so the loop can observe the shutdown flag set
    // by a handler thread.
    listener.set_nonblocking(true)?;
    let mut handlers = Vec::new();
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((conn, peer)) => {
                log::info!("connection from {peer}");
                let handle = cluster.handle();
                let flag = shutdown.clone();
                handlers.push(std::thread::spawn(move || {
                    if let Err(e) = handle_connection(conn, handle, flag) {
                        log::warn!("connection {peer}: {e:#}");
                    }
                }));
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                // Reap finished handlers so a long-lived server under
                // connection churn doesn't grow the vec unboundedly.
                handlers.retain(|h| !h.is_finished());
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => return Err(e.into()),
        }
    }
    log::info!(
        "shutdown requested: draining {} in-flight jobs",
        cluster.handle().inflight()
    );
    // Handlers finish their in-flight GEN (streaming is unaffected by the
    // flag), then observe it and exit.
    for h in handlers {
        let _ = h.join();
    }
    let handle = cluster.handle();
    let (_completions, report) = cluster.finish()?;
    log::info!("final report:\n{}", report.render());
    write_trace_out(&handle, trace_out.as_deref());
    Ok(())
}

/// Serve one connection: parse line commands, stream responses. A 100 ms
/// read timeout keeps idle handlers responsive to server shutdown without
/// interrupting an in-flight generation.
fn handle_connection(
    conn: TcpStream,
    cluster: ClusterHandle,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    conn.set_read_timeout(Some(Duration::from_millis(100)))?;
    // The protocol streams many tiny TOK lines; without TCP_NODELAY,
    // Nagle coalescing would distort the wire-observable token cadence.
    conn.set_nodelay(true)?;
    let mut reader = BufReader::new(conn.try_clone()?);
    let mut out = conn;
    let mut line = String::new();
    loop {
        line.clear();
        // Poll-read one full line; a timeout may leave a partial line in
        // the buffer, which the next iteration completes.
        loop {
            if shutdown.load(Ordering::SeqCst) {
                return Ok(());
            }
            match reader.read_line(&mut line) {
                Ok(0) => return Ok(()), // peer closed
                Ok(_) => break,
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                    continue
                }
                Err(e) => return Err(e.into()),
            }
        }
        let req = line.trim();
        if req.is_empty() {
            continue;
        }
        if req == "QUIT" {
            return Ok(());
        }
        if req == "STATS" {
            writeln!(out, "STATS {}", cluster.stats_json().dump())?;
            continue;
        }
        if req == "SHUTDOWN" {
            writeln!(out, "BYE")?;
            shutdown.store(true, Ordering::SeqCst);
            return Ok(());
        }
        let Some(rest) = req.strip_prefix("GEN ") else {
            writeln!(
                out,
                "ERR expected: GEN <max_tokens> [class=<c>] [deadline=<ms>] <prompt> \
                 | STATS | QUIT | SHUTDOWN"
            )?;
            continue;
        };
        let (max_new, class, deadline_ms, prompt_text) = match parse_gen(rest) {
            Ok(parsed) => parsed,
            Err(msg) => {
                writeln!(out, "ERR {msg}")?;
                continue;
            }
        };
        match cluster.try_submit_spec(tokenizer::encode(prompt_text), max_new, class, deadline_ms) {
            Admission::Busy(reason) => {
                let tag = match reason {
                    BusyReason::QueueFull => "queue_full",
                    BusyReason::Throttled => "throttled",
                };
                writeln!(out, "BUSY {tag}")?;
            }
            Admission::Accepted { id, updates } => stream_job(&mut out, id, updates)?,
        }
    }
}

/// Parse the payload of a `GEN` line: `<max_tokens> [class=<c>]
/// [deadline=<ms>] <prompt...>`. The annotations are optional and
/// order-tolerant; the first word matching neither starts the prompt, so
/// a legacy classless line parses exactly as before (standard class, no
/// deadline). A malformed annotation is an error, not prompt text — a
/// typo like `class=interactve` must not silently generate at the wrong
/// priority.
fn parse_gen(rest: &str) -> std::result::Result<(u32, SloClass, Option<f64>, &str), String> {
    let (max_s, mut rest) = rest.split_once(' ').unwrap_or((rest, ""));
    let max_new: u32 = max_s.parse().unwrap_or(16);
    let mut class = SloClass::default();
    let mut deadline_ms = None;
    loop {
        let (word, tail) = rest.split_once(' ').unwrap_or((rest, ""));
        if let Some(c) = word.strip_prefix("class=") {
            class = SloClass::parse(c)
                .ok_or_else(|| format!("unknown class '{c}' (interactive | standard | batch)"))?;
        } else if let Some(d) = word.strip_prefix("deadline=") {
            let ms: f64 = d
                .parse()
                .map_err(|_| format!("bad deadline '{d}' (milliseconds)"))?;
            if !ms.is_finite() || ms <= 0.0 {
                return Err(format!("bad deadline '{d}' (must be positive)"));
            }
            deadline_ms = Some(ms);
        } else {
            break;
        }
        rest = tail;
    }
    Ok((max_new, class, deadline_ms, rest))
}

/// Relay one job's update stream onto the wire as `TOK`/`DONE` lines.
fn stream_job(out: &mut TcpStream, id: u64, updates: Receiver<JobUpdate>) -> Result<()> {
    let t0 = std::time::Instant::now();
    let mut ttft_ms = -1.0f64;
    loop {
        let upd = updates
            .recv_timeout(Duration::from_secs(600))
            .map_err(|_| anyhow!("timed out streaming job {id}"))?;
        match upd {
            JobUpdate::Token { token, index, .. } => {
                if index == 0 {
                    ttft_ms = t0.elapsed().as_secs_f64() * 1e3;
                }
                writeln!(out, "TOK {id} {index} {token}")?;
            }
            JobUpdate::Done(c) => {
                writeln!(
                    out,
                    "DONE {id} ttft_ms={:.1} e2e_ms={:.1} tokens={} {}",
                    c.metrics.ttft().map(|t| t * 1e3).unwrap_or(ttft_ms),
                    t0.elapsed().as_secs_f64() * 1e3,
                    c.tokens.len(),
                    truncate(&tokenizer::decode(&c.tokens), 120)
                )?;
                return Ok(());
            }
            JobUpdate::Rejected { .. } => {
                writeln!(out, "BUSY rejected")?;
                return Ok(());
            }
        }
    }
}

/// Truncate to `n` chars and flatten control characters: the byte-level
/// tokenizer can generate newlines, which would split the single-line
/// `DONE` reply and corrupt the protocol stream.
fn truncate(s: &str, n: usize) -> String {
    let cleaned: String = s
        .chars()
        .map(|c| if c.is_control() { ' ' } else { c })
        .collect();
    if cleaned.chars().count() <= n {
        cleaned
    } else {
        cleaned.chars().take(n).collect::<String>() + "…"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classless_gen_line_parses_as_before() {
        // The legacy grammar must round-trip unchanged: default class,
        // no deadline, the full remainder as prompt text.
        let (max_new, class, deadline, prompt) = parse_gen("16 hello world").unwrap();
        assert_eq!(max_new, 16);
        assert_eq!(class, SloClass::Standard);
        assert_eq!(deadline, None);
        assert_eq!(prompt, "hello world");
    }

    #[test]
    fn gen_annotations_parse_in_either_order() {
        let (max_new, class, deadline, prompt) =
            parse_gen("8 class=interactive deadline=250 a prompt").unwrap();
        assert_eq!((max_new, class), (8, SloClass::Interactive));
        assert_eq!(deadline, Some(250.0));
        assert_eq!(prompt, "a prompt");
        let (_, class, deadline, prompt) = parse_gen("8 deadline=250 class=batch p").unwrap();
        assert_eq!(class, SloClass::Batch);
        assert_eq!(deadline, Some(250.0));
        assert_eq!(prompt, "p");
    }

    #[test]
    fn gen_prompt_mentioning_class_is_not_an_annotation() {
        // Only annotations *before* the prompt are consumed; prompt words
        // after the first non-annotation token pass through verbatim.
        let (_, class, _, prompt) = parse_gen("4 what class=batch means").unwrap();
        assert_eq!(class, SloClass::Standard);
        assert_eq!(prompt, "what class=batch means");
    }

    #[test]
    fn gen_malformed_annotations_are_errors() {
        assert!(parse_gen("4 class=premium p").is_err());
        assert!(parse_gen("4 deadline=soon p").is_err());
        assert!(parse_gen("4 deadline=-5 p").is_err());
    }
}
