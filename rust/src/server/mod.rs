//! Serving frontend over the real mini-cluster: an in-process batch mode
//! plus a minimal TCP line protocol
//! (`GEN <max_tokens> <prompt...>` → `OK <id> ttft_ms=.. e2e_ms=.. tokens=.. <text>`),
//! wired through `sbs serve`.

use crate::cli::Command;
use crate::cluster::workers::{Job, RealCluster, RealClusterConfig, RealSchedMode};
use crate::engine::sampler::Sampling;
use crate::engine::tokenizer;
use crate::runtime::artifacts_dir;
use crate::scheduler::baseline::ImmediatePolicy;
use anyhow::{anyhow, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::time::Duration;

/// `sbs serve` entrypoint.
pub fn cli_serve(argv: &[String]) -> Result<()> {
    let cmd = Command::new("sbs serve", "serve the nano-MoE model via SBS")
        .opt("artifacts", "artifact directory", Some("artifacts"))
        .opt("prefill", "prefill instances", Some("2"))
        .opt("batch", "decode batch size", Some("4"))
        .opt(
            "scheduler",
            "staggered | round_robin | least_outstanding",
            Some("staggered"),
        )
        .opt("requests", "batch mode: number of synthetic requests", Some("8"))
        .opt("max-new", "tokens to generate per request", Some("16"))
        .opt(
            "listen",
            "run the TCP server on this addr instead (e.g. 127.0.0.1:7433)",
            None,
        )
        .opt("seed", "rng seed", Some("7"));
    let args = cmd.parse(argv).map_err(|e| anyhow!("{e}"))?;
    let dir = std::path::PathBuf::from(
        args.str_or("artifacts", artifacts_dir().to_str().unwrap_or("artifacts")),
    );
    let mode = match args.str_or("scheduler", "staggered").as_str() {
        "staggered" => RealSchedMode::Staggered(Default::default()),
        "round_robin" => RealSchedMode::Immediate(ImmediatePolicy::RoundRobin),
        "least_outstanding" => RealSchedMode::Immediate(ImmediatePolicy::LeastOutstanding),
        other => return Err(anyhow!("unknown scheduler '{other}'")),
    };
    let cfg = RealClusterConfig {
        n_prefill: args.parse_or("prefill", 2u32).map_err(|e| anyhow!("{e}"))?,
        decode_batch: args.parse_or("batch", 4u32).map_err(|e| anyhow!("{e}"))?,
        mode,
        sampling: Sampling::Greedy,
        seed: args.parse_or("seed", 7u64).map_err(|e| anyhow!("{e}"))?,
        artifacts: dir,
        ..Default::default()
    };

    if let Some(addr) = args.value("listen") {
        return serve_tcp(cfg, addr);
    }

    // Batch mode: synthetic prompts through the cluster; print report.
    let n: usize = args.parse_or("requests", 8).map_err(|e| anyhow!("{e}"))?;
    let max_new: u32 = args.parse_or("max-new", 16).map_err(|e| anyhow!("{e}"))?;
    let mut cluster = RealCluster::start(cfg)?;
    for i in 0..n {
        let prompt = tokenizer::encode(&format!(
            "Request {i}: the staggered batch scheduler buffers requests to \
             form optimal execution batches before dispatch."
        ));
        cluster.submit(Job {
            id: i as u64,
            prompt,
            max_new,
        });
    }
    let (completions, report) = cluster.finish()?;
    for c in completions.iter().take(3) {
        println!(
            "job {}: {} tokens, ttft={:.0}ms",
            c.id,
            c.tokens.len(),
            c.metrics.ttft().unwrap_or(-1.0) * 1e3,
        );
    }
    println!("\n{}", report.render());
    Ok(())
}

/// Run the TCP line-protocol server. Connections are handled sequentially
/// and requests synchronously — the research focus is the scheduler, not
/// an async frontend.
fn serve_tcp(cfg: RealClusterConfig, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    log::info!("listening on {addr}");
    let mut cluster = RealCluster::start(cfg)?;
    let mut next_id: u64 = 0;
    for conn in listener.incoming() {
        let conn = conn?;
        let peer = conn.peer_addr()?;
        log::info!("connection from {peer}");
        let mut reader = BufReader::new(conn.try_clone()?);
        let mut out = conn;
        let mut line = String::new();
        loop {
            line.clear();
            if reader.read_line(&mut line)? == 0 {
                break;
            }
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if line == "QUIT" {
                return Ok(());
            }
            let Some(rest) = line.strip_prefix("GEN ") else {
                writeln!(out, "ERR expected: GEN <max_tokens> <prompt>")?;
                continue;
            };
            let (max_s, prompt_text) = rest.split_once(' ').unwrap_or((rest, ""));
            let max_new: u32 = max_s.parse().unwrap_or(16);
            let id = next_id;
            next_id += 1;
            let t0 = std::time::Instant::now();
            cluster.submit(Job {
                id,
                prompt: tokenizer::encode(prompt_text),
                max_new,
            });
            let c = cluster.wait_for(id, Duration::from_secs(600))?;
            writeln!(
                out,
                "OK {id} ttft_ms={:.0} e2e_ms={:.0} tokens={} {}",
                c.metrics.ttft().unwrap_or(-1.0) * 1e3,
                t0.elapsed().as_secs_f64() * 1e3,
                c.tokens.len(),
                truncate(&tokenizer::decode(&c.tokens), 120)
            )?;
        }
    }
    Ok(())
}

fn truncate(s: &str, n: usize) -> String {
    if s.chars().count() <= n {
        s.to_string()
    } else {
        s.chars().take(n).collect::<String>() + "…"
    }
}
