//! `sbs` — leader entrypoint and CLI for the Staggered Batch Scheduling
//! reproduction.
//!
//! Subcommands:
//!
//! * `simulate`      — run one cluster simulation and print the report.
//! * `bench-figures` — regenerate the paper's tables/figures (§5).
//! * `gen-trace`     — write a workload trace (JSONL) for replay.
//! * `serve`         — serve the nano-MoE model through SBS on the
//!                     threaded mini-cluster (`make artifacts` + the
//!                     `pjrt` feature, or `--engine mock`); drives
//!                     remote shards via `--remote-decode` /
//!                     `--remote-prefill` (P/D-separated deployment).
//! * `worker`        — run a standalone shard serving the binary
//!                     transport protocol (`--decode` or `--prefill`,
//!                     `--listen <addr>`).
//! * `loadgen`       — open-loop TCP load generator against `sbs serve
//!                     --listen`; prints a JSON latency report.
//! * `calibrate`     — measure real PJRT pass times and print calibrated
//!                     cost-model constants.
//! * `sweep`         — replicated parameter-sweep experiments over the
//!                     DES (and optionally the live mock cluster),
//!                     emitting versioned `BENCH_*.json`; also
//!                     `--validate doc.json` and `--compare old new`.

use sbs::cli::Command;
use sbs::cluster::sim::Simulation;
use sbs::config;
use sbs::json::Json;
use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    sbs::logging::init(log::LevelFilter::Info);
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((sub, rest)) = argv.split_first() else {
        eprintln!("{}", usage());
        return ExitCode::FAILURE;
    };
    let result = match sub.as_str() {
        "simulate" => cmd_simulate(rest),
        "bench-figures" => cmd_bench_figures(rest),
        "gen-trace" => cmd_gen_trace(rest),
        "serve" => cmd_serve(rest),
        "worker" => cmd_worker(rest),
        "loadgen" => cmd_loadgen(rest),
        "calibrate" => cmd_calibrate(rest),
        "sweep" => cmd_sweep(rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(format!("unknown subcommand '{other}'\n\n{}", usage())),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(msg) => {
            eprintln!("{msg}");
            ExitCode::FAILURE
        }
    }
}

fn usage() -> String {
    "sbs — Staggered Batch Scheduling (Tian et al., 2025) reproduction\n\n\
     Usage: sbs <subcommand> [options]\n\n\
     Subcommands:\n\
       simulate        run one cluster simulation (--help for knobs)\n\
       bench-figures   regenerate paper tables/figures (--all | --fig6a | --fig6b | --table1 | --fig7 | --fig8)\n\
       gen-trace       generate a JSONL workload trace\n\
       serve           serve the nano-MoE model via SBS (artifacts/ or --engine mock;\n\
                       multi-DP decode pool via --n-decode / --decode-policy;\n\
                       remote shards via --remote-decode / --remote-prefill addr[,addr...])\n\
       worker          run a standalone shard (--decode | --prefill, --listen addr)\n\
       loadgen         open-loop load generator against a running `serve --listen`\n\
                       (--arrival poisson|bursty|heavy-tail)\n\
       calibrate       measure PJRT pass times, print cost-model constants\n\
       sweep           replicated experiment grid emitting BENCH_*.json\n\
                       (--live for the mock cluster; --validate / --compare)"
        .to_string()
}

fn cmd_simulate(argv: &[String]) -> Result<(), String> {
    let cmd = Command::new("sbs simulate", "run one cluster simulation")
        .opt("preset", "fig6a | fig6b | table1 | fig7", Some("fig6a"))
        .opt("load", "load fraction of baseline peak", Some("0.8"))
        .opt("qps", "absolute request rate (overrides --load)", None)
        .opt(
            "scheduler",
            "staggered | round_robin | least_outstanding | jsq",
            Some("staggered"),
        )
        .opt("seed", "workload seed", Some("42"))
        .opt("duration", "workload horizon seconds", None)
        .opt("config", "key=value config file overriding the preset", None)
        .flag("json", "emit the report as JSON");
    let args = cmd.parse(argv)?;
    let seed: u64 = args.parse_or("seed", 42)?;
    let load: f64 = args.parse_or("load", 0.8)?;
    let sched = args.str_or("scheduler", "staggered");
    let staggered = sched == "staggered";
    let mut cfg = match args.str_or("preset", "fig6a").as_str() {
        "fig6a" => config::fig6a(load, staggered, seed),
        "fig6b" => config::fig6b(load, staggered, seed),
        "table1" => config::table1(3072, config::FIG6A_BASELINE_PEAK_QPS * load, staggered, seed),
        "fig7" => config::fig7(40.0 * load, staggered, seed),
        other => return Err(format!("unknown preset '{other}'")),
    };
    if let Some(path) = args.value("config") {
        let kv = config::KvFile::load(&PathBuf::from(path)).map_err(|e| e.to_string())?;
        kv.apply(&mut cfg).map_err(|e| e.to_string())?;
    }
    use sbs::scheduler::baseline::ImmediatePolicy;
    match sched.as_str() {
        "staggered" => {}
        "round_robin" => cfg.mode = config::SchedMode::Immediate(ImmediatePolicy::RoundRobin),
        "least_outstanding" => {
            cfg.mode = config::SchedMode::Immediate(ImmediatePolicy::LeastOutstanding)
        }
        "jsq" => cfg.mode = config::SchedMode::Immediate(ImmediatePolicy::JoinShortestQueue),
        other => return Err(format!("unknown scheduler '{other}'")),
    }
    if let Some(qps) = args.value("qps") {
        let qps: f64 = qps.parse().map_err(|_| "bad --qps")?;
        cfg.workload.arrivals = sbs::workload::ArrivalProcess::Poisson { qps };
    }
    if let Some(d) = args.value("duration") {
        cfg.workload.duration = d.parse().map_err(|_| "bad --duration")?;
    }
    let report = Simulation::run(&cfg);
    if args.flag("json") {
        let mut j = report.report.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("prefill_passes".into(), Json::from(report.prefill_passes));
            m.insert("decode_steps".into(), Json::from(report.decode_steps));
            m.insert("completed".into(), Json::from(report.completed));
            m.insert("offered".into(), Json::from(report.offered));
            m.insert("i_opt_final".into(), Json::from(report.i_opt_final));
        }
        println!("{}", j.dump());
    } else {
        println!("{}", report.report.render());
        println!(
            "passes={} steps={} completed={}/{} i_opt={:.4}s straggler_waste={:.1} DP-s t_end={:.1}s",
            report.prefill_passes,
            report.decode_steps,
            report.completed,
            report.offered,
            report.i_opt_final,
            report.straggler_waste_s,
            report.t_end
        );
    }
    Ok(())
}

fn cmd_bench_figures(argv: &[String]) -> Result<(), String> {
    let cmd = Command::new("sbs bench-figures", "regenerate paper tables/figures")
        .flag("all", "run everything")
        .flag("fig6a", "TTFT vs load, short inputs")
        .flag("fig6b", "TTFT vs load, long context")
        .flag("table1", "chunk utilization / max QPS under SLO")
        .flag("fig7", "decode KV dispersion")
        .flag("fig8", "decode throughput")
        .opt("seed", "workload seed", Some("2025"))
        .opt("out", "write merged JSON to this path", None);
    let args = cmd.parse(argv)?;
    let seed: u64 = args.parse_or("seed", sbs::figures::FIG_SEED)?;
    let all = args.flag("all")
        || !(args.flag("fig6a")
            || args.flag("fig6b")
            || args.flag("table1")
            || args.flag("fig7")
            || args.flag("fig8"));
    let mut merged = std::collections::BTreeMap::new();
    let mut absorb = |merged: &mut std::collections::BTreeMap<String, Json>, j: Json| {
        if let Json::Obj(m) = j {
            merged.extend(m);
        }
    };
    if all || args.flag("fig6a") {
        absorb(&mut merged, sbs::figures::run_fig6a(seed));
    }
    if all || args.flag("fig6b") {
        absorb(&mut merged, sbs::figures::run_fig6b(seed));
    }
    if all || args.flag("table1") {
        absorb(&mut merged, sbs::figures::run_table1(seed));
    }
    if all || args.flag("fig7") {
        absorb(&mut merged, sbs::figures::run_fig7(seed));
    }
    if all || args.flag("fig8") {
        absorb(&mut merged, sbs::figures::run_fig8(seed));
    }
    if let Some(path) = args.value("out") {
        std::fs::write(path, Json::Obj(merged).dump()).map_err(|e| e.to_string())?;
        println!("\nwrote {path}");
    }
    Ok(())
}

fn cmd_gen_trace(argv: &[String]) -> Result<(), String> {
    let cmd = Command::new("sbs gen-trace", "generate a JSONL workload trace")
        .opt("preset", "short | long | decode", Some("short"))
        .opt("qps", "request rate", Some("20"))
        .opt("duration", "horizon seconds", Some("60"))
        .opt("seed", "workload seed", Some("42"))
        .opt("out", "output path", Some("trace.jsonl"));
    let args = cmd.parse(argv)?;
    let qps: f64 = args.parse_or("qps", 20.0)?;
    let duration: f64 = args.parse_or("duration", 60.0)?;
    let seed: u64 = args.parse_or("seed", 42)?;
    let spec = match args.str_or("preset", "short").as_str() {
        "short" => sbs::workload::WorkloadSpec::paper_short(qps, duration, seed),
        "long" => sbs::workload::WorkloadSpec::paper_long(qps, duration, seed),
        "decode" => sbs::workload::WorkloadSpec::paper_decode(qps, duration, seed),
        other => return Err(format!("unknown preset '{other}'")),
    };
    let reqs = spec.generate();
    let out = PathBuf::from(args.str_or("out", "trace.jsonl"));
    sbs::workload::write_trace(&out, &reqs).map_err(|e| e.to_string())?;
    println!("wrote {} requests to {}", reqs.len(), out.display());
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<(), String> {
    sbs::server::cli_serve(argv).map_err(|e| format!("{e:#}"))
}

fn cmd_worker(argv: &[String]) -> Result<(), String> {
    sbs::cluster::shard::cli_worker(argv).map_err(|e| format!("{e:#}"))
}

fn cmd_loadgen(argv: &[String]) -> Result<(), String> {
    sbs::workload::loadgen::cli_loadgen(argv).map_err(|e| format!("{e:#}"))
}

fn cmd_calibrate(argv: &[String]) -> Result<(), String> {
    sbs::runtime::cli_calibrate(argv).map_err(|e| format!("{e:#}"))
}

fn cmd_sweep(argv: &[String]) -> Result<(), String> {
    sbs::workload::sweep::cli_sweep(argv).map_err(|e| format!("{e:#}"))
}
