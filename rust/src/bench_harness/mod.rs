//! Criterion-like measurement harness (the offline registry ships no
//! criterion).
//!
//! [`Bencher`] runs warmup iterations, then timed batches until a wall
//! budget is spent, and reports mean / p50 / p99 per iteration. Bench
//! binaries (`cargo bench`, `harness = false`) use this to time scheduler
//! hot paths and the DES; figure-level benches print paper-style tables.

use std::time::{Duration, Instant};

/// Result of one benchmark: per-iteration latency statistics.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Number of timed iterations.
    pub iters: u64,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Median seconds per iteration.
    pub p50_s: f64,
    /// 99th percentile seconds per iteration.
    pub p99_s: f64,
    /// Fastest iteration.
    pub min_s: f64,
}

impl BenchResult {
    /// Render one aligned report line.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            fmt_dur(self.mean_s),
            fmt_dur(self.p50_s),
            fmt_dur(self.p99_s),
        )
    }

    /// Iterations per second implied by the mean.
    pub fn per_sec(&self) -> f64 {
        if self.mean_s > 0.0 {
            1.0 / self.mean_s
        } else {
            f64::INFINITY
        }
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_dur(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark runner with a wall-time budget per benchmark.
#[derive(Debug, Clone)]
pub struct Bencher {
    /// Warmup budget.
    pub warmup: Duration,
    /// Measurement budget.
    pub measure: Duration,
    /// Cap on timed iterations (protects very fast ops from sample bloat).
    pub max_iters: u64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            max_iters: 2_000_000,
        }
    }
}

impl Bencher {
    /// Quick-profile bencher for CI-ish runs.
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            max_iters: 200_000,
        }
    }

    /// Run `f` repeatedly and collect per-iteration timings. `f` should
    /// return a value that depends on its work; we pass it through
    /// `std::hint::black_box` to keep the optimizer honest.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples: Vec<f64> = Vec::with_capacity(4096);
        let start = Instant::now();
        while start.elapsed() < self.measure && (samples.len() as u64) < self.max_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let iters = samples.len() as u64;
        let mean = samples.iter().sum::<f64>() / iters.max(1) as f64;
        BenchResult {
            name: name.to_string(),
            iters,
            mean_s: mean,
            p50_s: crate::util::stats::percentile_sorted(&samples, 50.0),
            p99_s: crate::util::stats::percentile_sorted(&samples, 99.0),
            min_s: samples.first().copied().unwrap_or(0.0),
        }
    }

    /// Run and immediately print the report line; returns the result for
    /// further assertions.
    pub fn report<T, F: FnMut() -> T>(&self, name: &str, f: F) -> BenchResult {
        let r = self.run(name, f);
        println!("{}", r.line());
        r
    }
}

/// Print a section header for a bench binary.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Returns a `Bencher` honoring `SBS_BENCH_QUICK=1` for fast CI runs.
pub fn default_bencher() -> Bencher {
    if std::env::var("SBS_BENCH_QUICK").as_deref() == Ok("1") {
        Bencher::quick()
    } else {
        Bencher::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(30),
            max_iters: 100_000,
        };
        let r = b.run("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.iters > 10);
        assert!(r.mean_s > 0.0);
        assert!(r.p50_s <= r.p99_s);
        assert!(r.min_s <= r.p50_s);
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(2.0).ends_with(" s"));
        assert!(fmt_dur(2e-3).ends_with("ms"));
        assert!(fmt_dur(2e-6).ends_with("µs"));
        assert!(fmt_dur(2e-9).ends_with("ns"));
    }
}
