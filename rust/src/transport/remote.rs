//! TCP clients for remote shards: decode (`sbs worker --decode`) and
//! prefill (`sbs worker --prefill`).
//!
//! One shard connection serves every unit the shard advertises in its
//! `HelloAck`; the scheduler holds one transport per unit
//! ([`RemoteUnit`] / [`RemotePrefill`]), all sharing the connection.
//!
//! ## Locking discipline
//!
//! A shard's state is split into two independent lock domains so the
//! send path can never stall the event path:
//!
//! * **pending lock** — the table of in-flight request ids (decode:
//!   admitted sequences; prefill: dispatched jobs plus their partially
//!   assembled KV). Token/terminal delivery and eviction take only this
//!   lock.
//! * **writer lock** — the connection's write half. Frames are encoded
//!   *outside* both locks (the KV-bearing hot paths borrow-serialize
//!   into a per-transport reused buffer) and the blocking `write_all`
//!   holds only the writer lock.
//!
//! A slow or blocked socket write therefore delays other *writers*, but
//! never Token/Done delivery from the same shard (the regression the
//! old single-io-mutex design had — asserted by
//! `blocked_admit_write_does_not_delay_token_delivery`). The reader's
//! liveness pings use `try_lock` and skip when a write is in flight: an
//! in-progress frame is itself keeping the shard's inbound-byte silence
//! guard fed.
//!
//! ## Failure semantics
//!
//! A dedicated reader thread owns the receive side. When the connection
//! dies (EOF, reset, transport error) the reader: marks the shard dead
//! and closes the write half (placements/dispatches stop immediately —
//! `alive()` gates admissibility, and an in-flight registration that
//! races the transition fails its write and unwinds itself), *then*
//! drains the pending table and delivers the resident ids through the
//! sinks' `on_evicted` so the scheduler releases their ledger charges
//! and rejects them upstream — nothing leaks. It then retries the
//! connect/handshake loop with backoff until it succeeds (the shard
//! aborts any stale state on a new handshake, so a reconnect starts
//! clean) or the cluster stops.
//!
//! ## Liveness and RTT
//!
//! The reader heartbeats: a `Ping` every ping interval (busy or idle),
//! with the `Pong` round trip published through the transport's
//! `rtt_ms` and surfaced in the pool gauges (`STATS`). Silence — no
//! inbound byte for `dead_after`, pings unanswered — declares the shard
//! dead even without an EOF/RST (black-holed link), triggering the same
//! evict-and-reconnect path. The steady ping cadence is also what the
//! shard's own symmetric silence guard keys off.

use super::proto::{self, DirectTarget, Frame, FrameReader, ProtoError, ShardRole, PROTO_VERSION};
use super::{
    AdmitJob, DecodeTransport, KvCodec, KvWireCounters, PrefillSinks, PrefillTransport,
    PrefillWork, ShardSinks,
};
use crate::engine::PrefillOutcome;
use crate::metrics::RequestMetrics;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::io::Write;
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, TryLockError};
use std::time::{Duration, Instant};

/// Tunables for one shard connection.
#[derive(Debug, Clone)]
pub struct RemoteShardConfig {
    /// Shard address (`host:port`).
    pub addr: String,
    /// KV wire codec this deployment produces (proposed in `Hello`; the
    /// shard must echo it back).
    pub kv_wire: KvCodec,
    /// Initial connect + handshake budget (startup fails fast past it);
    /// also the socket write timeout bounding a blocked writer.
    pub connect_timeout: Duration,
    /// Socket read timeout — the reader's idle-tick cadence.
    pub read_tick: Duration,
    /// Quiet time before the reader sends a liveness ping.
    pub ping_interval: Duration,
    /// Total silence (no frame of any kind, pings unanswered) after
    /// which the shard is declared dead even without an EOF/RST — the
    /// black-hole case: network partition, frozen host.
    pub dead_after: Duration,
    /// Delay between reconnect attempts after a drop.
    pub reconnect_backoff: Duration,
}

impl RemoteShardConfig {
    /// Defaults for `addr` (raw KV codec, 5 s connect budget, 250 ms
    /// ticks, 1 s pings, 5 s silence-to-death, 500 ms reconnect backoff).
    pub fn new(addr: &str) -> Self {
        RemoteShardConfig {
            addr: addr.to_string(),
            kv_wire: KvCodec::Raw,
            connect_timeout: Duration::from_secs(5),
            read_tick: Duration::from_millis(250),
            ping_interval: Duration::from_secs(1),
            dead_after: Duration::from_secs(5),
            reconnect_backoff: Duration::from_millis(500),
        }
    }
}

/// Connection state shared by both shard roles: the write half, the
/// liveness/RTT gauges and the reconnect identity (role + shape).
struct ShardCore {
    cfg: RemoteShardConfig,
    /// The connection's write half. Held only around `write_all` — never
    /// while delivering events or touching the pending table.
    writer: Mutex<Option<TcpStream>>,
    alive: AtomicBool,
    /// Last measured RTT, microseconds; 0 = not yet measured.
    rtt_us: AtomicU64,
    stop: AtomicBool,
    /// Epoch for ping timestamps.
    epoch: Instant,
    ping_nonce: AtomicU64,
    /// Last `StatsRequest` send instant (epoch µs): sibling units share
    /// one connection, so per-shard throttling keeps a pool-wide stats
    /// sweep from issuing one request per unit.
    last_stats_req_us: AtomicU64,
    /// Role + shape advertised at first handshake; the scheduler's pool
    /// is sized to it, so a reconnecting shard must match it exactly.
    role: ShardRole,
    units: u32,
    slots: u32,
    /// Direct-transfer peer address (`host:peer_port`) advertised in the
    /// last `HelloAck`; `None` for shards without a peer listener. A
    /// replacement shard may rebind its peer listener, so reconnect
    /// refreshes this.
    peer_addr: Mutex<Option<String>>,
    /// Relay-path KV accounting (the scheduler's own encode/decode of KV
    /// payloads); shared with every shard of the cluster.
    relay_kv: Arc<KvWireCounters>,
}

/// `host:peer_port` for a shard reached at `addr` (drops `addr`'s own
/// port).
fn peer_addr_of(addr: &str, peer_port: u16) -> Option<String> {
    if peer_port == 0 {
        return None;
    }
    let host = addr.rsplit_once(':').map(|(h, _)| h).unwrap_or(addr);
    Some(format!("{host}:{peer_port}"))
}

impl ShardCore {
    fn new(
        cfg: RemoteShardConfig,
        conn: TcpStream,
        role: ShardRole,
        units: u32,
        slots: u32,
        peer_port: u16,
        relay_kv: Arc<KvWireCounters>,
    ) -> Self {
        let peer_addr = peer_addr_of(&cfg.addr, peer_port);
        ShardCore {
            cfg,
            writer: Mutex::new(Some(conn)),
            alive: AtomicBool::new(true),
            rtt_us: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            epoch: Instant::now(),
            ping_nonce: AtomicU64::new(1),
            last_stats_req_us: AtomicU64::new(0),
            role,
            units,
            slots,
            peer_addr: Mutex::new(peer_addr),
            relay_kv,
        }
    }

    /// Throttled engine-truth gauge poll: at most one `StatsRequest` per
    /// shard per second, no matter how many sibling units ask.
    fn request_stats(&self) {
        const MIN_GAP_US: u64 = 1_000_000;
        let now = self.now_us();
        let last = self.last_stats_req_us.load(Ordering::Relaxed);
        if now.saturating_sub(last) < MIN_GAP_US {
            return;
        }
        if self
            .last_stats_req_us
            .compare_exchange(last, now, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
        {
            let _ = self.try_send_frame(&Frame::StatsRequest);
        }
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn on_pong(&self, t_us: u64) {
        let rtt = self.now_us().saturating_sub(t_us).max(1);
        self.rtt_us.store(rtt, Ordering::Relaxed);
    }

    fn rtt_ms(&self) -> Option<f64> {
        match self.rtt_us.load(Ordering::Relaxed) {
            0 => None,
            us => Some(us as f64 / 1e3),
        }
    }

    /// Write pre-encoded wire bytes under an already-held writer lock.
    /// On failure the socket is shut down so the reader notices promptly
    /// and runs eviction.
    fn write_held(&self, w: &mut Option<TcpStream>, bytes: &[u8]) -> std::io::Result<()> {
        let Some(conn) = w.as_mut() else {
            return Err(std::io::Error::new(
                std::io::ErrorKind::NotConnected,
                "shard disconnected",
            ));
        };
        match conn.write_all(bytes) {
            Ok(()) => Ok(()),
            Err(e) => {
                let _ = conn.shutdown(Shutdown::Both);
                *w = None;
                self.alive.store(false, Ordering::SeqCst);
                Err(e)
            }
        }
    }

    /// Write one pre-encoded length-prefixed frame, holding only the
    /// writer lock for the (possibly blocking) socket write.
    fn write_wire(&self, bytes: &[u8]) -> std::io::Result<()> {
        let mut w = self.writer.lock().unwrap();
        self.write_held(&mut w, bytes)
    }

    /// Encode + write one frame (cold paths: dispatch batches, Stop).
    fn send_frame(&self, f: &Frame) -> std::io::Result<()> {
        let mut buf = Vec::new();
        proto::write_frame(&mut buf, f).expect("Vec write cannot fail");
        self.write_wire(&buf)
    }

    /// Best-effort frame send that never waits on a busy writer (the
    /// reader's ping path: a write already in flight is itself activity,
    /// so skipping the ping loses nothing).
    fn try_send_frame(&self, f: &Frame) -> std::io::Result<()> {
        let mut buf = Vec::new();
        proto::write_frame(&mut buf, f).expect("Vec write cannot fail");
        match self.writer.try_lock() {
            Ok(mut w) => self.write_held(&mut w, &buf),
            Err(TryLockError::WouldBlock) => Ok(()),
            Err(TryLockError::Poisoned(e)) => {
                let mut w = e.into_inner();
                self.write_held(&mut w, &buf)
            }
        }
    }

    /// First unit to stop speaks for the whole shard: ask it to drain.
    fn stop_shard(&self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        let _ = self.send_frame(&Frame::Stop);
    }

    /// Close the connection without `Frame::Stop`: the shard sees EOF,
    /// aborts nothing it still owes (we own no sequences at drain) and
    /// goes back to accepting — ready for the next scheduler.
    fn detach_shard(&self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        let mut w = self.writer.lock().unwrap();
        if let Some(c) = w.take() {
            let _ = c.shutdown(Shutdown::Both);
        }
    }
}

/// Per-role shard state: the shared connection core plus the pending
/// table of in-flight request ids (`P` is the per-id payload — decode
/// keeps the scheduler metrics, prefill additionally assembles KV).
struct ShardState<P> {
    core: ShardCore,
    pending: Mutex<HashMap<u64, P>>,
}

type DecodeShard = ShardState<RequestMetrics>;
type PrefillShard = ShardState<PrefillPending>;

/// One dispatched-but-unfinished prefill job on the scheduler side: the
/// scheduler-clock state that never crosses the wire, plus the KV halves
/// being assembled from the shard's `KvSegment` stream.
struct PrefillPending {
    max_new: u32,
    metrics: RequestMetrics,
    k: Vec<f32>,
    v: Vec<f32>,
}

fn resolve(addr: &str) -> Result<std::net::SocketAddr> {
    addr.to_socket_addrs()
        .with_context(|| format!("resolving shard address {addr}"))?
        .next()
        .ok_or_else(|| anyhow!("shard address {addr} resolved to nothing"))
}

/// Connect, exchange `Hello`/`HelloAck`, verify the advertised role and
/// echoed codec, and return the ready stream plus the advertised shape
/// (`units`, `slots`, `peer_port`).
fn connect_and_handshake(
    cfg: &RemoteShardConfig,
    want: ShardRole,
) -> Result<(TcpStream, u32, u32, u16)> {
    let sockaddr = resolve(&cfg.addr)?;
    let conn = TcpStream::connect_timeout(&sockaddr, cfg.connect_timeout)
        .with_context(|| format!("connecting to shard {}", cfg.addr))?;
    conn.set_nodelay(true)?;
    conn.set_read_timeout(Some(cfg.read_tick))?;
    conn.set_write_timeout(Some(cfg.connect_timeout))?;
    let mut w = conn.try_clone()?;
    proto::write_frame(
        &mut w,
        &Frame::Hello {
            version: PROTO_VERSION,
            kv_wire: cfg.kv_wire,
        },
    )?;
    let mut reader = FrameReader::new();
    let mut r = conn.try_clone()?;
    let deadline = Instant::now() + cfg.connect_timeout;
    loop {
        match reader.poll(&mut r) {
            Ok(Some(Frame::HelloAck {
                version,
                role,
                units,
                slots,
                kv_wire,
                peer_port,
            })) => {
                if version != PROTO_VERSION {
                    return Err(anyhow!(
                        "shard {} speaks protocol v{version}, we speak v{PROTO_VERSION}",
                        cfg.addr
                    ));
                }
                if role != want {
                    return Err(anyhow!(
                        "shard {} serves {} units, but this pool needs {} units",
                        cfg.addr,
                        role.name(),
                        want.name()
                    ));
                }
                if kv_wire != cfg.kv_wire {
                    // A shard producing a different codec than negotiated
                    // would silently skew the byte accounting; refuse.
                    return Err(anyhow!(
                        "shard {} kv-wire codec mismatch: we asked for {}, it acked {}",
                        cfg.addr,
                        cfg.kv_wire.name(),
                        kv_wire.name()
                    ));
                }
                if units == 0 {
                    return Err(anyhow!("shard {} advertises zero units", cfg.addr));
                }
                if slots == 0 {
                    // A zero-slot unit could never admit: every placement
                    // would pend forever with no terminal event.
                    return Err(anyhow!("shard {} advertises zero slots", cfg.addr));
                }
                return Ok((conn, units, slots, peer_port));
            }
            // A reconnecting shard may flush stale events first; skip
            // them (but still within the handshake deadline — a peer
            // streaming non-HelloAck frames must not pin us forever).
            Ok(Some(_)) | Ok(None) => {
                if Instant::now() >= deadline {
                    return Err(anyhow!("shard {} handshake timed out", cfg.addr));
                }
            }
            Err(e) => return Err(anyhow!("shard {} handshake failed: {e}", cfg.addr)),
        }
    }
}

/// Role-specific half of the shared reader loop: frame delivery and
/// eviction against the role's pending table and sinks. `wire_len` is
/// the frame's full on-wire size (length prefix included) — what the KV
/// byte accounting charges for KV-bearing frames.
trait ReaderPeer: Send {
    fn core(&self) -> &ShardCore;
    fn on_frame(&self, frame: Frame, wire_len: u64);
    /// Drain the pending table and deliver the evicted ids upstream.
    /// Called only after the core is marked dead and the write half
    /// closed (see the locking discipline in the module docs).
    fn on_death(&self);
}

/// Receive side shared by both roles: deliver events, measure RTT, and
/// on connection death evict + reconnect (see module docs).
fn reader_loop<P: ReaderPeer>(peer: P, mut stream: TcpStream) {
    let core = peer.core();
    let addr = core.cfg.addr.clone();
    'conn: loop {
        let mut reader = FrameReader::new();
        let mut idle = proto::IdleGuard::new(&reader);
        let mut last_ping = Instant::now();
        // `poll` returns the moment a frame completes, so the consumed
        // delta between returned frames is exactly that frame's wire
        // size (used by the KV byte accounting).
        let mut consumed_at_last_frame = 0u64;
        loop {
            if core.stop.load(Ordering::SeqCst) {
                break 'conn;
            }
            match reader.poll(&mut stream) {
                Ok(Some(frame)) => {
                    idle.touch();
                    let wire_len = reader.consumed() - consumed_at_last_frame;
                    consumed_at_last_frame = reader.consumed();
                    peer.on_frame(frame, wire_len);
                }
                Ok(None) => {
                    // Total silence with pings outstanding: the link is
                    // black-holed (partition, frozen host) — no EOF/RST
                    // will ever come, so declare death ourselves.
                    if idle.idle_for(&reader) >= core.cfg.dead_after {
                        log::warn!(
                            "shard {addr}: no frames for {:?} (pings unanswered); declaring dead",
                            core.cfg.dead_after
                        );
                        break;
                    }
                }
                Err(ProtoError::Closed) => break,
                Err(e) => {
                    log::warn!("shard {addr}: receive failed: {e}");
                    break;
                }
            }
            // Heartbeat every ping interval, busy or idle: the pongs
            // measure RTT, and the shard relies on this steady inbound
            // cadence for its own symmetric silence-to-death guard. A
            // busy writer (blocked mid-frame) is skipped, not waited on.
            if last_ping.elapsed() >= core.cfg.ping_interval {
                last_ping = Instant::now();
                let ping = Frame::Ping {
                    nonce: core.ping_nonce.fetch_add(1, Ordering::Relaxed),
                    t_us: core.now_us(),
                };
                if core.try_send_frame(&ping).is_err() {
                    break;
                }
            }
        }
        // The connection is dead. Order matters: mark unplaceable and
        // close the write half *first*, then evict — a registration that
        // races this either lands before the eviction sweep (and is
        // evicted) or fails its write and unwinds itself.
        core.alive.store(false, Ordering::SeqCst);
        {
            let mut w = core.writer.lock().unwrap();
            if let Some(c) = w.take() {
                let _ = c.shutdown(Shutdown::Both);
            }
        }
        peer.on_death();
        if core.stop.load(Ordering::SeqCst) {
            break;
        }
        // Reconnect with backoff until the shard returns or we stop.
        log::info!("shard {addr}: reconnecting");
        loop {
            std::thread::sleep(core.cfg.reconnect_backoff);
            if core.stop.load(Ordering::SeqCst) {
                break 'conn;
            }
            match connect_and_handshake(&core.cfg, core.role) {
                Ok((conn, units, slots, peer_port)) => {
                    // The scheduler's pool was sized to the original
                    // shape; a replacement with a different one would
                    // leave phantom units that it rejects every
                    // placement for. Refuse it and keep retrying (the
                    // shard stays visibly dead in the gauges).
                    if units != core.units || slots != core.slots {
                        log::error!(
                            "shard {addr}: replacement advertises {units}×{slots} but the \
                             pool was built for {}×{}; refusing to rejoin",
                            core.units,
                            core.slots
                        );
                        continue;
                    }
                    log::info!("shard {addr}: reconnected ({units} {} units)", core.role.name());
                    let Ok(rs) = conn.try_clone() else { continue };
                    // A replacement process rebinds its peer listener, so
                    // direct targets must track the fresh port.
                    *core.peer_addr.lock().unwrap() = peer_addr_of(&core.cfg.addr, peer_port);
                    *core.writer.lock().unwrap() = Some(conn);
                    core.alive.store(true, Ordering::SeqCst);
                    stream = rs;
                    continue 'conn;
                }
                Err(e) => log::debug!("shard {addr}: reconnect attempt failed: {e:#}"),
            }
        }
    }
}

// ---- decode shards -----------------------------------------------------

struct DecodePeer {
    shard: Arc<DecodeShard>,
    sinks: ShardSinks,
}

impl ReaderPeer for DecodePeer {
    fn core(&self) -> &ShardCore {
        &self.shard.core
    }

    fn on_frame(&self, frame: Frame, _wire_len: u64) {
        match frame {
            Frame::Token { id, index, token } => {
                // Gate on the pending table: a stale id (evicted, or
                // left over from a connection this scheduler never
                // owned) must not produce upstream events. Direct
                // pre-placements are registered here at dispatch time,
                // so a direct sequence's stream (index 0 from the peer
                // commit onward) passes the same gate.
                if self.shard.pending.lock().unwrap().contains_key(&id) {
                    (self.sinks.on_token)(id, index, token);
                }
            }
            Frame::Done { id, tokens } => {
                let metrics = self.shard.pending.lock().unwrap().remove(&id);
                if let Some(m) = metrics {
                    (self.sinks.on_done)(id, tokens, m);
                }
            }
            Frame::Rejected { id } => {
                if self.shard.pending.lock().unwrap().remove(&id).is_some() {
                    (self.sinks.on_rejected)(id);
                }
            }
            Frame::StatsReply {
                units,
                kv_wire_bytes,
                kv_raw_bytes,
            } => (self.sinks.on_stats)(units, kv_wire_bytes, kv_raw_bytes),
            Frame::Pong { t_us, .. } => self.shard.core.on_pong(t_us),
            Frame::Bye => {
                // Clean shutdown acknowledgement; the close follows as EOF.
            }
            // The rest are informational or belong to the prefill role.
            _ => {}
        }
    }

    fn on_death(&self) {
        let resident: Vec<u64> = {
            let mut p = self.shard.pending.lock().unwrap();
            p.drain().map(|(id, _)| id).collect()
        };
        if !resident.is_empty() {
            log::warn!(
                "shard {} died with {} resident sequences; evicting",
                self.shard.core.cfg.addr,
                resident.len()
            );
            (self.sinks.on_evicted)(resident);
        }
    }
}

/// Connect to a decode shard and return one [`RemoteUnit`] transport per
/// DP unit it serves. Fails fast if the shard is unreachable at startup;
/// after that, drops are handled by evict-and-reconnect (module docs).
/// `relay_kv` is the cluster-wide relay-path KV accounting (what the
/// scheduler itself puts on the wire in `Admit` frames).
pub fn connect_shard(
    cfg: RemoteShardConfig,
    sinks: ShardSinks,
    relay_kv: Arc<KvWireCounters>,
) -> Result<Vec<RemoteUnit>> {
    let (conn, units, slots, peer_port) = connect_and_handshake(&cfg, ShardRole::Decode)?;
    let reader_stream = conn.try_clone()?;
    let shard = Arc::new(ShardState {
        core: ShardCore::new(cfg, conn, ShardRole::Decode, units, slots, peer_port, relay_kv),
        pending: Mutex::new(HashMap::new()),
    });
    {
        let peer = DecodePeer {
            shard: shard.clone(),
            sinks,
        };
        std::thread::spawn(move || reader_loop(peer, reader_stream));
    }
    Ok((0..units)
        .map(|u| RemoteUnit {
            shard: shard.clone(),
            unit: u,
            slots,
            wbuf: Vec::new(),
        })
        .collect())
}

/// Transport for one DP unit of a remote decode shard (shares the
/// shard's connection, liveness and RTT with its sibling units).
pub struct RemoteUnit {
    shard: Arc<DecodeShard>,
    unit: u32,
    slots: u32,
    /// Reused wire buffer for borrow-encoded `Admit` frames (KV is
    /// serialized straight from the prefill outcome — no intermediate
    /// copies, no steady-state allocation).
    wbuf: Vec<u8>,
}

impl DecodeTransport for RemoteUnit {
    fn label(&self) -> String {
        format!("{}#{}", self.shard.core.cfg.addr, self.unit)
    }

    fn alive(&self) -> bool {
        self.shard.core.alive.load(Ordering::SeqCst)
    }

    fn rtt_ms(&self) -> Option<f64> {
        self.shard.core.rtt_ms()
    }

    fn slots(&self) -> u32 {
        self.slots
    }

    fn admit(&mut self, job: AdmitJob) -> Result<(), AdmitJob> {
        let codec = self.shard.core.cfg.kv_wire;
        // Refuse frames the receiver would reject as oversized: sending
        // one would cost the whole connection (and every resident
        // sequence on the shard), not just this job.
        let bound = proto::admit_payload_bound(codec, job.outcome.k.len(), job.outcome.v.len());
        if bound > proto::MAX_FRAME as u64 {
            log::warn!(
                "shard {}: admit for job {} (~{bound} B) exceeds the frame limit; refusing",
                self.shard.core.cfg.addr,
                job.id
            );
            return Err(job);
        }
        if !self.alive() {
            return Err(job);
        }
        // Register before writing: a fast Done can only arrive after the
        // write lands, and an eviction sweeping the table will include
        // this id if the shard dies mid-write (a failed write removes it
        // again below — double release is guarded upstream).
        self.shard
            .pending
            .lock()
            .unwrap()
            .insert(job.id, job.metrics);
        // Borrow-encode outside every lock, write under the writer lock
        // only: a slow write here must not delay event delivery.
        proto::admit_frame_into(
            &mut self.wbuf,
            codec,
            self.unit,
            job.id,
            job.outcome.first_token,
            job.outcome.len as u32,
            job.max_new,
            &job.outcome.k,
            &job.outcome.v,
        );
        match self.shard.core.write_wire(&self.wbuf) {
            Ok(()) => {
                // Whole-frame accounting, matching the receiver side
                // (shards charge full frame lengths for KV-bearing
                // frames), so relay and shard gauges stay comparable.
                self.shard.core.relay_kv.record(
                    self.wbuf.len() as u64,
                    4 * (job.outcome.k.len() as u64 + job.outcome.v.len() as u64),
                );
                Ok(())
            }
            Err(e) => {
                self.shard.pending.lock().unwrap().remove(&job.id);
                log::warn!("shard {}: admit failed: {e}", self.shard.core.cfg.addr);
                Err(job)
            }
        }
    }

    fn request_stats(&self) {
        self.shard.core.request_stats();
    }

    fn direct_target(&self) -> Option<DirectTarget> {
        if !self.alive() {
            return None;
        }
        self.shard
            .core
            .peer_addr
            .lock()
            .unwrap()
            .as_ref()
            .map(|addr| DirectTarget {
                addr: addr.clone(),
                unit: self.unit,
            })
    }

    fn expect_direct(&self, id: u64, metrics: RequestMetrics) {
        self.shard.pending.lock().unwrap().insert(id, metrics);
    }

    fn cancel_direct(&self, id: u64) -> bool {
        self.shard.pending.lock().unwrap().remove(&id).is_some()
    }

    fn patch_direct(&self, id: u64, t_first: f64, exec_time: f64) {
        if let Some(m) = self.shard.pending.lock().unwrap().get_mut(&id) {
            m.t_first_token = t_first;
            m.t_exec_start = (t_first - exec_time).max(m.t_dispatch);
        }
    }

    fn stop(&mut self) {
        self.shard.core.stop_shard();
    }

    fn detach(&mut self) {
        self.shard.core.detach_shard();
    }
}

// ---- prefill shards ----------------------------------------------------

struct PrefillPeer {
    shard: Arc<PrefillShard>,
    sinks: PrefillSinks,
}

impl PrefillPeer {
    /// Drop a job whose KV stream is unusable and fail it upstream.
    fn fail_job(&self, id: u64) {
        if self.shard.pending.lock().unwrap().remove(&id).is_some() {
            (self.sinks.on_failed)(id);
        }
    }
}

impl ReaderPeer for PrefillPeer {
    fn core(&self) -> &ShardCore {
        &self.shard.core
    }

    fn on_frame(&self, frame: Frame, wire_len: u64) {
        match frame {
            Frame::KvSegment {
                id,
                half,
                offset,
                total,
                data,
            } => {
                // Relay-path accounting: this KV crossed the scheduler's
                // own wire (a direct handoff never produces this frame
                // here).
                self.shard
                    .core
                    .relay_kv
                    .record(wire_len, 4 * data.len() as u64);
                let failed = {
                    let mut p = self.shard.pending.lock().unwrap();
                    let Some(entry) = p.get_mut(&id) else {
                        return; // stale id (evicted or foreign); drop
                    };
                    // The shared geometry guards: a corrupt `total` must
                    // not allocate unbounded memory (a half that size
                    // could never be re-admitted to decode anyway — the
                    // Admit frame-size guard would refuse it), so fail
                    // the job instead of buffering it.
                    proto::apply_kv_segment(
                        &mut entry.k,
                        &mut entry.v,
                        half,
                        offset,
                        total,
                        &data,
                    )
                    .err()
                };
                if let Some(why) = failed {
                    log::warn!(
                        "shard {}: malformed KV segment for job {id} ({why}); failing the job",
                        self.shard.core.cfg.addr,
                    );
                    self.fail_job(id);
                }
            }
            Frame::PrefillDone {
                id,
                first_token,
                kv_len,
                exec_time,
            } => {
                let entry = self.shard.pending.lock().unwrap().remove(&id);
                if let Some(e) = entry {
                    let outcome = PrefillOutcome {
                        first_token,
                        len: kv_len as usize,
                        k: e.k,
                        v: e.v,
                        exec_time,
                        passes: 1,
                    };
                    (self.sinks.on_prefilled)(id, Box::new(outcome), e.max_new, e.metrics);
                }
            }
            Frame::PrefillFailed { id } => self.fail_job(id),
            Frame::HandoffCommit { id, exec_time, .. } => {
                // Direct transfer committed: the KV went straight to the
                // decode shard (which acked before the prefill shard sent
                // this), so the job leaves the prefill pending table with
                // nothing to assemble. The decode connection carries the
                // token stream from here on.
                if self.shard.pending.lock().unwrap().remove(&id).is_some() {
                    (self.sinks.on_handoff)(id, exec_time);
                }
            }
            Frame::EndForward {
                instance,
                t_measured,
                remaining,
            } => {
                // The index crosses a trust boundary: forwarded raw it
                // would index scheduler state sized to the advertised
                // shape, so an out-of-range instance must die here.
                if instance >= self.shard.core.units {
                    log::warn!(
                        "shard {}: EndForward for unknown instance {instance} \
                         (shard advertised {}); dropping",
                        self.shard.core.cfg.addr,
                        self.shard.core.units
                    );
                    return;
                }
                (self.sinks.on_end_forward)(instance, t_measured, remaining)
            }
            Frame::Pong { t_us, .. } => self.shard.core.on_pong(t_us),
            Frame::Bye => {}
            _ => {}
        }
    }

    fn on_death(&self) {
        let queued: Vec<u64> = {
            let mut p = self.shard.pending.lock().unwrap();
            p.drain().map(|(id, _)| id).collect()
        };
        if !queued.is_empty() {
            log::warn!(
                "prefill shard {} died with {} jobs in flight; rejecting them",
                self.shard.core.cfg.addr,
                queued.len()
            );
            (self.sinks.on_evicted)(queued);
        }
    }
}

/// Connect to a prefill shard and return one [`RemotePrefill`] transport
/// per instance it serves. Same startup/reconnect/eviction semantics as
/// [`connect_shard`].
pub fn connect_prefill_shard(
    cfg: RemoteShardConfig,
    sinks: PrefillSinks,
    relay_kv: Arc<KvWireCounters>,
) -> Result<Vec<RemotePrefill>> {
    let (conn, units, slots, peer_port) = connect_and_handshake(&cfg, ShardRole::Prefill)?;
    let reader_stream = conn.try_clone()?;
    let shard = Arc::new(ShardState {
        core: ShardCore::new(cfg, conn, ShardRole::Prefill, units, slots, peer_port, relay_kv),
        pending: Mutex::new(HashMap::new()),
    });
    {
        let peer = PrefillPeer {
            shard: shard.clone(),
            sinks,
        };
        std::thread::spawn(move || reader_loop(peer, reader_stream));
    }
    Ok((0..units)
        .map(|u| RemotePrefill {
            shard: shard.clone(),
            unit: u,
        })
        .collect())
}

/// Transport for one instance of a remote prefill shard (shares the
/// shard's connection, liveness and RTT with its sibling instances).
pub struct RemotePrefill {
    shard: Arc<PrefillShard>,
    unit: u32,
}

impl PrefillTransport for RemotePrefill {
    fn label(&self) -> String {
        format!("{}#p{}", self.shard.core.cfg.addr, self.unit)
    }

    fn alive(&self) -> bool {
        self.shard.core.alive.load(Ordering::SeqCst)
    }

    fn rtt_ms(&self) -> Option<f64> {
        self.shard.core.rtt_ms()
    }

    fn dispatch(&mut self, work: Vec<PrefillWork>) -> Result<(), Vec<PrefillWork>> {
        if !self.alive() {
            return Err(work);
        }
        // Register the whole batch before writing (same discipline as
        // decode admits: mid-write death evicts, failed write unwinds).
        {
            let mut p = self.shard.pending.lock().unwrap();
            for w in &work {
                p.insert(
                    w.id,
                    PrefillPending {
                        max_new: w.max_new,
                        metrics: w.metrics,
                        k: Vec::new(),
                        v: Vec::new(),
                    },
                );
            }
        }
        let frame = Frame::PrefillDispatch {
            unit: self.unit,
            jobs: work
                .iter()
                .map(|w| proto::PrefillJobWire {
                    id: w.id,
                    max_new: w.max_new,
                    prompt: w.prompt.clone(),
                    target: w.target.clone(),
                })
                .collect(),
        };
        match self.shard.core.send_frame(&frame) {
            Ok(()) => Ok(()),
            Err(e) => {
                let mut p = self.shard.pending.lock().unwrap();
                for w in &work {
                    p.remove(&w.id);
                }
                drop(p);
                log::warn!(
                    "prefill shard {}: dispatch failed: {e}",
                    self.shard.core.cfg.addr
                );
                Err(work)
            }
        }
    }

    fn supports_direct(&self) -> bool {
        true
    }

    fn stop(&mut self) {
        self.shard.core.stop_shard();
    }

    fn detach(&mut self) {
        self.shard.core.detach_shard();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::proto::KvHalf;
    use std::net::TcpListener;
    use std::sync::atomic::AtomicU32;

    fn counting_sinks(tokens: Arc<AtomicU32>) -> ShardSinks {
        ShardSinks {
            on_token: Box::new(move |_, _, _| {
                tokens.fetch_add(1, Ordering::SeqCst);
            }),
            on_done: Box::new(|_, _, _| {}),
            on_rejected: Box::new(|_| {}),
            on_evicted: Box::new(|_| {}),
            on_stats: Box::new(|_, _, _| {}),
        }
    }

    fn admit_job(id: u64, kv_elems: usize) -> AdmitJob {
        AdmitJob {
            id,
            outcome: Box::new(PrefillOutcome {
                first_token: 65,
                len: 4,
                k: vec![0.5; kv_elems],
                v: vec![0.5; kv_elems],
                exec_time: 0.0,
                passes: 1,
            }),
            max_new: 4,
            metrics: RequestMetrics::arrive(0.0, 4),
        }
    }

    /// The write-under-lock regression: an `Admit` write blocked on a
    /// peer that stopped draining its socket must not delay Token
    /// delivery from the same shard. The write path may hold only the
    /// writer lock — never the pending/event lock.
    #[test]
    fn blocked_admit_write_does_not_delay_token_delivery() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let done = Arc::new(AtomicBool::new(false));
        let shard_done = done.clone();
        let fake_shard = std::thread::spawn(move || {
            let (conn, _) = listener.accept().unwrap();
            conn.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
            let mut rd = conn.try_clone().unwrap();
            let mut reader = FrameReader::new();
            loop {
                match reader.poll(&mut rd) {
                    Ok(Some(Frame::Hello { .. })) => break,
                    Ok(_) => continue,
                    Err(e) => panic!("handshake: {e}"),
                }
            }
            let mut w = conn.try_clone().unwrap();
            proto::write_frame(
                &mut w,
                &Frame::HelloAck {
                    version: PROTO_VERSION,
                    role: ShardRole::Decode,
                    units: 1,
                    slots: 4,
                    kv_wire: KvCodec::Raw,
                    peer_port: 0,
                },
            )
            .unwrap();
            // Consume frames until the small admit for id 1 arrives,
            // then STOP reading forever: the scheduler's next big write
            // must block once the socket buffers fill.
            loop {
                match reader.poll(&mut rd) {
                    Ok(Some(Frame::Admit { id: 1, .. })) => break,
                    Ok(_) => continue,
                    Err(e) => panic!("waiting for admit: {e}"),
                }
            }
            // While never reading again, keep streaming tokens for the
            // resident sequence.
            let mut index = 1u32;
            while !shard_done.load(Ordering::SeqCst) {
                if proto::write_frame(&mut w, &Frame::Token { id: 1, index, token: 7 }).is_err() {
                    break;
                }
                index += 1;
                std::thread::sleep(Duration::from_millis(5));
            }
        });

        let tokens = Arc::new(AtomicU32::new(0));
        let mut cfg = RemoteShardConfig::new(&addr);
        // Bounds how long the deliberately blocked write can hang.
        cfg.connect_timeout = Duration::from_secs(3);
        let mut units =
            connect_shard(cfg, counting_sinks(tokens.clone()), Arc::default()).unwrap();
        assert_eq!(units.len(), 1);
        let mut unit = units.pop().unwrap();
        unit.admit(admit_job(1, 0)).map_err(|_| ()).expect("small admit");

        // Wait for the token stream to be live before starting the
        // blocked write.
        let deadline = Instant::now() + Duration::from_secs(10);
        while tokens.load(Ordering::SeqCst) == 0 {
            assert!(Instant::now() < deadline, "no tokens before the blocked write");
            std::thread::sleep(Duration::from_millis(5));
        }

        // A ~64 MB admit against a peer that stopped reading: write_all
        // fills the socket buffers and blocks until the write timeout.
        let admit_returned = Arc::new(AtomicBool::new(false));
        let flag = admit_returned.clone();
        let admit_thread = std::thread::spawn(move || {
            let failed = unit.admit(admit_job(2, 8 << 20)).is_err();
            flag.store(true, Ordering::SeqCst);
            unit.detach(); // stop the reader thread once we are done
            failed
        });

        // While that write is in flight, tokens must keep arriving
        // promptly. 10 tokens at 5 ms cadence is ~50 ms; serialized
        // behind the 3 s blocked write it would time this out.
        let base = tokens.load(Ordering::SeqCst);
        let t0 = Instant::now();
        while tokens.load(Ordering::SeqCst) < base + 10 {
            assert!(
                t0.elapsed() < Duration::from_secs(2),
                "token delivery stalled behind a blocked admit write \
                 ({} tokens in {:?})",
                tokens.load(Ordering::SeqCst) - base,
                t0.elapsed()
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(
            !admit_returned.load(Ordering::SeqCst),
            "test premise broken: the big admit finished before the \
             tokens did — it never actually blocked"
        );

        done.store(true, Ordering::SeqCst);
        let failed = admit_thread.join().unwrap();
        assert!(failed, "a write to a never-draining peer must time out and hand the job back");
        fake_shard.join().unwrap();
    }

    /// The KV handoff reassembly path: out-of-order, multi-chunk
    /// `KvSegment`s for both halves must assemble into the exact caches
    /// the shard serialized, committed by `PrefillDone` — and `EndForward`
    /// must surface through the sink with its backlog intact.
    #[test]
    fn prefill_client_reassembles_chunked_kv_handoff() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let k: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..600).map(|i| -(i as f32)).collect();
        let (k2, v2) = (k.clone(), v.clone());
        let fake_shard = std::thread::spawn(move || {
            let (conn, _) = listener.accept().unwrap();
            conn.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
            let mut rd = conn.try_clone().unwrap();
            let mut reader = FrameReader::new();
            loop {
                match reader.poll(&mut rd) {
                    Ok(Some(Frame::Hello { .. })) => break,
                    Ok(_) => continue,
                    Err(e) => panic!("handshake: {e}"),
                }
            }
            let mut w = conn.try_clone().unwrap();
            proto::write_frame(
                &mut w,
                &Frame::HelloAck {
                    version: PROTO_VERSION,
                    role: ShardRole::Prefill,
                    units: 2,
                    slots: 1,
                    kv_wire: KvCodec::Raw,
                    peer_port: 0,
                },
            )
            .unwrap();
            let id = loop {
                match reader.poll(&mut rd) {
                    Ok(Some(Frame::PrefillDispatch { unit, jobs })) => {
                        assert_eq!(unit, 1);
                        assert_eq!(jobs.len(), 1);
                        assert_eq!(jobs[0].prompt, vec![5; 16]);
                        break jobs[0].id;
                    }
                    Ok(_) => continue,
                    Err(e) => panic!("dispatch: {e}"),
                }
            };
            // Stream the halves chunked and *out of order* — the borrow
            // encoder producing exactly what write_frame would.
            let mut buf = Vec::new();
            for (half, data, cuts) in [
                (KvHalf::V, &v2, vec![0usize, 600]),
                (KvHalf::K, &k2, vec![512, 1000, 0, 512]),
            ] {
                for pair in cuts.chunks(2) {
                    let (a, b) = (pair[0], pair[1]);
                    proto::kv_segment_frame_into(
                        &mut buf,
                        KvCodec::Raw,
                        id,
                        half,
                        a as u32,
                        data.len() as u32,
                        &data[a..b],
                    );
                    use std::io::Write;
                    w.write_all(&buf).unwrap();
                }
            }
            proto::write_frame(
                &mut w,
                &Frame::PrefillDone {
                    id,
                    first_token: 0x41,
                    kv_len: 16,
                    exec_time: 0.25,
                },
            )
            .unwrap();
            proto::write_frame(
                &mut w,
                &Frame::EndForward {
                    instance: 1,
                    t_measured: 0.25,
                    remaining: Some(96),
                },
            )
            .unwrap();
            // Hold the connection open until the scheduler detaches.
            let mut tail = FrameReader::new();
            loop {
                match tail.poll(&mut rd) {
                    Ok(_) => continue,
                    Err(_) => break,
                }
            }
        });

        let (got_tx, got_rx) = std::sync::mpsc::channel();
        let (ef_tx, ef_rx) = std::sync::mpsc::channel();
        let sinks = PrefillSinks {
            on_prefilled: Box::new(move |id, outcome, max_new, _metrics| {
                let _ = got_tx.send((id, outcome, max_new));
            }),
            on_handoff: Box::new(|id, _| panic!("unexpected direct handoff for {id}")),
            on_failed: Box::new(|id| panic!("unexpected prefill failure for {id}")),
            on_end_forward: Box::new(move |instance, t, remaining| {
                let _ = ef_tx.send((instance, t, remaining));
            }),
            on_evicted: Box::new(|_| {}),
        };
        let relay_kv: Arc<KvWireCounters> = Arc::default();
        let mut units =
            connect_prefill_shard(RemoteShardConfig::new(&addr), sinks, relay_kv.clone()).unwrap();
        assert_eq!(units.len(), 2);
        assert_eq!(units[1].label(), format!("{addr}#p1"));
        units[1]
            .dispatch(vec![PrefillWork {
                id: 31,
                prompt: vec![5; 16],
                max_new: 7,
                metrics: RequestMetrics::arrive(0.0, 16),
                target: None,
            }])
            .map_err(|_| ())
            .expect("dispatch");

        let (id, outcome, max_new) = got_rx
            .recv_timeout(Duration::from_secs(10))
            .expect("handoff must commit");
        assert_eq!(id, 31);
        assert_eq!(max_new, 7);
        assert_eq!(outcome.first_token, 0x41);
        assert_eq!(outcome.len, 16);
        assert_eq!(outcome.k, k, "K half must reassemble exactly");
        assert_eq!(outcome.v, v, "V half must reassemble exactly");
        let (instance, t, remaining) = ef_rx
            .recv_timeout(Duration::from_secs(10))
            .expect("EndForward must surface");
        assert_eq!(instance, 1);
        assert!((t - 0.25).abs() < 1e-12);
        assert_eq!(remaining, Some(96), "engine backlog crosses the wire");
        let (wire, raw) = relay_kv.snapshot();
        assert_eq!(raw, 4 * (1000 + 600), "relayed KV raw bytes accounted");
        assert!(wire > raw, "raw codec wire bytes include frame overhead: {wire}");

        for u in &mut units {
            u.detach();
        }
        fake_shard.join().unwrap();
    }
}
