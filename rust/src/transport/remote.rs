//! TCP client for a remote decode shard (`sbs worker --decode`).
//!
//! One shard connection ([`connect_shard`]) serves every DP unit the
//! shard advertises in its `HelloAck`; the scheduler holds one
//! [`RemoteUnit`] transport per unit, all sharing the connection.
//!
//! ## Failure semantics
//!
//! A dedicated reader thread owns the receive side. When the connection
//! dies (EOF, reset, transport error) the reader atomically: marks the
//! shard dead (placements stop immediately — `alive()` gates
//! admissibility), drains the pending-sequence table, and delivers the
//! resident request ids through [`ShardSinks::on_evicted`] so the
//! scheduler releases their ledger charges and rejects them upstream —
//! *nothing leaks*. It then retries the connect/handshake loop with
//! backoff until it succeeds (the shard aborts any stale state on a new
//! handshake, so a reconnect starts clean) or the cluster stops.
//!
//! ## Liveness and RTT
//!
//! The reader heartbeats: a `Ping` every ping interval (busy or idle),
//! with the `Pong` round trip published through the transport's
//! `rtt_ms` and surfaced in the decode-pool gauges (`STATS`). Silence —
//! no inbound frame for `dead_after`, pings unanswered — declares the
//! shard dead even without an EOF/RST (black-holed link), triggering
//! the same evict-and-reconnect path. The steady ping cadence is also
//! what the shard's own symmetric silence guard keys off.

use super::proto::{self, Frame, FrameReader, PROTO_VERSION, ProtoError};
use super::{AdmitJob, DecodeTransport, ShardSinks};
use crate::metrics::RequestMetrics;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Tunables for one shard connection.
#[derive(Debug, Clone)]
pub struct RemoteShardConfig {
    /// Shard address (`host:port`).
    pub addr: String,
    /// Initial connect + handshake budget (startup fails fast past it).
    pub connect_timeout: Duration,
    /// Socket read timeout — the reader's idle-tick cadence.
    pub read_tick: Duration,
    /// Quiet time before the reader sends a liveness ping.
    pub ping_interval: Duration,
    /// Total silence (no frame of any kind, pings unanswered) after
    /// which the shard is declared dead even without an EOF/RST — the
    /// black-hole case: network partition, frozen host.
    pub dead_after: Duration,
    /// Delay between reconnect attempts after a drop.
    pub reconnect_backoff: Duration,
}

impl RemoteShardConfig {
    /// Defaults for `addr` (5 s connect budget, 250 ms ticks, 1 s pings,
    /// 5 s silence-to-death, 500 ms reconnect backoff).
    pub fn new(addr: &str) -> Self {
        RemoteShardConfig {
            addr: addr.to_string(),
            connect_timeout: Duration::from_secs(5),
            read_tick: Duration::from_millis(250),
            ping_interval: Duration::from_secs(1),
            dead_after: Duration::from_secs(5),
            reconnect_backoff: Duration::from_millis(500),
        }
    }
}

/// Send side + pending table, guarded together so admit/evict/complete
/// transitions are atomic (an admit can never slip a sequence into a
/// shard that was just declared dead without being evicted).
struct ShardIo {
    conn: Option<TcpStream>,
    /// Sequences admitted and not yet terminal: id → scheduler metrics.
    pending: HashMap<u64, RequestMetrics>,
}

/// State shared by the per-unit transports and the reader thread.
pub struct ShardHandle {
    cfg: RemoteShardConfig,
    io: Mutex<ShardIo>,
    alive: AtomicBool,
    /// Last measured RTT, microseconds; 0 = not yet measured.
    rtt_us: AtomicU64,
    stop: AtomicBool,
    /// Epoch for ping timestamps.
    epoch: Instant,
    ping_nonce: AtomicU64,
    /// Shape advertised at first handshake; the scheduler's pool is
    /// sized to it, so a reconnecting shard must match it exactly.
    units: u32,
    slots: u32,
}

impl ShardHandle {
    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Serialize one frame onto the connection. On failure the socket is
    /// shut down so the reader notices promptly and runs eviction.
    fn send(&self, io: &mut ShardIo, frame: &Frame) -> std::io::Result<()> {
        let Some(conn) = io.conn.as_mut() else {
            return Err(std::io::Error::new(
                std::io::ErrorKind::NotConnected,
                "shard disconnected",
            ));
        };
        match proto::write_frame(conn, frame) {
            Ok(()) => Ok(()),
            Err(e) => {
                let _ = conn.shutdown(Shutdown::Both);
                io.conn = None;
                self.alive.store(false, Ordering::SeqCst);
                Err(e)
            }
        }
    }
}

fn resolve(addr: &str) -> Result<std::net::SocketAddr> {
    addr.to_socket_addrs()
        .with_context(|| format!("resolving shard address {addr}"))?
        .next()
        .ok_or_else(|| anyhow!("shard address {addr} resolved to nothing"))
}

/// Connect, exchange `Hello`/`HelloAck`, and return the ready stream
/// plus the advertised shape.
fn connect_and_handshake(cfg: &RemoteShardConfig) -> Result<(TcpStream, u32, u32)> {
    let sockaddr = resolve(&cfg.addr)?;
    let conn = TcpStream::connect_timeout(&sockaddr, cfg.connect_timeout)
        .with_context(|| format!("connecting to shard {}", cfg.addr))?;
    conn.set_nodelay(true)?;
    conn.set_read_timeout(Some(cfg.read_tick))?;
    conn.set_write_timeout(Some(cfg.connect_timeout))?;
    let mut w = conn.try_clone()?;
    proto::write_frame(&mut w, &Frame::Hello { version: PROTO_VERSION })?;
    let mut reader = FrameReader::new();
    let mut r = conn.try_clone()?;
    let deadline = Instant::now() + cfg.connect_timeout;
    loop {
        match reader.poll(&mut r) {
            Ok(Some(Frame::HelloAck {
                version,
                units,
                slots,
            })) => {
                if version != PROTO_VERSION {
                    return Err(anyhow!(
                        "shard {} speaks protocol v{version}, we speak v{PROTO_VERSION}",
                        cfg.addr
                    ));
                }
                if units == 0 {
                    return Err(anyhow!("shard {} advertises zero units", cfg.addr));
                }
                if slots == 0 {
                    // A zero-slot unit could never admit: every placement
                    // would pend forever with no terminal event.
                    return Err(anyhow!("shard {} advertises zero slots", cfg.addr));
                }
                return Ok((conn, units, slots));
            }
            // A reconnecting shard may flush stale events first; skip
            // them (but still within the handshake deadline — a peer
            // streaming non-HelloAck frames must not pin us forever).
            Ok(Some(_)) | Ok(None) => {
                if Instant::now() >= deadline {
                    return Err(anyhow!("shard {} handshake timed out", cfg.addr));
                }
            }
            Err(e) => return Err(anyhow!("shard {} handshake failed: {e}", cfg.addr)),
        }
    }
}

/// Connect to a shard and return one [`RemoteUnit`] transport per DP
/// unit it serves. Fails fast if the shard is unreachable at startup;
/// after that, drops are handled by evict-and-reconnect (module docs).
pub fn connect_shard(cfg: RemoteShardConfig, sinks: ShardSinks) -> Result<Vec<RemoteUnit>> {
    let (conn, units, slots) = connect_and_handshake(&cfg)?;
    let reader_stream = conn.try_clone()?;
    let handle = Arc::new(ShardHandle {
        cfg,
        io: Mutex::new(ShardIo {
            conn: Some(conn),
            pending: HashMap::new(),
        }),
        alive: AtomicBool::new(true),
        rtt_us: AtomicU64::new(0),
        stop: AtomicBool::new(false),
        epoch: Instant::now(),
        ping_nonce: AtomicU64::new(1),
        units,
        slots,
    });
    {
        let handle = handle.clone();
        std::thread::spawn(move || reader_loop(handle, sinks, reader_stream));
    }
    Ok((0..units)
        .map(|u| RemoteUnit {
            shard: handle.clone(),
            unit: u,
            slots,
        })
        .collect())
}

/// Receive side: deliver events, measure RTT, and on connection death
/// evict + reconnect (see module docs).
fn reader_loop(handle: Arc<ShardHandle>, sinks: ShardSinks, mut stream: TcpStream) {
    let addr = handle.cfg.addr.clone();
    'conn: loop {
        let mut reader = FrameReader::new();
        let mut idle = proto::IdleGuard::new(&reader);
        let mut last_ping = Instant::now();
        loop {
            if handle.stop.load(Ordering::SeqCst) {
                break 'conn;
            }
            match reader.poll(&mut stream) {
                Ok(Some(frame)) => {
                    idle.touch();
                    handle_frame(&handle, &sinks, frame);
                }
                Ok(None) => {
                    // Total silence with pings outstanding: the link is
                    // black-holed (partition, frozen host) — no EOF/RST
                    // will ever come, so declare death ourselves.
                    if idle.idle_for(&reader) >= handle.cfg.dead_after {
                        log::warn!(
                            "shard {addr}: no frames for {:?} (pings unanswered); declaring dead",
                            handle.cfg.dead_after
                        );
                        break;
                    }
                }
                Err(ProtoError::Closed) => break,
                Err(e) => {
                    log::warn!("shard {addr}: receive failed: {e}");
                    break;
                }
            }
            // Heartbeat every ping interval, busy or idle: the pongs
            // measure RTT, and the shard relies on this steady inbound
            // cadence for its own symmetric silence-to-death guard.
            if last_ping.elapsed() >= handle.cfg.ping_interval {
                last_ping = Instant::now();
                let ping = Frame::Ping {
                    nonce: handle.ping_nonce.fetch_add(1, Ordering::Relaxed),
                    t_us: handle.now_us(),
                };
                let mut io = handle.io.lock().unwrap();
                if handle.send(&mut io, &ping).is_err() {
                    break;
                }
            }
        }
        // The connection is dead: evict everything resident, atomically
        // with marking the shard unplaceable.
        let resident: Vec<u64> = {
            let mut io = handle.io.lock().unwrap();
            handle.alive.store(false, Ordering::SeqCst);
            if let Some(c) = io.conn.take() {
                let _ = c.shutdown(Shutdown::Both);
            }
            io.pending.drain().map(|(id, _)| id).collect()
        };
        if !resident.is_empty() {
            log::warn!("shard {addr} died with {} resident sequences; evicting", resident.len());
            (sinks.on_evicted)(resident);
        }
        if handle.stop.load(Ordering::SeqCst) {
            break;
        }
        // Reconnect with backoff until the shard returns or we stop.
        log::info!("shard {addr}: reconnecting");
        loop {
            std::thread::sleep(handle.cfg.reconnect_backoff);
            if handle.stop.load(Ordering::SeqCst) {
                break 'conn;
            }
            match connect_and_handshake(&handle.cfg) {
                Ok((conn, units, slots)) => {
                    // The scheduler's pool was sized to the original
                    // shape; a replacement with a different one would
                    // leave phantom units that it rejects every admit
                    // for. Refuse it and keep retrying (the shard stays
                    // visibly dead in the gauges).
                    if units != handle.units || slots != handle.slots {
                        log::error!(
                            "shard {addr}: replacement advertises {units}×{slots} but the \
                             pool was built for {}×{}; refusing to rejoin",
                            handle.units,
                            handle.slots
                        );
                        continue;
                    }
                    log::info!("shard {addr}: reconnected ({units} units)");
                    let Ok(rs) = conn.try_clone() else { continue };
                    let mut io = handle.io.lock().unwrap();
                    io.conn = Some(conn);
                    handle.alive.store(true, Ordering::SeqCst);
                    drop(io);
                    stream = rs;
                    continue 'conn;
                }
                Err(e) => log::debug!("shard {addr}: reconnect attempt failed: {e:#}"),
            }
        }
    }
}

fn handle_frame(handle: &ShardHandle, sinks: &ShardSinks, frame: Frame) {
    match frame {
        Frame::Token { id, index, token } => {
            // Gate on the pending table: a stale id (evicted, or left
            // over from a connection this scheduler never owned) must
            // not produce upstream events.
            if handle.io.lock().unwrap().pending.contains_key(&id) {
                (sinks.on_token)(id, index, token);
            }
        }
        Frame::Done { id, tokens } => {
            let metrics = handle.io.lock().unwrap().pending.remove(&id);
            if let Some(m) = metrics {
                (sinks.on_done)(id, tokens, m);
            }
        }
        Frame::Rejected { id } => {
            if handle.io.lock().unwrap().pending.remove(&id).is_some() {
                (sinks.on_rejected)(id);
            }
        }
        Frame::Pong { t_us, .. } => {
            let rtt = handle.now_us().saturating_sub(t_us).max(1);
            handle.rtt_us.store(rtt, Ordering::Relaxed);
        }
        Frame::Bye => {
            // Clean shutdown acknowledgement; the close follows as EOF.
        }
        // StatsReply and the rest are informational or future-facing;
        // the scheduler's own ledger is authoritative for gauges.
        _ => {}
    }
}

/// Transport for one DP unit of a remote shard (shares the shard's
/// connection, liveness and RTT with its sibling units).
pub struct RemoteUnit {
    shard: Arc<ShardHandle>,
    unit: u32,
    slots: u32,
}

impl DecodeTransport for RemoteUnit {
    fn label(&self) -> String {
        format!("{}#{}", self.shard.cfg.addr, self.unit)
    }

    fn alive(&self) -> bool {
        self.shard.alive.load(Ordering::SeqCst)
    }

    fn rtt_ms(&self) -> Option<f64> {
        match self.shard.rtt_us.load(Ordering::Relaxed) {
            0 => None,
            us => Some(us as f64 / 1e3),
        }
    }

    fn slots(&self) -> u32 {
        self.slots
    }

    fn admit(&mut self, job: AdmitJob) -> Result<(), AdmitJob> {
        // Refuse frames the receiver would reject as oversized: sending
        // one would cost the whole connection (and every resident
        // sequence on the shard), not just this job.
        let bound = proto::admit_payload_bound(job.outcome.k.len(), job.outcome.v.len());
        if bound > proto::MAX_FRAME as u64 {
            log::warn!(
                "shard {}: admit for job {} (~{bound} B) exceeds the frame limit; refusing",
                self.shard.cfg.addr,
                job.id
            );
            return Err(job);
        }
        let frame = Frame::Admit {
            unit: self.unit,
            id: job.id,
            first_token: job.outcome.first_token,
            kv_len: job.outcome.len as u32,
            max_new: job.max_new,
            k: job.outcome.k.clone(),
            v: job.outcome.v.clone(),
        };
        let mut io = self.shard.io.lock().unwrap();
        if io.conn.is_none() {
            return Err(job);
        }
        // Register before writing: the reader (same lock) can deliver a
        // fast Done only after we release the lock, and an eviction
        // sweeping the table will include this id if the shard dies
        // mid-write.
        io.pending.insert(job.id, job.metrics);
        match self.shard.send(&mut io, &frame) {
            Ok(()) => Ok(()),
            Err(e) => {
                io.pending.remove(&job.id);
                drop(io);
                log::warn!("shard {}: admit failed: {e}", self.shard.cfg.addr);
                Err(job)
            }
        }
    }

    fn stop(&mut self) {
        // First unit to stop speaks for the whole shard.
        if self.shard.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        let mut io = self.shard.io.lock().unwrap();
        let _ = self.shard.send(&mut io, &Frame::Stop);
    }

    fn detach(&mut self) {
        // Close the connection without Frame::Stop: the shard sees EOF,
        // aborts nothing it still owes (we own no sequences at drain)
        // and goes back to accepting — ready for the next scheduler.
        if self.shard.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        let mut io = self.shard.io.lock().unwrap();
        if let Some(c) = io.conn.take() {
            let _ = c.shutdown(Shutdown::Both);
        }
    }
}
