//! TCP clients for remote shards: decode (`sbs worker --decode`) and
//! prefill (`sbs worker --prefill`).
//!
//! One shard connection serves every unit the shard advertises in its
//! `HelloAck`; the scheduler holds one transport per unit
//! ([`RemoteUnit`] / [`RemotePrefill`]), all sharing the connection.
//!
//! ## Event-driven IO
//!
//! Connections are owned by the process-global [`NetDriver`]: one
//! poller thread drives reads, writes and ticks for *every* shard, so
//! scheduler-side transport threads are O(1) in shard count. Send
//! paths no longer block on the socket — frames are encoded outside
//! every lock (the KV-bearing hot paths borrow-serialize into a reused
//! buffer, then hand the buffer to the outbound queue) and enqueued on
//! the connection's two-lane queue:
//!
//! * liveness pings and stats requests ride the **priority lane**, so
//!   a bulk KV backlog can never starve RTT/liveness updates (the old
//!   `try_lock`-skip ping path could be starved indefinitely by
//!   sustained KV streaming);
//! * `Admit` frames ride per-job streams in the **bulk lane**, where
//!   the queue round-robins across streams at frame granularity.
//!
//! A peer that stops draining its socket no longer blocks a writer
//! thread: the backlog accumulates up to the queue's soft cap (new
//! admits are refused, handing their jobs back to the scheduler) and
//! the driver's write-stall guard kills the connection, which evicts
//! the shard's pending work exactly like any other death.
//!
//! ## Failure semantics
//!
//! When the connection dies (EOF, reset, transport error, write
//! stall) the handler: marks the shard dead (placements/dispatches
//! stop immediately — `alive()` gates admissibility, and an in-flight
//! registration that races the transition fails its enqueue and
//! unwinds itself), *then* drains the pending table and delivers the
//! resident ids through the sinks' `on_evicted` so the scheduler
//! releases their ledger charges and rejects them upstream — nothing
//! leaks. A transient reconnect thread then retries the
//! connect/handshake loop with backoff until it succeeds (the shard
//! aborts any stale state on a new handshake, so a reconnect starts
//! clean) or the cluster stops.
//!
//! ## Liveness and RTT
//!
//! The handler heartbeats from the driver tick: a `Ping` every ping
//! interval (busy or idle) on the priority lane, with the `Pong`
//! round trip published through the transport's `rtt_ms` and surfaced
//! in the pool gauges (`STATS`). Silence — no inbound byte for
//! `dead_after`, pings unanswered — declares the shard dead even
//! without an EOF/RST (black-holed link), triggering the same
//! evict-and-reconnect path. The steady ping cadence is also what the
//! shard's own symmetric silence guard keys off.

use super::driver::{ConnHandle, ConnHandler, ConnIo, ConnOptions, NetDriver};
use super::proto::{
    self, DirectTarget, Frame, FrameReader, ShardRole, StreamId, PROTO_VERSION, STREAM_CONTROL,
};
use super::{
    AdmitJob, DecodeTransport, ExtractedSeq, KvCodec, KvWireCounters, PrefillSinks,
    PrefillTransport, PrefillWork, ShardSinks,
};
use crate::engine::PrefillOutcome;
use crate::metrics::RequestMetrics;
use crate::scheduler::types::SloClass;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Soft cap on a shard connection's outbound backlog (see
/// [`ConnOptions::cap`]): past this, admits are refused and handed
/// back to the scheduler rather than queued without bound.
const OUTBOUND_CAP: u64 = 64 * 1024 * 1024;

/// Tunables for one shard connection.
#[derive(Debug, Clone)]
pub struct RemoteShardConfig {
    /// Shard address (`host:port`).
    pub addr: String,
    /// KV wire codec this deployment produces (proposed in `Hello`; the
    /// shard must echo it back).
    pub kv_wire: KvCodec,
    /// Initial connect + handshake budget (startup fails fast past it);
    /// also the write-stall bound — a peer that drains nothing for this
    /// long while bytes are queued is declared dead.
    pub connect_timeout: Duration,
    /// Socket read timeout during the blocking handshake.
    pub read_tick: Duration,
    /// Quiet time before the handler sends a liveness ping.
    pub ping_interval: Duration,
    /// Total silence (no frame of any kind, pings unanswered) after
    /// which the shard is declared dead even without an EOF/RST — the
    /// black-hole case: network partition, frozen host.
    pub dead_after: Duration,
    /// Delay between reconnect attempts after a drop.
    pub reconnect_backoff: Duration,
    /// Epoch the heartbeat `Ping { t_us }` timestamps count from.
    /// Defaults to "now"; the cluster fabric overrides it with the
    /// scheduler clock's epoch so shards can align their trace marks to
    /// the scheduler timebase from the pings alone (error ≤ the one-way
    /// network delay, ≈ RTT).
    pub epoch: Instant,
}

impl RemoteShardConfig {
    /// Defaults for `addr` (raw KV codec, 5 s connect budget, 250 ms
    /// ticks, 1 s pings, 5 s silence-to-death, 500 ms reconnect backoff).
    pub fn new(addr: &str) -> Self {
        RemoteShardConfig {
            addr: addr.to_string(),
            kv_wire: KvCodec::Raw,
            connect_timeout: Duration::from_secs(5),
            read_tick: Duration::from_millis(250),
            ping_interval: Duration::from_secs(1),
            dead_after: Duration::from_secs(5),
            reconnect_backoff: Duration::from_millis(500),
            epoch: Instant::now(),
        }
    }
}

/// Connection state shared by both shard roles: the driver handle, the
/// liveness/RTT gauges and the reconnect identity (role + shape).
struct ShardCore {
    cfg: RemoteShardConfig,
    /// Handle to the driver-owned connection; `None` between death and
    /// a successful reconnect.
    conn: Mutex<Option<ConnHandle>>,
    alive: AtomicBool,
    /// Last measured RTT, microseconds; 0 = not yet measured.
    rtt_us: AtomicU64,
    stop: AtomicBool,
    /// Epoch for ping timestamps.
    epoch: Instant,
    ping_nonce: AtomicU64,
    /// Last `StatsRequest` send instant (epoch µs): sibling units share
    /// one connection, so per-shard throttling keeps a pool-wide stats
    /// sweep from issuing one request per unit.
    last_stats_req_us: AtomicU64,
    /// Role + shape advertised at first handshake; the scheduler's pool
    /// is sized to it, so a reconnecting shard must match it exactly.
    role: ShardRole,
    units: u32,
    slots: u32,
    /// Direct-transfer peer address (`host:peer_port`) advertised in the
    /// last `HelloAck`; `None` for shards without a peer listener. A
    /// replacement shard may rebind its peer listener, so reconnect
    /// refreshes this.
    peer_addr: Mutex<Option<String>>,
    /// Relay-path KV accounting (the scheduler's own encode/decode of KV
    /// payloads); shared with every shard of the cluster.
    relay_kv: Arc<KvWireCounters>,
}

/// `host:peer_port` for a shard reached at `addr` (drops `addr`'s own
/// port).
fn peer_addr_of(addr: &str, peer_port: u16) -> Option<String> {
    if peer_port == 0 {
        return None;
    }
    let host = addr.rsplit_once(':').map(|(h, _)| h).unwrap_or(addr);
    Some(format!("{host}:{peer_port}"))
}

impl ShardCore {
    fn new(
        cfg: RemoteShardConfig,
        role: ShardRole,
        units: u32,
        slots: u32,
        peer_port: u16,
        relay_kv: Arc<KvWireCounters>,
    ) -> Self {
        let peer_addr = peer_addr_of(&cfg.addr, peer_port);
        let epoch = cfg.epoch;
        ShardCore {
            cfg,
            conn: Mutex::new(None),
            alive: AtomicBool::new(true),
            rtt_us: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            epoch,
            ping_nonce: AtomicU64::new(1),
            last_stats_req_us: AtomicU64::new(0),
            role,
            units,
            slots,
            peer_addr: Mutex::new(peer_addr),
            relay_kv,
        }
    }

    fn handle(&self) -> Option<ConnHandle> {
        self.conn.lock().unwrap().clone()
    }

    /// Throttled engine-truth gauge poll: at most one `StatsRequest` per
    /// shard per second, no matter how many sibling units ask.
    fn request_stats(&self) {
        const MIN_GAP_US: u64 = 1_000_000;
        let now = self.now_us();
        let last = self.last_stats_req_us.load(Ordering::Relaxed);
        if now.saturating_sub(last) < MIN_GAP_US {
            return;
        }
        if self
            .last_stats_req_us
            .compare_exchange(last, now, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
        {
            // Priority lane: a stats poll must not wait out a KV backlog.
            if let Some(h) = self.handle() {
                let _ = h.enqueue_priority(proto::frame_bytes_on(
                    STREAM_CONTROL,
                    &Frame::StatsRequest,
                ));
            }
        }
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn on_pong(&self, t_us: u64) {
        let rtt = self.now_us().saturating_sub(t_us).max(1);
        self.rtt_us.store(rtt, Ordering::Relaxed);
    }

    fn rtt_ms(&self) -> Option<f64> {
        match self.rtt_us.load(Ordering::Relaxed) {
            0 => None,
            us => Some(us as f64 / 1e3),
        }
    }

    /// Queue pre-encoded wire bytes on `stream`'s bulk lane. Fails when
    /// the shard is disconnected or the backlog is over the cap — the
    /// caller unwinds its registration and hands the job back.
    fn send_wire(&self, stream: StreamId, bytes: Vec<u8>) -> std::io::Result<()> {
        let Some(h) = self.handle() else {
            return Err(std::io::Error::new(
                std::io::ErrorKind::NotConnected,
                "shard disconnected",
            ));
        };
        h.enqueue(stream, bytes)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::WouldBlock, e.to_string()))
    }

    /// Encode + queue one control frame (cold paths: dispatch batches,
    /// Stop).
    fn send_frame(&self, f: &Frame) -> std::io::Result<()> {
        self.send_wire(STREAM_CONTROL, proto::frame_bytes_on(STREAM_CONTROL, f))
    }

    /// First unit to stop speaks for the whole shard: ask it to drain.
    /// The Stop rides the bulk lane, behind any already-queued work.
    fn stop_shard(&self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        let _ = self.send_frame(&Frame::Stop);
    }

    /// Close the connection without `Frame::Stop`: the shard sees EOF,
    /// aborts nothing it still owes (we own no sequences at drain) and
    /// goes back to accepting — ready for the next scheduler.
    fn detach_shard(&self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        if let Some(h) = self.conn.lock().unwrap().take() {
            h.close("detached");
        }
    }
}

/// Per-role shard state: the shared connection core plus the pending
/// table of in-flight request ids (`P` is the per-id payload — decode
/// keeps the scheduler metrics, prefill additionally assembles KV).
struct ShardState<P> {
    core: ShardCore,
    pending: Mutex<HashMap<u64, P>>,
}

type DecodeShard = ShardState<RequestMetrics>;
type PrefillShard = ShardState<PrefillPending>;

/// One dispatched-but-unfinished prefill job on the scheduler side: the
/// scheduler-clock state that never crosses the wire, plus the KV halves
/// being assembled from the shard's `KvSegment` stream.
struct PrefillPending {
    max_new: u32,
    class: SloClass,
    metrics: RequestMetrics,
    k: Vec<f32>,
    v: Vec<f32>,
}

fn resolve(addr: &str) -> Result<std::net::SocketAddr> {
    addr.to_socket_addrs()
        .with_context(|| format!("resolving shard address {addr}"))?
        .next()
        .ok_or_else(|| anyhow!("shard address {addr} resolved to nothing"))
}

/// Connect, exchange `Hello`/`HelloAck`, verify the advertised role and
/// echoed codec, and return the ready stream plus the advertised shape
/// (`units`, `slots`, `peer_port`). Blocking — runs on the connecting
/// thread (startup or a transient reconnect thread), never on the
/// driver loop.
fn connect_and_handshake(
    cfg: &RemoteShardConfig,
    want: ShardRole,
) -> Result<(TcpStream, u32, u32, u16)> {
    let sockaddr = resolve(&cfg.addr)?;
    let conn = TcpStream::connect_timeout(&sockaddr, cfg.connect_timeout)
        .with_context(|| format!("connecting to shard {}", cfg.addr))?;
    conn.set_nodelay(true)?;
    conn.set_read_timeout(Some(cfg.read_tick))?;
    conn.set_write_timeout(Some(cfg.connect_timeout))?;
    let mut w = conn.try_clone()?;
    proto::write_frame(
        &mut w,
        &Frame::Hello {
            version: PROTO_VERSION,
            kv_wire: cfg.kv_wire,
        },
    )?;
    let mut reader = FrameReader::new();
    let mut r = conn.try_clone()?;
    let deadline = Instant::now() + cfg.connect_timeout;
    loop {
        match reader.poll(&mut r) {
            Ok(Some(Frame::HelloAck {
                version,
                role,
                units,
                slots,
                kv_wire,
                peer_port,
            })) => {
                if version != PROTO_VERSION {
                    return Err(anyhow!(
                        "shard {} speaks protocol v{version}, we speak v{PROTO_VERSION}",
                        cfg.addr
                    ));
                }
                if role != want {
                    return Err(anyhow!(
                        "shard {} serves {} units, but this pool needs {} units",
                        cfg.addr,
                        role.name(),
                        want.name()
                    ));
                }
                if kv_wire != cfg.kv_wire {
                    // A shard producing a different codec than negotiated
                    // would silently skew the byte accounting; refuse.
                    return Err(anyhow!(
                        "shard {} kv-wire codec mismatch: we asked for {}, it acked {}",
                        cfg.addr,
                        cfg.kv_wire.name(),
                        kv_wire.name()
                    ));
                }
                if units == 0 {
                    return Err(anyhow!("shard {} advertises zero units", cfg.addr));
                }
                if slots == 0 {
                    // A zero-slot unit could never admit: every placement
                    // would pend forever with no terminal event.
                    return Err(anyhow!("shard {} advertises zero slots", cfg.addr));
                }
                return Ok((conn, units, slots, peer_port));
            }
            // A reconnecting shard may flush stale events first; skip
            // them (but still within the handshake deadline — a peer
            // streaming non-HelloAck frames must not pin us forever).
            Ok(Some(_)) | Ok(None) => {
                if Instant::now() >= deadline {
                    return Err(anyhow!("shard {} handshake timed out", cfg.addr));
                }
            }
            Err(e) => return Err(anyhow!("shard {} handshake failed: {e}", cfg.addr)),
        }
    }
}

/// Role-specific half of the shared connection handler: frame delivery
/// and eviction against the role's pending table and sinks. `wire_len`
/// is the frame's full on-wire size (header included) — what the KV
/// byte accounting charges for KV-bearing frames.
trait SchedPeer: Send + Sized + 'static {
    fn core(&self) -> &ShardCore;
    fn on_frame(&self, frame: Frame, wire_len: u64);
    /// Drain the pending table and deliver the evicted ids upstream.
    /// Called only after the core is marked dead and the handle cleared
    /// (see the failure semantics in the module docs).
    fn on_death(&self);
    /// Register this peer's connection with the driver and publish the
    /// resulting handle in the core (consumes `self` into the handler).
    fn attach(self, conn: TcpStream) -> std::io::Result<()>;
}

/// Register `conn` with the global driver and publish the handle.
///
/// The handle is published *after* `add` (it does not exist earlier),
/// so an immediately-dying connection can race: `on_close` clears the
/// slot and this then stores a stale-but-closed handle with
/// `alive = true`. Benign — every enqueue on it fails `Closed` (so
/// admits unwind themselves), and the reconnect already spawned by
/// `on_close` overwrites both fields when it lands.
fn attach_shared<P: SchedPeer, T>(
    peer: P,
    shard: Arc<ShardState<T>>,
    conn: TcpStream,
) -> std::io::Result<()> {
    let opts = ConnOptions {
        cap: OUTBOUND_CAP,
        stall_after: shard.core.cfg.connect_timeout,
    };
    // Backdate the ping timer so the first tick pings immediately: the
    // shard's trace clock alignment (and first RTT sample) should not
    // wait out a full ping interval after every (re)connect.
    let backdated = Instant::now()
        .checked_sub(shard.core.cfg.ping_interval)
        .unwrap_or_else(Instant::now);
    let handler = SchedHandler {
        peer: Some(peer),
        last_consumed: 0,
        last_activity: Instant::now(),
        last_ping: backdated,
    };
    let handle = NetDriver::global().add(conn, Box::new(handler), opts)?;
    *shard.core.conn.lock().unwrap() = Some(handle);
    shard.core.alive.store(true, Ordering::SeqCst);
    Ok(())
}

/// Driver-side handler shared by both roles: deliver events, heartbeat
/// on the priority lane, watch for silence, and on death evict +
/// reconnect (see module docs). Owns the role peer; hands it to a
/// transient reconnect thread when the connection dies.
struct SchedHandler<P: SchedPeer> {
    peer: Option<P>,
    last_consumed: u64,
    last_activity: Instant,
    last_ping: Instant,
}

impl<P: SchedPeer> ConnHandler for SchedHandler<P> {
    fn on_frame(&mut self, _io: &mut ConnIo<'_>, _stream: StreamId, frame: Frame, wire_len: u64) {
        self.last_activity = Instant::now();
        if let Some(peer) = &self.peer {
            peer.on_frame(frame, wire_len);
        }
    }

    fn on_tick(&mut self, io: &mut ConnIo<'_>) {
        let Some(peer) = &self.peer else { return };
        let core = peer.core();
        // Byte-granular silence guard: consumed-byte progress counts as
        // activity, so a large frame trickling in never reads as
        // silence (same contract as the old IdleGuard).
        if io.consumed() != self.last_consumed {
            self.last_consumed = io.consumed();
            self.last_activity = Instant::now();
        }
        if self.last_activity.elapsed() >= core.cfg.dead_after {
            log::warn!(
                "shard {}: no frames for {:?} (pings unanswered); declaring dead",
                core.cfg.addr,
                core.cfg.dead_after
            );
            io.close();
            return;
        }
        // Heartbeat every ping interval, busy or idle, on the priority
        // lane: a bulk KV backlog cannot starve liveness (the fix for
        // the old try_lock-skip path, which dropped pings for as long
        // as a writer stayed saturated).
        if self.last_ping.elapsed() >= core.cfg.ping_interval {
            self.last_ping = Instant::now();
            let ping = Frame::Ping {
                nonce: core.ping_nonce.fetch_add(1, Ordering::Relaxed),
                t_us: core.now_us(),
            };
            io.enqueue_priority(proto::frame_bytes_on(STREAM_CONTROL, &ping));
        }
    }

    fn on_close(&mut self, reason: &str) {
        let Some(peer) = self.peer.take() else { return };
        let core = peer.core();
        let addr = core.cfg.addr.clone();
        // Order matters: mark unplaceable and clear the handle *first*,
        // then evict — a registration that races this either lands
        // before the eviction sweep (and is evicted) or fails its
        // enqueue and unwinds itself.
        core.alive.store(false, Ordering::SeqCst);
        *core.conn.lock().unwrap() = None;
        peer.on_death();
        if core.stop.load(Ordering::SeqCst) {
            return;
        }
        log::info!("shard {addr}: connection lost ({reason}); reconnecting");
        // Reconnect on a transient thread: the blocking
        // connect/handshake must not stall the driver loop serving
        // every other shard.
        std::thread::spawn(move || reconnect_loop(peer));
    }
}

/// Retry connect/handshake with backoff until the shard returns (with
/// its original shape) or the cluster stops.
fn reconnect_loop<P: SchedPeer>(mut peer: P) {
    loop {
        std::thread::sleep(peer.core().cfg.reconnect_backoff);
        if peer.core().stop.load(Ordering::SeqCst) {
            return;
        }
        let addr = peer.core().cfg.addr.clone();
        match connect_and_handshake(&peer.core().cfg, peer.core().role) {
            Ok((conn, units, slots, peer_port)) => {
                // The scheduler's pool was sized to the original shape;
                // a replacement with a different one would leave phantom
                // units that it rejects every placement for. Refuse it
                // and keep retrying (the shard stays visibly dead in the
                // gauges).
                if units != peer.core().units || slots != peer.core().slots {
                    log::error!(
                        "shard {addr}: replacement advertises {units}×{slots} but the \
                         pool was built for {}×{}; refusing to rejoin",
                        peer.core().units,
                        peer.core().slots
                    );
                    continue;
                }
                // A replacement process rebinds its peer listener, so
                // direct targets must track the fresh port.
                *peer.core().peer_addr.lock().unwrap() =
                    peer_addr_of(&peer.core().cfg.addr, peer_port);
                log::info!(
                    "shard {addr}: reconnected ({units} {} units)",
                    peer.core().role.name()
                );
                match peer.attach(conn) {
                    Ok(()) => return,
                    Err(e) => {
                        log::warn!("shard {addr}: attach after reconnect failed: {e}");
                        return;
                    }
                }
            }
            Err(e) => log::debug!("shard {addr}: reconnect attempt failed: {e:#}"),
        }
    }
}

// ---- decode shards -----------------------------------------------------

struct DecodePeer {
    shard: Arc<DecodeShard>,
    sinks: ShardSinks,
    /// KV halves being reassembled from `KvSegment` streams that precede
    /// a `MigrateAck` — the rescue-migration return path. Keyed by
    /// request id; an entry exists only between the first segment and
    /// the ack (or death, which drops the partial assembly with the
    /// sequence it belonged to).
    migrating: Mutex<HashMap<u64, (Vec<f32>, Vec<f32>)>>,
}

impl SchedPeer for DecodePeer {
    fn core(&self) -> &ShardCore {
        &self.shard.core
    }

    fn on_frame(&self, frame: Frame, wire_len: u64) {
        match frame {
            Frame::Token { id, index, token } => {
                // Gate on the pending table: a stale id (evicted, or
                // left over from a connection this scheduler never
                // owned) must not produce upstream events. Direct
                // pre-placements are registered here at dispatch time,
                // so a direct sequence's stream (index 0 from the peer
                // commit onward) passes the same gate.
                if self.shard.pending.lock().unwrap().contains_key(&id) {
                    (self.sinks.on_token)(id, index, token);
                }
            }
            Frame::Done { id, tokens } => {
                let metrics = self.shard.pending.lock().unwrap().remove(&id);
                if let Some(m) = metrics {
                    (self.sinks.on_done)(id, tokens, m);
                }
            }
            Frame::Rejected { id } => {
                if self.shard.pending.lock().unwrap().remove(&id).is_some() {
                    (self.sinks.on_rejected)(id);
                }
            }
            Frame::KvSegment {
                id,
                half,
                offset,
                total,
                data,
            } => {
                // A decode shard streams KV back only ahead of a
                // `MigrateAck`: the extracted sequence's prompt caches
                // returning to the scheduler for re-placement. Gate on
                // the pending table so a stale stream cannot allocate.
                if !self.shard.pending.lock().unwrap().contains_key(&id) {
                    return;
                }
                self.shard
                    .core
                    .relay_kv
                    .record(wire_len, 4 * data.len() as u64);
                let failed = {
                    let mut m = self.migrating.lock().unwrap();
                    let (k, v) = m.entry(id).or_default();
                    proto::apply_kv_segment(k, v, half, offset, total, &data).err()
                };
                if let Some(why) = failed {
                    // A corrupt segment makes the extraction unusable;
                    // drop the assembly and let the MigrateAck hand the
                    // scheduler a KV-less extraction it will terminalize.
                    log::warn!(
                        "shard {}: malformed migration KV segment for job {id} ({why})",
                        self.shard.core.cfg.addr,
                    );
                    self.migrating.lock().unwrap().remove(&id);
                }
            }
            Frame::MigrateAck {
                id,
                found,
                kv_len,
                remaining,
                tokens,
            } => {
                let assembly = self.migrating.lock().unwrap().remove(&id);
                if found {
                    // The sequence left the shard; its pending entry
                    // carries the scheduler-clock metrics the re-placed
                    // sequence keeps. A raced `Done` already removed it
                    // — then the sequence finished before extraction and
                    // there is nothing to move.
                    let metrics = self.shard.pending.lock().unwrap().remove(&id);
                    if let Some(metrics) = metrics {
                        let (k, v) = assembly.unwrap_or_default();
                        (self.sinks.on_migrated)(
                            id,
                            Some(ExtractedSeq {
                                tokens,
                                remaining,
                                kv_len,
                                k,
                                v,
                                metrics,
                            }),
                        );
                    }
                } else if self.shard.pending.lock().unwrap().contains_key(&id) {
                    // Extraction failed shard-side (unknown unit, seq
                    // already gone): tell the scheduler so it stops
                    // waiting for the move; the sequence stays resident.
                    (self.sinks.on_migrated)(id, None);
                }
            }
            Frame::StatsReply {
                units,
                kv_wire_bytes,
                kv_raw_bytes,
            } => (self.sinks.on_stats)(units, kv_wire_bytes, kv_raw_bytes),
            Frame::TraceSpans { dropped, marks } => (self.sinks.on_trace)(dropped, marks),
            Frame::Pong { t_us, .. } => self.shard.core.on_pong(t_us),
            Frame::Bye => {
                // Clean shutdown acknowledgement; the close follows as EOF.
            }
            // The rest are informational or belong to the prefill role.
            _ => {}
        }
    }

    fn on_death(&self) {
        // Partial migration assemblies die with the connection: the ids
        // they belong to are evicted below, and a reconnected shard
        // starts clean.
        self.migrating.lock().unwrap().clear();
        let resident: Vec<u64> = {
            let mut p = self.shard.pending.lock().unwrap();
            p.drain().map(|(id, _)| id).collect()
        };
        if !resident.is_empty() {
            log::warn!(
                "shard {} died with {} resident sequences; evicting",
                self.shard.core.cfg.addr,
                resident.len()
            );
            (self.sinks.on_evicted)(resident);
        }
    }

    fn attach(self, conn: TcpStream) -> std::io::Result<()> {
        let shard = Arc::clone(&self.shard);
        attach_shared(self, shard, conn)
    }
}

/// Connect to a decode shard and return one [`RemoteUnit`] transport per
/// DP unit it serves. Fails fast if the shard is unreachable at startup;
/// after that, drops are handled by evict-and-reconnect (module docs).
/// `relay_kv` is the cluster-wide relay-path KV accounting (what the
/// scheduler itself puts on the wire in `Admit` frames).
pub fn connect_shard(
    cfg: RemoteShardConfig,
    sinks: ShardSinks,
    relay_kv: Arc<KvWireCounters>,
) -> Result<Vec<RemoteUnit>> {
    let (conn, units, slots, peer_port) = connect_and_handshake(&cfg, ShardRole::Decode)?;
    let shard = Arc::new(ShardState {
        core: ShardCore::new(cfg, ShardRole::Decode, units, slots, peer_port, relay_kv),
        pending: Mutex::new(HashMap::new()),
    });
    let peer = DecodePeer {
        shard: shard.clone(),
        sinks,
        migrating: Mutex::new(HashMap::new()),
    };
    peer.attach(conn)?;
    Ok((0..units)
        .map(|u| RemoteUnit {
            shard: shard.clone(),
            unit: u,
            slots,
            wbuf: Vec::new(),
        })
        .collect())
}

/// Transport for one DP unit of a remote decode shard (shares the
/// shard's connection, liveness and RTT with its sibling units).
pub struct RemoteUnit {
    shard: Arc<DecodeShard>,
    unit: u32,
    slots: u32,
    /// Wire buffer for borrow-encoded `Admit` frames: KV is serialized
    /// straight from the prefill outcome (no intermediate copies), then
    /// the buffer's ownership passes to the outbound queue — one
    /// allocation per admit, zero extra copies.
    wbuf: Vec<u8>,
}

impl DecodeTransport for RemoteUnit {
    fn label(&self) -> String {
        format!("{}#{}", self.shard.core.cfg.addr, self.unit)
    }

    fn alive(&self) -> bool {
        self.shard.core.alive.load(Ordering::SeqCst)
    }

    fn rtt_ms(&self) -> Option<f64> {
        self.shard.core.rtt_ms()
    }

    fn slots(&self) -> u32 {
        self.slots
    }

    fn admit(&mut self, job: AdmitJob) -> Result<(), AdmitJob> {
        let codec = self.shard.core.cfg.kv_wire;
        // Refuse frames the receiver would reject as oversized: sending
        // one would cost the whole connection (and every resident
        // sequence on the shard), not just this job.
        let bound = proto::admit_payload_bound(
            codec,
            job.resume.len(),
            job.outcome.k.len(),
            job.outcome.v.len(),
        );
        if bound > proto::MAX_FRAME as u64 {
            log::warn!(
                "shard {}: admit for job {} (~{bound} B) exceeds the frame limit; refusing",
                self.shard.core.cfg.addr,
                job.id
            );
            return Err(job);
        }
        if !self.alive() {
            return Err(job);
        }
        // Register before queueing: a fast Done can only arrive after
        // the frame lands, and an eviction sweeping the table will
        // include this id if the shard dies with the frame still queued
        // (a failed enqueue removes it again below — double release is
        // guarded upstream).
        self.shard
            .pending
            .lock()
            .unwrap()
            .insert(job.id, job.metrics);
        // Each admit rides its own stream, so concurrent bulk frames
        // round-robin on the wire instead of serializing.
        let stream = proto::job_stream(job.id);
        proto::admit_frame_into(
            &mut self.wbuf,
            codec,
            stream,
            self.unit,
            job.id,
            job.outcome.first_token,
            job.outcome.len as u32,
            job.max_new,
            job.class,
            &job.resume,
            &job.outcome.k,
            &job.outcome.v,
        );
        let wire_len = self.wbuf.len() as u64;
        match self
            .shard
            .core
            .send_wire(stream, std::mem::take(&mut self.wbuf))
        {
            Ok(()) => {
                // Whole-frame accounting, matching the receiver side
                // (shards charge full frame lengths for KV-bearing
                // frames), so relay and shard gauges stay comparable.
                self.shard.core.relay_kv.record(
                    wire_len,
                    4 * (job.outcome.k.len() as u64 + job.outcome.v.len() as u64),
                );
                Ok(())
            }
            Err(e) => {
                self.shard.pending.lock().unwrap().remove(&job.id);
                log::warn!("shard {}: admit refused: {e}", self.shard.core.cfg.addr);
                Err(job)
            }
        }
    }

    fn request_stats(&self) {
        self.shard.core.request_stats();
    }

    fn extract(&mut self, id: u64) -> bool {
        if !self.alive() {
            return false;
        }
        // Control lane: a Migrate must not queue behind a KV backlog
        // bound for the same shard — the whole point is moving a
        // sequence *off* a hot unit quickly. The ack (and the KV coming
        // back) rides the job's own stream like any other admit.
        self.shard
            .core
            .send_frame(&Frame::Migrate { unit: self.unit, id })
            .is_ok()
    }

    fn direct_target(&self) -> Option<DirectTarget> {
        if !self.alive() {
            return None;
        }
        self.shard
            .core
            .peer_addr
            .lock()
            .unwrap()
            .as_ref()
            .map(|addr| DirectTarget {
                addr: addr.clone(),
                unit: self.unit,
            })
    }

    fn expect_direct(&self, id: u64, metrics: RequestMetrics) {
        self.shard.pending.lock().unwrap().insert(id, metrics);
    }

    fn cancel_direct(&self, id: u64) -> bool {
        self.shard.pending.lock().unwrap().remove(&id).is_some()
    }

    fn patch_direct(&self, id: u64, t_first: f64, exec_time: f64) {
        if let Some(m) = self.shard.pending.lock().unwrap().get_mut(&id) {
            m.t_first_token = t_first;
            m.t_exec_start = (t_first - exec_time).max(m.t_dispatch);
        }
    }

    fn stop(&mut self) {
        self.shard.core.stop_shard();
    }

    fn detach(&mut self) {
        self.shard.core.detach_shard();
    }
}

// ---- prefill shards ----------------------------------------------------

struct PrefillPeer {
    shard: Arc<PrefillShard>,
    sinks: PrefillSinks,
}

impl PrefillPeer {
    /// Drop a job whose KV stream is unusable and fail it upstream.
    fn fail_job(&self, id: u64) {
        if self.shard.pending.lock().unwrap().remove(&id).is_some() {
            (self.sinks.on_failed)(id);
        }
    }
}

impl SchedPeer for PrefillPeer {
    fn core(&self) -> &ShardCore {
        &self.shard.core
    }

    fn on_frame(&self, frame: Frame, wire_len: u64) {
        match frame {
            Frame::KvSegment {
                id,
                half,
                offset,
                total,
                data,
            } => {
                // Relay-path accounting: this KV crossed the scheduler's
                // own wire (a direct handoff never produces this frame
                // here).
                self.shard
                    .core
                    .relay_kv
                    .record(wire_len, 4 * data.len() as u64);
                let failed = {
                    let mut p = self.shard.pending.lock().unwrap();
                    let Some(entry) = p.get_mut(&id) else {
                        return; // stale id (evicted or foreign); drop
                    };
                    // The shared geometry guards: a corrupt `total` must
                    // not allocate unbounded memory (a half that size
                    // could never be re-admitted to decode anyway — the
                    // Admit frame-size guard would refuse it), so fail
                    // the job instead of buffering it.
                    proto::apply_kv_segment(
                        &mut entry.k,
                        &mut entry.v,
                        half,
                        offset,
                        total,
                        &data,
                    )
                    .err()
                };
                if let Some(why) = failed {
                    log::warn!(
                        "shard {}: malformed KV segment for job {id} ({why}); failing the job",
                        self.shard.core.cfg.addr,
                    );
                    self.fail_job(id);
                }
            }
            Frame::PrefillDone {
                id,
                first_token,
                kv_len,
                exec_time,
            } => {
                let entry = self.shard.pending.lock().unwrap().remove(&id);
                if let Some(e) = entry {
                    let outcome = PrefillOutcome {
                        first_token,
                        len: kv_len as usize,
                        k: e.k,
                        v: e.v,
                        exec_time,
                        passes: 1,
                    };
                    (self.sinks.on_prefilled)(id, Box::new(outcome), e.max_new, e.class, e.metrics);
                }
            }
            Frame::PrefillFailed { id } => self.fail_job(id),
            Frame::HandoffCommit { id, exec_time, .. } => {
                // Direct transfer committed: the KV went straight to the
                // decode shard (which acked before the prefill shard sent
                // this), so the job leaves the prefill pending table with
                // nothing to assemble. The decode connection carries the
                // token stream from here on.
                if self.shard.pending.lock().unwrap().remove(&id).is_some() {
                    (self.sinks.on_handoff)(id, exec_time);
                }
            }
            Frame::EndForward {
                instance,
                t_measured,
                remaining,
            } => {
                // The index crosses a trust boundary: forwarded raw it
                // would index scheduler state sized to the advertised
                // shape, so an out-of-range instance must die here.
                if instance >= self.shard.core.units {
                    log::warn!(
                        "shard {}: EndForward for unknown instance {instance} \
                         (shard advertised {}); dropping",
                        self.shard.core.cfg.addr,
                        self.shard.core.units
                    );
                    return;
                }
                (self.sinks.on_end_forward)(instance, t_measured, remaining)
            }
            Frame::TraceSpans { dropped, marks } => (self.sinks.on_trace)(dropped, marks),
            Frame::Pong { t_us, .. } => self.shard.core.on_pong(t_us),
            Frame::Bye => {}
            _ => {}
        }
    }

    fn on_death(&self) {
        let queued: Vec<u64> = {
            let mut p = self.shard.pending.lock().unwrap();
            p.drain().map(|(id, _)| id).collect()
        };
        if !queued.is_empty() {
            log::warn!(
                "prefill shard {} died with {} jobs in flight; rejecting them",
                self.shard.core.cfg.addr,
                queued.len()
            );
            (self.sinks.on_evicted)(queued);
        }
    }

    fn attach(self, conn: TcpStream) -> std::io::Result<()> {
        let shard = Arc::clone(&self.shard);
        attach_shared(self, shard, conn)
    }
}

/// Connect to a prefill shard and return one [`RemotePrefill`] transport
/// per instance it serves. Same startup/reconnect/eviction semantics as
/// [`connect_shard`].
pub fn connect_prefill_shard(
    cfg: RemoteShardConfig,
    sinks: PrefillSinks,
    relay_kv: Arc<KvWireCounters>,
) -> Result<Vec<RemotePrefill>> {
    let (conn, units, slots, peer_port) = connect_and_handshake(&cfg, ShardRole::Prefill)?;
    let shard = Arc::new(ShardState {
        core: ShardCore::new(cfg, ShardRole::Prefill, units, slots, peer_port, relay_kv),
        pending: Mutex::new(HashMap::new()),
    });
    let peer = PrefillPeer {
        shard: shard.clone(),
        sinks,
    };
    peer.attach(conn)?;
    Ok((0..units)
        .map(|u| RemotePrefill {
            shard: shard.clone(),
            unit: u,
        })
        .collect())
}

/// Transport for one instance of a remote prefill shard (shares the
/// shard's connection, liveness and RTT with its sibling instances).
pub struct RemotePrefill {
    shard: Arc<PrefillShard>,
    unit: u32,
}

impl PrefillTransport for RemotePrefill {
    fn label(&self) -> String {
        format!("{}#p{}", self.shard.core.cfg.addr, self.unit)
    }

    fn alive(&self) -> bool {
        self.shard.core.alive.load(Ordering::SeqCst)
    }

    fn rtt_ms(&self) -> Option<f64> {
        self.shard.core.rtt_ms()
    }

    fn dispatch(&mut self, work: Vec<PrefillWork>) -> Result<(), Vec<PrefillWork>> {
        if !self.alive() {
            return Err(work);
        }
        // Register the whole batch before queueing (same discipline as
        // decode admits: mid-flight death evicts, failed enqueue
        // unwinds).
        {
            let mut p = self.shard.pending.lock().unwrap();
            for w in &work {
                p.insert(
                    w.id,
                    PrefillPending {
                        max_new: w.max_new,
                        class: w.class,
                        metrics: w.metrics,
                        k: Vec::new(),
                        v: Vec::new(),
                    },
                );
            }
        }
        let frame = Frame::PrefillDispatch {
            unit: self.unit,
            jobs: work
                .iter()
                .map(|w| proto::PrefillJobWire {
                    id: w.id,
                    max_new: w.max_new,
                    class: w.class,
                    prompt: w.prompt.clone(),
                    target: w.target.clone(),
                })
                .collect(),
        };
        match self.shard.core.send_frame(&frame) {
            Ok(()) => Ok(()),
            Err(e) => {
                let mut p = self.shard.pending.lock().unwrap();
                for w in &work {
                    p.remove(&w.id);
                }
                drop(p);
                log::warn!(
                    "prefill shard {}: dispatch failed: {e}",
                    self.shard.core.cfg.addr
                );
                Err(work)
            }
        }
    }

    fn supports_direct(&self) -> bool {
        true
    }

    fn stop(&mut self) {
        self.shard.core.stop_shard();
    }

    fn detach(&mut self) {
        self.shard.core.detach_shard();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::proto::KvHalf;
    use std::io::{Read, Write};
    use std::net::TcpListener;
    use std::sync::atomic::AtomicU32;

    fn counting_sinks(tokens: Arc<AtomicU32>, evicted: Arc<Mutex<Vec<u64>>>) -> ShardSinks {
        ShardSinks {
            on_token: Box::new(move |_, _, _| {
                tokens.fetch_add(1, Ordering::SeqCst);
            }),
            on_done: Box::new(|_, _, _| {}),
            on_rejected: Box::new(|_| {}),
            on_evicted: Box::new(move |ids| {
                evicted.lock().unwrap().extend(ids);
            }),
            on_stats: Box::new(|_, _, _| {}),
            on_trace: Box::new(|_, _| {}),
            on_migrated: Box::new(|_, _| {}),
        }
    }

    fn admit_job(id: u64, kv_elems: usize) -> AdmitJob {
        AdmitJob {
            id,
            outcome: Box::new(PrefillOutcome {
                first_token: 65,
                len: 4,
                k: vec![0.5; kv_elems],
                v: vec![0.5; kv_elems],
                exec_time: 0.0,
                passes: 1,
            }),
            max_new: 4,
            class: SloClass::Standard,
            resume: Vec::new(),
            metrics: RequestMetrics::arrive(0.0, 4),
        }
    }

    /// The queueing replacement for the old write-under-lock
    /// regression: an `Admit` for a peer that stopped draining its
    /// socket is *queued* (the admit returns immediately), Token
    /// delivery from the same shard keeps flowing, and the write-stall
    /// guard then declares the shard dead and evicts every resident
    /// sequence — including the queued one — after which admits are
    /// refused outright.
    #[test]
    fn blocked_peer_stalls_out_and_evicts_without_delaying_tokens() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let done = Arc::new(AtomicBool::new(false));
        let shard_done = done.clone();
        let fake_shard = std::thread::spawn(move || {
            let (conn, _) = listener.accept().unwrap();
            conn.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
            let mut rd = conn.try_clone().unwrap();
            let mut reader = FrameReader::new();
            loop {
                match reader.poll(&mut rd) {
                    Ok(Some(Frame::Hello { .. })) => break,
                    Ok(_) => continue,
                    Err(e) => panic!("handshake: {e}"),
                }
            }
            let mut w = conn.try_clone().unwrap();
            proto::write_frame(
                &mut w,
                &Frame::HelloAck {
                    version: PROTO_VERSION,
                    role: ShardRole::Decode,
                    units: 1,
                    slots: 4,
                    kv_wire: KvCodec::Raw,
                    peer_port: 0,
                },
            )
            .unwrap();
            // Consume frames until the small admit for id 1 arrives,
            // then STOP reading forever: the 64 MB admit that follows
            // can never drain past the socket buffers.
            loop {
                match reader.poll(&mut rd) {
                    Ok(Some(Frame::Admit { id: 1, .. })) => break,
                    Ok(_) => continue,
                    Err(e) => panic!("waiting for admit: {e}"),
                }
            }
            // While never reading again, keep streaming tokens for the
            // resident sequence.
            let mut index = 1u32;
            while !shard_done.load(Ordering::SeqCst) {
                if proto::write_frame(&mut w, &Frame::Token { id: 1, index, token: 7 }).is_err() {
                    break;
                }
                index += 1;
                std::thread::sleep(Duration::from_millis(5));
            }
        });

        let tokens = Arc::new(AtomicU32::new(0));
        let evicted = Arc::new(Mutex::new(Vec::new()));
        let mut cfg = RemoteShardConfig::new(&addr);
        // Bounds how long the stalled backlog may sit before the shard
        // is declared dead.
        cfg.connect_timeout = Duration::from_secs(1);
        let mut units = connect_shard(
            cfg,
            counting_sinks(tokens.clone(), evicted.clone()),
            Arc::default(),
        )
        .unwrap();
        assert_eq!(units.len(), 1);
        let mut unit = units.pop().unwrap();
        unit.admit(admit_job(1, 0)).map_err(|_| ()).expect("small admit");

        // Wait for the token stream to be live before queueing the big
        // frame.
        let deadline = Instant::now() + Duration::from_secs(10);
        while tokens.load(Ordering::SeqCst) == 0 {
            assert!(Instant::now() < deadline, "no tokens before the big admit");
            std::thread::sleep(Duration::from_millis(5));
        }

        // A ~64 MB admit against a peer that stopped reading: accepted
        // into the queue immediately (no blocking write), it fills the
        // socket buffers and then sits.
        let t_admit = Instant::now();
        unit.admit(admit_job(2, 8 << 20)).map_err(|_| ()).expect("queued admit");
        assert!(
            t_admit.elapsed() < Duration::from_millis(500),
            "admit must queue, not block on the socket"
        );

        // While that backlog sits, tokens must keep arriving promptly —
        // the read path is independent of the outbound queue.
        let base = tokens.load(Ordering::SeqCst);
        let t0 = Instant::now();
        while tokens.load(Ordering::SeqCst) < base + 10 {
            assert!(
                t0.elapsed() < Duration::from_secs(2),
                "token delivery stalled behind a queued bulk write \
                 ({} tokens in {:?})",
                tokens.load(Ordering::SeqCst) - base,
                t0.elapsed()
            );
            std::thread::sleep(Duration::from_millis(5));
        }

        // The stall guard declares the shard dead (no write progress
        // for connect_timeout with bytes queued) and evicts both
        // resident ids: the streaming sequence and the queued admit.
        let deadline = Instant::now() + Duration::from_secs(15);
        loop {
            {
                let ev = evicted.lock().unwrap();
                if ev.contains(&1) && ev.contains(&2) {
                    break;
                }
            }
            assert!(
                Instant::now() < deadline,
                "stall guard never evicted the resident sequences: {:?}",
                evicted.lock().unwrap()
            );
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(!unit.alive(), "the stalled shard must read as dead");
        assert!(
            unit.admit(admit_job(3, 0)).is_err(),
            "admits against a dead shard must hand the job back"
        );

        done.store(true, Ordering::SeqCst);
        unit.detach();
        fake_shard.join().unwrap();
    }

    /// A `Read` that throttles to ~`per_read` bytes every 2 ms — a peer
    /// that drains slowly enough to keep the sender's outbound queue
    /// saturated for seconds.
    struct Throttled<R> {
        inner: R,
        per_read: usize,
    }

    impl<R: Read> Read for Throttled<R> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            std::thread::sleep(Duration::from_millis(2));
            let n = buf.len().min(self.per_read);
            self.inner.read(&mut buf[..n])
        }
    }

    /// The ping-starvation regression (satellite fix): under the old
    /// writer-lock model, RTT pings used `try_lock` and were skipped
    /// whenever a bulk write held the writer — sustained KV streaming
    /// starved liveness indefinitely. With the priority lane, pings
    /// jump the queued bulk frames: the RTT must be measured while the
    /// bulk backlog is still draining, well before the last admit
    /// reaches the shard.
    #[test]
    fn pings_outrun_a_bulk_saturated_outbound_queue() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        const ADMITS: u64 = 24;
        const ELEMS: usize = 128 * 1024; // 512 KB per half-pair frame
        let all_admits_at = Arc::new(Mutex::new(None::<Instant>));
        let admits_at = all_admits_at.clone();
        let fake_shard = std::thread::spawn(move || {
            let (conn, _) = listener.accept().unwrap();
            conn.set_read_timeout(Some(Duration::from_millis(20))).unwrap();
            let mut rd = Throttled {
                inner: conn.try_clone().unwrap(),
                per_read: 8 * 1024,
            };
            let mut w = conn.try_clone().unwrap();
            let mut reader = FrameReader::new();
            let mut seen = 0u64;
            loop {
                match reader.poll(&mut rd) {
                    Ok(Some(Frame::Hello { .. })) => {
                        proto::write_frame(
                            &mut w,
                            &Frame::HelloAck {
                                version: PROTO_VERSION,
                                role: ShardRole::Decode,
                                units: 1,
                                slots: 64,
                                kv_wire: KvCodec::Raw,
                                peer_port: 0,
                            },
                        )
                        .unwrap();
                    }
                    // Answer pings immediately: the write direction is
                    // unthrottled, only the drain of our inbound side
                    // is slow.
                    Ok(Some(Frame::Ping { nonce, t_us })) => {
                        proto::write_frame(&mut w, &Frame::Pong { nonce, t_us }).unwrap();
                    }
                    Ok(Some(Frame::Admit { .. })) => {
                        seen += 1;
                        if seen == ADMITS {
                            *admits_at.lock().unwrap() = Some(Instant::now());
                            return;
                        }
                    }
                    Ok(_) => continue,
                    Err(e) => panic!("fake shard receive: {e}"),
                }
            }
        });

        let tokens = Arc::new(AtomicU32::new(0));
        let evicted = Arc::new(Mutex::new(Vec::new()));
        let mut cfg = RemoteShardConfig::new(&addr);
        // Fast pings so several land during the ~3+ s throttled drain;
        // generous stall/death bounds so slow progress is not death.
        cfg.ping_interval = Duration::from_millis(100);
        cfg.connect_timeout = Duration::from_secs(20);
        cfg.dead_after = Duration::from_secs(30);
        let mut units = connect_shard(
            cfg,
            counting_sinks(tokens, evicted),
            Arc::default(),
        )
        .unwrap();
        let mut unit = units.pop().unwrap();

        let t0 = Instant::now();
        for id in 1..=ADMITS {
            unit.admit(admit_job(id, ELEMS)).map_err(|_| ()).expect("queued admit");
        }
        // ~24 MB through an ~4 MB/s peer: the backlog drains for
        // seconds. The RTT must be measured long before that finishes.
        let rtt_deadline = t0 + Duration::from_millis(1500);
        while unit.rtt_ms().is_none() {
            assert!(
                Instant::now() < rtt_deadline,
                "no pong during a saturated bulk drain: pings are being starved"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        let rtt_at = Instant::now();

        fake_shard.join().unwrap();
        let drained_at = all_admits_at.lock().unwrap().expect("all admits delivered");
        assert!(
            drained_at.duration_since(t0) > rtt_at.duration_since(t0),
            "test premise broken: the bulk backlog drained before the first pong"
        );
        unit.detach();
    }

    /// The KV handoff reassembly path: out-of-order, multi-chunk
    /// `KvSegment`s for both halves must assemble into the exact caches
    /// the shard serialized, committed by `PrefillDone` — and `EndForward`
    /// must surface through the sink with its backlog intact.
    #[test]
    fn prefill_client_reassembles_chunked_kv_handoff() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let k: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..600).map(|i| -(i as f32)).collect();
        let (k2, v2) = (k.clone(), v.clone());
        let fake_shard = std::thread::spawn(move || {
            let (conn, _) = listener.accept().unwrap();
            conn.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
            let mut rd = conn.try_clone().unwrap();
            let mut reader = FrameReader::new();
            loop {
                match reader.poll(&mut rd) {
                    Ok(Some(Frame::Hello { .. })) => break,
                    Ok(_) => continue,
                    Err(e) => panic!("handshake: {e}"),
                }
            }
            let mut w = conn.try_clone().unwrap();
            proto::write_frame(
                &mut w,
                &Frame::HelloAck {
                    version: PROTO_VERSION,
                    role: ShardRole::Prefill,
                    units: 2,
                    slots: 1,
                    kv_wire: KvCodec::Raw,
                    peer_port: 0,
                },
            )
            .unwrap();
            let id = loop {
                match reader.poll(&mut rd) {
                    Ok(Some(Frame::PrefillDispatch { unit, jobs })) => {
                        assert_eq!(unit, 1);
                        assert_eq!(jobs.len(), 1);
                        assert_eq!(jobs[0].prompt, vec![5; 16]);
                        break jobs[0].id;
                    }
                    Ok(_) => continue,
                    Err(e) => panic!("dispatch: {e}"),
                }
            };
            // Stream the halves chunked and *out of order* — the borrow
            // encoder producing exactly what write_frame would, on the
            // job's stream.
            let mut buf = Vec::new();
            for (half, data, cuts) in [
                (KvHalf::V, &v2, vec![0usize, 600]),
                (KvHalf::K, &k2, vec![512, 1000, 0, 512]),
            ] {
                for pair in cuts.chunks(2) {
                    let (a, b) = (pair[0], pair[1]);
                    proto::kv_segment_frame_into(
                        &mut buf,
                        KvCodec::Raw,
                        proto::job_stream(id),
                        id,
                        half,
                        a as u32,
                        data.len() as u32,
                        &data[a..b],
                    );
                    w.write_all(&buf).unwrap();
                }
            }
            proto::write_frame(
                &mut w,
                &Frame::PrefillDone {
                    id,
                    first_token: 0x41,
                    kv_len: 16,
                    exec_time: 0.25,
                },
            )
            .unwrap();
            proto::write_frame(
                &mut w,
                &Frame::EndForward {
                    instance: 1,
                    t_measured: 0.25,
                    remaining: Some(96),
                },
            )
            .unwrap();
            // Hold the connection open until the scheduler detaches.
            let mut tail = FrameReader::new();
            loop {
                match tail.poll(&mut rd) {
                    Ok(_) => continue,
                    Err(_) => break,
                }
            }
        });

        let (got_tx, got_rx) = std::sync::mpsc::channel();
        let (ef_tx, ef_rx) = std::sync::mpsc::channel();
        let sinks = PrefillSinks {
            on_prefilled: Box::new(move |id, outcome, max_new, class, _metrics| {
                let _ = got_tx.send((id, outcome, max_new, class));
            }),
            on_handoff: Box::new(|id, _| panic!("unexpected direct handoff for {id}")),
            on_failed: Box::new(|id| panic!("unexpected prefill failure for {id}")),
            on_end_forward: Box::new(move |instance, t, remaining| {
                let _ = ef_tx.send((instance, t, remaining));
            }),
            on_evicted: Box::new(|_| {}),
            on_trace: Box::new(|_, _| {}),
        };
        let relay_kv: Arc<KvWireCounters> = Arc::default();
        let mut units =
            connect_prefill_shard(RemoteShardConfig::new(&addr), sinks, relay_kv.clone()).unwrap();
        assert_eq!(units.len(), 2);
        assert_eq!(units[1].label(), format!("{addr}#p1"));
        units[1]
            .dispatch(vec![PrefillWork {
                id: 31,
                prompt: vec![5; 16],
                max_new: 7,
                class: SloClass::Interactive,
                metrics: RequestMetrics::arrive(0.0, 16),
                target: None,
            }])
            .map_err(|_| ())
            .expect("dispatch");

        let (id, outcome, max_new, class) = got_rx
            .recv_timeout(Duration::from_secs(10))
            .expect("handoff must commit");
        assert_eq!(id, 31);
        assert_eq!(max_new, 7);
        assert_eq!(class, SloClass::Interactive, "class survives the round trip");
        assert_eq!(outcome.first_token, 0x41);
        assert_eq!(outcome.len, 16);
        assert_eq!(outcome.k, k, "K half must reassemble exactly");
        assert_eq!(outcome.v, v, "V half must reassemble exactly");
        let (instance, t, remaining) = ef_rx
            .recv_timeout(Duration::from_secs(10))
            .expect("EndForward must surface");
        assert_eq!(instance, 1);
        assert!((t - 0.25).abs() < 1e-12);
        assert_eq!(remaining, Some(96), "engine backlog crosses the wire");
        let (wire, raw) = relay_kv.snapshot();
        assert_eq!(raw, 4 * (1000 + 600), "relayed KV raw bytes accounted");
        assert!(wire > raw, "raw codec wire bytes include frame overhead: {wire}");

        for u in &mut units {
            u.detach();
        }
        fake_shard.join().unwrap();
    }
}
