//! Minimal readiness poller behind the event-driven transport.
//!
//! Dependency-free by design (no `libc`, no `mio`): on Linux the
//! backend is raw `epoll` FFI, on other unixes classic `poll(2)`, and
//! on anything else a timed tick that reports every registered source
//! as ready (sockets are nonblocking, so spurious readiness degrades to
//! a bounded busy-poll, not a correctness loss).
//!
//! The API is the small slice the [`super::driver`] needs: register a
//! socket under a `u64` token with read/write interest, re-arm the
//! interest as outbound queues fill and drain, and wait for events with
//! a timeout that doubles as the driver's tick.

use std::io;
use std::time::Duration;

/// Readiness interest for one registered source. Readable interest is
/// effectively always on for the driver; writable tracks whether the
/// connection's outbound queue has bytes to drain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest {
        readable: true,
        writable: false,
    };
    pub const READ_WRITE: Interest = Interest {
        readable: true,
        writable: true,
    };
}

/// One readiness event. `closed` folds the backend's error/hangup
/// signals together: the driver reacts identically (drive the read path,
/// which surfaces the real error or EOF).
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    pub closed: bool,
}

/// Sources the poller can watch. On unix anything with a raw fd
/// qualifies; elsewhere registration is token-only (the tick backend
/// reports readiness unconditionally).
#[cfg(unix)]
pub trait Pollable: std::os::unix::io::AsRawFd {}
#[cfg(unix)]
impl<T: std::os::unix::io::AsRawFd> Pollable for T {}
#[cfg(not(unix))]
pub trait Pollable {}
#[cfg(not(unix))]
impl<T> Pollable for T {}

pub use imp::Poller;

#[cfg(target_os = "linux")]
mod imp {
    use super::{Event, Interest, Pollable};
    use std::io;
    use std::os::unix::io::AsRawFd;
    use std::time::Duration;

    // Raw epoll bindings; the kernel ABI here is stable and tiny, and
    // pulling in `libc` for five calls would be the only dependency
    // added by the whole transport layer.
    #[derive(Clone, Copy)]
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    const EPOLL_CLOEXEC: i32 = 0o2000000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLLIN: u32 = 0x1;
    const EPOLLOUT: u32 = 0x4;
    const EPOLLERR: u32 = 0x8;
    const EPOLLHUP: u32 = 0x10;
    const EPOLLRDHUP: u32 = 0x2000;

    pub struct Poller {
        ep: i32,
    }

    // The epoll fd is just an fd; the driver owns the poller on one
    // thread but construction happens elsewhere.
    unsafe impl Send for Poller {}

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let ep = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if ep < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { ep })
        }

        fn ctl(&self, op: i32, fd: i32, token: u64, interest: Interest) -> io::Result<()> {
            let mut ev = EpollEvent {
                events: mask(interest),
                data: token,
            };
            let rc = unsafe { epoll_ctl(self.ep, op, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn register(
            &mut self,
            src: &impl Pollable,
            token: u64,
            interest: Interest,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, src.as_raw_fd(), token, interest)
        }

        pub fn modify(
            &mut self,
            src: &impl Pollable,
            token: u64,
            interest: Interest,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, src.as_raw_fd(), token, interest)
        }

        pub fn deregister(&mut self, src: &impl Pollable, _token: u64) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, src.as_raw_fd(), 0, Interest::READ)
        }

        pub fn wait(&mut self, out: &mut Vec<Event>, timeout: Duration) -> io::Result<()> {
            out.clear();
            let mut buf = [EpollEvent { events: 0, data: 0 }; 64];
            let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
            let n = unsafe { epoll_wait(self.ep, buf.as_mut_ptr(), buf.len() as i32, ms) };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for ev in buf.iter().take(n as usize) {
                // Copy out of the (packed) struct before use.
                let (bits, token) = (ev.events, ev.data);
                out.push(Event {
                    token,
                    readable: bits & (EPOLLIN | EPOLLRDHUP) != 0,
                    writable: bits & EPOLLOUT != 0,
                    closed: bits & (EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0,
                });
            }
            Ok(())
        }
    }

    fn mask(interest: Interest) -> u32 {
        let mut m = EPOLLRDHUP;
        if interest.readable {
            m |= EPOLLIN;
        }
        if interest.writable {
            m |= EPOLLOUT;
        }
        m
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.ep);
            }
        }
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
mod imp {
    use super::{Event, Interest, Pollable};
    use std::collections::HashMap;
    use std::io;
    use std::os::unix::io::AsRawFd;
    use std::time::Duration;

    #[repr(C)]
    struct PollFd {
        fd: i32,
        events: i16,
        revents: i16,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: u64, timeout: i32) -> i32;
    }

    const POLLIN: i16 = 0x1;
    const POLLOUT: i16 = 0x4;
    const POLLERR: i16 = 0x8;
    const POLLHUP: i16 = 0x10;

    /// `poll(2)` backend: the registry lives here and the fd set is
    /// rebuilt per wait. O(n) per wake, fine for the handful of
    /// connections a scheduler or shard holds.
    pub struct Poller {
        registered: HashMap<u64, (i32, Interest)>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller {
                registered: HashMap::new(),
            })
        }

        pub fn register(
            &mut self,
            src: &impl Pollable,
            token: u64,
            interest: Interest,
        ) -> io::Result<()> {
            self.registered.insert(token, (src.as_raw_fd(), interest));
            Ok(())
        }

        pub fn modify(
            &mut self,
            src: &impl Pollable,
            token: u64,
            interest: Interest,
        ) -> io::Result<()> {
            self.registered.insert(token, (src.as_raw_fd(), interest));
            Ok(())
        }

        pub fn deregister(&mut self, _src: &impl Pollable, token: u64) -> io::Result<()> {
            self.registered.remove(&token);
            Ok(())
        }

        pub fn wait(&mut self, out: &mut Vec<Event>, timeout: Duration) -> io::Result<()> {
            out.clear();
            let mut fds: Vec<PollFd> = Vec::with_capacity(self.registered.len());
            let mut tokens: Vec<u64> = Vec::with_capacity(self.registered.len());
            for (&token, &(fd, interest)) in &self.registered {
                let mut events = 0i16;
                if interest.readable {
                    events |= POLLIN;
                }
                if interest.writable {
                    events |= POLLOUT;
                }
                fds.push(PollFd {
                    fd,
                    events,
                    revents: 0,
                });
                tokens.push(token);
            }
            let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
            let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as u64, ms) };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for (pfd, &token) in fds.iter().zip(&tokens) {
                let r = pfd.revents;
                if r == 0 {
                    continue;
                }
                out.push(Event {
                    token,
                    readable: r & POLLIN != 0,
                    writable: r & POLLOUT != 0,
                    closed: r & (POLLERR | POLLHUP) != 0,
                });
            }
            Ok(())
        }
    }
}

#[cfg(not(unix))]
mod imp {
    use super::{Event, Interest, Pollable};
    use std::collections::HashMap;
    use std::io;
    use std::time::Duration;

    /// Portability fallback: a timed tick that reports every registered
    /// source as ready. Sockets are nonblocking, so a spurious "ready"
    /// costs one `WouldBlock` syscall per tick — a bounded busy-poll,
    /// never a hang or a missed byte.
    pub struct Poller {
        registered: HashMap<u64, Interest>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller {
                registered: HashMap::new(),
            })
        }

        pub fn register(
            &mut self,
            _src: &impl Pollable,
            token: u64,
            interest: Interest,
        ) -> io::Result<()> {
            self.registered.insert(token, interest);
            Ok(())
        }

        pub fn modify(
            &mut self,
            _src: &impl Pollable,
            token: u64,
            interest: Interest,
        ) -> io::Result<()> {
            self.registered.insert(token, interest);
            Ok(())
        }

        pub fn deregister(&mut self, _src: &impl Pollable, token: u64) -> io::Result<()> {
            self.registered.remove(&token);
            Ok(())
        }

        pub fn wait(&mut self, out: &mut Vec<Event>, timeout: Duration) -> io::Result<()> {
            out.clear();
            std::thread::sleep(timeout.min(Duration::from_millis(10)));
            for (&token, &interest) in &self.registered {
                out.push(Event {
                    token,
                    readable: interest.readable,
                    writable: interest.writable,
                    closed: false,
                });
            }
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn reports_readability_and_honors_write_interest() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        let mut poller = Poller::new().unwrap();
        poller.register(&server, 7, Interest::READ).unwrap();

        // Nothing to read yet: a short wait returns no read event for
        // the token (the tick backend may report spurious readiness;
        // skip the emptiness assertion there).
        let mut events = Vec::new();
        #[cfg(unix)]
        {
            poller.wait(&mut events, Duration::from_millis(20)).unwrap();
            assert!(events.iter().all(|e| e.token != 7 || !e.readable || e.closed));
        }

        client.write_all(b"ping").unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        let mut saw_read = false;
        while std::time::Instant::now() < deadline && !saw_read {
            poller.wait(&mut events, Duration::from_millis(50)).unwrap();
            saw_read = events.iter().any(|e| e.token == 7 && e.readable);
        }
        assert!(saw_read, "data on the socket must surface as readable");
        let mut buf = [0u8; 4];
        (&server).read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"ping");

        // Write interest on an idle socket reports writable promptly.
        poller.modify(&server, 7, Interest::READ_WRITE).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        let mut saw_write = false;
        while std::time::Instant::now() < deadline && !saw_write {
            poller.wait(&mut events, Duration::from_millis(50)).unwrap();
            saw_write = events.iter().any(|e| e.token == 7 && e.writable);
        }
        assert!(saw_write, "an idle socket must report writable");

        poller.deregister(&server, 7).unwrap();
        poller.wait(&mut events, Duration::from_millis(20)).unwrap();
        assert!(events.iter().all(|e| e.token != 7));
    }
}
