//! The **transport subsystem**: how the scheduler thread reaches a
//! prefill instance or a decode DP unit, wherever it runs.
//!
//! PR 2 made the dispatch core transport-agnostic; this module supplies
//! the transports for both planes of the P/D-separated cluster:
//!
//! * [`DecodeTransport`] — the scheduler's handle to one decode DP unit.
//!   Placement commits go *down* through it, token/terminal events come
//!   *back* through scheduler-side [`ShardSinks`]. Implementations:
//!   [`LocalUnit`] (in-process engine thread over an `mpsc` channel;
//!   always alive, no RTT) and [`remote::RemoteUnit`] (one DP unit of an
//!   out-of-process `sbs worker --decode` shard over TCP).
//! * [`PrefillTransport`] — the scheduler's handle to one prefill
//!   instance. Staggered-trigger dispatches go *down*; first tokens, the
//!   streamed prompt-KV handoff and `EndForward` backlog feedback come
//!   *back* through [`PrefillSinks`]. Implementations: [`LocalPrefill`]
//!   (in-process worker thread) and [`remote::RemotePrefill`] (one
//!   instance of an `sbs worker --prefill` shard; the KV handoff crosses
//!   the wire as a chunked `KvSegment` stream committed by
//!   `PrefillDone`).
//!
//! Both planes ride the same length-prefixed [`proto`] frame protocol
//! with the same per-shard liveness tracking, RTT measurement and
//! reconnect/eviction semantics. The scheduler drives *mixed* pools —
//! local and remote units behind the same `DispatchCore`, the same
//! staggered trigger and the same Algorithm 3 placement — so scaling out
//! (or fully disaggregating P from D across machines) is a deployment
//! decision, not a scheduling one.

pub mod codec;
pub mod driver;
pub mod peer;
pub mod poll;
pub mod proto;
pub mod remote;

pub use codec::KvCodec;

use crate::engine::PrefillOutcome;
use crate::metrics::RequestMetrics;
use crate::scheduler::types::SloClass;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;

/// Shared KV byte accounting: coded bytes as they crossed the wire vs
/// the same payloads as raw `f32` bytes. One pair is kept per counting
/// domain (the scheduler's relay traffic; each decode shard's inbound
/// KV) and surfaced through `STATS` as the `kv_wire` gauge — the
/// observable behind the paper-level claim that compression + direct
/// transfer shrink the handoff.
#[derive(Debug, Default)]
pub struct KvWireCounters {
    /// Coded KV bytes on the wire (block headers included).
    pub wire_bytes: AtomicU64,
    /// The same KV as raw `f32` bytes (4 × elements).
    pub raw_bytes: AtomicU64,
}

impl KvWireCounters {
    /// Record one KV block (or frame) that crossed the wire.
    pub fn record(&self, wire: u64, raw: u64) {
        self.wire_bytes.fetch_add(wire, Ordering::Relaxed);
        self.raw_bytes.fetch_add(raw, Ordering::Relaxed);
    }

    /// Snapshot `(wire_bytes, raw_bytes)`.
    pub fn snapshot(&self) -> (u64, u64) {
        (
            self.wire_bytes.load(Ordering::Relaxed),
            self.raw_bytes.load(Ordering::Relaxed),
        )
    }
}

/// Parse a comma-separated shard address list (`a:p[,a:p...]`), the
/// shared grammar of `sbs serve --remote-decode` / `--remote-prefill`
/// and the example's `SBS_E2E_SHARDS` env knobs. Empty segments are
/// dropped.
pub fn parse_shard_list(s: &str) -> Vec<String> {
    s.split(',')
        .map(str::trim)
        .filter(|a| !a.is_empty())
        .map(str::to_string)
        .collect()
}

/// One prefilled sequence being committed to a decode DP unit: the
/// engine payload plus the scheduler-clock metrics that stay
/// scheduler-side (remote shards never see wall-clock state; the
/// scheduler re-stamps terminal events on receipt so all timestamps
/// share one clock).
pub struct AdmitJob {
    /// Request id.
    pub id: u64,
    /// Prefill result (first token + KV caches).
    pub outcome: Box<PrefillOutcome>,
    /// Output tokens still to generate.
    pub max_new: u32,
    /// SLO class (carried on the wire so shard-side traces see it; the
    /// decode engine itself is class-blind).
    pub class: SloClass,
    /// Already-generated tokens for a sequence re-admitted
    /// mid-generation (live migration), oldest first; empty for a fresh
    /// join. The receiver seeds its emission index past this history so
    /// the client-visible token stream stays contiguous across the move.
    pub resume: Vec<i32>,
    /// Lifecycle metrics, scheduler clock.
    pub metrics: RequestMetrics,
}

/// A decode sequence extracted mid-generation for live migration: the
/// state a destination unit needs to continue it, plus the lifecycle
/// metrics that accompany the sequence wherever it is resident.
pub struct ExtractedSeq {
    /// Every token generated so far, oldest first (first token
    /// included) — the destination's [`AdmitJob::resume`] payload.
    pub tokens: Vec<i32>,
    /// Output tokens still to generate.
    pub remaining: u32,
    /// Prompt KV rows at the original join (the destination's
    /// `outcome.len`).
    pub kv_len: u32,
    /// Prompt K caches (empty for engines without transferable KV).
    pub k: Vec<f32>,
    /// Prompt V caches.
    pub v: Vec<f32>,
    /// Lifecycle metrics, scheduler clock.
    pub metrics: RequestMetrics,
}

/// Message consumed by one decode engine runner (local worker thread or
/// shard-side unit thread).
pub enum UnitMsg {
    /// Admit a sequence into a free slot.
    Admit(AdmitJob),
    /// Drop every tracked sequence *silently* — no terminal events, and
    /// the engine slots are freed immediately. Sent by a shard when a
    /// new scheduler connection supersedes the state the old one left
    /// behind (the old scheduler already evicted and rejected those
    /// sequences on its side; their ids must not keep generating, or
    /// they could collide with the new scheduler's id space). The
    /// runner acknowledges on `ack` once the abort is applied, so the
    /// shard can fence the new connection behind it — no stale
    /// emission can slip out after the ack.
    Abort {
        /// Signalled (best-effort) after the abort has been applied.
        ack: Sender<()>,
    },
    /// Extract one resident sequence for live migration: remove it from
    /// the engine (no further emissions) and report its state through
    /// the unit's event sink — `Some` with the extracted state, `None`
    /// if the sequence already terminalized.
    Extract {
        /// Request id to extract.
        id: u64,
    },
    /// Finish active sequences, then exit.
    Stop,
}

/// The scheduler's handle to one decode DP unit. `admit` is the
/// placement-commit path; liveness and RTT feed both the admissibility
/// check (dead units are never placed onto) and the per-shard gauges.
pub trait DecodeTransport: Send {
    /// Stable display label (`local:<i>` or `<addr>#<unit>`).
    fn label(&self) -> String;
    /// Whether the unit can currently receive placements.
    fn alive(&self) -> bool;
    /// Last measured round-trip time, if this transport crosses a wire.
    fn rtt_ms(&self) -> Option<f64>;
    /// Decode slots on this unit (its engine batch size).
    fn slots(&self) -> u32;
    /// Commit one placement. On failure the job is handed back so the
    /// caller can terminalize it (release the ledger, reject upstream).
    fn admit(&mut self, job: AdmitJob) -> Result<(), AdmitJob>;
    /// Ask the unit's shard for its engine-truth occupancy gauges
    /// (`StatsRequest`); the `StatsReply` comes back through
    /// [`ShardSinks::on_stats`] as the cross-check against the
    /// scheduler's own ledger. No-op for in-process units — the ledger
    /// *is* their engine truth.
    fn request_stats(&self) {}
    /// Direct-transfer address of this unit (`host:peer_port` + the
    /// shard-local unit index), when its shard runs a peer listener.
    /// `None` for in-process units — a local pool has no wire to skip.
    fn direct_target(&self) -> Option<proto::DirectTarget> {
        None
    }
    /// Register a sequence the scheduler pre-placed onto this unit for
    /// direct transfer: tokens/terminals for `id` may start arriving
    /// from the shard the moment the prefill peer commits, so the
    /// pending gate must know the id *before* dispatch leaves.
    fn expect_direct(&self, _id: u64, _metrics: RequestMetrics) {}
    /// Un-register a direct pre-placement that will not happen (relay
    /// fallback, prefill death, failed dispatch). Returns whether the
    /// registration was still present.
    fn cancel_direct(&self, _id: u64) -> bool {
        false
    }
    /// Stamp first-token metrics onto a direct registration once its
    /// `HandoffCommit` surfaces (no-op if the sequence already
    /// terminalized).
    fn patch_direct(&self, _id: u64, _t_first: f64, _exec_time: f64) {}
    /// Ask the unit to extract a resident sequence for live migration.
    /// Returns whether the request was delivered; the extraction result
    /// arrives asynchronously through the unit's event path (the local
    /// sink's `extracted`, or [`ShardSinks::on_migrated`] for remote
    /// shards). `false` (the default) means this transport cannot
    /// migrate — the caller must not wait for a result.
    fn extract(&mut self, _id: u64) -> bool {
        false
    }
    /// Ask the unit (and its shard, once per shard) to drain and stop.
    fn stop(&mut self);
    /// Release the unit without stopping its backing process: an
    /// in-process worker still stops (its thread must exit with the
    /// cluster), but a remote shard is merely disconnected, left running
    /// for a future scheduler. Defaults to [`DecodeTransport::stop`].
    fn detach(&mut self) {
        self.stop();
    }
}

/// In-process transport: one decode worker thread behind an `mpsc`
/// channel. Alive as long as the thread holds its receiver.
pub struct LocalUnit {
    label: String,
    tx: Sender<UnitMsg>,
    slots: u32,
    dead: bool,
}

impl LocalUnit {
    /// Wrap a worker thread's channel as a transport.
    pub fn new(index: u32, tx: Sender<UnitMsg>, slots: u32) -> Self {
        LocalUnit {
            label: format!("local:{index}"),
            tx,
            slots,
            dead: false,
        }
    }
}

impl DecodeTransport for LocalUnit {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn alive(&self) -> bool {
        !self.dead
    }

    fn rtt_ms(&self) -> Option<f64> {
        None
    }

    fn slots(&self) -> u32 {
        self.slots
    }

    fn admit(&mut self, job: AdmitJob) -> Result<(), AdmitJob> {
        match self.tx.send(UnitMsg::Admit(job)) {
            Ok(()) => Ok(()),
            Err(e) => {
                // The worker thread is gone; stop placing onto it.
                self.dead = true;
                match e.0 {
                    UnitMsg::Admit(job) => Err(job),
                    _ => unreachable!("send payload is the admit we passed"),
                }
            }
        }
    }

    fn extract(&mut self, id: u64) -> bool {
        self.tx.send(UnitMsg::Extract { id }).is_ok()
    }

    fn stop(&mut self) {
        let _ = self.tx.send(UnitMsg::Stop);
    }
}

/// Scheduler-side event sinks a remote shard client delivers into.
/// Invoked from one thread at a time (the net-driver loop, or the
/// shard's transient reconnect thread after a drop), hence `Send`
/// without `Sync`. The cluster fabric builds these over its private
/// router/scheduler channels; the transport layer stays ignorant of
/// those types.
pub struct ShardSinks {
    /// One generated token: `(id, index, token)`.
    pub on_token: Box<dyn Fn(u64, u32, i32) + Send>,
    /// Terminal success: `(id, generation tokens, metrics)` — the
    /// metrics the scheduler attached at admit time, handed back for
    /// final stamping on the scheduler clock.
    pub on_done: Box<dyn Fn(u64, Vec<i32>, RequestMetrics) + Send>,
    /// Terminal failure reported by the shard.
    pub on_rejected: Box<dyn Fn(u64) + Send>,
    /// The shard died with these sequences resident: release their
    /// ledger charges and reject them upstream.
    pub on_evicted: Box<dyn Fn(Vec<u64>) + Send>,
    /// A `StatsReply` arrived: the shard's engine-truth per-unit gauges
    /// (shard-local unit order) plus its inbound-KV wire/raw byte
    /// counters, for divergence cross-checks against the scheduler's
    /// ledger and the `kv_wire` gauge.
    pub on_stats: Box<dyn Fn(Vec<proto::UnitLoad>, u64, u64) + Send>,
    /// A `TraceSpans` batch arrived: `(shard-side shed count, marks)`.
    /// The marks are already scheduler-clock microseconds; the sink
    /// attributes them to this shard's track in the trace collector.
    pub on_trace: Box<dyn Fn(u32, Vec<crate::trace::TraceMark>) + Send>,
    /// A `MigrateAck` arrived (behind the sequence's `KvSegment`
    /// stream): `Some` with the fully-assembled extracted state, `None`
    /// when the shard reported the sequence gone (already terminal) or
    /// its KV stream was unusable — the scheduler treats `None` as a
    /// no-op rescue.
    pub on_migrated: Box<dyn Fn(u64, Option<ExtractedSeq>) + Send>,
}

/// One prefill job being dispatched to a prefill instance: the prompt
/// plus the scheduler-clock metrics that stay scheduler-side (remote
/// shards never see wall-clock instants; the scheduler stamps
/// `t_first_token` when the handoff lands, so all timestamps share one
/// clock).
pub struct PrefillWork {
    /// Request id.
    pub id: u64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Max tokens to generate (first token included).
    pub max_new: u32,
    /// SLO class (crosses the wire; rides back with the handoff so the
    /// decode-side admit keeps the class without a scheduler lookup).
    pub class: SloClass,
    /// Lifecycle metrics, scheduler clock (`t_dispatch` stamped by the
    /// scheduler before dispatch).
    pub metrics: RequestMetrics,
    /// Direct-transfer placement (the decode unit the scheduler
    /// pre-placed this job onto); `None` = relay the KV handoff through
    /// the scheduler.
    pub target: Option<proto::DirectTarget>,
}

/// Message consumed by one prefill engine runner (local worker thread or
/// shard-side instance thread). Mirrors [`UnitMsg`] for the prefill
/// plane.
pub enum PrefillMsg {
    /// Prefill this batch, in order.
    Work(Vec<PrefillWork>),
    /// Drop every queued job *silently* — no terminal events. Sent by a
    /// shard when a new scheduler connection supersedes the old one's
    /// state (which that scheduler already evicted); acknowledged on
    /// `ack` once applied, so the shard can fence the new connection
    /// behind it. One engine prefill bounds how long the runner takes to
    /// observe it.
    Abort {
        /// Signalled (best-effort) after the abort has been applied.
        ack: Sender<()>,
    },
    /// Finish queued jobs, then exit.
    Stop,
}

/// The scheduler's handle to one prefill instance — the prefill-plane
/// sibling of [`DecodeTransport`]. `dispatch` carries one staggered
/// batch; liveness and RTT feed the readiness gates and the per-shard
/// gauges.
pub trait PrefillTransport: Send {
    /// Stable display label (`prefill:<i>` or `<addr>#p<unit>`).
    fn label(&self) -> String;
    /// Whether the instance can currently receive dispatches.
    fn alive(&self) -> bool;
    /// Last measured round-trip time, if this transport crosses a wire.
    fn rtt_ms(&self) -> Option<f64>;
    /// Ship one dispatch batch. On failure the batch is handed back so
    /// the caller can terminalize every job in it (reject upstream).
    fn dispatch(&mut self, work: Vec<PrefillWork>) -> Result<(), Vec<PrefillWork>>;
    /// Whether this instance can execute direct prefill→decode transfer
    /// (`true` only for remote shards — a local prefill's handoff is an
    /// in-process move, not a wire hop worth bypassing).
    fn supports_direct(&self) -> bool {
        false
    }
    /// Ask the instance (and its shard, once per shard) to drain and
    /// stop.
    fn stop(&mut self);
    /// Release the instance without stopping its backing process (see
    /// [`DecodeTransport::detach`]).
    fn detach(&mut self) {
        self.stop();
    }
}

/// In-process prefill transport: one worker thread behind an `mpsc`
/// channel. Alive as long as the thread holds its receiver.
pub struct LocalPrefill {
    label: String,
    tx: Sender<PrefillMsg>,
    dead: bool,
}

impl LocalPrefill {
    /// Wrap a prefill worker thread's channel as a transport.
    pub fn new(index: u32, tx: Sender<PrefillMsg>) -> Self {
        LocalPrefill {
            label: format!("prefill:{index}"),
            tx,
            dead: false,
        }
    }
}

impl PrefillTransport for LocalPrefill {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn alive(&self) -> bool {
        !self.dead
    }

    fn rtt_ms(&self) -> Option<f64> {
        None
    }

    fn dispatch(&mut self, work: Vec<PrefillWork>) -> Result<(), Vec<PrefillWork>> {
        match self.tx.send(PrefillMsg::Work(work)) {
            Ok(()) => Ok(()),
            Err(e) => {
                // The worker thread is gone; stop dispatching onto it.
                self.dead = true;
                match e.0 {
                    PrefillMsg::Work(w) => Err(w),
                    _ => unreachable!("send payload is the batch we passed"),
                }
            }
        }
    }

    fn stop(&mut self) {
        let _ = self.tx.send(PrefillMsg::Stop);
    }
}

/// Scheduler-side event sinks for one remote *prefill* shard (invoked
/// from one thread at a time, like [`ShardSinks`]). The cluster fabric
/// builds these over its private router/scheduler channels; the
/// transport layer stays ignorant of those types.
pub struct PrefillSinks {
    /// A prefill finished and its KV handoff is fully assembled:
    /// `(id, outcome, max_new, class, metrics)` — the metrics the
    /// scheduler attached at dispatch, handed back for first-token
    /// stamping on the scheduler clock.
    pub on_prefilled: Box<dyn Fn(u64, Box<PrefillOutcome>, u32, SloClass, RequestMetrics) + Send>,
    /// A direct prefill→decode handoff committed (`HandoffCommit` from
    /// the prefill shard, sent only after the decode peer acked):
    /// `(id, exec_time)`. The KV never touched the scheduler; the
    /// decode shard emits the token stream from here on.
    pub on_handoff: Box<dyn Fn(u64, f64) + Send>,
    /// Terminal prefill failure reported by the shard.
    pub on_failed: Box<dyn Fn(u64) + Send>,
    /// `EndForward` crossed the wire: `(shard-local instance, measured
    /// pass seconds, remaining backlog tokens)` — the staggered
    /// trigger's readiness + capacity feedback.
    pub on_end_forward: Box<dyn Fn(u32, f64, Option<u32>) + Send>,
    /// The shard died with these jobs queued or mid-handoff: reject them
    /// upstream so nothing leaks.
    pub on_evicted: Box<dyn Fn(Vec<u64>) + Send>,
    /// A `TraceSpans` batch arrived from the prefill shard (see
    /// [`ShardSinks::on_trace`]).
    pub on_trace: Box<dyn Fn(u32, Vec<crate::trace::TraceMark>) + Send>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn job(id: u64) -> AdmitJob {
        AdmitJob {
            id,
            outcome: Box::new(PrefillOutcome {
                first_token: 65,
                len: 4,
                k: Vec::new(),
                v: Vec::new(),
                exec_time: 0.0,
                passes: 1,
            }),
            max_new: 3,
            class: SloClass::Standard,
            resume: Vec::new(),
            metrics: RequestMetrics::arrive(0.0, 4),
        }
    }

    #[test]
    fn local_unit_delivers_and_reports_shape() {
        let (tx, rx) = channel();
        let mut t = LocalUnit::new(2, tx, 8);
        assert_eq!(t.label(), "local:2");
        assert_eq!(t.slots(), 8);
        assert!(t.alive());
        assert!(t.rtt_ms().is_none());
        t.admit(job(9)).map_err(|_| ()).unwrap();
        match rx.recv().unwrap() {
            UnitMsg::Admit(j) => assert_eq!(j.id, 9),
            _ => panic!("expected admit"),
        }
        t.stop();
        assert!(matches!(rx.recv().unwrap(), UnitMsg::Stop));
    }

    #[test]
    fn local_unit_dead_receiver_hands_job_back() {
        let (tx, rx) = channel();
        drop(rx);
        let mut t = LocalUnit::new(0, tx, 8);
        let back = t.admit(job(5)).unwrap_err();
        assert_eq!(back.id, 5);
        assert!(!t.alive(), "failed admit marks the unit dead");
    }

    fn prefill_work(id: u64) -> PrefillWork {
        PrefillWork {
            id,
            prompt: vec![7; 12],
            max_new: 4,
            class: SloClass::Standard,
            metrics: RequestMetrics::arrive(0.0, 12),
            target: None,
        }
    }

    #[test]
    fn local_prefill_delivers_and_reports_shape() {
        let (tx, rx) = channel();
        let mut t = LocalPrefill::new(1, tx);
        assert_eq!(t.label(), "prefill:1");
        assert!(t.alive());
        assert!(t.rtt_ms().is_none());
        t.dispatch(vec![prefill_work(3), prefill_work(4)])
            .map_err(|_| ())
            .unwrap();
        match rx.recv().unwrap() {
            PrefillMsg::Work(w) => {
                assert_eq!(w.iter().map(|j| j.id).collect::<Vec<_>>(), vec![3, 4]);
            }
            _ => panic!("expected work"),
        }
        t.stop();
        assert!(matches!(rx.recv().unwrap(), PrefillMsg::Stop));
    }

    #[test]
    fn local_prefill_dead_receiver_hands_batch_back() {
        let (tx, rx) = channel();
        drop(rx);
        let mut t = LocalPrefill::new(0, tx);
        let back = t.dispatch(vec![prefill_work(9)]).unwrap_err();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].id, 9);
        assert!(!t.alive(), "failed dispatch marks the instance dead");
    }
}
