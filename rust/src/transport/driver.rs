//! Event-driven connection driver: one poller thread per process.
//!
//! Every multiplexed transport connection — scheduler→shard links, the
//! prefill→decode peer links, and the decode shard's accepted peer
//! connections — registers with a [`NetDriver`], which owns the socket
//! from then on. The driver thread runs a readiness loop over the
//! hand-rolled [`super::poll::Poller`]: frames are parsed incrementally
//! with [`FrameReader`] and dispatched to the connection's
//! [`ConnHandler`]; outbound bytes go through a per-connection
//! [`OutboundQueue`] drained only when the socket reports writable.
//!
//! This replaces the old thread-per-connection blocking IO (a reader
//! thread per shard, a thread per accepted peer, writer locks with
//! `try_lock`-skip pings): per-process transport thread count is now
//! O(1) in shard count, and the queue's two-lane discipline removes the
//! two tail-latency hazards the thread model had —
//!
//! * a **priority lane** for pings/acks, so liveness frames can never
//!   starve behind a bulk KV write (the old `try_lock` path simply
//!   dropped pings while a multi-megabyte admit held the writer);
//! * **round-robin across logical streams** in the bulk lane (one frame
//!   per stream per turn), so N in-flight KV handoffs sharing one
//!   connection interleave at frame granularity instead of serializing
//!   — per-stream FIFO order is preserved, which is all the protocol
//!   requires.
//!
//! Connections die by explicit close, read error/EOF, or the
//! **write-stall guard**: a queue that stays non-empty with zero write
//! progress for `stall_after` means the peer stopped draining; the
//! driver kills the connection so its pending work can be evicted
//! (the queued-bytes soft cap bounds memory until then).

use super::proto::{Frame, FrameReader, StreamId};
use std::collections::{HashMap, VecDeque};
use std::io::{self, ErrorKind, Write};
use std::net::{TcpStream, UdpSocket};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use super::poll::{Event, Interest, Poller};

/// Driver-assigned connection id (also the poller token; 0 is the
/// waker).
pub type ConnId = u64;

const WAKER_TOKEN: u64 = 0;
/// Handler tick cadence (ping scheduling, idle checks, GC).
const TICK: Duration = Duration::from_millis(100);
/// Max frames dispatched per connection per wake, so one firehose
/// connection cannot starve the others (level-triggered readiness
/// re-reports the remainder immediately).
const MAX_FRAMES_PER_WAKE: usize = 64;

/// Per-connection tuning for [`NetDriver::add`].
#[derive(Debug, Clone, Copy)]
pub struct ConnOptions {
    /// Soft bound on queued-but-unwritten outbound bytes. The check is
    /// *admission* against the current backlog — a single frame larger
    /// than the cap is still accepted on an empty queue (the frame
    /// limit is [`super::proto::MAX_FRAME`]); the cap only refuses new
    /// work once a backlog exists.
    pub cap: u64,
    /// Kill the connection if the queue is non-empty and no byte has
    /// been written for this long.
    pub stall_after: Duration,
}

impl Default for ConnOptions {
    fn default() -> Self {
        ConnOptions {
            cap: 64 * 1024 * 1024,
            stall_after: Duration::from_secs(5),
        }
    }
}

/// Why an enqueue was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EnqueueError {
    /// The connection is closed (or closing).
    Closed,
    /// The outbound backlog exceeds the connection's soft cap.
    Full,
}

impl std::fmt::Display for EnqueueError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EnqueueError::Closed => write!(f, "connection closed"),
            EnqueueError::Full => write!(f, "outbound queue full"),
        }
    }
}

/// Callbacks for one driver-owned connection. All methods run on the
/// driver thread; keep them non-blocking (hand heavy work to channels).
pub trait ConnHandler: Send {
    /// One complete frame arrived. `wire_len` is the consumed wire
    /// bytes attributed to this frame (header included).
    fn on_frame(&mut self, io: &mut ConnIo<'_>, stream: StreamId, frame: Frame, wire_len: u64);
    /// Called roughly every [`TICK`]; drive pings, idle guards, GC.
    fn on_tick(&mut self, _io: &mut ConnIo<'_>) {}
    /// The connection died (close requested, read/write error, EOF, or
    /// write stall). The handler is dropped right after.
    fn on_close(&mut self, _reason: &str) {}
}

/// The handler's window onto its own connection during a callback.
pub struct ConnIo<'a> {
    queue: &'a mut OutboundQueue,
    consumed: u64,
    close: bool,
}

impl ConnIo<'_> {
    /// Queue one complete wire frame on a stream's bulk lane. Returns
    /// `false` (dropping the bytes) when the backlog is over the cap.
    pub fn enqueue(&mut self, stream: StreamId, bytes: Vec<u8>) -> bool {
        if self.queue.over_cap() {
            return false;
        }
        self.queue.accept(bytes.len() as u64);
        self.queue.push(stream, bytes);
        true
    }

    /// Queue one wire frame on the priority lane (pings, acks — small
    /// control frames that must never wait behind bulk KV). Never
    /// refused.
    pub fn enqueue_priority(&mut self, bytes: Vec<u8>) {
        self.queue.accept(bytes.len() as u64);
        self.queue.push_priority(bytes);
    }

    /// Total wire bytes consumed from this connection so far (the
    /// [`FrameReader::consumed`] counter — byte-granular, so idle
    /// guards see a large frame trickling in as activity).
    pub fn consumed(&self) -> u64 {
        self.consumed
    }

    /// Tear the connection down after this callback returns.
    pub fn close(&mut self) {
        self.close = true;
    }
}

/// Cloneable external handle to one driver-owned connection: the
/// scheduler's admit path and the prefill peer mux enqueue through
/// this from their own threads.
#[derive(Clone)]
pub struct ConnHandle {
    inner: Arc<DriverInner>,
    id: ConnId,
    cap: u64,
    queued: Arc<AtomicU64>,
    open: Arc<AtomicBool>,
}

impl ConnHandle {
    /// Queue one complete wire frame on a stream's bulk lane.
    pub fn enqueue(&self, stream: StreamId, bytes: Vec<u8>) -> Result<(), EnqueueError> {
        if !self.is_open() {
            return Err(EnqueueError::Closed);
        }
        if self.queued.load(Ordering::Relaxed) > self.cap {
            return Err(EnqueueError::Full);
        }
        self.send(stream, false, bytes)
    }

    /// Queue one wire frame on the priority lane. Only refused when the
    /// connection is closed.
    pub fn enqueue_priority(&self, bytes: Vec<u8>) -> Result<(), EnqueueError> {
        if !self.is_open() {
            return Err(EnqueueError::Closed);
        }
        self.send(0, true, bytes)
    }

    fn send(&self, stream: StreamId, prio: bool, bytes: Vec<u8>) -> Result<(), EnqueueError> {
        let len = bytes.len() as u64;
        self.queued.fetch_add(len, Ordering::Relaxed);
        let cmd = Cmd::Enqueue {
            id: self.id,
            stream,
            prio,
            bytes,
        };
        if self.inner.send(cmd).is_err() {
            self.queued.fetch_sub(len, Ordering::Relaxed);
            return Err(EnqueueError::Closed);
        }
        Ok(())
    }

    /// Ask the driver to tear the connection down (`on_close` fires on
    /// the driver thread).
    pub fn close(&self, reason: &str) {
        let _ = self.inner.send(Cmd::Close {
            id: self.id,
            reason: reason.to_string(),
        });
    }

    /// Whether the connection is still registered. Turns false the
    /// moment the driver tears it down, before `on_close` returns.
    pub fn is_open(&self) -> bool {
        self.open.load(Ordering::Acquire)
    }

    /// Outbound backlog gauge: accepted bytes not yet written.
    pub fn queued_bytes(&self) -> u64 {
        self.queued.load(Ordering::Relaxed)
    }
}

enum Cmd {
    Add {
        id: ConnId,
        sock: TcpStream,
        handler: Box<dyn ConnHandler>,
        opts: ConnOptions,
        queued: Arc<AtomicU64>,
        open: Arc<AtomicBool>,
    },
    Enqueue {
        id: ConnId,
        stream: StreamId,
        prio: bool,
        bytes: Vec<u8>,
    },
    Close {
        id: ConnId,
        reason: String,
    },
}

struct DriverInner {
    tx: Mutex<Sender<Cmd>>,
    waker: Arc<UdpSocket>,
    wake_pending: AtomicBool,
    next_id: AtomicU64,
}

impl DriverInner {
    fn send(&self, cmd: Cmd) -> Result<(), ()> {
        {
            let tx = self.tx.lock().unwrap();
            tx.send(cmd).map_err(|_| ())?;
        }
        // Coalesce wakes: one pending datagram is enough, and the loop
        // clears the flag *before* draining the command queue, so a
        // skipped wake can never strand a command (its send happened
        // before the flag check).
        if !self.wake_pending.swap(true, Ordering::AcqRel) {
            let _ = self.waker.send(&[1]);
        }
        Ok(())
    }
}

/// One event-loop thread multiplexing every registered connection.
/// Most callers want [`NetDriver::global`] — one driver per process
/// keeps transport threads O(1) no matter how many shards connect.
pub struct NetDriver {
    inner: Arc<DriverInner>,
}

impl NetDriver {
    /// Start a dedicated driver thread. Tests use this for isolation;
    /// production paths share [`NetDriver::global`].
    pub fn start(label: &str) -> io::Result<NetDriver> {
        let poller = Poller::new()?;
        let waker = UdpSocket::bind("127.0.0.1:0")?;
        waker.connect(waker.local_addr()?)?;
        waker.set_nonblocking(true)?;
        let waker = Arc::new(waker);
        let (tx, rx) = mpsc::channel();
        let inner = Arc::new(DriverInner {
            tx: Mutex::new(tx),
            waker: Arc::clone(&waker),
            wake_pending: AtomicBool::new(false),
            next_id: AtomicU64::new(1),
        });
        let loop_inner = Arc::clone(&inner);
        std::thread::Builder::new()
            .name(format!("net-driver-{label}"))
            .spawn(move || run_loop(poller, loop_inner, rx))?;
        Ok(NetDriver { inner })
    }

    /// The process-wide driver, started on first use. Every scheduler
    /// connection, peer link, and accepted shard-side peer in this
    /// process shares its single thread.
    pub fn global() -> &'static NetDriver {
        static GLOBAL: OnceLock<NetDriver> = OnceLock::new();
        GLOBAL.get_or_init(|| NetDriver::start("global").expect("start global net driver"))
    }

    /// Hand a connected socket to the driver. The driver owns it from
    /// here: sets it nonblocking, registers it with the poller, and
    /// routes frames/ticks to `handler` until the connection dies.
    pub fn add(
        &self,
        sock: TcpStream,
        handler: Box<dyn ConnHandler>,
        opts: ConnOptions,
    ) -> io::Result<ConnHandle> {
        sock.set_nonblocking(true)?;
        let _ = sock.set_nodelay(true);
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        let queued = Arc::new(AtomicU64::new(0));
        let open = Arc::new(AtomicBool::new(true));
        let handle = ConnHandle {
            inner: Arc::clone(&self.inner),
            id,
            cap: opts.cap,
            queued: Arc::clone(&queued),
            open: Arc::clone(&open),
        };
        self.inner
            .send(Cmd::Add {
                id,
                sock,
                handler,
                opts,
                queued,
                open,
            })
            .map_err(|_| io::Error::new(ErrorKind::BrokenPipe, "net driver stopped"))?;
        Ok(handle)
    }
}

/// Two-lane outbound queue: a priority lane for control frames and a
/// round-robin ring of per-stream FIFO lanes for bulk frames. Frames
/// are atomic on the wire (one frame fully written before the next
/// starts); interleaving happens *between* frames of different
/// streams — one frame per stream per turn.
pub struct OutboundQueue {
    prio: VecDeque<Vec<u8>>,
    ring: VecDeque<(StreamId, VecDeque<Vec<u8>>)>,
    inflight: Option<(Vec<u8>, usize)>,
    queued: Arc<AtomicU64>,
    cap: u64,
}

impl OutboundQueue {
    fn new(queued: Arc<AtomicU64>, cap: u64) -> Self {
        OutboundQueue {
            prio: VecDeque::new(),
            ring: VecDeque::new(),
            inflight: None,
            queued,
            cap,
        }
    }

    #[cfg(test)]
    fn for_test(cap: u64) -> Self {
        Self::new(Arc::new(AtomicU64::new(0)), cap)
    }

    fn is_empty(&self) -> bool {
        self.inflight.is_none() && self.prio.is_empty() && self.ring.is_empty()
    }

    fn over_cap(&self) -> bool {
        self.queued.load(Ordering::Relaxed) > self.cap
    }

    /// Record acceptance of `n` bytes in the backlog gauge. External
    /// enqueues ([`ConnHandle`]) pre-count before the command crosses
    /// the channel; handler-side enqueues count here.
    fn accept(&self, n: u64) {
        self.queued.fetch_add(n, Ordering::Relaxed);
    }

    fn push(&mut self, stream: StreamId, bytes: Vec<u8>) {
        if let Some((_, lane)) = self.ring.iter_mut().find(|(s, _)| *s == stream) {
            lane.push_back(bytes);
        } else {
            self.ring.push_back((stream, VecDeque::from([bytes])));
        }
    }

    fn push_priority(&mut self, bytes: Vec<u8>) {
        self.prio.push_back(bytes);
    }

    fn next_frame(&mut self) -> Option<Vec<u8>> {
        if let Some(b) = self.prio.pop_front() {
            return Some(b);
        }
        let (stream, mut lane) = self.ring.pop_front()?;
        let b = lane.pop_front().expect("ring lanes are never empty");
        if !lane.is_empty() {
            // Rotate to the back *after* taking one frame: that is the
            // round-robin that interleaves concurrent streams.
            self.ring.push_back((stream, lane));
        }
        Some(b)
    }

    /// Write queued frames until the sink would block or the queue is
    /// empty. Returns bytes written; partial frames stay in flight
    /// across calls, so a frame is never interleaved mid-body.
    fn drain<W: Write>(&mut self, w: &mut W) -> io::Result<u64> {
        let mut wrote = 0u64;
        loop {
            if self.inflight.is_none() {
                match self.next_frame() {
                    Some(b) => self.inflight = Some((b, 0)),
                    None => return Ok(wrote),
                }
            }
            let (buf, at) = self.inflight.as_mut().expect("inflight set above");
            match w.write(&buf[*at..]) {
                Ok(0) => {
                    return Err(io::Error::new(ErrorKind::WriteZero, "peer closed"));
                }
                Ok(n) => {
                    *at += n;
                    wrote += n as u64;
                    self.queued.fetch_sub(n as u64, Ordering::Relaxed);
                    if *at == buf.len() {
                        self.inflight = None;
                    }
                }
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                    return Ok(wrote);
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
    }
}

struct Conn {
    sock: TcpStream,
    reader: FrameReader,
    queue: OutboundQueue,
    handler: Box<dyn ConnHandler>,
    open: Arc<AtomicBool>,
    interest: Interest,
    /// Read bytes consumed but not yet attributed to a completed frame
    /// (a frame can span many wakes).
    pending_wire: u64,
    last_write_progress: Instant,
    stall_after: Duration,
}

fn run_loop(mut poller: Poller, inner: Arc<DriverInner>, rx: Receiver<Cmd>) {
    let mut conns: HashMap<ConnId, Conn> = HashMap::new();
    let mut events: Vec<Event> = Vec::new();
    let mut last_tick = Instant::now();
    if let Err(e) = poller.register(inner.waker.as_ref(), WAKER_TOKEN, Interest::READ) {
        log::error!("net driver: register waker: {e}");
        return;
    }
    loop {
        let until_tick = TICK.saturating_sub(last_tick.elapsed());
        let timeout = until_tick.max(Duration::from_millis(1));
        if let Err(e) = poller.wait(&mut events, timeout) {
            log::error!("net driver: poll: {e}");
            std::thread::sleep(Duration::from_millis(10));
            continue;
        }

        // Drain the waker before the command queue: a sender that
        // skipped its wake (flag already set) had already enqueued its
        // command, so clearing the flag first guarantees we see it.
        inner.wake_pending.store(false, Ordering::Release);
        let mut scratch = [0u8; 16];
        while inner.waker.recv(&mut scratch).is_ok() {}

        let mut dead: Vec<(ConnId, String)> = Vec::new();
        loop {
            match rx.try_recv() {
                Ok(Cmd::Add {
                    id,
                    sock,
                    handler,
                    opts,
                    queued,
                    open,
                }) => {
                    let mut conn = Conn {
                        sock,
                        reader: FrameReader::new(),
                        queue: OutboundQueue::new(queued, opts.cap),
                        handler,
                        open,
                        interest: Interest::READ,
                        pending_wire: 0,
                        last_write_progress: Instant::now(),
                        stall_after: opts.stall_after,
                    };
                    if let Err(e) = poller.register(&conn.sock, id, conn.interest) {
                        conn.open.store(false, Ordering::Release);
                        conn.handler.on_close(&format!("register: {e}"));
                        continue;
                    }
                    conns.insert(id, conn);
                }
                Ok(Cmd::Enqueue {
                    id,
                    stream,
                    prio,
                    bytes,
                }) => {
                    if let Some(conn) = conns.get_mut(&id) {
                        if conn.queue.is_empty() {
                            conn.last_write_progress = Instant::now();
                        }
                        if prio {
                            conn.queue.push_priority(bytes);
                        } else {
                            conn.queue.push(stream, bytes);
                        }
                    }
                    // Unknown id: the connection died after the sender's
                    // open check — bytes dropped, same as a death
                    // mid-write under the old blocking model.
                }
                Ok(Cmd::Close { id, reason }) => dead.push((id, reason)),
                Err(TryRecvError::Empty) => break,
                // Every sender handle dropped; the loop keeps serving
                // its registered connections until they close.
                Err(TryRecvError::Disconnected) => break,
            }
        }

        for ev in events.drain(..) {
            if ev.token == WAKER_TOKEN {
                continue;
            }
            let Some(conn) = conns.get_mut(&ev.token) else {
                continue;
            };
            if ev.writable || ev.closed {
                if let Err(reason) = drive_write(conn) {
                    dead.push((ev.token, reason));
                    continue;
                }
            }
            if ev.readable || ev.closed {
                if let Err(reason) = drive_read(conn) {
                    dead.push((ev.token, reason));
                }
            }
        }

        if last_tick.elapsed() >= TICK {
            last_tick = Instant::now();
            for (&id, conn) in conns.iter_mut() {
                let mut io = ConnIo {
                    consumed: conn.reader.consumed(),
                    queue: &mut conn.queue,
                    close: false,
                };
                conn.handler.on_tick(&mut io);
                if io.close {
                    dead.push((id, "closed by handler".to_string()));
                    continue;
                }
                if !conn.queue.is_empty()
                    && conn.last_write_progress.elapsed() > conn.stall_after
                {
                    dead.push((id, "write stalled: peer not draining".to_string()));
                }
            }
        }

        for (id, reason) in dead {
            if let Some(mut conn) = conns.remove(&id) {
                let _ = poller.deregister(&conn.sock, id);
                conn.open.store(false, Ordering::Release);
                conn.queue.queued.store(0, Ordering::Relaxed);
                let _ = conn.sock.shutdown(std::net::Shutdown::Both);
                conn.handler.on_close(&reason);
            }
        }

        for (&id, conn) in conns.iter_mut() {
            let want = if conn.queue.is_empty() {
                Interest::READ
            } else {
                Interest::READ_WRITE
            };
            if want != conn.interest {
                conn.interest = want;
                if let Err(e) = poller.modify(&conn.sock, id, want) {
                    log::warn!("net driver: rearm conn {id}: {e}");
                }
            }
        }
    }
}

fn drive_write(conn: &mut Conn) -> Result<(), String> {
    match conn.queue.drain(&mut conn.sock) {
        Ok(n) => {
            if n > 0 {
                conn.last_write_progress = Instant::now();
            }
            Ok(())
        }
        Err(e) => Err(format!("write failed: {e}")),
    }
}

fn drive_read(conn: &mut Conn) -> Result<(), String> {
    for _ in 0..MAX_FRAMES_PER_WAKE {
        let before = conn.reader.consumed();
        let polled = conn.reader.poll_stream(&mut conn.sock);
        conn.pending_wire += conn.reader.consumed() - before;
        match polled {
            Ok(Some((stream, frame))) => {
                let wire = conn.pending_wire;
                conn.pending_wire = 0;
                let mut io = ConnIo {
                    consumed: conn.reader.consumed(),
                    queue: &mut conn.queue,
                    close: false,
                };
                conn.handler.on_frame(&mut io, stream, frame, wire);
                if io.close {
                    return Err("closed by handler".to_string());
                }
            }
            Ok(None) => return Ok(()),
            Err(e) => return Err(format!("read failed: {e}")),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::proto::{frame_bytes_on, write_frame, ProtoError, STREAM_CONTROL};
    use std::net::TcpListener;

    fn ack(stream: StreamId, id: u64) -> Vec<u8> {
        frame_bytes_on(stream, &Frame::HandoffAck { id })
    }

    fn parse_all(bytes: &[u8]) -> Vec<(StreamId, Frame)> {
        let mut reader = FrameReader::new();
        let mut src = bytes;
        let mut out = Vec::new();
        loop {
            match reader.poll_stream(&mut src) {
                Ok(Some(pair)) => out.push(pair),
                Ok(None) => break,
                Err(ProtoError::Closed) => break,
                Err(e) => panic!("{e}"),
            }
        }
        out
    }

    #[test]
    fn queue_round_robins_streams_and_lets_priority_jump() {
        let mut q = OutboundQueue::for_test(u64::MAX);
        for id in [10u64, 11, 12] {
            q.accept(0);
            q.push(1, ack(1, id));
        }
        for id in [20u64, 21] {
            q.push(2, ack(2, id));
        }
        q.push_priority(ack(STREAM_CONTROL, 99));
        let mut wire = Vec::new();
        q.drain(&mut wire).unwrap();
        assert!(q.is_empty());
        let got: Vec<(StreamId, u64)> = parse_all(&wire)
            .into_iter()
            .map(|(s, f)| match f {
                Frame::HandoffAck { id } => (s, id),
                other => panic!("unexpected {other:?}"),
            })
            .collect();
        // Priority first, then one frame per stream per turn: the
        // deterministic interleave two concurrent handoffs rely on.
        assert_eq!(
            got,
            vec![(0, 99), (1, 10), (2, 20), (1, 11), (2, 21), (1, 12)]
        );
    }

    /// A sink that writes at most 3 bytes per call and inserts a
    /// `WouldBlock` between calls — the worst case a nonblocking
    /// socket can produce.
    struct Choppy {
        out: Vec<u8>,
        tick: bool,
    }

    impl Write for Choppy {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.tick = !self.tick;
            if self.tick {
                return Err(io::Error::new(ErrorKind::WouldBlock, "tick"));
            }
            let n = buf.len().min(3);
            self.out.extend_from_slice(&buf[..n]);
            Ok(n)
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn partial_writes_never_interleave_frame_bodies() {
        let mut q = OutboundQueue::for_test(u64::MAX);
        let mut expect = Vec::new();
        for id in 0..5u64 {
            let b = ack(id as u32 + 1, id);
            q.accept(b.len() as u64);
            q.push(id as u32 + 1, b);
        }
        let mut sink = Choppy {
            out: Vec::new(),
            tick: false,
        };
        while !q.is_empty() {
            q.drain(&mut sink).unwrap();
        }
        assert_eq!(q.queued.load(Ordering::Relaxed), 0, "gauge returns to zero");
        // Whatever the chop pattern, the byte stream must parse as 5
        // complete frames, one per stream, in ring order.
        for (i, (s, f)) in parse_all(&sink.out).into_iter().enumerate() {
            assert_eq!(s, i as u32 + 1);
            expect.push(f);
        }
        assert_eq!(expect.len(), 5);
    }

    struct Echo;

    impl ConnHandler for Echo {
        fn on_frame(&mut self, io: &mut ConnIo<'_>, stream: StreamId, frame: Frame, _wire: u64) {
            if let Frame::Ping { nonce, t_us } = frame {
                io.enqueue_priority(frame_bytes_on(stream, &Frame::Pong { nonce, t_us }));
            }
        }
    }

    #[test]
    fn driver_echoes_frames_end_to_end() {
        let driver = NetDriver::start("echo-test").unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        client
            .set_read_timeout(Some(Duration::from_millis(100)))
            .unwrap();
        let (server, _) = listener.accept().unwrap();
        let handle = driver.add(server, Box::new(Echo), ConnOptions::default()).unwrap();

        write_frame(&mut client, &Frame::Ping { nonce: 5, t_us: 9 }).unwrap();
        let mut reader = FrameReader::new();
        let deadline = Instant::now() + Duration::from_secs(5);
        let pong = loop {
            assert!(Instant::now() < deadline, "no pong before deadline");
            match reader.poll(&mut client) {
                Ok(Some(f)) => break f,
                Ok(None) => continue,
                Err(e) => panic!("{e}"),
            }
        };
        assert_eq!(pong, Frame::Pong { nonce: 5, t_us: 9 });

        // External enqueue path: bytes pushed through the handle reach
        // the peer too.
        handle.enqueue(3, ack(3, 77)).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            assert!(Instant::now() < deadline, "no ack before deadline");
            match reader.poll_stream(&mut client) {
                Ok(Some((3, Frame::HandoffAck { id: 77 }))) => break,
                Ok(Some(other)) => panic!("unexpected {other:?}"),
                Ok(None) => continue,
                Err(e) => panic!("{e}"),
            }
        }
        assert!(handle.is_open());
        handle.close("test done");
        let deadline = Instant::now() + Duration::from_secs(5);
        while handle.is_open() {
            assert!(Instant::now() < deadline, "close must land");
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    struct CloseProbe {
        reason: Arc<Mutex<Option<String>>>,
    }

    impl ConnHandler for CloseProbe {
        fn on_frame(&mut self, _io: &mut ConnIo<'_>, _s: StreamId, _f: Frame, _w: u64) {}
        fn on_close(&mut self, reason: &str) {
            *self.reason.lock().unwrap() = Some(reason.to_string());
        }
    }

    #[test]
    fn write_stall_kills_the_connection_and_caps_refuse_backlog() {
        let driver = NetDriver::start("stall-test").unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        // The client connects and then never reads a byte.
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        let reason = Arc::new(Mutex::new(None));
        let handle = driver
            .add(
                server,
                Box::new(CloseProbe {
                    reason: Arc::clone(&reason),
                }),
                ConnOptions {
                    cap: 1024 * 1024,
                    stall_after: Duration::from_millis(300),
                },
            )
            .unwrap();

        // A single frame far larger than kernel buffers: accepted (the
        // cap is a backlog check, not a frame-size check) but never
        // drained by the stuck peer.
        let big = frame_bytes_on(1, &Frame::Done {
            id: 1,
            tokens: vec![7; 16 * 1024 * 1024],
        });
        handle.enqueue(1, big).unwrap();
        // With megabytes already queued, further bulk frames bounce.
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            match handle.enqueue(2, ack(2, 1)) {
                Err(EnqueueError::Full) | Err(EnqueueError::Closed) => break,
                Ok(()) => {
                    assert!(Instant::now() < deadline, "cap never engaged");
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
        }
        // The stall guard fires once the stuck peer stops the drain.
        let deadline = Instant::now() + Duration::from_secs(10);
        while handle.is_open() {
            assert!(Instant::now() < deadline, "stall guard never fired");
            std::thread::sleep(Duration::from_millis(20));
        }
        let reason = reason.lock().unwrap().clone().expect("on_close ran");
        assert!(reason.contains("stall"), "unexpected close reason: {reason}");
        drop(client);
    }
}
