//! Multiplexed direct-transfer peer client: the prefill shard's side of
//! the prefill→decode KV handoff.
//!
//! A [`PeerMux`] keeps **one driver-owned connection per decode-shard
//! peer address**, shared by every prefill instance thread. Each handoff
//! rides its own [`StreamId`], so N concurrent handoffs to the same
//! decode shard interleave their `KvSegment` frames at frame granularity
//! on the shared socket (the outbound queue round-robins across streams)
//! instead of serializing behind each other — the wire-level analogue of
//! the paper's staggered buffering, and the fix for the old
//! one-connection-per-pair pool where concurrent handoffs to one shard
//! queued on a mutex.
//!
//! The per-stream FIFO guarantee is all the receiver needs: a handoff's
//! `KvSegment`s and its `HandoffCommit` share the job's stream, so the
//! commit can never overtake its own payload, while frames of *other*
//! jobs are free to land in between (the decode shard keys reassembly by
//! job id).
//!
//! Handoffs block their instance thread only on the **ack**: segments
//! and commit are enqueued without waiting, then the caller parks on a
//! per-job waiter until the decode shard's `HandoffAck` arrives, the
//! connection dies (all waiters are failed), or the ack timeout lapses —
//! every failure path surfaces as an error so the caller falls back to
//! the scheduler relay. A stale pooled connection gets one reconnect
//! before giving up, matching the old pool's semantics.

use super::driver::{ConnHandle, ConnHandler, ConnIo, ConnOptions, NetDriver};
use super::proto::{self, DirectTarget, Frame, FrameReader, StreamId, PROTO_VERSION, STREAM_CONTROL};
use super::KvCodec;
use crate::engine::PrefillOutcome;
use crate::scheduler::types::SloClass;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Ack waiters for one peer connection, shared between handoff callers
/// (insert/park) and the driver-side handler (resolve/fail).
type Waiters = Arc<Mutex<HashMap<u64, Sender<bool>>>>;

/// One live peer connection: the driver handle plus its ack waiters.
#[derive(Clone)]
struct PeerEntry {
    handle: ConnHandle,
    waiters: Waiters,
}

/// Multiplexing pool of peer connections from this prefill shard to
/// decode shards, keyed by peer address and shared by every instance
/// thread.
pub struct PeerMux {
    conns: Mutex<HashMap<String, Arc<Mutex<Option<PeerEntry>>>>>,
    /// Per-handoff stream allocator (skips [`STREAM_CONTROL`]).
    next_stream: AtomicU32,
    /// KV elements per `KvSegment` chunk (tests shrink this to force
    /// many frames per handoff).
    chunk_elems: usize,
    /// How long a handoff waits for its `HandoffAck` before falling
    /// back to relay.
    ack_timeout: Duration,
}

impl PeerMux {
    pub fn new(chunk_elems: usize, ack_timeout: Duration) -> Self {
        PeerMux {
            conns: Mutex::new(HashMap::new()),
            next_stream: AtomicU32::new(1),
            chunk_elems,
            ack_timeout,
        }
    }

    /// A fresh nonzero stream id for one handoff. Wrap-around collisions
    /// (after 2³²−1 handoffs) only cost interleaving, never correctness.
    fn alloc_stream(&self) -> StreamId {
        loop {
            let s = self.next_stream.fetch_add(1, Ordering::Relaxed);
            if s != STREAM_CONTROL {
                return s;
            }
        }
    }

    /// Get the live connection for `addr`, dialing if absent or dead.
    /// Returns `(entry, pooled)` — `pooled` is true when the entry
    /// predates this call (eligible for one reconnect retry).
    fn entry(&self, addr: &str, codec: KvCodec) -> Result<(PeerEntry, bool)> {
        let slot = {
            let mut conns = self.conns.lock().unwrap();
            conns.entry(addr.to_string()).or_default().clone()
        };
        let mut slot = slot.lock().unwrap();
        if let Some(e) = slot.as_ref() {
            if e.handle.is_open() {
                return Ok((e.clone(), true));
            }
        }
        let e = Self::connect(addr, codec)?;
        *slot = Some(e.clone());
        Ok((e, false))
    }

    /// Drop `entry` from the pool (if it is still the pooled one) and
    /// close its connection, failing every parked waiter.
    fn invalidate(&self, addr: &str, entry: &PeerEntry) {
        let slot = {
            let conns = self.conns.lock().unwrap();
            conns.get(addr).cloned()
        };
        if let Some(slot) = slot {
            let mut slot = slot.lock().unwrap();
            if let Some(e) = slot.as_ref() {
                if Arc::ptr_eq(&e.waiters, &entry.waiters) {
                    *slot = None;
                }
            }
        }
        entry.handle.close("invalidated by handoff failure");
    }

    /// Close every pooled connection (shard drain).
    pub fn close_all(&self) {
        let entries: Vec<_> = {
            let conns = self.conns.lock().unwrap();
            conns.values().cloned().collect()
        };
        for slot in entries {
            if let Some(e) = slot.lock().unwrap().take() {
                e.handle.close("shard draining");
            }
        }
    }

    /// Dial `addr`, run the blocking `PeerHello` handshake, then hand
    /// the socket to the global driver. Blocking reads happen *before*
    /// the driver owns the socket, so the handshake never stalls the
    /// event loop.
    fn connect(addr: &str, codec: KvCodec) -> Result<PeerEntry> {
        use std::net::ToSocketAddrs;
        let sockaddr = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving peer {addr}"))?
            .next()
            .ok_or_else(|| anyhow!("peer address {addr} resolved to nothing"))?;
        let conn = TcpStream::connect_timeout(&sockaddr, Duration::from_secs(5))
            .with_context(|| format!("connecting to decode peer {addr}"))?;
        conn.set_nodelay(true)?;
        conn.set_read_timeout(Some(Duration::from_millis(250)))?;
        conn.set_write_timeout(Some(Duration::from_secs(5)))?;
        let mut w = conn.try_clone()?;
        proto::write_frame(
            &mut w,
            &Frame::PeerHello {
                version: PROTO_VERSION,
                kv_wire: codec,
            },
        )?;
        let mut rd = conn.try_clone()?;
        let mut reader = FrameReader::new();
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            match reader.poll(&mut rd) {
                Ok(Some(Frame::PeerHelloAck { version })) if version == PROTO_VERSION => break,
                Ok(Some(Frame::PeerHelloAck { version })) => {
                    return Err(anyhow!("peer {addr} speaks v{version}, we speak v{PROTO_VERSION}"))
                }
                Ok(Some(other)) => {
                    return Err(anyhow!("peer {addr}: expected PeerHelloAck, got {other:?}"))
                }
                Ok(None) if Instant::now() < deadline => continue,
                Ok(None) => return Err(anyhow!("peer {addr} handshake timed out")),
                Err(e) => return Err(anyhow!("peer {addr} handshake failed: {e}")),
            }
        }
        let waiters: Waiters = Arc::default();
        let handler = PeerClientHandler {
            waiters: Arc::clone(&waiters),
        };
        let handle = NetDriver::global()
            .add(conn, Box::new(handler), ConnOptions::default())
            .with_context(|| format!("registering peer {addr} with the net driver"))?;
        Ok(PeerEntry { handle, waiters })
    }

    /// Stream one finished prefill's KV to `target` and wait for the
    /// decode shard's ack. On any failure the error surfaces so the
    /// caller falls back to the scheduler relay; a stale pooled
    /// connection gets one reconnect before giving up.
    pub fn handoff(
        &self,
        codec: KvCodec,
        target: &DirectTarget,
        id: u64,
        outcome: &PrefillOutcome,
        decode_max_new: u32,
        class: SloClass,
    ) -> Result<()> {
        let (entry, pooled) = self.entry(&target.addr, codec)?;
        match self.try_handoff(&entry, codec, target, id, outcome, decode_max_new, class) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.invalidate(&target.addr, &entry);
                if !pooled {
                    return Err(e);
                }
                // The pooled connection may have died idle; retry once
                // on a fresh one before declaring the peer unreachable.
                log::debug!(
                    "peer {}: pooled connection failed ({e:#}); reconnecting",
                    target.addr
                );
                let (entry, _) = self.entry(&target.addr, codec)?;
                let out =
                    self.try_handoff(&entry, codec, target, id, outcome, decode_max_new, class);
                if out.is_err() {
                    self.invalidate(&target.addr, &entry);
                }
                out
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn try_handoff(
        &self,
        entry: &PeerEntry,
        codec: KvCodec,
        target: &DirectTarget,
        id: u64,
        outcome: &PrefillOutcome,
        decode_max_new: u32,
        class: SloClass,
    ) -> Result<()> {
        // Park the waiter before the commit can possibly be acked.
        let (ack_tx, ack_rx) = channel::<bool>();
        entry.waiters.lock().unwrap().insert(id, ack_tx);
        let unpark = |entry: &PeerEntry| {
            entry.waiters.lock().unwrap().remove(&id);
        };
        // The handoff's own stream: its segments and commit stay FIFO
        // relative to each other, while other jobs' frames interleave.
        let stream = self.alloc_stream();
        let mut buf = Vec::new();
        let sent = proto::each_kv_segment(
            &mut buf,
            codec,
            stream,
            id,
            self.chunk_elems,
            &outcome.k,
            &outcome.v,
            |bytes| entry.handle.enqueue(stream, bytes.to_vec()),
        );
        if let Err(e) = sent {
            unpark(entry);
            return Err(anyhow!("peer {}: enqueue failed: {e}", target.addr));
        }
        let commit = Frame::HandoffCommit {
            unit: target.unit,
            id,
            first_token: outcome.first_token,
            kv_len: outcome.len as u32,
            max_new: decode_max_new,
            class,
            exec_time: outcome.exec_time,
        };
        if let Err(e) = entry.handle.enqueue(stream, proto::frame_bytes_on(stream, &commit)) {
            unpark(entry);
            return Err(anyhow!("peer {}: commit enqueue failed: {e}", target.addr));
        }
        // The ack is what makes the commit safe to report: after it, the
        // sequence is durably enqueued on the decode unit, so the
        // scheduler-facing HandoffCommit can never name a lost handoff.
        match ack_rx.recv_timeout(self.ack_timeout) {
            Ok(true) => Ok(()),
            Ok(false) => Err(anyhow!("peer {} connection died mid-handoff", target.addr)),
            Err(_) => {
                unpark(entry);
                Err(anyhow!(
                    "peer {}: no HandoffAck for job {id} within {:?}",
                    target.addr,
                    self.ack_timeout
                ))
            }
        }
    }
}

/// Driver-side handler for one outbound peer connection: resolves ack
/// waiters, answers pings, and fails every parked handoff when the
/// connection dies.
struct PeerClientHandler {
    waiters: Waiters,
}

impl ConnHandler for PeerClientHandler {
    fn on_frame(&mut self, io: &mut ConnIo<'_>, _stream: StreamId, frame: Frame, _wire_len: u64) {
        match frame {
            Frame::HandoffAck { id } => {
                if let Some(tx) = self.waiters.lock().unwrap().remove(&id) {
                    let _ = tx.send(true);
                }
            }
            Frame::Ping { nonce, t_us } => {
                io.enqueue_priority(proto::frame_bytes_on(
                    STREAM_CONTROL,
                    &Frame::Pong { nonce, t_us },
                ));
            }
            other => log::debug!("peer client: ignoring frame {other:?}"),
        }
    }

    fn on_close(&mut self, _reason: &str) {
        // Fail every parked handoff: their callers fall back to relay.
        for (_, tx) in self.waiters.lock().unwrap().drain() {
            let _ = tx.send(false);
        }
    }
}
