//! KV wire codecs: how `f32` cache blocks are represented on the wire.
//!
//! The prefill→decode KV handoff is the largest payload in the system by
//! orders of magnitude, so the protocol encodes every KV block behind a
//! self-describing header (`[codec][elements][payload bytes][payload]`,
//! see `proto::kv_block_into`). Three codecs:
//!
//! * [`KvCodec::Raw`] — little-endian `f32`s, 4 B/element. The identity
//!   baseline; bit-exact.
//! * [`KvCodec::Fp16`] — IEEE 754 binary16, 2 B/element, round-to-
//!   nearest-even. Lossy (≤ 2⁻¹¹ relative error on normals), halves the
//!   wire, mirrors serving systems that ship half-precision KV.
//! * [`KvCodec::Lz`] — byte-oriented LZ (LZ4-style token stream, own
//!   format) over the raw `f32` bytes. Bit-exact; wins whenever caches
//!   carry structure (repeated heads, zero-padding, low-entropy values).
//!
//! Everything here is dependency-free and allocation-disciplined: the
//! compressor appends into a caller-owned buffer (reserve-bounded so the
//! hot-path encoders stay zero-alloc in steady state), and the
//! decompressor is fully bounds-checked — arbitrary corrupt input must
//! produce an error, never a panic, wrap, or out-of-bounds copy.

/// KV block codec negotiated in `Hello`/`HelloAck` and stamped on every
/// encoded block (blocks are self-describing, so mixed streams decode
/// regardless of what was negotiated — negotiation picks what senders
/// *produce*).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvCodec {
    /// Raw little-endian `f32`s (the identity codec).
    #[default]
    Raw,
    /// IEEE 754 binary16, round-to-nearest-even (lossy).
    Fp16,
    /// LZ-compressed raw bytes (bit-exact).
    Lz,
}

impl KvCodec {
    /// Wire byte for handshakes and block headers.
    pub fn to_wire(self) -> u8 {
        match self {
            KvCodec::Raw => 0,
            KvCodec::Fp16 => 1,
            KvCodec::Lz => 2,
        }
    }

    /// Inverse of [`KvCodec::to_wire`]; `None` for unknown bytes (the
    /// caller maps it onto its own error type).
    pub fn from_wire(x: u8) -> Option<Self> {
        match x {
            0 => Some(KvCodec::Raw),
            1 => Some(KvCodec::Fp16),
            2 => Some(KvCodec::Lz),
            _ => None,
        }
    }

    /// Stable codec name for CLI round-trips and gauges.
    pub fn name(self) -> &'static str {
        match self {
            KvCodec::Raw => "raw",
            KvCodec::Fp16 => "fp16",
            KvCodec::Lz => "lz",
        }
    }

    /// Parse a `--kv-wire` CLI value.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "raw" => Some(KvCodec::Raw),
            "fp16" => Some(KvCodec::Fp16),
            "lz" => Some(KvCodec::Lz),
            _ => None,
        }
    }

    /// Worst-case encoded payload size for `n` elements — what a caller
    /// must `reserve` so encoding never reallocates mid-append.
    pub fn payload_bound(self, n: usize) -> usize {
        match self {
            KvCodec::Raw => 4 * n,
            KvCodec::Fp16 => 2 * n,
            KvCodec::Lz => lz_compress_bound(4 * n),
        }
    }
}

// ---- fp16 ---------------------------------------------------------------

/// `f32` → binary16 bits, round-to-nearest-even; overflow saturates to
/// ±inf, underflow flushes to signed zero, NaN payload (truncated) is
/// preserved as a quiet NaN.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x7f_ffff;
    if exp == 0xff {
        // Inf / NaN: keep NaN-ness even when the truncated payload is 0.
        let payload = (mant >> 13) as u16 & 0x3ff;
        return if mant != 0 {
            sign | 0x7c00 | payload.max(1)
        } else {
            sign | 0x7c00
        };
    }
    let e = exp - 127;
    if e >= 16 {
        return sign | 0x7c00; // overflow → inf
    }
    if e >= -14 {
        // Normal half: 23→10 bit mantissa, round to nearest even. A
        // mantissa carry correctly rolls into the exponent (and 65504+
        // rounds up to inf) because the fields are adjacent.
        let mant10 = (mant >> 13) as u16;
        let rem = mant & 0x1fff;
        let mut h = sign | (((e + 15) as u16) << 10) | mant10;
        if rem > 0x1000 || (rem == 0x1000 && (mant10 & 1) == 1) {
            h = h.wrapping_add(1);
        }
        return h;
    }
    if e >= -24 {
        // Subnormal half.
        let full = mant | 0x80_0000;
        let shift = (13 + (-14 - e)) as u32;
        let mant10 = (full >> shift) as u16;
        let half_point = 1u32 << (shift - 1);
        let rem = full & ((1u32 << shift) - 1);
        let mut h = sign | mant10;
        if rem > half_point || (rem == half_point && (mant10 & 1) == 1) {
            h += 1;
        }
        return h;
    }
    sign // underflow → signed zero
}

/// binary16 bits → `f32` (exact; every half value is representable).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal half → normalized f32.
            let mut e = 0u32;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e += 1;
            }
            sign | ((113 - e) << 23) | ((m & 0x3ff) << 13)
        }
    } else {
        sign | ((exp + 112) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

// ---- LZ -----------------------------------------------------------------

const LZ_MIN_MATCH: usize = 4;
const LZ_HASH_BITS: u32 = 13;
const LZ_MAX_OFFSET: usize = 0xffff;

/// Worst-case compressed size for `raw_len` input bytes: all-literal
/// output plus one length-extension byte per 255 literals and a small
/// constant for the final token.
pub fn lz_compress_bound(raw_len: usize) -> usize {
    raw_len + raw_len / 255 + 16
}

#[inline]
fn lz_hash(bytes: &[u8]) -> usize {
    let w = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
    (w.wrapping_mul(0x9E37_79B1) >> (32 - LZ_HASH_BITS)) as usize
}

/// Append a length in the LZ4 extension scheme: the nibble held `15`,
/// the remainder follows as 255-saturated bytes.
fn lz_put_ext_len(out: &mut Vec<u8>, mut rest: usize) {
    while rest >= 255 {
        out.push(255);
        rest -= 255;
    }
    out.push(rest as u8);
}

/// Compress `src` into `out` (appended; `out` is *not* cleared). The
/// format is an LZ4-style token stream: `[token][ext lit len][literals]
/// [offset u16 LE][ext match len]`, token nibbles = literal length /
/// match length − 4, the final sequence carrying literals only. Greedy
/// single-pass matching over a 2^13-entry hash table, 64 KiB window.
pub fn lz_compress(src: &[u8], out: &mut Vec<u8>) {
    out.reserve(lz_compress_bound(src.len()));
    let mut table = [usize::MAX; 1 << LZ_HASH_BITS];
    let mut anchor = 0usize;
    let mut i = 0usize;
    // Matching needs 4 bytes to hash; everything past this is literal.
    let match_limit = src.len().saturating_sub(LZ_MIN_MATCH);
    while i < match_limit {
        let h = lz_hash(&src[i..]);
        let cand = table[h];
        table[h] = i;
        let ok = cand != usize::MAX
            && i - cand <= LZ_MAX_OFFSET
            && src[cand..cand + LZ_MIN_MATCH] == src[i..i + LZ_MIN_MATCH];
        if !ok {
            i += 1;
            continue;
        }
        // Extend the match as far as the input allows.
        let mut len = LZ_MIN_MATCH;
        while i + len < src.len() && src[cand + len] == src[i + len] {
            len += 1;
        }
        let lit = i - anchor;
        let lit_nib = lit.min(15) as u8;
        let match_nib = (len - LZ_MIN_MATCH).min(15) as u8;
        out.push((lit_nib << 4) | match_nib);
        if lit >= 15 {
            lz_put_ext_len(out, lit - 15);
        }
        out.extend_from_slice(&src[anchor..i]);
        out.extend_from_slice(&((i - cand) as u16).to_le_bytes());
        if len - LZ_MIN_MATCH >= 15 {
            lz_put_ext_len(out, len - LZ_MIN_MATCH - 15);
        }
        i += len;
        anchor = i;
    }
    // Final literals (possibly zero) under a match-free token.
    let lit = src.len() - anchor;
    out.push((lit.min(15) as u8) << 4);
    if lit >= 15 {
        lz_put_ext_len(out, lit - 15);
    }
    out.extend_from_slice(&src[anchor..]);
}

/// Why an LZ payload failed to decompress. All variants are reachable
/// from corrupt wire bytes; none may panic or over-read.
#[derive(Debug, PartialEq, Eq)]
pub enum LzError {
    /// Input ended inside a token, length extension, literal run or
    /// offset.
    Truncated,
    /// A copy (literal or match) would overrun the declared output size.
    OutputOverflow,
    /// A match offset points before the start of the output.
    BadOffset,
    /// The stream ended before producing the declared output size.
    ShortOutput,
}

impl std::fmt::Display for LzError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LzError::Truncated => write!(f, "lz stream truncated"),
            LzError::OutputOverflow => write!(f, "lz copy overruns declared output"),
            LzError::BadOffset => write!(f, "lz match offset before output start"),
            LzError::ShortOutput => write!(f, "lz stream ended short of declared output"),
        }
    }
}

fn lz_get_ext_len(src: &[u8], at: &mut usize, base: usize) -> Result<usize, LzError> {
    let mut len = base;
    loop {
        let b = *src.get(*at).ok_or(LzError::Truncated)?;
        *at += 1;
        len = len.checked_add(b as usize).ok_or(LzError::OutputOverflow)?;
        if b != 255 {
            return Ok(len);
        }
    }
}

/// Decompress `src` into exactly `expected_len` bytes. Fully
/// bounds-checked: corrupt input errors out without panicking, and the
/// output allocation is capped at `expected_len` (the caller bounds that
/// against the frame limit before calling).
pub fn lz_decompress(src: &[u8], expected_len: usize) -> Result<Vec<u8>, LzError> {
    let mut out = Vec::with_capacity(expected_len);
    let mut at = 0usize;
    loop {
        let token = *src.get(at).ok_or(LzError::Truncated)?;
        at += 1;
        let mut lit = (token >> 4) as usize;
        if lit == 15 {
            lit = lz_get_ext_len(src, &mut at, 15)?;
        }
        let lit_end = at.checked_add(lit).ok_or(LzError::Truncated)?;
        if lit_end > src.len() {
            return Err(LzError::Truncated);
        }
        if out.len() + lit > expected_len {
            return Err(LzError::OutputOverflow);
        }
        out.extend_from_slice(&src[at..lit_end]);
        at = lit_end;
        if out.len() == expected_len {
            // Complete. A well-formed stream ends here (its final token
            // has no match part); trailing garbage is tolerated — the
            // frame layer already accounts the payload length.
            return Ok(out);
        }
        if at == src.len() {
            return Err(LzError::ShortOutput);
        }
        if at + 2 > src.len() {
            return Err(LzError::Truncated);
        }
        let offset = u16::from_le_bytes([src[at], src[at + 1]]) as usize;
        at += 2;
        if offset == 0 || offset > out.len() {
            return Err(LzError::BadOffset);
        }
        let mut mlen = (token & 0x0f) as usize + LZ_MIN_MATCH;
        if mlen == 15 + LZ_MIN_MATCH {
            mlen = lz_get_ext_len(src, &mut at, mlen)?;
        }
        if out.len() + mlen > expected_len {
            return Err(LzError::OutputOverflow);
        }
        // Byte-at-a-time copy: offsets smaller than the match length are
        // legal (run-length encoding of repeating patterns).
        let start = out.len() - offset;
        for k in 0..mlen {
            let b = out[start + k];
            out.push(b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn f16_round_trips_exactly_representable_values() {
        for x in [
            0.0f32, -0.0, 1.0, -1.0, 0.5, 0.0625, 2048.0, 65504.0, -65504.0,
            f32::INFINITY, f32::NEG_INFINITY,
        ] {
            let back = f16_bits_to_f32(f32_to_f16_bits(x));
            assert_eq!(back.to_bits(), x.to_bits(), "{x} must survive fp16 exactly");
        }
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn f16_relative_error_is_bounded_on_normals() {
        let mut rng = Rng::new(0x1F16);
        for _ in 0..20_000 {
            let sign = if rng.chance(0.5) { -1.0 } else { 1.0 };
            let x = (rng.uniform(-6.0, 6.0)).exp() as f32 * sign;
            let back = f16_bits_to_f32(f32_to_f16_bits(x));
            let rel = ((back - x) / x).abs();
            assert!(rel <= 1.0 / 2048.0 + 1e-7, "x={x} back={back} rel={rel}");
        }
    }

    #[test]
    fn f16_saturates_and_flushes_at_the_extremes() {
        assert_eq!(f32_to_f16_bits(1e9), 0x7c00, "overflow → +inf");
        assert_eq!(f32_to_f16_bits(-1e9), 0xfc00, "overflow → -inf");
        assert_eq!(f32_to_f16_bits(1e-12), 0x0000, "underflow → +0");
        assert_eq!(f32_to_f16_bits(-1e-12), 0x8000, "underflow → -0");
        // The smallest-subnormal neighborhood survives (2⁻²⁴ ≈ 5.96e-8).
        let tiny = 6.0e-8f32;
        let back = f16_bits_to_f32(f32_to_f16_bits(tiny));
        assert!((back - tiny).abs() / tiny < 0.05, "{back}");
    }

    #[test]
    fn f16_rounds_to_nearest_even() {
        // 1 + 2^-11 sits exactly between 1.0 and 1 + 2^-10: even wins.
        let x = f32::from_bits(0x3f80_1000);
        assert_eq!(f32_to_f16_bits(x), 0x3c00, "tie must round to even (1.0)");
        let y = f32::from_bits(0x3f80_3000); // 1 + 3·2^-11 → rounds up
        assert_eq!(f32_to_f16_bits(y), 0x3c02);
    }

    fn round_trip(src: &[u8]) {
        let mut packed = Vec::new();
        lz_compress(src, &mut packed);
        assert!(packed.len() <= lz_compress_bound(src.len()), "bound violated");
        let back = lz_decompress(&packed, src.len()).expect("decompress");
        assert_eq!(back, src, "lz must be bit-exact");
    }

    #[test]
    fn lz_round_trips_edge_shapes() {
        round_trip(&[]);
        round_trip(&[7]);
        round_trip(&[1, 2, 3]);
        round_trip(&[0; 4]);
        round_trip(&[9; 1000]);
        round_trip(&(0..=255u8).collect::<Vec<_>>());
    }

    #[test]
    fn lz_round_trips_random_and_structured_blocks() {
        let mut rng = Rng::new(0x17AB);
        for case in 0..60 {
            let n = (rng.below(6000) + 1) as usize;
            let data: Vec<u8> = match case % 3 {
                0 => (0..n).map(|_| rng.below(256) as u8).collect(), // incompressible
                1 => (0..n).map(|i| ((i / 16) % 7) as u8).collect(), // runs
                _ => {
                    // f32-shaped: repeating 4-byte words, the KV pattern.
                    let words: Vec<[u8; 4]> =
                        (0..8).map(|k| ((k as f32) * 0.125f32).to_le_bytes()).collect();
                    (0..n).map(|i| words[(i / 4) % 8][i % 4]).collect()
                }
            };
            round_trip(&data);
        }
    }

    #[test]
    fn lz_shrinks_structured_f32_blocks_hard() {
        // The mock KV shape: values constant over short runs — the wire
        // claim the e2e byte-accounting test asserts end to end.
        let floats: Vec<f32> = (0..16_384).map(|i| (7.0 + (i / 7) as f32 * 0.5) * 0.125).collect();
        let raw: Vec<u8> = floats.iter().flat_map(|f| f.to_le_bytes()).collect();
        let mut packed = Vec::new();
        lz_compress(&raw, &mut packed);
        assert!(
            (packed.len() as f64) < 0.6 * raw.len() as f64,
            "structured KV must compress ≥40%: {} / {}",
            packed.len(),
            raw.len()
        );
        assert_eq!(lz_decompress(&packed, raw.len()).unwrap(), raw);
    }

    #[test]
    fn lz_decompress_survives_truncation_at_every_offset() {
        let src: Vec<u8> = (0..400u32).flat_map(|i| ((i % 11) as f32).to_le_bytes()).collect();
        let mut packed = Vec::new();
        lz_compress(&src, &mut packed);
        for cut in 0..packed.len() {
            // Must error (never panic); a prefix cannot produce the full
            // declared output.
            assert!(lz_decompress(&packed[..cut], src.len()).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn lz_decompress_survives_arbitrary_corruption() {
        let mut rng = Rng::new(0xC0);
        let src: Vec<u8> = (0..600).map(|i| (i % 30) as u8).collect();
        let mut packed = Vec::new();
        lz_compress(&src, &mut packed);
        for _ in 0..2000 {
            let mut bad = packed.clone();
            let flips = 1 + rng.below(4);
            for _ in 0..flips {
                let at = rng.index(bad.len());
                bad[at] ^= rng.below(255) as u8 + 1;
            }
            // Either decodes to *something* of the right length or errors
            // cleanly — never panics, never wrong-sized output.
            if let Ok(out) = lz_decompress(&bad, src.len()) {
                assert_eq!(out.len(), src.len());
            }
        }
        // Pure garbage too.
        for _ in 0..500 {
            let garbage: Vec<u8> = (0..rng.below(200)).map(|_| rng.below(256) as u8).collect();
            if let Ok(out) = lz_decompress(&garbage, 333) {
                assert_eq!(out.len(), 333);
            }
        }
    }

    #[test]
    fn lz_offsets_shorter_than_match_length_rle() {
        // A run compresses via offset-1 self-overlapping matches.
        let src = vec![0xABu8; 5000];
        let mut packed = Vec::new();
        lz_compress(&src, &mut packed);
        assert!(packed.len() < 64, "RLE shape must collapse: {}", packed.len());
        assert_eq!(lz_decompress(&packed, src.len()).unwrap(), src);
    }

    #[test]
    fn codec_names_round_trip() {
        for c in [KvCodec::Raw, KvCodec::Fp16, KvCodec::Lz] {
            assert_eq!(KvCodec::from_wire(c.to_wire()), Some(c));
            assert_eq!(KvCodec::parse(c.name()), Some(c));
        }
        assert_eq!(KvCodec::from_wire(9), None);
        assert_eq!(KvCodec::parse("zstd"), None);
    }
}
