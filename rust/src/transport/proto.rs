//! Length-prefixed binary wire protocol for remote shards (decode *and*
//! prefill).
//!
//! One frame on the wire is `[u32 LE payload length][u32 LE stream
//! id][payload]`, where the payload is `[u8 tag][fields...]` with all
//! integers little-endian and `f64` as LE bit patterns. The [`StreamId`]
//! multiplexes independent in-flight transfers over one connection (see
//! [`STREAM_CONTROL`]); the frame set mirrors the dispatch-core message
//! vocabulary, so both shard roles ride one protocol:
//!
//! | direction | frame | dispatch-core meaning |
//! |---|---|---|
//! | sched → shard | [`Frame::Hello`] | connection handshake |
//! | shard → sched | [`Frame::HelloAck`] | shard role + shape (units, slots) |
//! | sched → shard | [`Frame::Admit`] | decode join / placement commit |
//! | shard → sched | [`Frame::Token`] | one generated token |
//! | shard → sched | [`Frame::Done`] | `DecodeDone` — ledger release (success) |
//! | shard → sched | [`Frame::Rejected`] | `DecodeDone` — ledger release (failure) |
//! | sched → shard | [`Frame::PrefillDispatch`] | prefill batch dispatch (SBS trigger output) |
//! | shard → sched | [`Frame::KvSegment`] | one chunk of prompt KV (prefill→decode handoff) |
//! | shard → sched | [`Frame::PrefillDone`] | prefill finished — commits the KV handoff |
//! | shard → sched | [`Frame::PrefillFailed`] | prefill error — reject upstream |
//! | shard → sched | [`Frame::EndForward`] | engine backlog feedback into the staggered trigger |
//! | both | [`Frame::Ping`] / [`Frame::Pong`] | liveness + RTT measurement |
//! | sched → shard | [`Frame::StatsRequest`] | gauge snapshot request |
//! | shard → sched | [`Frame::StatsReply`] | per-unit occupancy gauges + KV wire counters |
//! | sched → shard | [`Frame::Stop`] | drain and exit |
//! | shard → sched | [`Frame::Bye`] | drain complete, closing |
//! | prefill → peer | [`Frame::PeerHello`] / [`Frame::PeerHelloAck`] | direct-transfer handshake |
//! | prefill → peer | [`Frame::HandoffCommit`] | commit a direct KV handoff (also → sched) |
//! | peer → prefill | [`Frame::HandoffAck`] | the handoff is durably accepted |
//! | shard → sched | [`Frame::TraceSpans`] | batched TTFT trace marks (best-effort) |
//! | sched → shard | [`Frame::Migrate`] | extract a resident sequence for live migration |
//! | shard → sched | [`Frame::MigrateAck`] | extraction result (follows the sequence's `KvSegment` stream) |
//!
//! Reads are driven through the stateful [`FrameReader`], which preserves
//! partial progress across socket read timeouts — a timeout mid-frame
//! must never desynchronize the stream.
//!
//! ## Hot-path encoding and the KV wire codec
//!
//! The KV-bearing frames (`Admit`, `KvSegment`) are the only ones whose
//! payloads reach megabytes, and building a [`Frame`] for them would copy
//! the caches into the enum before serialization copies them again.
//! Senders on those paths use the borrow-based
//! [`admit_frame_into`] / [`kv_segment_frame_into`] encoders instead:
//! the caches are serialized straight from the engine's buffers into one
//! reusable length-prefixed wire buffer — no intermediate `Vec`s, no
//! steady-state allocation.
//!
//! Every KV payload travels as a **self-describing coded block**
//! (`[u8 codec][u32 elements][u32 payload bytes][payload]`, see
//! [`crate::transport::codec::KvCodec`]): raw `f32`s, fp16, or an
//! LZ-compressed block. The codec a sender *produces* is negotiated in
//! `Hello`/`HelloAck` (`--kv-wire`); receivers decode whatever the block
//! header declares, so mixed streams stay well-formed. The borrow
//! encoders return the block's wire size so senders can keep the
//! `kv_wire_bytes` / `kv_raw_bytes` accounting exact.

use super::codec::{self, KvCodec};
use crate::scheduler::types::SloClass;
use crate::trace::{Mark, TraceMark};
use std::io::{ErrorKind, Read, Write};
use std::time::{Duration, Instant};

/// Protocol version carried in `Hello`/`HelloAck`; bumped on any frame
/// layout change. Mismatched peers refuse the handshake.
/// v2: `HelloAck` carries the shard role; prefill frames added.
/// v3: KV payloads ride the codec layer (`Hello`/`HelloAck` negotiate a
/// [`KvCodec`], `HelloAck` advertises the shard's peer port), and the
/// direct prefill→decode transfer frames (`PeerHello`/`PeerHelloAck`,
/// `HandoffCommit`/`HandoffAck`, per-job [`DirectTarget`]s) exist.
/// v4: the frame header grows a [`StreamId`] (`[u32 len][u32 stream]`),
/// so N in-flight KV handoffs multiplex one connection per peer pair
/// without serializing behind each other.
/// v5: shards piggyback batched TTFT trace marks on the control stream
/// ([`Frame::TraceSpans`], carrying the shard-side shed count).
/// v6: the job-bearing frames (`Admit`, per-job in `PrefillDispatch`,
/// `HandoffCommit`) carry the request's [`SloClass`] as one byte, so
/// remote shards and the trace subsystem see the same class the
/// scheduler admitted (deadlines stay scheduler-side).
/// v7: deadline-rescue live migration — [`Frame::Migrate`] asks a decode
/// shard to extract a resident sequence, [`Frame::MigrateAck`] carries
/// the extraction result behind the sequence's coded `KvSegment` stream,
/// and `Admit` grows a `resume` token history so a migrated sequence
/// re-admits mid-generation with its stream position intact.
pub const PROTO_VERSION: u32 = 7;

/// Logical stream a frame belongs to within one connection. Streams let
/// independent in-flight transfers (e.g. two concurrent KV handoffs to
/// the same decode shard) interleave their frames on a shared socket:
/// the sender's outbound queue drains round-robin across streams, and a
/// receiver keys reassembly state by job id, so per-stream FIFO order is
/// all the protocol requires. Stream ids are allocated by the sender and
/// carry no meaning beyond "frames with the same id are ordered".
pub type StreamId = u32;

/// The control stream: handshakes, pings, acks, and every frame that
/// predates multiplexing. [`write_frame`] always sends on this stream.
pub const STREAM_CONTROL: StreamId = 0;

/// Bulk-lane stream for one job's transfer frames: nonzero (never the
/// control stream), derived from the job id. Collisions between jobs
/// are harmless — sharing a stream only means their frames drain FIFO
/// instead of round-robin.
pub fn job_stream(id: u64) -> StreamId {
    ((id as u32) << 1) | 1
}

/// Upper bound on one frame's payload (guards against a corrupt length
/// prefix allocating unbounded memory). Sized for an `Admit` carrying
/// full-context KV caches of a small model.
pub const MAX_FRAME: u32 = 256 * 1024 * 1024;

/// Which plane a shard serves, advertised in its `HelloAck`. A scheduler
/// connecting for one role refuses a shard of the other — a prefill pool
/// must never be built over decode units or vice versa.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardRole {
    /// Decode DP units (`sbs worker --decode`).
    Decode,
    /// Prefill instances (`sbs worker --prefill`).
    Prefill,
}

impl ShardRole {
    fn to_wire(self) -> u8 {
        match self {
            ShardRole::Decode => 0,
            ShardRole::Prefill => 1,
        }
    }

    fn from_wire(x: u8) -> Result<Self, ProtoError> {
        match x {
            0 => Ok(ShardRole::Decode),
            1 => Ok(ShardRole::Prefill),
            _ => Err(ProtoError::BadValue("shard role")),
        }
    }

    /// Human-readable role name (log/error messages).
    pub fn name(self) -> &'static str {
        match self {
            ShardRole::Decode => "decode",
            ShardRole::Prefill => "prefill",
        }
    }
}

/// Which half of a KV cache a [`Frame::KvSegment`] carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvHalf {
    /// Key cache.
    K,
    /// Value cache.
    V,
}

impl KvHalf {
    fn to_wire(self) -> u8 {
        match self {
            KvHalf::K => 0,
            KvHalf::V => 1,
        }
    }

    fn from_wire(x: u8) -> Result<Self, ProtoError> {
        match x {
            0 => Ok(KvHalf::K),
            1 => Ok(KvHalf::V),
            _ => Err(ProtoError::BadValue("kv half")),
        }
    }
}

/// Where a prefill shard should stream a finished job's KV directly: a
/// decode shard's peer listener plus the shard-local unit the scheduler
/// pre-placed the sequence onto (Algorithm 3, decided inside the
/// buffering window). Carried per job in [`Frame::PrefillDispatch`];
/// absent = relay the handoff through the scheduler.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DirectTarget {
    /// Decode shard peer address (`host:peer_port`).
    pub addr: String,
    /// Shard-local decode unit index.
    pub unit: u32,
}

/// One job inside a [`Frame::PrefillDispatch`] batch.
#[derive(Debug, Clone, PartialEq)]
pub struct PrefillJobWire {
    /// Request id (scheduler-scoped; echoed in every reply).
    pub id: u64,
    /// Output tokens to generate after the first.
    pub max_new: u32,
    /// The request's SLO class.
    pub class: SloClass,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Direct-transfer placement, when the scheduler pre-placed the
    /// sequence onto a remote decode unit.
    pub target: Option<DirectTarget>,
}

/// Per-unit occupancy snapshot carried by [`Frame::StatsReply`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UnitLoad {
    /// Sequences currently resident on the unit.
    pub active: u32,
    /// Free decode slots.
    pub free_slots: u32,
    /// Resident KV tokens (engine ground truth where available).
    pub kv_tokens: u64,
}

/// One protocol frame (see module docs for the direction table).
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Scheduler handshake: protocol version check + KV codec proposal.
    Hello {
        /// Sender's [`PROTO_VERSION`].
        version: u32,
        /// KV codec the scheduler wants this deployment to produce.
        kv_wire: KvCodec,
    },
    /// Shard handshake reply: the role and shape the scheduler adds to
    /// its pool.
    HelloAck {
        /// Shard's [`PROTO_VERSION`].
        version: u32,
        /// Plane this shard serves.
        role: ShardRole,
        /// DP units (decode) / instances (prefill) served by this shard.
        units: u32,
        /// Decode slots per unit (the shard's batch size); 1 for prefill
        /// shards, whose instances are gated single-pass engines.
        slots: u32,
        /// KV codec the shard will produce — must echo the `Hello`
        /// proposal or the scheduler refuses the handshake.
        kv_wire: KvCodec,
        /// Port of the shard's direct-transfer peer listener (decode
        /// shards only); 0 = no direct transfer into this shard.
        peer_port: u16,
    },
    /// Placement commit: admit a prefilled sequence onto `unit`.
    Admit {
        /// Target DP unit, shard-local index in `0..units`.
        unit: u32,
        /// Request id (scheduler-scoped; echoed in every reply).
        id: u64,
        /// First generated token (produced by prefill).
        first_token: i32,
        /// Prompt length — resident KV rows at join.
        kv_len: u32,
        /// Output tokens still to generate.
        max_new: u32,
        /// The sequence's SLO class.
        class: SloClass,
        /// Already-generated tokens, oldest first, for a sequence being
        /// re-admitted mid-generation (migration); empty for a fresh
        /// join. The last entry is the token the engine continues from,
        /// and the receiver seeds its emission index past the history so
        /// the client-visible stream stays contiguous.
        resume: Vec<i32>,
        /// Prompt K caches (`[L, S, H, Dh]` flattened; empty for engines
        /// without transferable KV, e.g. the mock).
        k: Vec<f32>,
        /// Prompt V caches.
        v: Vec<f32>,
    },
    /// One generated token for request `id`.
    Token {
        /// Request id.
        id: u64,
        /// 0-based position in the generation (0 was emitted by prefill
        /// scheduler-side, so shard tokens start at 1).
        index: u32,
        /// Token id.
        token: i32,
    },
    /// Terminal: generation finished; releases the ledger charge.
    Done {
        /// Request id.
        id: u64,
        /// The full generation, first (prefill-produced) token included —
        /// identical to what an in-process unit reports.
        tokens: Vec<i32>,
    },
    /// Terminal: the shard could not serve the sequence; releases the
    /// ledger charge.
    Rejected {
        /// Request id.
        id: u64,
    },
    /// Dispatch a batch of prefill jobs onto one prefill instance — the
    /// staggered trigger's output crossing the wire.
    PrefillDispatch {
        /// Target instance, shard-local index in `0..units`.
        unit: u32,
        /// The batch (PBAA assignments for this instance).
        jobs: Vec<PrefillJobWire>,
    },
    /// One chunk of a finished prefill's prompt KV, streamed ahead of the
    /// committing [`Frame::PrefillDone`]. Chunking keeps a long prompt's
    /// caches from monopolizing the connection: other units' tokens and
    /// terminals interleave between segments.
    KvSegment {
        /// Request id the segment belongs to.
        id: u64,
        /// K or V cache.
        half: KvHalf,
        /// Element offset of this chunk within the flattened cache.
        offset: u32,
        /// Total elements of this cache half (receiver pre-sizes once).
        total: u32,
        /// The chunk's elements.
        data: Vec<f32>,
    },
    /// Prefill finished: commits the KV handoff assembled from the
    /// preceding [`Frame::KvSegment`]s and hands the first token back.
    PrefillDone {
        /// Request id.
        id: u64,
        /// First generated token.
        first_token: i32,
        /// Prompt length — valid KV rows.
        kv_len: u32,
        /// Engine execution time of the prefill passes, seconds
        /// (shard-clock duration, safe to ship: only wall-clock *instants*
        /// stay scheduler-side).
        exec_time: f64,
    },
    /// Prefill failed terminally; the scheduler rejects the job upstream.
    PrefillFailed {
        /// Request id.
        id: u64,
    },
    /// Engine backlog feedback: a prefill instance finished a pass and
    /// reports what is still queued behind it (the Fig. 5 `EndForward`
    /// payload, feeding the staggered trigger's readiness + capacity
    /// model). The decode path never sends it.
    EndForward {
        /// Shard-local instance index.
        instance: u32,
        /// Measured pass time, seconds.
        t_measured: f64,
        /// Tokens still buffered on the device; `None` means the engine
        /// consumed everything dispatched (`EndForwardBacklog::ConsumedAll`).
        remaining: Option<u32>,
    },
    /// Liveness probe; the peer echoes both fields in a [`Frame::Pong`].
    Ping {
        /// Correlates the pong.
        nonce: u64,
        /// Sender-clock send instant, microseconds.
        t_us: u64,
    },
    /// Echo of a [`Frame::Ping`].
    Pong {
        /// Echoed nonce.
        nonce: u64,
        /// Echoed send instant (the pinger computes RTT from it).
        t_us: u64,
    },
    /// Ask the shard for its per-unit occupancy.
    StatsRequest,
    /// Per-unit occupancy gauges, shard-local unit order, plus the
    /// shard's inbound-KV wire accounting.
    StatsReply {
        /// One entry per DP unit.
        units: Vec<UnitLoad>,
        /// Coded KV bytes this shard has received (relay admits + direct
        /// peer handoffs), as they crossed the wire.
        kv_wire_bytes: u64,
        /// The same KV as raw `f32` bytes (4 × elements) — the
        /// denominator of the compression claim.
        kv_raw_bytes: u64,
    },
    /// Drain every active sequence, then exit.
    Stop,
    /// Drain complete; the shard closes the connection after this.
    Bye,
    /// Peer handshake on a decode shard's peer listener: a prefill shard
    /// opening a direct-transfer connection.
    PeerHello {
        /// Sender's [`PROTO_VERSION`].
        version: u32,
        /// KV codec the peer will produce on this connection.
        kv_wire: KvCodec,
    },
    /// Peer handshake reply; the decode shard is ready to receive
    /// `KvSegment` streams committed by `HandoffCommit`.
    PeerHelloAck {
        /// Receiver's [`PROTO_VERSION`].
        version: u32,
    },
    /// Commit one direct KV handoff. On a peer connection it follows the
    /// job's `KvSegment` stream and admits the sequence into `unit`; on
    /// the prefill shard's scheduler connection it is the lightweight
    /// notification that replaces the relayed `KvSegment*`+`PrefillDone`
    /// (sent only after the decode peer's [`Frame::HandoffAck`]).
    HandoffCommit {
        /// Shard-local decode unit (the scheduler's pre-placement).
        unit: u32,
        /// Request id.
        id: u64,
        /// First generated token (produced by prefill).
        first_token: i32,
        /// Prompt length — valid KV rows.
        kv_len: u32,
        /// Output tokens still to generate *after* the first.
        max_new: u32,
        /// The sequence's SLO class.
        class: SloClass,
        /// Engine execution time of the prefill passes, seconds.
        exec_time: f64,
    },
    /// The decode shard durably accepted a direct handoff (sequence
    /// enqueued on its unit); the prefill shard may now report the
    /// commit to the scheduler instead of falling back to relay.
    HandoffAck {
        /// Request id.
        id: u64,
    },
    /// Batched TTFT trace marks, shard → scheduler on the control
    /// stream. Best-effort telemetry: the shard sheds marks instead of
    /// ever blocking the request path, and reports how many it shed.
    TraceSpans {
        /// Marks the shard dropped since the last batch (buffer full or
        /// clock offset not yet established).
        dropped: u32,
        /// The marks, already converted to scheduler-clock microseconds.
        marks: Vec<TraceMark>,
    },
    /// Extract a resident decode sequence for live migration (deadline
    /// rescue). The shard removes the sequence from its engine, streams
    /// its KV as coded [`Frame::KvSegment`]s on the sequence's job
    /// stream, and commits with a [`Frame::MigrateAck`] — all *behind*
    /// any Token frames already queued for the sequence, so the token
    /// stream stays contiguous and exactly-once across the move.
    Migrate {
        /// Shard-local DP unit the sequence is resident on.
        unit: u32,
        /// Request id.
        id: u64,
    },
    /// Extraction result for a [`Frame::Migrate`]. With `found`, the
    /// sequence has been removed from the source engine (no further
    /// tokens will be emitted for it here) and its KV was streamed ahead
    /// of this frame; the scheduler re-places it elsewhere. Without
    /// `found`, the sequence already terminalized (or was never
    /// resident) and the migration is a no-op.
    MigrateAck {
        /// Request id.
        id: u64,
        /// Whether the sequence was resident and extracted.
        found: bool,
        /// Resident KV rows at extraction (prompt + generated).
        kv_len: u32,
        /// Output tokens still to generate.
        remaining: u32,
        /// Every token generated so far, oldest first (the destination's
        /// `Admit.resume` payload).
        tokens: Vec<i32>,
    },
}

/// Why a frame could not be decoded.
#[derive(Debug)]
pub enum ProtoError {
    /// Payload ended before the fields it declared.
    Truncated,
    /// Unknown frame tag.
    BadTag(u8),
    /// Length prefix exceeds [`MAX_FRAME`].
    Oversize(u32),
    /// Trailing bytes after a complete frame body.
    TrailingBytes,
    /// A field carried a value outside its domain (named for the error).
    BadValue(&'static str),
    /// The peer closed the stream.
    Closed,
    /// Underlying transport error.
    Io(std::io::Error),
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Truncated => write!(f, "truncated frame payload"),
            ProtoError::BadTag(t) => write!(f, "unknown frame tag {t}"),
            ProtoError::Oversize(n) => write!(f, "frame length {n} exceeds MAX_FRAME"),
            ProtoError::TrailingBytes => write!(f, "trailing bytes after frame body"),
            ProtoError::BadValue(what) => write!(f, "out-of-domain {what}"),
            ProtoError::Closed => write!(f, "connection closed"),
            ProtoError::Io(e) => write!(f, "transport error: {e}"),
        }
    }
}

impl std::error::Error for ProtoError {}

const TAG_HELLO: u8 = 1;
const TAG_HELLO_ACK: u8 = 2;
const TAG_ADMIT: u8 = 3;
const TAG_TOKEN: u8 = 4;
const TAG_DONE: u8 = 5;
const TAG_REJECTED: u8 = 6;
const TAG_END_FORWARD: u8 = 7;
const TAG_PING: u8 = 8;
const TAG_PONG: u8 = 9;
const TAG_STATS_REQUEST: u8 = 10;
const TAG_STATS_REPLY: u8 = 11;
const TAG_STOP: u8 = 12;
const TAG_BYE: u8 = 13;
const TAG_PREFILL_DISPATCH: u8 = 14;
const TAG_KV_SEGMENT: u8 = 15;
const TAG_PREFILL_DONE: u8 = 16;
const TAG_PREFILL_FAILED: u8 = 17;
const TAG_PEER_HELLO: u8 = 18;
const TAG_PEER_HELLO_ACK: u8 = 19;
const TAG_HANDOFF_COMMIT: u8 = 20;
const TAG_HANDOFF_ACK: u8 = 21;
const TAG_TRACE_SPANS: u8 = 22;
const TAG_MIGRATE: u8 = 23;
const TAG_MIGRATE_ACK: u8 = 24;

/// Cap on the address string inside a [`DirectTarget`]: long enough for
/// any `host:port`, short enough that a corrupt length cannot allocate
/// meaningfully.
const MAX_ADDR_LEN: usize = 256;

/// Fixed overhead of one coded KV block: codec byte + element count +
/// payload length.
const KV_BLOCK_HEADER: usize = 9;

struct Enc(Vec<u8>);

impl Enc {
    fn u8(&mut self, x: u8) {
        self.0.push(x);
    }

    fn u32(&mut self, x: u32) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }

    fn u64(&mut self, x: u64) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }

    fn i32(&mut self, x: i32) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }

    fn f64(&mut self, x: f64) {
        self.0.extend_from_slice(&x.to_bits().to_le_bytes());
    }

    fn i32s(&mut self, xs: &[i32]) {
        self.u32(xs.len() as u32);
        for x in xs {
            self.0.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn str(&mut self, s: &str) {
        let bytes = &s.as_bytes()[..s.len().min(MAX_ADDR_LEN)];
        self.u32(bytes.len() as u32);
        self.0.extend_from_slice(bytes);
    }

    /// Append one self-describing coded KV block
    /// (`[codec][elements][payload bytes][payload]`) and return its total
    /// wire size. LZ compresses the raw little-endian bytes through a
    /// thread-local scratch buffer (clear + reuse — no steady-state
    /// allocation on the hot path).
    fn kv_block(&mut self, codec: KvCodec, xs: &[f32]) -> usize {
        let at0 = self.0.len();
        self.u8(codec.to_wire());
        self.u32(xs.len() as u32);
        let len_at = self.0.len();
        self.0.extend_from_slice(&[0u8; 4]);
        let start = self.0.len();
        match codec {
            KvCodec::Raw => {
                for x in xs {
                    self.0.extend_from_slice(&x.to_le_bytes());
                }
            }
            KvCodec::Fp16 => {
                for x in xs {
                    self.0
                        .extend_from_slice(&codec::f32_to_f16_bits(*x).to_le_bytes());
                }
            }
            KvCodec::Lz => {
                thread_local! {
                    static LZ_SCRATCH: std::cell::RefCell<Vec<u8>> =
                        const { std::cell::RefCell::new(Vec::new()) };
                }
                LZ_SCRATCH.with(|s| {
                    let mut raw = s.borrow_mut();
                    raw.clear();
                    raw.reserve(4 * xs.len());
                    for x in xs {
                        raw.extend_from_slice(&x.to_le_bytes());
                    }
                    codec::lz_compress(&raw, &mut self.0);
                });
            }
        }
        let payload = (self.0.len() - start) as u32;
        self.0[len_at..len_at + 4].copy_from_slice(&payload.to_le_bytes());
        self.0.len() - at0
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        if self.at + n > self.buf.len() {
            return Err(ProtoError::Truncated);
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, ProtoError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, ProtoError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ProtoError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn i32(&mut self) -> Result<i32, ProtoError> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, ProtoError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Guard before allocating: the declared element count must fit in
    /// the bytes actually present (checked arithmetic — a huge count
    /// must not wrap past the guard on 32-bit targets).
    fn check_elems(&self, n: usize, elem_size: usize) -> Result<(), ProtoError> {
        match n.checked_mul(elem_size) {
            Some(bytes) if self.at.saturating_add(bytes) <= self.buf.len() => Ok(()),
            _ => Err(ProtoError::Truncated),
        }
    }

    fn i32s(&mut self) -> Result<Vec<i32>, ProtoError> {
        let n = self.u32()? as usize;
        self.check_elems(n, 4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.i32()?);
        }
        Ok(out)
    }

    fn str(&mut self) -> Result<String, ProtoError> {
        let n = self.u32()? as usize;
        if n > MAX_ADDR_LEN {
            return Err(ProtoError::BadValue("address length"));
        }
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| ProtoError::BadValue("address utf-8"))
    }

    /// Decode one self-describing coded KV block into `f32`s. Guards:
    /// the element count is bounded by [`MAX_FRAME`] *before* allocating,
    /// the declared payload must be fully present, and a raw/fp16 payload
    /// must match the element count exactly (LZ declares its own output
    /// size — `4 × elements` — which decompression enforces).
    fn kv_block(&mut self) -> Result<Vec<f32>, ProtoError> {
        let codec =
            KvCodec::from_wire(self.u8()?).ok_or(ProtoError::BadValue("kv codec"))?;
        let n = self.u32()? as usize;
        match n.checked_mul(4) {
            Some(bytes) if bytes <= MAX_FRAME as usize => {}
            _ => return Err(ProtoError::BadValue("kv element count")),
        }
        let plen = self.u32()? as usize;
        let payload = self.take(plen)?;
        match codec {
            KvCodec::Raw => {
                if plen != 4 * n {
                    return Err(ProtoError::BadValue("raw kv payload length"));
                }
                Ok(payload
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect())
            }
            KvCodec::Fp16 => {
                if plen != 2 * n {
                    return Err(ProtoError::BadValue("fp16 kv payload length"));
                }
                Ok(payload
                    .chunks_exact(2)
                    .map(|c| {
                        codec::f16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap()))
                    })
                    .collect())
            }
            KvCodec::Lz => {
                let raw = codec::lz_decompress(payload, 4 * n)
                    .map_err(|_| ProtoError::BadValue("lz kv payload"))?;
                Ok(raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect())
            }
        }
    }

    fn finish(self) -> Result<(), ProtoError> {
        if self.at == self.buf.len() {
            Ok(())
        } else {
            Err(ProtoError::TrailingBytes)
        }
    }
}

/// Conservative upper bound on a frame's encoded payload size, for
/// sender-side [`MAX_FRAME`] checks *before* serializing: an oversized
/// frame must be refused locally (failing one job), never written —
/// the receiver's `Oversize` error would kill the whole connection.
pub fn admit_payload_bound(codec: KvCodec, resume_len: usize, k_len: usize, v_len: usize) -> u64 {
    // tag + unit + id + first_token + kv_len + max_new + class + resume
    // vector + 2 block headers.
    64 + 4 * resume_len as u64
        + codec.payload_bound(k_len) as u64
        + codec.payload_bound(v_len) as u64
}

/// Encode one frame body into `buf` behind the 8-byte
/// `[u32 len][u32 stream]` header, the length backpatched once the body
/// is complete. `body_size` pre-reserves so a steady-state caller
/// (same-shape frames into one reused buffer) never reallocates.
fn frame_scaffold(
    buf: &mut Vec<u8>,
    stream: StreamId,
    body_size: usize,
    body: impl FnOnce(&mut Enc),
) {
    buf.clear();
    buf.reserve(8 + body_size);
    let mut e = Enc(std::mem::take(buf));
    e.0.extend_from_slice(&[0u8; 8]);
    body(&mut e);
    *buf = e.0;
    let len = (buf.len() - 8) as u32;
    buf[..4].copy_from_slice(&len.to_le_bytes());
    buf[4..8].copy_from_slice(&stream.to_le_bytes());
}

/// Serialize one length-prefixed [`Frame::Admit`] into `buf` (cleared
/// first), borrowing the KV caches straight from the engine's buffers.
/// This is the placement-commit hot path: the enum-based
/// `write_frame(&Frame::Admit { .. })` route would copy each cache three
/// times (into the frame, the payload, the prefixed buffer); this
/// serializes them once, into a buffer the caller reuses across admits —
/// zero intermediate `Vec`s, zero steady-state allocation. Returns the
/// wire size of the two coded KV blocks (the `kv_wire_bytes` increment;
/// raw is `4 × (k + v)` elements).
#[allow(clippy::too_many_arguments)]
pub fn admit_frame_into(
    buf: &mut Vec<u8>,
    kv_wire: KvCodec,
    stream: StreamId,
    unit: u32,
    id: u64,
    first_token: i32,
    kv_len: u32,
    max_new: u32,
    class: SloClass,
    resume: &[i32],
    k: &[f32],
    v: &[f32],
) -> u64 {
    let mut kv_bytes = 0usize;
    frame_scaffold(
        buf,
        stream,
        30 + 4 * resume.len()
            + 2 * KV_BLOCK_HEADER
            + kv_wire.payload_bound(k.len())
            + kv_wire.payload_bound(v.len()),
        |e| {
            e.u8(TAG_ADMIT);
            e.u32(unit);
            e.u64(id);
            e.i32(first_token);
            e.u32(kv_len);
            e.u32(max_new);
            e.u8(class.to_wire());
            e.i32s(resume);
            kv_bytes = e.kv_block(kv_wire, k) + e.kv_block(kv_wire, v);
        },
    );
    kv_bytes as u64
}

/// Serialize one length-prefixed [`Frame::KvSegment`] into `buf`
/// (cleared first), borrowing the chunk's elements from the prefill
/// outcome — the KV-handoff hot path, same single-buffer discipline as
/// [`admit_frame_into`]. Returns the coded block's wire size.
#[allow(clippy::too_many_arguments)]
pub fn kv_segment_frame_into(
    buf: &mut Vec<u8>,
    kv_wire: KvCodec,
    stream: StreamId,
    id: u64,
    half: KvHalf,
    offset: u32,
    total: u32,
    data: &[f32],
) -> u64 {
    let mut kv_bytes = 0usize;
    frame_scaffold(
        buf,
        stream,
        18 + KV_BLOCK_HEADER + kv_wire.payload_bound(data.len()),
        |e| {
            e.u8(TAG_KV_SEGMENT);
            e.u64(id);
            e.u8(half.to_wire());
            e.u32(offset);
            e.u32(total);
            kv_bytes = e.kv_block(kv_wire, data);
        },
    );
    kv_bytes as u64
}

/// Drive `emit` once per `chunk_elems`-sized chunk of both cache halves,
/// borrow-encoding each chunk into `buf` (reused across chunks). Shared
/// by the relay and direct-transfer senders so the two routes cannot
/// drift in framing; stops at the first `emit` error.
#[allow(clippy::too_many_arguments)]
pub fn each_kv_segment<E>(
    buf: &mut Vec<u8>,
    codec: KvCodec,
    stream: StreamId,
    id: u64,
    chunk_elems: usize,
    k: &[f32],
    v: &[f32],
    mut emit: impl FnMut(&[u8]) -> Result<(), E>,
) -> Result<(), E> {
    for (half, data) in [(KvHalf::K, k), (KvHalf::V, v)] {
        let total = data.len() as u32;
        let mut off = 0usize;
        while off < data.len() {
            let end = (off + chunk_elems.max(1)).min(data.len());
            kv_segment_frame_into(buf, codec, stream, id, half, off as u32, total, &data[off..end]);
            emit(buf)?;
            off = end;
        }
    }
    Ok(())
}

/// Apply one `KvSegment` to a job's assembling cache halves, with the
/// shared geometry guards (a corrupt `total` must not allocate unbounded
/// memory; a chunk must fit its declared total). Shared by the
/// scheduler-side relay reassembly and the decode shard's peer
/// reassembly so the two routes cannot drift in validation.
pub fn apply_kv_segment(
    k: &mut Vec<f32>,
    v: &mut Vec<f32>,
    half: KvHalf,
    offset: u32,
    total: u32,
    data: &[f32],
) -> Result<(), &'static str> {
    let (offset, total) = (offset as usize, total as usize);
    if total > MAX_FRAME as usize / 4 {
        return Err("total exceeds the frame limit");
    }
    if offset.saturating_add(data.len()) > total {
        return Err("chunk overruns its declared total");
    }
    let dst = match half {
        KvHalf::K => k,
        KvHalf::V => v,
    };
    if dst.len() != total {
        dst.resize(total, 0.0);
    }
    dst[offset..offset + data.len()].copy_from_slice(data);
    Ok(())
}

/// Serialize one frame payload (tag + fields, *without* the length
/// prefix).
pub fn encode(f: &Frame) -> Vec<u8> {
    let mut e = Enc(Vec::new());
    match f {
        Frame::Hello { version, kv_wire } => {
            e.u8(TAG_HELLO);
            e.u32(*version);
            e.u8(kv_wire.to_wire());
        }
        Frame::HelloAck {
            version,
            role,
            units,
            slots,
            kv_wire,
            peer_port,
        } => {
            e.u8(TAG_HELLO_ACK);
            e.u32(*version);
            e.u8(role.to_wire());
            e.u32(*units);
            e.u32(*slots);
            e.u8(kv_wire.to_wire());
            e.u32(*peer_port as u32);
        }
        Frame::Admit {
            unit,
            id,
            first_token,
            kv_len,
            max_new,
            class,
            resume,
            k,
            v,
        } => {
            // The enum path always encodes raw (the borrow encoders are
            // the codec-bearing senders); decode accepts any codec.
            e.u8(TAG_ADMIT);
            e.u32(*unit);
            e.u64(*id);
            e.i32(*first_token);
            e.u32(*kv_len);
            e.u32(*max_new);
            e.u8(class.to_wire());
            e.i32s(resume);
            e.kv_block(KvCodec::Raw, k);
            e.kv_block(KvCodec::Raw, v);
        }
        Frame::PrefillDispatch { unit, jobs } => {
            e.u8(TAG_PREFILL_DISPATCH);
            e.u32(*unit);
            e.u32(jobs.len() as u32);
            for j in jobs {
                e.u64(j.id);
                e.u32(j.max_new);
                e.u8(j.class.to_wire());
                e.i32s(&j.prompt);
                match &j.target {
                    Some(t) => {
                        e.u8(1);
                        e.str(&t.addr);
                        e.u32(t.unit);
                    }
                    None => e.u8(0),
                }
            }
        }
        Frame::KvSegment {
            id,
            half,
            offset,
            total,
            data,
        } => {
            e.u8(TAG_KV_SEGMENT);
            e.u64(*id);
            e.u8(half.to_wire());
            e.u32(*offset);
            e.u32(*total);
            e.kv_block(KvCodec::Raw, data);
        }
        Frame::PrefillDone {
            id,
            first_token,
            kv_len,
            exec_time,
        } => {
            e.u8(TAG_PREFILL_DONE);
            e.u64(*id);
            e.i32(*first_token);
            e.u32(*kv_len);
            e.f64(*exec_time);
        }
        Frame::PrefillFailed { id } => {
            e.u8(TAG_PREFILL_FAILED);
            e.u64(*id);
        }
        Frame::Token { id, index, token } => {
            e.u8(TAG_TOKEN);
            e.u64(*id);
            e.u32(*index);
            e.i32(*token);
        }
        Frame::Done { id, tokens } => {
            e.u8(TAG_DONE);
            e.u64(*id);
            e.i32s(tokens);
        }
        Frame::Rejected { id } => {
            e.u8(TAG_REJECTED);
            e.u64(*id);
        }
        Frame::EndForward {
            instance,
            t_measured,
            remaining,
        } => {
            e.u8(TAG_END_FORWARD);
            e.u32(*instance);
            e.f64(*t_measured);
            match remaining {
                Some(r) => {
                    e.u8(1);
                    e.u32(*r);
                }
                None => e.u8(0),
            }
        }
        Frame::Ping { nonce, t_us } => {
            e.u8(TAG_PING);
            e.u64(*nonce);
            e.u64(*t_us);
        }
        Frame::Pong { nonce, t_us } => {
            e.u8(TAG_PONG);
            e.u64(*nonce);
            e.u64(*t_us);
        }
        Frame::StatsRequest => e.u8(TAG_STATS_REQUEST),
        Frame::StatsReply {
            units,
            kv_wire_bytes,
            kv_raw_bytes,
        } => {
            e.u8(TAG_STATS_REPLY);
            e.u32(units.len() as u32);
            for u in units {
                e.u32(u.active);
                e.u32(u.free_slots);
                e.u64(u.kv_tokens);
            }
            e.u64(*kv_wire_bytes);
            e.u64(*kv_raw_bytes);
        }
        Frame::Stop => e.u8(TAG_STOP),
        Frame::Bye => e.u8(TAG_BYE),
        Frame::PeerHello { version, kv_wire } => {
            e.u8(TAG_PEER_HELLO);
            e.u32(*version);
            e.u8(kv_wire.to_wire());
        }
        Frame::PeerHelloAck { version } => {
            e.u8(TAG_PEER_HELLO_ACK);
            e.u32(*version);
        }
        Frame::HandoffCommit {
            unit,
            id,
            first_token,
            kv_len,
            max_new,
            class,
            exec_time,
        } => {
            e.u8(TAG_HANDOFF_COMMIT);
            e.u32(*unit);
            e.u64(*id);
            e.i32(*first_token);
            e.u32(*kv_len);
            e.u32(*max_new);
            e.u8(class.to_wire());
            e.f64(*exec_time);
        }
        Frame::HandoffAck { id } => {
            e.u8(TAG_HANDOFF_ACK);
            e.u64(*id);
        }
        Frame::TraceSpans { dropped, marks } => {
            e.u8(TAG_TRACE_SPANS);
            e.u32(*dropped);
            e.u32(marks.len() as u32);
            for m in marks {
                e.u64(m.id);
                e.u8(m.mark.to_wire());
                e.u64(m.t_us);
                e.u32(m.unit);
            }
        }
        Frame::Migrate { unit, id } => {
            e.u8(TAG_MIGRATE);
            e.u32(*unit);
            e.u64(*id);
        }
        Frame::MigrateAck {
            id,
            found,
            kv_len,
            remaining,
            tokens,
        } => {
            e.u8(TAG_MIGRATE_ACK);
            e.u64(*id);
            e.u8(*found as u8);
            e.u32(*kv_len);
            e.u32(*remaining);
            e.i32s(tokens);
        }
    }
    e.0
}

/// Decode one frame payload (tag + fields, the bytes `encode` produced).
pub fn decode(buf: &[u8]) -> Result<Frame, ProtoError> {
    let mut d = Dec { buf, at: 0 };
    let tag = d.u8()?;
    let f = match tag {
        TAG_HELLO => Frame::Hello {
            version: d.u32()?,
            kv_wire: KvCodec::from_wire(d.u8()?).ok_or(ProtoError::BadValue("kv codec"))?,
        },
        TAG_HELLO_ACK => Frame::HelloAck {
            version: d.u32()?,
            role: ShardRole::from_wire(d.u8()?)?,
            units: d.u32()?,
            slots: d.u32()?,
            kv_wire: KvCodec::from_wire(d.u8()?).ok_or(ProtoError::BadValue("kv codec"))?,
            peer_port: {
                let p = d.u32()?;
                u16::try_from(p).map_err(|_| ProtoError::BadValue("peer port"))?
            },
        },
        TAG_ADMIT => Frame::Admit {
            unit: d.u32()?,
            id: d.u64()?,
            first_token: d.i32()?,
            kv_len: d.u32()?,
            max_new: d.u32()?,
            class: SloClass::from_wire(d.u8()?).ok_or(ProtoError::BadValue("slo class"))?,
            resume: d.i32s()?,
            k: d.kv_block()?,
            v: d.kv_block()?,
        },
        TAG_TOKEN => Frame::Token {
            id: d.u64()?,
            index: d.u32()?,
            token: d.i32()?,
        },
        TAG_DONE => Frame::Done {
            id: d.u64()?,
            tokens: d.i32s()?,
        },
        TAG_REJECTED => Frame::Rejected { id: d.u64()? },
        TAG_END_FORWARD => Frame::EndForward {
            instance: d.u32()?,
            t_measured: d.f64()?,
            remaining: match d.u8()? {
                0 => None,
                _ => Some(d.u32()?),
            },
        },
        TAG_PING => Frame::Ping {
            nonce: d.u64()?,
            t_us: d.u64()?,
        },
        TAG_PONG => Frame::Pong {
            nonce: d.u64()?,
            t_us: d.u64()?,
        },
        TAG_STATS_REQUEST => Frame::StatsRequest,
        TAG_STATS_REPLY => {
            let n = d.u32()? as usize;
            d.check_elems(n, 16)?;
            let mut units = Vec::with_capacity(n);
            for _ in 0..n {
                units.push(UnitLoad {
                    active: d.u32()?,
                    free_slots: d.u32()?,
                    kv_tokens: d.u64()?,
                });
            }
            Frame::StatsReply {
                units,
                kv_wire_bytes: d.u64()?,
                kv_raw_bytes: d.u64()?,
            }
        }
        TAG_STOP => Frame::Stop,
        TAG_BYE => Frame::Bye,
        TAG_PREFILL_DISPATCH => {
            let unit = d.u32()?;
            let n = d.u32()? as usize;
            // Every job is at least id + max_new + class + prompt header.
            d.check_elems(n, 17)?;
            let mut jobs = Vec::with_capacity(n);
            for _ in 0..n {
                jobs.push(PrefillJobWire {
                    id: d.u64()?,
                    max_new: d.u32()?,
                    class: SloClass::from_wire(d.u8()?)
                        .ok_or(ProtoError::BadValue("slo class"))?,
                    prompt: d.i32s()?,
                    target: match d.u8()? {
                        0 => None,
                        1 => Some(DirectTarget {
                            addr: d.str()?,
                            unit: d.u32()?,
                        }),
                        _ => return Err(ProtoError::BadValue("target flag")),
                    },
                });
            }
            Frame::PrefillDispatch { unit, jobs }
        }
        TAG_KV_SEGMENT => Frame::KvSegment {
            id: d.u64()?,
            half: KvHalf::from_wire(d.u8()?)?,
            offset: d.u32()?,
            total: d.u32()?,
            data: d.kv_block()?,
        },
        TAG_PREFILL_DONE => Frame::PrefillDone {
            id: d.u64()?,
            first_token: d.i32()?,
            kv_len: d.u32()?,
            exec_time: d.f64()?,
        },
        TAG_PREFILL_FAILED => Frame::PrefillFailed { id: d.u64()? },
        TAG_PEER_HELLO => Frame::PeerHello {
            version: d.u32()?,
            kv_wire: KvCodec::from_wire(d.u8()?).ok_or(ProtoError::BadValue("kv codec"))?,
        },
        TAG_PEER_HELLO_ACK => Frame::PeerHelloAck { version: d.u32()? },
        TAG_HANDOFF_COMMIT => Frame::HandoffCommit {
            unit: d.u32()?,
            id: d.u64()?,
            first_token: d.i32()?,
            kv_len: d.u32()?,
            max_new: d.u32()?,
            class: SloClass::from_wire(d.u8()?).ok_or(ProtoError::BadValue("slo class"))?,
            exec_time: d.f64()?,
        },
        TAG_HANDOFF_ACK => Frame::HandoffAck { id: d.u64()? },
        TAG_TRACE_SPANS => {
            let dropped = d.u32()?;
            let n = d.u32()? as usize;
            // Each mark is id(8) + mark(1) + t_us(8) + unit(4) bytes.
            d.check_elems(n, 21)?;
            let mut marks = Vec::with_capacity(n);
            for _ in 0..n {
                marks.push(TraceMark {
                    id: d.u64()?,
                    mark: Mark::from_wire(d.u8()?).ok_or(ProtoError::BadValue("trace mark"))?,
                    t_us: d.u64()?,
                    unit: d.u32()?,
                });
            }
            Frame::TraceSpans { dropped, marks }
        }
        TAG_MIGRATE => Frame::Migrate {
            unit: d.u32()?,
            id: d.u64()?,
        },
        TAG_MIGRATE_ACK => Frame::MigrateAck {
            id: d.u64()?,
            found: match d.u8()? {
                0 => false,
                1 => true,
                _ => return Err(ProtoError::BadValue("migrate found flag")),
            },
            kv_len: d.u32()?,
            remaining: d.u32()?,
            tokens: d.i32s()?,
        },
        t => return Err(ProtoError::BadTag(t)),
    };
    d.finish()?;
    Ok(f)
}

/// Write one frame on the control stream. The whole frame is serialized
/// first and written with one `write_all`, so a frame is never
/// interleaved with another writer's bytes as long as callers serialize
/// writes.
pub fn write_frame<W: Write>(w: &mut W, f: &Frame) -> std::io::Result<()> {
    write_frame_on(w, STREAM_CONTROL, f)
}

/// Write one frame on an explicit stream (same single-`write_all`
/// discipline as [`write_frame`]).
pub fn write_frame_on<W: Write>(w: &mut W, stream: StreamId, f: &Frame) -> std::io::Result<()> {
    w.write_all(&frame_bytes_on(stream, f))
}

/// Serialize one complete wire frame (`[u32 len][u32 stream][payload]`)
/// for callers that enqueue bytes instead of writing a socket directly.
pub fn frame_bytes_on(stream: StreamId, f: &Frame) -> Vec<u8> {
    let payload = encode(f);
    let mut out = Vec::with_capacity(8 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&stream.to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

enum ReadState {
    /// Filling the 8-byte `[u32 len][u32 stream]` header.
    Header,
    /// Filling a payload (`buf` is sized to the decoded length).
    Payload,
}

/// Incremental frame reader that survives socket read timeouts.
///
/// [`FrameReader::poll`] returns `Ok(None)` on `WouldBlock`/`TimedOut`
/// *keeping any partial bytes already consumed*, so the caller can use a
/// socket read timeout as an idle tick (to check a stop flag, send a
/// ping) without ever desynchronizing the stream.
pub struct FrameReader {
    state: ReadState,
    buf: Vec<u8>,
    filled: usize,
    consumed: u64,
    /// Stream id from the frame header being read (valid once the
    /// header is complete; reported by [`FrameReader::poll_stream`]).
    stream: StreamId,
}

impl Default for FrameReader {
    fn default() -> Self {
        Self::new()
    }
}

impl FrameReader {
    /// Fresh reader at a frame boundary.
    pub fn new() -> Self {
        FrameReader {
            state: ReadState::Header,
            buf: vec![0; 8],
            filled: 0,
            consumed: 0,
            stream: STREAM_CONTROL,
        }
    }

    /// Total bytes consumed from the stream so far. Monotonic across
    /// frames *and across timeouts*, so liveness guards can treat a
    /// large frame trickling in slowly as activity rather than silence.
    pub fn consumed(&self) -> u64 {
        self.consumed
    }

    fn reset_frame(&mut self) {
        self.state = ReadState::Header;
        self.buf = vec![0; 8];
        self.filled = 0;
    }

    /// Drive the reader with one blocking-with-timeout source. Returns
    /// `Ok(Some(frame))` when a full frame is available, `Ok(None)` on a
    /// read timeout (partial progress is preserved), or an error on EOF /
    /// transport failure / malformed frame. Stream-agnostic consumers
    /// (the scheduler planes, where every frame is stand-alone) use
    /// this; multiplexed consumers use [`FrameReader::poll_stream`].
    pub fn poll<R: Read>(&mut self, r: &mut R) -> Result<Option<Frame>, ProtoError> {
        Ok(self.poll_stream(r)?.map(|(_, f)| f))
    }

    /// Like [`FrameReader::poll`], but reports the [`StreamId`] from the
    /// frame header alongside the frame.
    pub fn poll_stream<R: Read>(
        &mut self,
        r: &mut R,
    ) -> Result<Option<(StreamId, Frame)>, ProtoError> {
        loop {
            while self.filled < self.buf.len() {
                match r.read(&mut self.buf[self.filled..]) {
                    Ok(0) => return Err(ProtoError::Closed),
                    Ok(n) => {
                        self.filled += n;
                        self.consumed += n as u64;
                    }
                    Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                        return Ok(None)
                    }
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(e) => return Err(ProtoError::Io(e)),
                }
            }
            match self.state {
                ReadState::Header => {
                    let len = u32::from_le_bytes(self.buf[..4].try_into().unwrap());
                    if len > MAX_FRAME {
                        return Err(ProtoError::Oversize(len));
                    }
                    self.stream = u32::from_le_bytes(self.buf[4..8].try_into().unwrap());
                    self.state = ReadState::Payload;
                    self.buf = vec![0; len as usize];
                    self.filled = 0;
                }
                ReadState::Payload => {
                    let frame = decode(&self.buf)?;
                    let stream = self.stream;
                    self.reset_frame();
                    return Ok(Some((stream, frame)));
                }
            }
        }
    }
}

/// Byte-granular silence tracker for the symmetric silence-to-death
/// guards on both ends of a shard connection (the scheduler's
/// `dead_after` and the shard's connection timeout). Activity is
/// *consumed bytes*, not complete frames, so a large frame trickling in
/// over a slow link never reads as silence. Both guards rely on the
/// scheduler's 1 s ping cadence keeping a healthy link audible; keep
/// any deadline comfortably above it.
pub struct IdleGuard {
    last_activity: Instant,
    last_consumed: u64,
}

impl IdleGuard {
    /// Start the guard against `reader`'s current position.
    pub fn new(reader: &FrameReader) -> Self {
        IdleGuard {
            last_activity: Instant::now(),
            last_consumed: reader.consumed(),
        }
    }

    /// How long the stream has been byte-silent. Call with the same
    /// reader each poll cycle; any consumed-byte progress (or a call to
    /// [`IdleGuard::touch`] on a complete frame) resets the clock.
    pub fn idle_for(&mut self, reader: &FrameReader) -> Duration {
        if reader.consumed() != self.last_consumed {
            self.last_consumed = reader.consumed();
            self.last_activity = Instant::now();
        }
        self.last_activity.elapsed()
    }

    /// Record explicit activity (a complete frame was handled).
    pub fn touch(&mut self) {
        self.last_activity = Instant::now();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn arbitrary_codec(rng: &mut Rng) -> KvCodec {
        match rng.below(3) {
            0 => KvCodec::Raw,
            1 => KvCodec::Fp16,
            _ => KvCodec::Lz,
        }
    }

    fn arbitrary_class(rng: &mut Rng) -> SloClass {
        SloClass::from_wire(rng.below(3) as u8).unwrap()
    }

    fn arbitrary_frame(rng: &mut Rng) -> Frame {
        match rng.below(24) {
            0 => Frame::Hello {
                version: rng.next_u64() as u32,
                kv_wire: arbitrary_codec(rng),
            },
            1 => Frame::HelloAck {
                version: rng.next_u64() as u32,
                role: if rng.chance(0.5) {
                    ShardRole::Decode
                } else {
                    ShardRole::Prefill
                },
                units: rng.below(64) as u32,
                slots: rng.below(256) as u32,
                kv_wire: arbitrary_codec(rng),
                peer_port: rng.below(1 << 16) as u16,
            },
            2 => Frame::Admit {
                unit: rng.below(16) as u32,
                id: rng.next_u64(),
                first_token: rng.next_u64() as i32,
                kv_len: rng.below(4096) as u32,
                max_new: rng.below(1024) as u32,
                class: arbitrary_class(rng),
                resume: (0..rng.below(16)).map(|_| rng.next_u64() as i32).collect(),
                k: (0..rng.below(32)).map(|_| rng.f64() as f32).collect(),
                v: (0..rng.below(32)).map(|_| rng.f64() as f32).collect(),
            },
            3 => Frame::Token {
                id: rng.next_u64(),
                index: rng.below(1 << 20) as u32,
                token: rng.next_u64() as i32,
            },
            4 => Frame::Done {
                id: rng.next_u64(),
                tokens: (0..rng.below(64)).map(|_| rng.next_u64() as i32).collect(),
            },
            5 => Frame::Rejected { id: rng.next_u64() },
            6 => Frame::EndForward {
                instance: rng.below(32) as u32,
                t_measured: rng.f64() * 10.0,
                remaining: rng.chance(0.5).then(|| rng.below(1 << 16) as u32),
            },
            7 => Frame::Ping {
                nonce: rng.next_u64(),
                t_us: rng.next_u64(),
            },
            8 => Frame::Pong {
                nonce: rng.next_u64(),
                t_us: rng.next_u64(),
            },
            9 => Frame::StatsRequest,
            10 => Frame::StatsReply {
                units: (0..rng.below(8))
                    .map(|_| UnitLoad {
                        active: rng.below(64) as u32,
                        free_slots: rng.below(64) as u32,
                        kv_tokens: rng.below(1 << 30),
                    })
                    .collect(),
                kv_wire_bytes: rng.below(1 << 40),
                kv_raw_bytes: rng.below(1 << 40),
            },
            11 => Frame::Stop,
            12 => Frame::Bye,
            13 => Frame::PrefillDispatch {
                unit: rng.below(8) as u32,
                jobs: (0..rng.below(6))
                    .map(|_| PrefillJobWire {
                        id: rng.next_u64(),
                        max_new: rng.below(512) as u32,
                        class: arbitrary_class(rng),
                        prompt: (0..1 + rng.below(48)).map(|_| rng.next_u64() as i32).collect(),
                        target: rng.chance(0.5).then(|| DirectTarget {
                            addr: format!("127.0.0.1:{}", rng.below(1 << 16)),
                            unit: rng.below(16) as u32,
                        }),
                    })
                    .collect(),
            },
            14 => Frame::KvSegment {
                id: rng.next_u64(),
                half: if rng.chance(0.5) { KvHalf::K } else { KvHalf::V },
                offset: rng.below(1 << 20) as u32,
                total: rng.below(1 << 20) as u32,
                data: (0..rng.below(64)).map(|_| rng.f64() as f32).collect(),
            },
            15 => Frame::PrefillDone {
                id: rng.next_u64(),
                first_token: rng.next_u64() as i32,
                kv_len: rng.below(4096) as u32,
                exec_time: rng.f64() * 5.0,
            },
            16 => Frame::PrefillFailed { id: rng.next_u64() },
            17 => Frame::PeerHello {
                version: rng.next_u64() as u32,
                kv_wire: arbitrary_codec(rng),
            },
            18 => Frame::PeerHelloAck {
                version: rng.next_u64() as u32,
            },
            19 => Frame::HandoffCommit {
                unit: rng.below(16) as u32,
                id: rng.next_u64(),
                first_token: rng.next_u64() as i32,
                kv_len: rng.below(4096) as u32,
                max_new: rng.below(1024) as u32,
                class: arbitrary_class(rng),
                exec_time: rng.f64() * 5.0,
            },
            20 => Frame::HandoffAck { id: rng.next_u64() },
            21 => Frame::TraceSpans {
                dropped: rng.below(1 << 10) as u32,
                marks: (0..rng.below(16))
                    .map(|_| TraceMark {
                        id: rng.next_u64(),
                        mark: Mark::from_wire(rng.below(9) as u8).unwrap(),
                        t_us: rng.next_u64() >> 16,
                        unit: rng.below(16) as u32,
                    })
                    .collect(),
            },
            22 => Frame::Migrate {
                unit: rng.below(16) as u32,
                id: rng.next_u64(),
            },
            _ => Frame::MigrateAck {
                id: rng.next_u64(),
                found: rng.chance(0.5),
                kv_len: rng.below(4096) as u32,
                remaining: rng.below(1024) as u32,
                tokens: (0..rng.below(48)).map(|_| rng.next_u64() as i32).collect(),
            },
        }
    }

    #[test]
    fn every_frame_round_trips() {
        let mut rng = Rng::new(0xF8A3);
        for i in 0..2000 {
            let f = arbitrary_frame(&mut rng);
            let bytes = encode(&f);
            let back = decode(&bytes).unwrap_or_else(|e| panic!("iter {i}: {e} for {f:?}"));
            assert_eq!(f, back, "iter {i}");
        }
    }

    #[test]
    fn truncated_payloads_error_not_panic() {
        let mut rng = Rng::new(0xBEEF);
        for _ in 0..500 {
            let f = arbitrary_frame(&mut rng);
            let bytes = encode(&f);
            for cut in 0..bytes.len() {
                assert!(decode(&bytes[..cut]).is_err(), "prefix of {f:?} must not decode");
            }
        }
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut bytes = encode(&Frame::Stop);
        bytes.push(0);
        assert!(matches!(decode(&bytes), Err(ProtoError::TrailingBytes)));
    }

    #[test]
    fn corrupt_element_counts_error_not_oom() {
        // A Done frame whose token count claims far more elements than
        // the payload carries must fail before allocating.
        let mut e = Enc(Vec::new());
        e.u8(TAG_DONE);
        e.u64(7);
        e.u32(u32::MAX); // element count
        assert!(matches!(decode(&e.0), Err(ProtoError::Truncated)));
    }

    #[test]
    fn unknown_tag_rejected() {
        assert!(matches!(decode(&[200]), Err(ProtoError::BadTag(200))));
    }

    #[test]
    fn out_of_domain_role_byte_rejected() {
        let mut e = Enc(Vec::new());
        e.u8(TAG_HELLO_ACK);
        e.u32(PROTO_VERSION);
        e.u8(9); // role: neither decode nor prefill
        e.u32(1);
        e.u32(1);
        assert!(matches!(decode(&e.0), Err(ProtoError::BadValue("shard role"))));
    }

    #[test]
    fn out_of_domain_slo_class_byte_rejected() {
        let mut buf = Vec::new();
        admit_frame_into(
            &mut buf,
            KvCodec::Raw,
            STREAM_CONTROL,
            0,
            1,
            0,
            4,
            4,
            SloClass::Standard,
            &[],
            &[1.0; 4],
            &[1.0; 4],
        );
        // The class byte sits after tag+unit+id+first_token+kv_len+max_new
        // past the 8-byte frame header (resume and the KV blocks follow
        // the class byte, so its offset is layout-stable).
        let class_at = 8 + 1 + 4 + 8 + 4 + 4 + 4;
        assert_eq!(buf[class_at], SloClass::Standard.to_wire());
        buf[class_at] = 9;
        assert!(matches!(
            decode(&buf[8..]),
            Err(ProtoError::BadValue("slo class"))
        ));
    }

    #[test]
    fn borrow_encoders_match_the_enum_encoding() {
        let k: Vec<f32> = (0..70).map(|i| i as f32 * 0.5).collect();
        let v: Vec<f32> = (0..70).map(|i| i as f32 * -0.25).collect();
        let mut wire = Vec::new();
        write_frame(
            &mut wire,
            &Frame::Admit {
                unit: 3,
                id: 99,
                first_token: 7,
                kv_len: 5,
                max_new: 11,
                class: SloClass::Interactive,
                resume: vec![7, 8, 9],
                k: k.clone(),
                v: v.clone(),
            },
        )
        .unwrap();
        let mut buf = Vec::new();
        let kv_bytes = admit_frame_into(
            &mut buf,
            KvCodec::Raw,
            STREAM_CONTROL,
            3,
            99,
            7,
            5,
            11,
            SloClass::Interactive,
            &[7, 8, 9],
            &k,
            &v,
        );
        assert_eq!(buf, wire, "admit borrow encoder must be byte-identical");
        assert_eq!(
            kv_bytes,
            2 * (KV_BLOCK_HEADER as u64 + 4 * 70),
            "raw block accounting"
        );

        let mut wire = Vec::new();
        write_frame(
            &mut wire,
            &Frame::KvSegment {
                id: 99,
                half: KvHalf::V,
                offset: 128,
                total: 4096,
                data: k.clone(),
            },
        )
        .unwrap();
        let mut buf = Vec::new();
        kv_segment_frame_into(&mut buf, KvCodec::Raw, STREAM_CONTROL, 99, KvHalf::V, 128, 4096, &k);
        assert_eq!(buf, wire, "kv-segment borrow encoder must be byte-identical");
    }

    #[test]
    fn stream_ids_round_trip_through_header_and_reader() {
        let mut wire = Vec::new();
        write_frame_on(&mut wire, 7, &Frame::HandoffAck { id: 1 }).unwrap();
        write_frame_on(&mut wire, 12, &Frame::HandoffAck { id: 2 }).unwrap();
        write_frame(&mut wire, &Frame::StatsRequest).unwrap();
        let mut buf = Vec::new();
        kv_segment_frame_into(&mut buf, KvCodec::Raw, 7, 1, KvHalf::K, 0, 4, &[1.0; 4]);
        wire.extend_from_slice(&buf);
        let mut reader = FrameReader::new();
        let mut src = wire.as_slice();
        let mut got = Vec::new();
        while let Ok(Some((s, f))) = reader.poll_stream(&mut src) {
            got.push((s, f));
        }
        assert_eq!(got.len(), 4);
        assert_eq!(got[0].0, 7);
        assert_eq!(got[1].0, 12);
        assert_eq!(got[2].0, STREAM_CONTROL, "write_frame sends on the control stream");
        assert_eq!(got[3].0, 7, "borrow encoders stamp the stream header");
        assert!(matches!(got[3].1, Frame::KvSegment { id: 1, .. }));
    }

    /// Representative KV content: fp16-exact values (multiples of 2⁻⁴)
    /// with short constant runs, so lz has structure and fp16 is
    /// bit-recoverable.
    fn kv_pattern(n: usize) -> Vec<f32> {
        (0..n).map(|i| (7.0 + (i / 7) as f32 * 0.5) * 0.125).collect()
    }

    #[test]
    fn coded_admit_frames_round_trip_per_codec() {
        let k = kv_pattern(3000);
        let v: Vec<f32> = kv_pattern(3000).iter().map(|x| -x).collect();
        for codec in [KvCodec::Raw, KvCodec::Fp16, KvCodec::Lz] {
            let mut buf = Vec::new();
            let kv_bytes = admit_frame_into(
                &mut buf,
                codec,
                STREAM_CONTROL,
                2,
                77,
                9,
                3000,
                5,
                SloClass::Batch,
                &[],
                &k,
                &v,
            );
            let frame = decode(&buf[8..]).unwrap_or_else(|e| panic!("{}: {e}", codec.name()));
            let Frame::Admit { id: 77, class: SloClass::Batch, k: dk, v: dv, .. } = frame else {
                panic!("wrong frame: {frame:?}")
            };
            assert_eq!(dk, k, "{}: K must survive (values are fp16-exact)", codec.name());
            assert_eq!(dv, v, "{}: V must survive", codec.name());
            match codec {
                KvCodec::Raw => assert_eq!(kv_bytes, 2 * (9 + 4 * 3000)),
                KvCodec::Fp16 => assert_eq!(kv_bytes, 2 * (9 + 2 * 3000)),
                KvCodec::Lz => assert!(
                    (kv_bytes as f64) < 0.6 * (2.0 * 4.0 * 3000.0),
                    "structured KV must shrink ≥40% under lz: {kv_bytes}"
                ),
            }
        }
    }

    #[test]
    fn fp16_blocks_stay_within_half_precision_tolerance() {
        let mut rng = Rng::new(0xF16);
        let data: Vec<f32> = (0..4096).map(|_| rng.uniform(-100.0, 100.0) as f32).collect();
        let mut buf = Vec::new();
        kv_segment_frame_into(
            &mut buf,
            KvCodec::Fp16,
            STREAM_CONTROL,
            5,
            KvHalf::K,
            0,
            4096,
            &data,
        );
        let Frame::KvSegment { data: back, .. } = decode(&buf[8..]).unwrap() else {
            panic!("wrong frame")
        };
        for (a, b) in data.iter().zip(&back) {
            let rel = ((a - b) / a.abs().max(1e-3)).abs();
            assert!(rel <= 1.0 / 1024.0, "fp16 error too large: {a} vs {b}");
        }
    }

    #[test]
    fn lz_blocks_are_bit_exact_on_random_data() {
        let mut rng = Rng::new(0x12E);
        for _ in 0..20 {
            let data: Vec<f32> = (0..rng.below(5000)).map(|_| rng.f64() as f32).collect();
            let mut buf = Vec::new();
            kv_segment_frame_into(
                &mut buf,
                KvCodec::Lz,
                STREAM_CONTROL,
                5,
                KvHalf::V,
                0,
                data.len() as u32,
                &data,
            );
            let Frame::KvSegment { data: back, .. } = decode(&buf[8..]).unwrap() else {
                panic!("wrong frame")
            };
            assert_eq!(back, data, "lz must be bit-exact");
        }
    }

    #[test]
    fn coded_frames_reject_truncation_at_every_byte_offset() {
        let k = kv_pattern(600);
        for codec in [KvCodec::Raw, KvCodec::Fp16, KvCodec::Lz] {
            let mut buf = Vec::new();
            admit_frame_into(
                &mut buf,
                codec,
                STREAM_CONTROL,
                0,
                1,
                0,
                600,
                4,
                SloClass::Standard,
                &[],
                &k,
                &k,
            );
            let payload = &buf[8..];
            for cut in 0..payload.len() {
                assert!(
                    decode(&payload[..cut]).is_err(),
                    "{}: truncated admit at {cut} must not decode",
                    codec.name()
                );
            }
            let mut buf = Vec::new();
            kv_segment_frame_into(&mut buf, codec, STREAM_CONTROL, 1, KvHalf::K, 0, 600, &k);
            let payload = &buf[8..];
            for cut in 0..payload.len() {
                assert!(
                    decode(&payload[..cut]).is_err(),
                    "{}: truncated segment at {cut} must not decode",
                    codec.name()
                );
            }
        }
    }

    #[test]
    fn corrupt_codec_byte_and_element_count_rejected() {
        let mut buf = Vec::new();
        kv_segment_frame_into(
            &mut buf,
            KvCodec::Raw,
            STREAM_CONTROL,
            1,
            KvHalf::K,
            0,
            4,
            &[1.0, 2.0, 3.0, 4.0],
        );
        // The codec byte sits right after id(8)+half(1)+offset(4)+total(4)
        // past the tag; flip it to an unknown codec.
        let codec_at = 8 + 1 + 8 + 1 + 4 + 4;
        let mut bad = buf.clone();
        bad[codec_at] = 7;
        assert!(matches!(
            decode(&bad[8..]),
            Err(ProtoError::BadValue("kv codec"))
        ));
        // A huge element count must fail before allocating.
        let mut bad = buf.clone();
        bad[codec_at + 1..codec_at + 5].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&bad[8..]).is_err());
    }

    #[test]
    fn borrow_encoders_reuse_the_buffer_without_reallocating() {
        // The zero-intermediate-allocation property of the hot path:
        // same-shape frames into one reused buffer must not touch the
        // allocator — heap pointer and capacity stay fixed after the
        // first encode (clear + reserve only, never a fresh Vec). The
        // compressed codecs must hold the same property: their scaffold
        // reservation is the worst-case bound, so a varying compressed
        // size never grows the buffer.
        let k = vec![1.0f32; 4096];
        let v = vec![2.0f32; 4096];
        for codec in [KvCodec::Raw, KvCodec::Fp16, KvCodec::Lz] {
            let cls = SloClass::Standard;
            let mut buf = Vec::new();
            admit_frame_into(&mut buf, codec, STREAM_CONTROL, 0, 1, 0, 4, 4, cls, &[], &k, &v);
            let (ptr, cap) = (buf.as_ptr(), buf.capacity());
            for id in 2..32u64 {
                admit_frame_into(&mut buf, codec, STREAM_CONTROL, 0, id, 0, 4, 4, cls, &[], &k, &v);
                assert_eq!(buf.as_ptr(), ptr, "{}: admit encode reallocated", codec.name());
                assert_eq!(buf.capacity(), cap, "{}: admit encode grew", codec.name());
            }
            let mut buf = Vec::new();
            kv_segment_frame_into(&mut buf, codec, 1, 1, KvHalf::K, 0, 8192, &k);
            let (ptr, cap) = (buf.as_ptr(), buf.capacity());
            for off in 1..32u32 {
                kv_segment_frame_into(&mut buf, codec, 1, 1, KvHalf::K, off, 8192, &k);
                assert_eq!(buf.as_ptr(), ptr, "{}: segment encode reallocated", codec.name());
                assert_eq!(buf.capacity(), cap, "{}: segment encode grew", codec.name());
            }
        }
    }

    /// A reader that delivers one byte per call, interleaving timeouts —
    /// the worst case a socket read timeout can produce.
    struct Trickle {
        data: Vec<u8>,
        at: usize,
        tick: bool,
    }

    impl std::io::Read for Trickle {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            self.tick = !self.tick;
            if self.tick {
                return Err(std::io::Error::new(ErrorKind::WouldBlock, "tick"));
            }
            if self.at >= self.data.len() {
                return Ok(0);
            }
            buf[0] = self.data[self.at];
            self.at += 1;
            Ok(1)
        }
    }

    #[test]
    fn frame_reader_survives_timeouts_mid_frame() {
        let mut rng = Rng::new(0xC0DE);
        let frames: Vec<Frame> = (0..40).map(|_| arbitrary_frame(&mut rng)).collect();
        let mut data = Vec::new();
        for f in &frames {
            write_frame(&mut data, f).unwrap();
        }
        let mut src = Trickle {
            data,
            at: 0,
            tick: false,
        };
        let mut reader = FrameReader::new();
        let mut got = Vec::new();
        loop {
            match reader.poll(&mut src) {
                Ok(Some(f)) => got.push(f),
                Ok(None) => continue, // timeout tick: state preserved
                Err(ProtoError::Closed) => break,
                Err(e) => panic!("{e}"),
            }
        }
        assert_eq!(got, frames);
    }

    #[test]
    fn oversize_length_prefix_rejected() {
        let mut data = (MAX_FRAME + 1).to_le_bytes().to_vec();
        data.extend_from_slice(&[0; 16]);
        let mut reader = FrameReader::new();
        assert!(matches!(
            reader.poll(&mut data.as_slice()),
            Err(ProtoError::Oversize(_))
        ));
    }
}
