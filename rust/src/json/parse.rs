//! Recursive-descent JSON parser.

use super::Json;
use std::collections::BTreeMap;
use std::fmt;

/// Parse failure with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    /// Byte offset at which parsing failed.
    pub at: usize,
    /// Human-readable cause.
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (rejects trailing garbage).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        b: input.as_bytes(),
        i: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            at: self.i,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // BMP only; surrogate pairs are rejected (our
                        // artifacts are ASCII).
                        match char::from_u32(cp) {
                            Some(c) => s.push(c),
                            None => return Err(self.err("invalid \\u escape")),
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = utf8_width(c).ok_or_else(|| self.err("bad utf-8"))?;
                        let end = start + width;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf-8"))?;
                        s.push_str(chunk);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v: u32 = 0;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_width(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numbers() {
        assert_eq!(parse("3").unwrap(), Json::Num(3.0));
        assert_eq!(parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(parse("0.125").unwrap(), Json::Num(0.125));
    }

    #[test]
    fn nested() {
        let j = parse(r#"{"a": [1, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[1].get("b"),
            Some(&Json::Null)
        );
        assert_eq!(j.get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("nul").is_err());
    }
}
