//! Minimal JSON value model, parser and writer.
//!
//! The offline registry ships no `serde`, so traces, metrics dumps and the
//! python-side `model_meta.json` interchange go through this small,
//! dependency-free implementation. It supports the full JSON grammar
//! except `\u` surrogate pairs beyond the BMP (sufficient for our ASCII
//! artifacts).

mod parse;
mod write;

pub use parse::{parse, ParseError};
pub use write::to_string;

use std::collections::BTreeMap;

/// A JSON value. Object keys are kept in a `BTreeMap` so serialization is
/// deterministic (stable golden tests, reproducible traces).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (held as f64; integers round-trip exactly to 2^53).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object with deterministic (sorted) key order.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Look up a key in an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Nested object lookup: `j.path(&["summary", "ttft_p99_ms", "mean"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        keys.iter().try_fold(self, |j, k| j.get(k))
    }

    /// Numeric value at a nested path.
    pub fn f64_at(&self, keys: &[&str]) -> Option<f64> {
        self.path(keys).and_then(Json::as_f64)
    }

    /// Numeric accessor.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integer accessor (exact for |x| <= 2^53).
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    /// Unsigned accessor.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Bool accessor.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array accessor.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        to_string(self)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Self {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj(vec![
            ("a", Json::from(1.5)),
            ("b", Json::from("hi")),
            ("c", Json::from(vec![1.0, 2.0])),
            ("d", Json::Null),
            ("e", Json::from(true)),
        ]);
        let s = j.dump();
        let back = parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn accessors() {
        let j = parse(r#"{"n": 3, "s": "x", "a": [1, 2], "b": false}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(j.get("b").unwrap().as_bool(), Some(false));
        assert!(j.get("missing").is_none());
    }

    #[test]
    fn nested_path_lookup() {
        let j = parse(r#"{"a": {"b": {"c": 2.5}}, "n": 1}"#).unwrap();
        assert_eq!(j.f64_at(&["a", "b", "c"]), Some(2.5));
        assert_eq!(j.f64_at(&["a", "b", "nope"]), None);
        assert_eq!(j.f64_at(&["n"]), Some(1.0));
        assert!(j.path(&["n", "deeper"]).is_none());
    }
}
