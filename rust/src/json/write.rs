//! Compact JSON serialization.

use super::Json;
use std::fmt::Write as _;

/// Serialize a [`Json`] value to a compact string. Object keys are emitted
/// in sorted order (deterministic output).
pub fn to_string(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, &mut out);
    out
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(x) => write_num(*x, out),
        Json::Str(s) => write_str(s, out),
        Json::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

fn write_num(x: f64, out: &mut String) {
    if !x.is_finite() {
        // JSON has no NaN/Inf; emit null like most tolerant writers.
        out.push_str("null");
    } else if x == x.trunc() && x.abs() < 9.007_199_254_740_992e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;

    #[test]
    fn integers_stay_integers() {
        assert_eq!(to_string(&Json::Num(3.0)), "3");
        assert_eq!(to_string(&Json::Num(-7.0)), "-7");
        assert_eq!(to_string(&Json::Num(1.5)), "1.5");
    }

    #[test]
    fn escapes() {
        assert_eq!(to_string(&Json::Str("a\"b\n".into())), r#""a\"b\n""#);
    }

    #[test]
    fn nonfinite_to_null() {
        assert_eq!(to_string(&Json::Num(f64::NAN)), "null");
    }

    #[test]
    fn roundtrip_keys_sorted() {
        let j = Json::obj(vec![("z", Json::from(1.0)), ("a", Json::from(2.0))]);
        let s = to_string(&j);
        assert_eq!(s, r#"{"a":2,"z":1}"#);
        assert_eq!(parse(&s).unwrap(), j);
    }
}
