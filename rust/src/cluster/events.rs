//! Discrete-event machinery: a time-ordered event heap over `f64`
//! timestamps with deterministic FIFO tie-breaking.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Heap entry: time + insertion sequence + payload.
struct Entry<E> {
    t: f64,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first. NaN times
        // are rejected at push.
        other
            .t
            .partial_cmp(&self.t)
            .unwrap()
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Earliest-first event queue with stable ordering for simultaneous
/// events (insertion order).
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: f64,
}

impl<E> EventQueue<E> {
    /// Empty queue at t = 0.
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
        }
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `ev` at absolute time `t` (must be finite; events in the
    /// past are clamped to `now` — zero-delay self-messages are legal).
    pub fn push(&mut self, t: f64, ev: E) {
        assert!(t.is_finite(), "event time must be finite");
        let t = t.max(self.now);
        self.heap.push(Entry {
            t,
            seq: self.seq,
            ev,
        });
        self.seq += 1;
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|e| {
            self.now = e.t;
            (e.t, e.ev)
        })
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn earliest_first() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn fifo_on_ties() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(1.0, 2);
        q.push(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn clock_advances_and_clamps() {
        let mut q = EventQueue::new();
        q.push(5.0, "x");
        q.pop();
        assert_eq!(q.now(), 5.0);
        q.push(1.0, "past"); // clamped to now
        assert_eq!(q.pop(), Some((5.0, "past")));
    }

    #[test]
    #[should_panic]
    fn rejects_nan() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }
}
