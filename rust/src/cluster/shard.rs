//! Standalone decode shard process: `sbs worker --decode --listen
//! <addr>` runs one or more decode DP units and serves the
//! [`crate::transport::proto`] frame protocol, so a scheduler
//! (`sbs serve --remote-decode <addr>`) can drive them from another
//! process or machine through the same dispatch core as its local pool.
//!
//! ## Connection model
//!
//! The shard serves **one scheduler at a time**: the accept loop
//! handshakes (`Hello`/`HelloAck`), aborts any state a previous
//! connection left behind (that scheduler already evicted those
//! sequences on its side), then relays frames until EOF — after which it
//! goes back to accepting, which is what makes scheduler-side reconnect
//! work. Unit engine threads persist across connections.
//!
//! A single writer thread serializes all outbound frames (unit events,
//! `Pong`, `StatsReply`, `Bye`) onto the current connection; events that
//! arrive while no scheduler is connected are dropped — their sequences
//! were (or will be) evicted by the scheduler that owned them.
//!
//! `Stop` drains: units finish their active sequences (their `Done`
//! frames flush first), the shard replies `Bye` and the process exits.

use super::workers::{DecodeEventSink, EngineSpec, run_decode_unit, UnitGauges};
use crate::cli::Command;
use crate::engine::mock::MockEngineConfig;
use crate::engine::sampler::Sampling;
use crate::engine::PrefillOutcome;
use crate::metrics::RequestMetrics;
use crate::runtime::artifacts_dir;
use crate::transport::proto::{self, Frame, FrameReader, PROTO_VERSION, ProtoError, UnitLoad};
use crate::transport::{AdmitJob, UnitMsg};
use crate::util::{Clock, RealClock};
use anyhow::{anyhow, Result};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Decode shard configuration.
#[derive(Debug, Clone)]
pub struct ShardConfig {
    /// Decode DP units (one batched engine thread each).
    pub units: u32,
    /// Decode slots per unit (advertised in `HelloAck`).
    pub batch: u32,
    /// Execution backend for the unit threads.
    pub engine: EngineSpec,
    /// Sampling policy for generation.
    pub sampling: Sampling,
    /// RNG seed.
    pub seed: u64,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            units: 1,
            batch: 8,
            engine: EngineSpec::Mock(MockEngineConfig::default()),
            sampling: Sampling::Greedy,
            seed: 17,
        }
    }
}

/// `sbs worker` entrypoint.
pub fn cli_worker(argv: &[String]) -> Result<()> {
    let cmd = Command::new("sbs worker", "run a standalone decode shard")
        .flag("decode", "serve decode DP units (required; prefill later)")
        .opt(
            "listen",
            "bind address (e.g. 127.0.0.1:7501; port 0 = ephemeral)",
            Some("127.0.0.1:7501"),
        )
        .opt("units", "decode DP units in this shard", Some("1"))
        .opt("batch", "decode slots per unit", Some("8"))
        .opt("engine", "pjrt | mock", Some("mock"))
        .opt("artifacts", "artifact directory (pjrt engine)", Some("artifacts"))
        .opt("mock-decode-ms", "mock engine: one decode step, milliseconds", Some("4"))
        .opt("mock-jitter", "mock engine: execution-time jitter fraction", Some("0.1"))
        .opt("seed", "rng seed", Some("17"));
    let args = cmd.parse(argv).map_err(|e| anyhow!("{e}"))?;
    if !args.flag("decode") {
        return Err(anyhow!(
            "`sbs worker` currently serves decode shards only: pass --decode"
        ));
    }
    let engine = match args.str_or("engine", "mock").as_str() {
        "pjrt" => EngineSpec::Pjrt {
            artifacts: std::path::PathBuf::from(
                args.str_or("artifacts", artifacts_dir().to_str().unwrap_or("artifacts")),
            ),
        },
        "mock" => {
            let step_ms: f64 = args.parse_or("mock-decode-ms", 4.0).map_err(|e| anyhow!("{e}"))?;
            let jitter: f64 = args.parse_or("mock-jitter", 0.1).map_err(|e| anyhow!("{e}"))?;
            EngineSpec::Mock(MockEngineConfig {
                t_decode_step: step_ms / 1e3,
                jitter,
                ..Default::default()
            })
        }
        other => return Err(anyhow!("unknown engine '{other}'")),
    };
    let cfg = ShardConfig {
        units: args.parse_or("units", 1u32).map_err(|e| anyhow!("{e}"))?,
        batch: args.parse_or("batch", 8u32).map_err(|e| anyhow!("{e}"))?,
        engine,
        sampling: Sampling::Greedy,
        seed: args.parse_or("seed", 17u64).map_err(|e| anyhow!("{e}"))?,
    };
    let listener = TcpListener::bind(args.str_or("listen", "127.0.0.1:7501"))?;
    // Announce the bound address on stdout so a parent that asked for an
    // ephemeral port (`:0`) can learn it.
    println!("LISTENING {}", listener.local_addr()?);
    use std::io::Write;
    std::io::stdout().flush().ok();
    run_shard(cfg, listener)
}

/// Outbound frame sink for one unit thread: every engine event becomes a
/// wire frame. Timestamps and request metrics stay shard-local and are
/// *not* sent — the scheduler re-stamps terminal events on its own
/// clock.
struct WireSink {
    out: Sender<Outbound>,
}

impl DecodeEventSink for WireSink {
    fn token(&self, id: u64, index: u32, token: i32, _t: f64) {
        let _ = self.out.send(Outbound::Frame(Frame::Token { id, index, token }));
    }

    fn done(&self, id: u64, tokens: Vec<i32>, _metrics: RequestMetrics) {
        let _ = self.out.send(Outbound::Frame(Frame::Done { id, tokens }));
    }

    fn rejected(&self, id: u64) {
        let _ = self.out.send(Outbound::Frame(Frame::Rejected { id }));
    }
}

/// Run a decode shard on an already-bound listener until a scheduler
/// sends `Stop` (tests use this with an ephemeral port; `cli_worker`
/// binds from the CLI flags).
/// Shard-internal outbound queue entry: wire frames, plus a flush
/// marker used to fence a new connection behind everything the units
/// queued before their abort ack (stale frames must be *dropped* while
/// no connection is attached, never flushed to the new scheduler).
enum Outbound {
    Frame(Frame),
    Flush(Sender<()>),
}

pub fn run_shard(cfg: ShardConfig, listener: TcpListener) -> Result<()> {
    let cfg = ShardConfig {
        units: cfg.units.max(1),
        // slots = 0 would advertise a unit that can never admit — every
        // placement would pend forever with no terminal event.
        batch: cfg.batch.max(1),
        ..cfg
    };
    let units = cfg.units;
    let clock = Arc::new(RealClock::new());
    let (ev_tx, ev_rx) = channel::<Outbound>();
    let (ready_tx, ready_rx) = channel::<bool>();
    let mut unit_txs: Vec<Sender<UnitMsg>> = Vec::new();
    let mut gauges: Vec<Arc<UnitGauges>> = Vec::new();
    let mut unit_threads = Vec::new();
    for u in 0..units {
        let (tx, rx) = channel::<UnitMsg>();
        unit_txs.push(tx);
        let g = Arc::new(UnitGauges::default());
        gauges.push(g.clone());
        let spec = cfg.engine.clone();
        let sink = WireSink { out: ev_tx.clone() };
        let clock = clock.clone();
        let (sampling, batch) = (cfg.sampling, cfg.batch);
        let seed = cfg.seed.wrapping_add(7000 + u as u64);
        let ready = ready_tx.clone();
        unit_threads.push(std::thread::spawn(move || {
            run_decode_unit(
                &format!("shard-unit:{u}"),
                &spec,
                batch,
                sampling,
                seed,
                rx,
                sink,
                move || clock.now_s(),
                Some(&g),
                ready,
            );
        }));
    }
    drop(ready_tx);
    for _ in 0..units {
        match ready_rx.recv_timeout(Duration::from_secs(600)) {
            Ok(true) => {}
            _ => return Err(anyhow!("a shard unit failed to build its engine (see log)")),
        }
    }
    log::info!("decode shard ready: {units} units × {} slots", cfg.batch);

    // One writer serializes every outbound frame onto the current
    // connection; with no connection, events are dropped (their owners
    // evicted them).
    let current: Arc<Mutex<Option<TcpStream>>> = Arc::new(Mutex::new(None));
    let writer = {
        let current = current.clone();
        std::thread::spawn(move || {
            while let Ok(out) = ev_rx.recv() {
                let frame = match out {
                    Outbound::Frame(f) => f,
                    Outbound::Flush(ack) => {
                        // Everything queued before this marker has been
                        // drained (written or dropped); tell the fence.
                        let _ = ack.send(());
                        continue;
                    }
                };
                let is_bye = matches!(frame, Frame::Bye);
                let mut cur = current.lock().unwrap();
                if let Some(conn) = cur.as_mut() {
                    if proto::write_frame(conn, &frame).is_err() {
                        // The scheduler hung up (or the write timed out
                        // mid-frame): shut the socket so the peer sees
                        // the failure now, not after its silence guard.
                        let _ = conn.shutdown(std::net::Shutdown::Both);
                        *cur = None;
                    }
                }
                if is_bye {
                    break;
                }
            }
        })
    };

    let mut stopping = false;
    while !stopping {
        let (conn, peer) = match listener.accept() {
            Ok(x) => x,
            Err(e) => {
                log::warn!("accept failed: {e}");
                continue;
            }
        };
        log::info!("scheduler connected from {peer}");
        // A failed handshake/setup on one connection must never take the
        // whole shard down — drop it and keep accepting.
        stopping = match serve_connection(conn, &cfg, &unit_txs, &gauges, &ev_tx, &current) {
            Ok(stop) => stop,
            Err(e) => {
                log::warn!("connection setup failed: {e:#}");
                false
            }
        };
    }

    // Graceful drain: units finish their active sequences (flushing Done
    // frames through the writer), then Bye closes the stream.
    for tx in &unit_txs {
        let _ = tx.send(UnitMsg::Stop);
    }
    for t in unit_threads {
        let _ = t.join();
    }
    let _ = ev_tx.send(Outbound::Frame(Frame::Bye));
    let _ = writer.join();
    log::info!("decode shard drained; exiting");
    Ok(())
}

/// Serve one scheduler connection. Returns `Ok(true)` when the scheduler
/// asked the shard to stop, `Ok(false)` on disconnect (go back to
/// accepting).
fn serve_connection(
    conn: TcpStream,
    cfg: &ShardConfig,
    unit_txs: &[Sender<UnitMsg>],
    gauges: &[Arc<UnitGauges>],
    ev_tx: &Sender<Outbound>,
    current: &Arc<Mutex<Option<TcpStream>>>,
) -> Result<bool> {
    conn.set_nodelay(true)?;
    conn.set_read_timeout(Some(Duration::from_millis(250)))?;
    // Bound writes too: a wedged scheduler socket must error out of the
    // writer thread (which then detaches the connection), not block it.
    conn.set_write_timeout(Some(Duration::from_secs(5)))?;
    let mut rd = conn.try_clone()?;
    let mut reader = FrameReader::new();
    // Handshake: Hello must arrive promptly, then HelloAck is written
    // directly (before the writer thread can interleave unit events).
    let hello = {
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            match reader.poll(&mut rd) {
                Ok(Some(f)) => break f,
                Ok(None) if std::time::Instant::now() < deadline => continue,
                Ok(None) => return Ok(false),
                Err(e) => {
                    log::warn!("handshake read failed: {e}");
                    return Ok(false);
                }
            }
        }
    };
    match hello {
        Frame::Hello { version } if version == PROTO_VERSION => {}
        Frame::Hello { version } => {
            log::warn!("scheduler speaks protocol v{version}, we speak v{PROTO_VERSION}");
            return Ok(false);
        }
        other => {
            log::warn!("expected Hello, got {other:?}");
            return Ok(false);
        }
    }
    {
        let mut w = conn.try_clone()?;
        proto::write_frame(
            &mut w,
            &Frame::HelloAck {
                version: PROTO_VERSION,
                units: unit_txs.len() as u32,
                slots: cfg.batch,
            },
        )?;
    }
    // A new scheduler owns the shard from here: silently drop whatever a
    // previous connection left tracked (its scheduler already evicted
    // those sequences), and *wait for the abort to land* before
    // attaching the connection — a unit mid-step could otherwise emit a
    // stale id that collides with the new scheduler's fresh id space.
    // One engine step bounds how long a unit takes to see the abort.
    {
        let (ack_tx, ack_rx) = channel::<()>();
        for tx in unit_txs {
            let _ = tx.send(UnitMsg::Abort { ack: ack_tx.clone() });
        }
        drop(ack_tx);
        for _ in 0..unit_txs.len() {
            if ack_rx.recv_timeout(Duration::from_secs(60)).is_err() {
                log::warn!("a unit did not acknowledge the abort in time");
                break;
            }
        }
        // The acks fence unit *state*; frames a unit queued just before
        // its abort could still sit in the outbound queue. Drain the
        // queue (dropped — no connection attached) behind a flush
        // marker before the new connection can receive anything.
        let (flush_tx, flush_rx) = channel::<()>();
        if ev_tx.send(Outbound::Flush(flush_tx)).is_ok()
            && flush_rx.recv_timeout(Duration::from_secs(10)).is_err()
        {
            log::warn!("outbound queue flush timed out");
        }
    }
    *current.lock().unwrap() = Some(conn.try_clone()?);

    // A healthy scheduler heartbeats every second (transport pings), so
    // prolonged byte-silence (see `proto::IdleGuard`) means it is gone
    // without an EOF/RST (black-holed link, or its FIN was lost). Time
    // the connection out so the accept loop frees up for the
    // scheduler's reconnect — without this, a half-open connection
    // wedges the shard forever.
    const CONN_DEAD_AFTER: Duration = Duration::from_secs(6);
    let mut idle = proto::IdleGuard::new(&reader);
    let result = loop {
        if idle.idle_for(&reader) >= CONN_DEAD_AFTER {
            log::warn!("scheduler silent for {CONN_DEAD_AFTER:?}; dropping the connection");
            break false;
        }
        match reader.poll(&mut rd) {
            Ok(Some(frame)) => {
                idle.touch();
                if handle_scheduler_frame(frame, cfg, unit_txs, gauges, ev_tx) {
                    break true;
                }
            }
            Ok(None) => continue,
            Err(ProtoError::Closed) => {
                log::info!("scheduler disconnected");
                break false;
            }
            Err(e) => {
                log::warn!("connection failed: {e}");
                break false;
            }
        }
    };
    // Detach the writer from this connection; on Stop it stays attached
    // so the drain's Done/Bye frames flush to the scheduler.
    if !result {
        *current.lock().unwrap() = None;
    }
    Ok(result)
}

/// Handle one inbound frame on an established scheduler connection.
/// Returns `true` when the frame was `Stop` (drain and exit).
fn handle_scheduler_frame(
    frame: Frame,
    cfg: &ShardConfig,
    unit_txs: &[Sender<UnitMsg>],
    gauges: &[Arc<UnitGauges>],
    ev_tx: &Sender<Outbound>,
) -> bool {
    match frame {
        Frame::Admit {
            unit,
            id,
            first_token,
            kv_len,
            max_new,
            k,
            v,
        } => {
            let job = AdmitJob {
                id,
                outcome: Box::new(PrefillOutcome {
                    first_token,
                    len: kv_len as usize,
                    k,
                    v,
                    exec_time: 0.0,
                    passes: 0,
                }),
                max_new,
                // Shard-local bookkeeping only (KV gauge); real metrics
                // stay with the scheduler.
                metrics: RequestMetrics::arrive(0.0, kv_len),
            };
            match unit_txs.get(unit as usize) {
                Some(tx) => {
                    if tx.send(UnitMsg::Admit(job)).is_err() {
                        let _ = ev_tx.send(Outbound::Frame(Frame::Rejected { id }));
                    }
                }
                None => {
                    log::warn!("admit for unknown unit {unit}");
                    let _ = ev_tx.send(Outbound::Frame(Frame::Rejected { id }));
                }
            }
        }
        Frame::Ping { nonce, t_us } => {
            let _ = ev_tx.send(Outbound::Frame(Frame::Pong { nonce, t_us }));
        }
        Frame::StatsRequest => {
            let units = gauges
                .iter()
                .map(|g| {
                    let used = g.slots_used.load(Ordering::Relaxed);
                    UnitLoad {
                        active: g.active.load(Ordering::Relaxed),
                        free_slots: cfg.batch.saturating_sub(used),
                        kv_tokens: g.kv_tokens.load(Ordering::Relaxed),
                    }
                })
                .collect();
            let _ = ev_tx.send(Outbound::Frame(Frame::StatsReply { units }));
        }
        Frame::Stop => return true,
        other => log::debug!("ignoring frame {other:?}"),
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Raw protocol smoke against an in-thread shard: handshake, admit,
    /// stream to Done, stats, clean Stop/Bye drain.
    #[test]
    fn shard_serves_the_frame_protocol_end_to_end() {
        let cfg = ShardConfig {
            units: 2,
            batch: 4,
            engine: EngineSpec::Mock(MockEngineConfig {
                t_prefill_base: 0.0,
                t_prefill_per_token: 0.0,
                t_decode_step: 0.001,
                chunk: 128,
                jitter: 0.0,
            }),
            sampling: Sampling::Greedy,
            seed: 3,
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let shard = std::thread::spawn(move || run_shard(cfg, listener));

        let conn = TcpStream::connect(addr).unwrap();
        conn.set_nodelay(true).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut w = conn.try_clone().unwrap();
        let mut rd = conn.try_clone().unwrap();
        let mut reader = FrameReader::new();
        let mut recv = || loop {
            if let Some(f) = reader.poll(&mut rd).expect("read frame") {
                return f;
            }
        };

        proto::write_frame(&mut w, &Frame::Hello { version: PROTO_VERSION }).unwrap();
        let ack = Frame::HelloAck {
            version: PROTO_VERSION,
            units: 2,
            slots: 4,
        };
        assert_eq!(recv(), ack);

        proto::write_frame(
            &mut w,
            &Frame::Admit {
                unit: 1,
                id: 42,
                first_token: 0x30,
                kv_len: 5,
                max_new: 3,
                k: Vec::new(),
                v: Vec::new(),
            },
        )
        .unwrap();
        let mut tokens = Vec::new();
        let done = loop {
            match recv() {
                Frame::Token { id, index, token } => {
                    assert_eq!(id, 42);
                    assert_eq!(index as usize, tokens.len() + 1, "indices continue past prefill");
                    tokens.push(token);
                }
                Frame::Done { id, tokens: all } => {
                    assert_eq!(id, 42);
                    break all;
                }
                other => panic!("unexpected frame {other:?}"),
            }
        };
        assert_eq!(done.len(), 4, "prefill token + 3 generated");
        assert_eq!(done[0], 0x30);
        assert_eq!(&done[1..], &tokens[..]);

        proto::write_frame(&mut w, &Frame::Ping { nonce: 9, t_us: 123 }).unwrap();
        assert_eq!(recv(), Frame::Pong { nonce: 9, t_us: 123 });

        proto::write_frame(&mut w, &Frame::StatsRequest).unwrap();
        match recv() {
            Frame::StatsReply { units } => assert_eq!(units.len(), 2),
            other => panic!("unexpected frame {other:?}"),
        }

        proto::write_frame(&mut w, &Frame::Stop).unwrap();
        assert_eq!(recv(), Frame::Bye);
        shard.join().unwrap().unwrap();
    }

    /// Admits for an out-of-range unit come back Rejected instead of
    /// wedging the scheduler's ledger.
    #[test]
    fn unknown_unit_admit_is_rejected() {
        let cfg = ShardConfig {
            units: 1,
            batch: 2,
            engine: EngineSpec::Mock(MockEngineConfig {
                t_prefill_base: 0.0,
                t_prefill_per_token: 0.0,
                t_decode_step: 0.001,
                chunk: 128,
                jitter: 0.0,
            }),
            sampling: Sampling::Greedy,
            seed: 3,
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let shard = std::thread::spawn(move || run_shard(cfg, listener));
        let conn = TcpStream::connect(addr).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut w = conn.try_clone().unwrap();
        let mut rd = conn.try_clone().unwrap();
        let mut reader = FrameReader::new();
        let mut recv = || loop {
            if let Some(f) = reader.poll(&mut rd).expect("read frame") {
                return f;
            }
        };
        proto::write_frame(&mut w, &Frame::Hello { version: PROTO_VERSION }).unwrap();
        recv(); // HelloAck
        proto::write_frame(
            &mut w,
            &Frame::Admit {
                unit: 5,
                id: 1,
                first_token: 0x30,
                kv_len: 2,
                max_new: 2,
                k: Vec::new(),
                v: Vec::new(),
            },
        )
        .unwrap();
        assert_eq!(recv(), Frame::Rejected { id: 1 });
        proto::write_frame(&mut w, &Frame::Stop).unwrap();
        assert_eq!(recv(), Frame::Bye);
        shard.join().unwrap().unwrap();
    }
}
