//! Standalone shard process: `sbs worker --decode|--prefill --listen
//! <addr>` runs decode DP units *or* prefill instances and serves the
//! [`crate::transport::proto`] frame protocol, so a scheduler
//! (`sbs serve --remote-decode <addr> --remote-prefill <addr>`) can
//! drive a fully P/D-separated cluster from another process or machine
//! through the same dispatch core as its local pool.
//!
//! ## Connection model
//!
//! The shard serves **one scheduler at a time**: the accept loop
//! handshakes (`Hello`/`HelloAck`, the ack carrying the shard's role and
//! shape), aborts any state a previous connection left behind (that
//! scheduler already evicted those sequences/jobs on its side), then
//! relays frames until EOF — after which it goes back to accepting,
//! which is what makes scheduler-side reconnect work. Unit engine
//! threads persist across connections.
//!
//! A single writer thread serializes all outbound frames (unit events,
//! `Pong`, `StatsReply`, `Bye`) onto the current connection; events that
//! arrive while no scheduler is connected are dropped — their sequences
//! were (or will be) evicted by the scheduler that owned them.
//!
//! ## Prefill shards and the KV handoff
//!
//! A prefill shard's instances run the same [`run_prefill_unit`] engine
//! loop as the in-process pool. A finished prefill leaves the shard as
//! a **streamed KV handoff**: the prompt caches are borrow-serialized
//! into [`config::KV_SEGMENT_ELEMS`]-sized `KvSegment` frames (one
//! buffer per chunk, no intermediate copies) and committed by a
//! `PrefillDone` — chunking lets other instances' frames interleave, so
//! a long prompt's caches never monopolize the connection. Each pass
//! also emits `EndForward` with the instance's *real remaining backlog*,
//! which the scheduler feeds to the staggered trigger's capacity model.
//!
//! `Stop` drains: units finish their queued work (their terminal frames
//! flush first), the shard replies `Bye` and the process exits.

use super::workers::{
    run_decode_unit, run_prefill_unit, DecodeEventSink, EngineSpec, PrefillEventSink,
    PrefillGauges, UnitGauges,
};
use crate::cli::Command;
use crate::config;
use crate::engine::mock::MockEngineConfig;
use crate::engine::sampler::Sampling;
use crate::engine::PrefillOutcome;
use crate::metrics::RequestMetrics;
use crate::runtime::artifacts_dir;
use crate::transport::proto::{
    self, Frame, FrameReader, KvHalf, ProtoError, ShardRole, UnitLoad, PROTO_VERSION,
};
use crate::transport::{AdmitJob, PrefillMsg, PrefillWork, UnitMsg};
use crate::util::{Clock, RealClock};
use anyhow::{anyhow, Result};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Shard configuration (one role per process).
#[derive(Debug, Clone)]
pub struct ShardConfig {
    /// Which plane this shard serves.
    pub role: ShardRole,
    /// Units: decode DP units or prefill instances (one engine thread
    /// each).
    pub units: u32,
    /// Decode slots per unit (advertised in `HelloAck`; prefill shards
    /// advertise 1 — their instances are gated single-pass engines).
    pub batch: u32,
    /// Execution backend for the unit threads.
    pub engine: EngineSpec,
    /// Sampling policy for generation.
    pub sampling: Sampling,
    /// RNG seed.
    pub seed: u64,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            role: ShardRole::Decode,
            units: 1,
            batch: 8,
            engine: EngineSpec::Mock(MockEngineConfig::default()),
            sampling: Sampling::Greedy,
            seed: 17,
        }
    }
}

/// `sbs worker` entrypoint.
pub fn cli_worker(argv: &[String]) -> Result<()> {
    let cmd = Command::new("sbs worker", "run a standalone shard (decode or prefill)")
        .flag("decode", "serve decode DP units")
        .flag("prefill", "serve prefill instances")
        .opt(
            "listen",
            "bind address (e.g. 127.0.0.1:7501; port 0 = ephemeral)",
            Some("127.0.0.1:7501"),
        )
        .opt("units", "DP units / instances in this shard", Some("1"))
        .opt("batch", "decode slots per unit (decode shards)", Some("8"))
        .opt("engine", "pjrt | mock", Some("mock"))
        .opt("artifacts", "artifact directory (pjrt engine)", Some("artifacts"))
        .opt("mock-decode-ms", "mock engine: one decode step, milliseconds", Some("4"))
        .opt("mock-jitter", "mock engine: execution-time jitter fraction", Some("0.1"))
        .opt("seed", "rng seed", Some("17"));
    let args = cmd.parse(argv).map_err(|e| anyhow!("{e}"))?;
    let role = match (args.flag("decode"), args.flag("prefill")) {
        (true, false) => ShardRole::Decode,
        (false, true) => ShardRole::Prefill,
        _ => {
            return Err(anyhow!(
                "`sbs worker` serves exactly one plane: pass --decode or --prefill"
            ))
        }
    };
    let engine = match args.str_or("engine", "mock").as_str() {
        "pjrt" => EngineSpec::Pjrt {
            artifacts: std::path::PathBuf::from(
                args.str_or("artifacts", artifacts_dir().to_str().unwrap_or("artifacts")),
            ),
        },
        "mock" => {
            let step_ms: f64 = args.parse_or("mock-decode-ms", 4.0).map_err(|e| anyhow!("{e}"))?;
            let jitter: f64 = args.parse_or("mock-jitter", 0.1).map_err(|e| anyhow!("{e}"))?;
            EngineSpec::Mock(MockEngineConfig {
                t_decode_step: step_ms / 1e3,
                jitter,
                ..Default::default()
            })
        }
        other => return Err(anyhow!("unknown engine '{other}'")),
    };
    let cfg = ShardConfig {
        role,
        units: args.parse_or("units", 1u32).map_err(|e| anyhow!("{e}"))?,
        batch: args.parse_or("batch", 8u32).map_err(|e| anyhow!("{e}"))?,
        engine,
        sampling: Sampling::Greedy,
        seed: args.parse_or("seed", 17u64).map_err(|e| anyhow!("{e}"))?,
    };
    let listener = TcpListener::bind(args.str_or("listen", "127.0.0.1:7501"))?;
    // Announce the bound address on stdout so a parent that asked for an
    // ephemeral port (`:0`) can learn it.
    println!("LISTENING {}", listener.local_addr()?);
    use std::io::Write;
    std::io::stdout().flush().ok();
    run_shard(cfg, listener)
}

/// Shard-internal outbound queue entry: pre-framed wire bytes (the
/// KV-handoff hot path — already length-prefixed, borrow-encoded into
/// one buffer per chunk), plain frames (everything else), plus a flush
/// marker used to fence a new connection behind everything the units
/// queued before their abort ack (stale frames must be *dropped* while
/// no connection is attached, never flushed to the new scheduler).
enum Outbound {
    Frame(Frame),
    Bytes(Vec<u8>),
    Flush(Sender<()>),
}

/// Outbound frame sink for one decode unit thread: every engine event
/// becomes a wire frame. Timestamps and request metrics stay shard-local
/// and are *not* sent — the scheduler re-stamps terminal events on its
/// own clock.
struct WireSink {
    out: Sender<Outbound>,
}

impl DecodeEventSink for WireSink {
    fn token(&self, id: u64, index: u32, token: i32, _t: f64) {
        let _ = self.out.send(Outbound::Frame(Frame::Token { id, index, token }));
    }

    fn done(&self, id: u64, tokens: Vec<i32>, _metrics: RequestMetrics) {
        let _ = self.out.send(Outbound::Frame(Frame::Done { id, tokens }));
    }

    fn rejected(&self, id: u64) {
        let _ = self.out.send(Outbound::Frame(Frame::Rejected { id }));
    }
}

/// Outbound sink for one prefill instance thread: finished prefills
/// leave as a chunked `KvSegment` stream + `PrefillDone`, passes as
/// `EndForward` carrying the instance's real remaining backlog.
struct PrefillWireSink {
    out: Sender<Outbound>,
}

impl PrefillEventSink for PrefillWireSink {
    fn prefilled(&self, id: u64, outcome: PrefillOutcome, _max_new: u32, _metrics: RequestMetrics) {
        for (half, data) in [(KvHalf::K, &outcome.k), (KvHalf::V, &outcome.v)] {
            let total = data.len() as u32;
            let mut off = 0usize;
            while off < data.len() {
                let end = (off + config::KV_SEGMENT_ELEMS).min(data.len());
                // Borrow-encode the chunk straight from the outcome into
                // one wire buffer — the only copy between engine memory
                // and the socket.
                let mut buf = Vec::new();
                proto::kv_segment_frame_into(&mut buf, id, half, off as u32, total, &data[off..end]);
                if self.out.send(Outbound::Bytes(buf)).is_err() {
                    return;
                }
                off = end;
            }
        }
        let _ = self.out.send(Outbound::Frame(Frame::PrefillDone {
            id,
            first_token: outcome.first_token,
            kv_len: outcome.len as u32,
            exec_time: outcome.exec_time,
        }));
    }

    fn failed(&self, id: u64) {
        let _ = self.out.send(Outbound::Frame(Frame::PrefillFailed { id }));
    }

    fn end_forward(&self, instance: u32, t_measured: f64, remaining: u32) {
        let _ = self.out.send(Outbound::Frame(Frame::EndForward {
            instance,
            t_measured,
            remaining: Some(remaining),
        }));
    }
}

/// The shard's unit channels + gauges, shaped by its role.
enum UnitChannels {
    Decode {
        txs: Vec<Sender<UnitMsg>>,
        gauges: Vec<Arc<UnitGauges>>,
    },
    Prefill {
        txs: Vec<Sender<PrefillMsg>>,
        gauges: Vec<Arc<PrefillGauges>>,
    },
}

impl UnitChannels {
    fn len(&self) -> usize {
        match self {
            UnitChannels::Decode { txs, .. } => txs.len(),
            UnitChannels::Prefill { txs, .. } => txs.len(),
        }
    }

    /// Tell every unit to silently drop state a superseded connection
    /// left behind; returns one ack receiver covering all of them.
    fn send_aborts(&self) -> std::sync::mpsc::Receiver<()> {
        let (ack_tx, ack_rx) = channel::<()>();
        match self {
            UnitChannels::Decode { txs, .. } => {
                for tx in txs {
                    let _ = tx.send(UnitMsg::Abort { ack: ack_tx.clone() });
                }
            }
            UnitChannels::Prefill { txs, .. } => {
                for tx in txs {
                    let _ = tx.send(PrefillMsg::Abort { ack: ack_tx.clone() });
                }
            }
        }
        ack_rx
    }

    fn send_stops(&self) {
        match self {
            UnitChannels::Decode { txs, .. } => {
                for tx in txs {
                    let _ = tx.send(UnitMsg::Stop);
                }
            }
            UnitChannels::Prefill { txs, .. } => {
                for tx in txs {
                    let _ = tx.send(PrefillMsg::Stop);
                }
            }
        }
    }

    /// Role-appropriate per-unit loads for `StatsReply`: decode units
    /// report residency/slots/KV, prefill instances report queued jobs
    /// (as `active`) and queued prompt tokens (as `kv_tokens`).
    fn unit_loads(&self, batch: u32) -> Vec<UnitLoad> {
        match self {
            UnitChannels::Decode { gauges, .. } => gauges
                .iter()
                .map(|g| {
                    let used = g.slots_used.load(Ordering::Relaxed);
                    UnitLoad {
                        active: g.active.load(Ordering::Relaxed),
                        free_slots: batch.saturating_sub(used),
                        kv_tokens: g.kv_tokens.load(Ordering::Relaxed),
                    }
                })
                .collect(),
            UnitChannels::Prefill { gauges, .. } => gauges
                .iter()
                .map(|g| UnitLoad {
                    active: g.queued_jobs.load(Ordering::Relaxed),
                    free_slots: 0,
                    kv_tokens: g.queued_tokens.load(Ordering::Relaxed),
                })
                .collect(),
        }
    }
}

/// Run a shard on an already-bound listener until a scheduler sends
/// `Stop` (tests use this with an ephemeral port; `cli_worker` binds
/// from the CLI flags).
pub fn run_shard(cfg: ShardConfig, listener: TcpListener) -> Result<()> {
    let cfg = ShardConfig {
        units: cfg.units.max(1),
        // slots = 0 would advertise a unit that can never admit — every
        // placement would pend forever with no terminal event.
        batch: cfg.batch.max(1),
        ..cfg
    };
    let units = cfg.units;
    let clock = Arc::new(RealClock::new());
    let (ev_tx, ev_rx) = channel::<Outbound>();
    let (ready_tx, ready_rx) = channel::<bool>();
    let mut unit_threads = Vec::new();
    let channels = match cfg.role {
        ShardRole::Decode => {
            let mut txs = Vec::new();
            let mut gauges = Vec::new();
            for u in 0..units {
                let (tx, rx) = channel::<UnitMsg>();
                txs.push(tx);
                let g = Arc::new(UnitGauges::default());
                gauges.push(g.clone());
                let spec = cfg.engine.clone();
                let sink = WireSink { out: ev_tx.clone() };
                let clock = clock.clone();
                let (sampling, batch) = (cfg.sampling, cfg.batch);
                let seed = cfg.seed.wrapping_add(7000 + u as u64);
                let ready = ready_tx.clone();
                unit_threads.push(std::thread::spawn(move || {
                    run_decode_unit(
                        &format!("shard-unit:{u}"),
                        &spec,
                        batch,
                        sampling,
                        seed,
                        rx,
                        sink,
                        move || clock.now_s(),
                        Some(&g),
                        ready,
                    );
                }));
            }
            UnitChannels::Decode { txs, gauges }
        }
        ShardRole::Prefill => {
            let mut txs = Vec::new();
            let mut gauges = Vec::new();
            for u in 0..units {
                let (tx, rx) = channel::<PrefillMsg>();
                txs.push(tx);
                let g = Arc::new(PrefillGauges::default());
                gauges.push(g.clone());
                let spec = cfg.engine.clone();
                let sink = PrefillWireSink { out: ev_tx.clone() };
                let seed = cfg.seed.wrapping_add(8000 + u as u64);
                let ready = ready_tx.clone();
                unit_threads.push(std::thread::spawn(move || {
                    run_prefill_unit(
                        &format!("shard-prefill:{u}"),
                        u,
                        &spec,
                        seed,
                        rx,
                        sink,
                        Some(&g),
                        ready,
                    );
                }));
            }
            UnitChannels::Prefill { txs, gauges }
        }
    };
    drop(ready_tx);
    for _ in 0..units {
        match ready_rx.recv_timeout(Duration::from_secs(600)) {
            Ok(true) => {}
            _ => return Err(anyhow!("a shard unit failed to build its engine (see log)")),
        }
    }
    log::info!(
        "{} shard ready: {units} units{}",
        cfg.role.name(),
        match cfg.role {
            ShardRole::Decode => format!(" × {} slots", cfg.batch),
            ShardRole::Prefill => String::new(),
        }
    );

    // One writer serializes every outbound frame onto the current
    // connection; with no connection, events are dropped (their owners
    // evicted them).
    let current: Arc<Mutex<Option<TcpStream>>> = Arc::new(Mutex::new(None));
    let writer = {
        let current = current.clone();
        std::thread::spawn(move || {
            while let Ok(out) = ev_rx.recv() {
                let (bytes, is_bye) = match out {
                    Outbound::Frame(f) => {
                        let mut buf = Vec::new();
                        proto::write_frame(&mut buf, &f).expect("Vec write cannot fail");
                        (buf, matches!(f, Frame::Bye))
                    }
                    // Pre-framed wire bytes (the KV-handoff hot path).
                    Outbound::Bytes(b) => (b, false),
                    Outbound::Flush(ack) => {
                        // Everything queued before this marker has been
                        // drained (written or dropped); tell the fence.
                        let _ = ack.send(());
                        continue;
                    }
                };
                {
                    let mut cur = current.lock().unwrap();
                    if let Some(conn) = cur.as_mut() {
                        use std::io::Write;
                        if conn.write_all(&bytes).is_err() {
                            // The scheduler hung up (or the write timed
                            // out mid-frame): shut the socket so the peer
                            // sees the failure now, not after its silence
                            // guard.
                            let _ = conn.shutdown(std::net::Shutdown::Both);
                            *cur = None;
                        }
                    }
                }
                if is_bye {
                    break;
                }
            }
        })
    };

    let mut stopping = false;
    while !stopping {
        let (conn, peer) = match listener.accept() {
            Ok(x) => x,
            Err(e) => {
                log::warn!("accept failed: {e}");
                continue;
            }
        };
        log::info!("scheduler connected from {peer}");
        // A failed handshake/setup on one connection must never take the
        // whole shard down — drop it and keep accepting.
        stopping = match serve_connection(conn, &cfg, &channels, &ev_tx, &current) {
            Ok(stop) => stop,
            Err(e) => {
                log::warn!("connection setup failed: {e:#}");
                false
            }
        };
    }

    // Graceful drain: units finish their active work (flushing terminal
    // frames through the writer), then Bye closes the stream.
    channels.send_stops();
    for t in unit_threads {
        let _ = t.join();
    }
    let _ = ev_tx.send(Outbound::Frame(Frame::Bye));
    let _ = writer.join();
    log::info!("{} shard drained; exiting", cfg.role.name());
    Ok(())
}

/// Serve one scheduler connection. Returns `Ok(true)` when the scheduler
/// asked the shard to stop, `Ok(false)` on disconnect (go back to
/// accepting).
fn serve_connection(
    conn: TcpStream,
    cfg: &ShardConfig,
    channels: &UnitChannels,
    ev_tx: &Sender<Outbound>,
    current: &Arc<Mutex<Option<TcpStream>>>,
) -> Result<bool> {
    conn.set_nodelay(true)?;
    conn.set_read_timeout(Some(Duration::from_millis(250)))?;
    // Bound writes too: a wedged scheduler socket must error out of the
    // writer thread (which then detaches the connection), not block it.
    conn.set_write_timeout(Some(Duration::from_secs(5)))?;
    let mut rd = conn.try_clone()?;
    let mut reader = FrameReader::new();
    // Handshake: Hello must arrive promptly, then HelloAck is written
    // directly (before the writer thread can interleave unit events).
    let hello = {
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            match reader.poll(&mut rd) {
                Ok(Some(f)) => break f,
                Ok(None) if std::time::Instant::now() < deadline => continue,
                Ok(None) => return Ok(false),
                Err(e) => {
                    log::warn!("handshake read failed: {e}");
                    return Ok(false);
                }
            }
        }
    };
    match hello {
        Frame::Hello { version } if version == PROTO_VERSION => {}
        Frame::Hello { version } => {
            log::warn!("scheduler speaks protocol v{version}, we speak v{PROTO_VERSION}");
            return Ok(false);
        }
        other => {
            log::warn!("expected Hello, got {other:?}");
            return Ok(false);
        }
    }
    {
        let mut w = conn.try_clone()?;
        proto::write_frame(
            &mut w,
            &Frame::HelloAck {
                version: PROTO_VERSION,
                role: cfg.role,
                units: channels.len() as u32,
                slots: match cfg.role {
                    ShardRole::Decode => cfg.batch,
                    // Prefill instances are gated single-pass engines;
                    // "slots" only exists for the shape check.
                    ShardRole::Prefill => 1,
                },
            },
        )?;
    }
    // A new scheduler owns the shard from here: silently drop whatever a
    // previous connection left tracked (its scheduler already evicted
    // that state), and *wait for the abort to land* before attaching the
    // connection — a unit mid-step could otherwise emit a stale id that
    // collides with the new scheduler's fresh id space. One engine pass
    // bounds how long a unit takes to see the abort.
    {
        let ack_rx = channels.send_aborts();
        for _ in 0..channels.len() {
            if ack_rx.recv_timeout(Duration::from_secs(60)).is_err() {
                log::warn!("a unit did not acknowledge the abort in time");
                break;
            }
        }
        // The acks fence unit *state*; frames a unit queued just before
        // its abort could still sit in the outbound queue. Drain the
        // queue (dropped — no connection attached) behind a flush
        // marker before the new connection can receive anything.
        let (flush_tx, flush_rx) = channel::<()>();
        if ev_tx.send(Outbound::Flush(flush_tx)).is_ok()
            && flush_rx.recv_timeout(Duration::from_secs(10)).is_err()
        {
            log::warn!("outbound queue flush timed out");
        }
    }
    *current.lock().unwrap() = Some(conn.try_clone()?);

    // A healthy scheduler heartbeats every second (transport pings), so
    // prolonged byte-silence (see `proto::IdleGuard`) means it is gone
    // without an EOF/RST (black-holed link, or its FIN was lost). Time
    // the connection out so the accept loop frees up for the
    // scheduler's reconnect — without this, a half-open connection
    // wedges the shard forever.
    const CONN_DEAD_AFTER: Duration = Duration::from_secs(6);
    let mut idle = proto::IdleGuard::new(&reader);
    let result = loop {
        if idle.idle_for(&reader) >= CONN_DEAD_AFTER {
            log::warn!("scheduler silent for {CONN_DEAD_AFTER:?}; dropping the connection");
            break false;
        }
        match reader.poll(&mut rd) {
            Ok(Some(frame)) => {
                idle.touch();
                if handle_scheduler_frame(frame, cfg, channels, ev_tx) {
                    break true;
                }
            }
            Ok(None) => continue,
            Err(ProtoError::Closed) => {
                log::info!("scheduler disconnected");
                break false;
            }
            Err(e) => {
                log::warn!("connection failed: {e}");
                break false;
            }
        }
    };
    // Detach the writer from this connection; on Stop it stays attached
    // so the drain's terminal/Bye frames flush to the scheduler.
    if !result {
        *current.lock().unwrap() = None;
    }
    Ok(result)
}

/// Handle one inbound frame on an established scheduler connection.
/// Returns `true` when the frame was `Stop` (drain and exit).
fn handle_scheduler_frame(
    frame: Frame,
    cfg: &ShardConfig,
    channels: &UnitChannels,
    ev_tx: &Sender<Outbound>,
) -> bool {
    match frame {
        Frame::Admit {
            unit,
            id,
            first_token,
            kv_len,
            max_new,
            k,
            v,
        } => {
            let UnitChannels::Decode { txs, .. } = channels else {
                // Role was checked at handshake; an admit here is a
                // protocol violation, not a crash.
                log::warn!("admit sent to a prefill shard; rejecting job {id}");
                let _ = ev_tx.send(Outbound::Frame(Frame::Rejected { id }));
                return false;
            };
            let job = AdmitJob {
                id,
                outcome: Box::new(PrefillOutcome {
                    first_token,
                    len: kv_len as usize,
                    k,
                    v,
                    exec_time: 0.0,
                    passes: 0,
                }),
                max_new,
                // Shard-local bookkeeping only (KV gauge); real metrics
                // stay with the scheduler.
                metrics: RequestMetrics::arrive(0.0, kv_len),
            };
            match txs.get(unit as usize) {
                Some(tx) => {
                    if tx.send(UnitMsg::Admit(job)).is_err() {
                        let _ = ev_tx.send(Outbound::Frame(Frame::Rejected { id }));
                    }
                }
                None => {
                    log::warn!("admit for unknown unit {unit}");
                    let _ = ev_tx.send(Outbound::Frame(Frame::Rejected { id }));
                }
            }
        }
        Frame::PrefillDispatch { unit, jobs } => {
            let UnitChannels::Prefill { txs, .. } = channels else {
                log::warn!("prefill dispatch sent to a decode shard; failing the batch");
                for j in &jobs {
                    let _ = ev_tx.send(Outbound::Frame(Frame::PrefillFailed { id: j.id }));
                }
                return false;
            };
            let work: Vec<PrefillWork> = jobs
                .into_iter()
                .map(|j| {
                    let len = j.prompt.len() as u32;
                    PrefillWork {
                        id: j.id,
                        prompt: j.prompt,
                        max_new: j.max_new,
                        // Shard-local bookkeeping only; the scheduler
                        // keeps the real wall-clock metrics.
                        metrics: RequestMetrics::arrive(0.0, len),
                    }
                })
                .collect();
            match txs.get(unit as usize) {
                Some(tx) => {
                    let ids: Vec<u64> = work.iter().map(|w| w.id).collect();
                    if tx.send(PrefillMsg::Work(work)).is_err() {
                        for id in ids {
                            let _ = ev_tx.send(Outbound::Frame(Frame::PrefillFailed { id }));
                        }
                    }
                }
                None => {
                    log::warn!("prefill dispatch for unknown instance {unit}");
                    for w in work {
                        let _ = ev_tx.send(Outbound::Frame(Frame::PrefillFailed { id: w.id }));
                    }
                }
            }
        }
        Frame::Ping { nonce, t_us } => {
            let _ = ev_tx.send(Outbound::Frame(Frame::Pong { nonce, t_us }));
        }
        Frame::StatsRequest => {
            let units = channels.unit_loads(cfg.batch);
            let _ = ev_tx.send(Outbound::Frame(Frame::StatsReply { units }));
        }
        Frame::Stop => return true,
        other => log::debug!("ignoring frame {other:?}"),
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_mock() -> EngineSpec {
        EngineSpec::Mock(MockEngineConfig {
            t_prefill_base: 0.0,
            t_prefill_per_token: 0.0,
            t_decode_step: 0.001,
            chunk: 128,
            jitter: 0.0,
        })
    }

    struct ShardClient {
        w: TcpStream,
        rd: TcpStream,
        reader: FrameReader,
    }

    impl ShardClient {
        fn connect(addr: std::net::SocketAddr) -> ShardClient {
            let conn = TcpStream::connect(addr).unwrap();
            conn.set_nodelay(true).unwrap();
            conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            ShardClient {
                w: conn.try_clone().unwrap(),
                rd: conn.try_clone().unwrap(),
                reader: FrameReader::new(),
            }
        }

        fn send(&mut self, f: &Frame) {
            proto::write_frame(&mut self.w, f).unwrap();
        }

        fn recv(&mut self) -> Frame {
            loop {
                if let Some(f) = self.reader.poll(&mut self.rd).expect("read frame") {
                    return f;
                }
            }
        }
    }

    /// Raw protocol smoke against an in-thread decode shard: handshake,
    /// admit, stream to Done, stats, clean Stop/Bye drain.
    #[test]
    fn decode_shard_serves_the_frame_protocol_end_to_end() {
        let cfg = ShardConfig {
            role: ShardRole::Decode,
            units: 2,
            batch: 4,
            engine: fast_mock(),
            sampling: Sampling::Greedy,
            seed: 3,
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let shard = std::thread::spawn(move || run_shard(cfg, listener));

        let mut c = ShardClient::connect(addr);
        c.send(&Frame::Hello { version: PROTO_VERSION });
        let ack = Frame::HelloAck {
            version: PROTO_VERSION,
            role: ShardRole::Decode,
            units: 2,
            slots: 4,
        };
        assert_eq!(c.recv(), ack);

        c.send(&Frame::Admit {
            unit: 1,
            id: 42,
            first_token: 0x30,
            kv_len: 5,
            max_new: 3,
            k: Vec::new(),
            v: Vec::new(),
        });
        let mut tokens = Vec::new();
        let done = loop {
            match c.recv() {
                Frame::Token { id, index, token } => {
                    assert_eq!(id, 42);
                    assert_eq!(index as usize, tokens.len() + 1, "indices continue past prefill");
                    tokens.push(token);
                }
                Frame::Done { id, tokens: all } => {
                    assert_eq!(id, 42);
                    break all;
                }
                other => panic!("unexpected frame {other:?}"),
            }
        };
        assert_eq!(done.len(), 4, "prefill token + 3 generated");
        assert_eq!(done[0], 0x30);
        assert_eq!(&done[1..], &tokens[..]);

        c.send(&Frame::Ping { nonce: 9, t_us: 123 });
        assert_eq!(c.recv(), Frame::Pong { nonce: 9, t_us: 123 });

        c.send(&Frame::StatsRequest);
        match c.recv() {
            Frame::StatsReply { units } => assert_eq!(units.len(), 2),
            other => panic!("unexpected frame {other:?}"),
        }

        c.send(&Frame::Stop);
        assert_eq!(c.recv(), Frame::Bye);
        shard.join().unwrap().unwrap();
    }

    /// Admits for an out-of-range unit come back Rejected instead of
    /// wedging the scheduler's ledger.
    #[test]
    fn unknown_unit_admit_is_rejected() {
        let cfg = ShardConfig {
            role: ShardRole::Decode,
            units: 1,
            batch: 2,
            engine: fast_mock(),
            sampling: Sampling::Greedy,
            seed: 3,
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let shard = std::thread::spawn(move || run_shard(cfg, listener));
        let mut c = ShardClient::connect(addr);
        c.send(&Frame::Hello { version: PROTO_VERSION });
        c.recv(); // HelloAck
        c.send(&Frame::Admit {
            unit: 5,
            id: 1,
            first_token: 0x30,
            kv_len: 2,
            max_new: 2,
            k: Vec::new(),
            v: Vec::new(),
        });
        assert_eq!(c.recv(), Frame::Rejected { id: 1 });
        c.send(&Frame::Stop);
        assert_eq!(c.recv(), Frame::Bye);
        shard.join().unwrap().unwrap();
    }

    /// Raw protocol smoke against an in-thread *prefill* shard: the
    /// dispatch→KvSegment*→PrefillDone handoff plus EndForward backlog
    /// feedback, stats, and a clean drain. The mock engine produces
    /// empty KV, so the handoff here carries no segments and the commit
    /// alone must suffice; segment framing itself is covered by the
    /// proto property tests and the remote-prefill client test.
    #[test]
    fn prefill_shard_streams_the_kv_handoff_end_to_end() {
        let cfg = ShardConfig {
            role: ShardRole::Prefill,
            units: 1,
            batch: 8, // ignored for prefill; HelloAck must advertise 1
            engine: fast_mock(),
            sampling: Sampling::Greedy,
            seed: 3,
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let shard = std::thread::spawn(move || run_shard(cfg, listener));

        let mut c = ShardClient::connect(addr);
        c.send(&Frame::Hello { version: PROTO_VERSION });
        let ack = Frame::HelloAck {
            version: PROTO_VERSION,
            role: ShardRole::Prefill,
            units: 1,
            slots: 1,
        };
        assert_eq!(c.recv(), ack);

        c.send(&Frame::PrefillDispatch {
            unit: 0,
            jobs: vec![
                proto::PrefillJobWire {
                    id: 7,
                    max_new: 4,
                    prompt: vec![1, 2, 3, 4, 5],
                },
                proto::PrefillJobWire {
                    id: 8,
                    max_new: 4,
                    prompt: vec![9; 12],
                },
            ],
        });
        let mut done_ids = Vec::new();
        let mut end_forwards = 0u32;
        while done_ids.len() < 2 || end_forwards < 2 {
            match c.recv() {
                Frame::KvSegment { id, offset, total, data, .. } => {
                    assert!(id == 7 || id == 8);
                    assert!(offset as usize + data.len() <= total as usize);
                }
                Frame::PrefillDone { id, kv_len, .. } => {
                    let expect_len = if id == 7 { 5 } else { 12 };
                    assert_eq!(kv_len, expect_len, "kv_len echoes the prompt length");
                    done_ids.push(id);
                }
                Frame::EndForward { instance, remaining, .. } => {
                    assert_eq!(instance, 0);
                    assert!(remaining.is_some(), "prefill shards report real backlog");
                    end_forwards += 1;
                }
                other => panic!("unexpected frame {other:?}"),
            }
        }
        assert_eq!(done_ids.len(), 2);

        c.send(&Frame::StatsRequest);
        match c.recv() {
            Frame::StatsReply { units } => {
                assert_eq!(units.len(), 1);
                assert_eq!(units[0].active, 0, "queue drained");
            }
            other => panic!("unexpected frame {other:?}"),
        }

        // An admit against a prefill shard is rejected, not served.
        c.send(&Frame::Admit {
            unit: 0,
            id: 99,
            first_token: 0,
            kv_len: 1,
            max_new: 1,
            k: Vec::new(),
            v: Vec::new(),
        });
        assert_eq!(c.recv(), Frame::Rejected { id: 99 });

        c.send(&Frame::Stop);
        assert_eq!(c.recv(), Frame::Bye);
        shard.join().unwrap().unwrap();
    }

    /// Dispatches for an out-of-range prefill instance come back
    /// PrefillFailed instead of silently vanishing.
    #[test]
    fn unknown_prefill_instance_dispatch_fails_the_jobs() {
        let cfg = ShardConfig {
            role: ShardRole::Prefill,
            units: 1,
            batch: 1,
            engine: fast_mock(),
            sampling: Sampling::Greedy,
            seed: 3,
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let shard = std::thread::spawn(move || run_shard(cfg, listener));
        let mut c = ShardClient::connect(addr);
        c.send(&Frame::Hello { version: PROTO_VERSION });
        c.recv(); // HelloAck
        c.send(&Frame::PrefillDispatch {
            unit: 3,
            jobs: vec![proto::PrefillJobWire {
                id: 11,
                max_new: 2,
                prompt: vec![1, 2],
            }],
        });
        assert_eq!(c.recv(), Frame::PrefillFailed { id: 11 });
        c.send(&Frame::Stop);
        assert_eq!(c.recv(), Frame::Bye);
        shard.join().unwrap().unwrap();
    }
}
