//! Standalone shard process: `sbs worker --decode|--prefill --listen
//! <addr>` runs decode DP units *or* prefill instances and serves the
//! [`crate::transport::proto`] frame protocol, so a scheduler
//! (`sbs serve --remote-decode <addr> --remote-prefill <addr>`) can
//! drive a fully P/D-separated cluster from another process or machine
//! through the same dispatch core as its local pool.
//!
//! ## Connection model
//!
//! The shard serves **one scheduler at a time**: the accept loop
//! handshakes (`Hello`/`HelloAck`, the ack carrying the shard's role and
//! shape), aborts any state a previous connection left behind (that
//! scheduler already evicted those sequences/jobs on its side), then
//! relays frames until EOF — after which it goes back to accepting,
//! which is what makes scheduler-side reconnect work. Unit engine
//! threads persist across connections.
//!
//! A single writer thread serializes all outbound frames (unit events,
//! `Pong`, `StatsReply`, `Bye`) onto the current connection; events that
//! arrive while no scheduler is connected are dropped — their sequences
//! were (or will be) evicted by the scheduler that owned them.
//!
//! ## Prefill shards and the KV handoff
//!
//! A prefill shard's instances run the same [`run_prefill_unit`] engine
//! loop as the in-process pool. A finished prefill leaves the shard as
//! a **streamed KV handoff**: the prompt caches are borrow-serialized
//! into [`config::KV_SEGMENT_ELEMS`]-sized `KvSegment` frames (one
//! buffer per chunk, no intermediate copies, coded per the negotiated
//! `--kv-wire` codec) and committed by a `PrefillDone` — chunking lets
//! other instances' frames interleave, so a long prompt's caches never
//! monopolize the connection. Each pass also emits `EndForward` with the
//! instance's *real remaining backlog*, which the scheduler feeds to the
//! staggered trigger's capacity model.
//!
//! ## Direct prefill→decode transfer
//!
//! When a dispatched job carries a [`DirectTarget`], the prefill shard
//! bypasses the scheduler on the KV path entirely: its
//! [`PeerMux`] shares **one multiplexed connection per decode peer**
//! (the port advertised in the decode shard's `HelloAck`), streams the
//! coded `KvSegment`s there on a per-job [`StreamId`] — so concurrent
//! handoffs to the same shard interleave at frame granularity instead
//! of serializing — commits with `HandoffCommit`, and waits for the
//! decode shard's `HandoffAck` — only then does it send the lightweight
//! `HandoffCommit` notification to the scheduler. Any failure on the
//! peer path (connect, stream, ack timeout) falls back to the relayed
//! `KvSegment*`+`PrefillDone` route, which the scheduler handles by
//! re-placing the join; a decode shard that dies mid-handoff is covered
//! twice (the fallback, and the scheduler's eviction of its pending
//! ids). On the decode side, accepted peer connections are served by
//! the process-global [`NetDriver`] event loop (no thread per peer);
//! the handler keys KV reassembly by job id and emits the sequence's
//! `Token index 0` on the scheduler connection the moment a handoff is
//! admitted, before any decode-step token, so the stream stays ordered.
//!
//! `Stop` drains: units finish their queued work (their terminal frames
//! flush first), the shard replies `Bye` and the process exits.

use super::workers::{
    run_decode_unit, run_prefill_unit, DecodeEventSink, EngineSpec, PrefillEventSink,
    PrefillGauges, UnitGauges,
};
use crate::cli::Command;
use crate::config;
use crate::engine::mock::MockEngineConfig;
use crate::engine::sampler::Sampling;
use crate::engine::PrefillOutcome;
use crate::metrics::RequestMetrics;
use crate::runtime::artifacts_dir;
use crate::scheduler::types::SloClass;
use crate::transport::driver::{ConnHandler, ConnIo, ConnOptions, NetDriver};
use crate::transport::peer::PeerMux;
use crate::transport::proto::{
    self, DirectTarget, Frame, FrameReader, ProtoError, ShardRole, StreamId, UnitLoad,
    PROTO_VERSION, STREAM_CONTROL,
};
use crate::trace::{Mark, TraceMark};
use crate::transport::{
    AdmitJob, ExtractedSeq, KvCodec, KvWireCounters, PrefillMsg, PrefillWork, UnitMsg,
};
use crate::util::{Clock, RealClock};
use anyhow::{anyhow, Context, Result};
use std::collections::{HashMap, HashSet};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU32, AtomicU8, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Shard configuration (one role per process).
#[derive(Debug, Clone)]
pub struct ShardConfig {
    /// Which plane this shard serves.
    pub role: ShardRole,
    /// Units: decode DP units or prefill instances (one engine thread
    /// each).
    pub units: u32,
    /// Decode slots per unit (advertised in `HelloAck`; prefill shards
    /// advertise 1 — their instances are gated single-pass engines).
    pub batch: u32,
    /// Execution backend for the unit threads.
    pub engine: EngineSpec,
    /// Sampling policy for generation.
    pub sampling: Sampling,
    /// RNG seed.
    pub seed: u64,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            role: ShardRole::Decode,
            units: 1,
            batch: 8,
            engine: EngineSpec::Mock(MockEngineConfig::default()),
            sampling: Sampling::Greedy,
            seed: 17,
        }
    }
}

/// `sbs worker` entrypoint.
pub fn cli_worker(argv: &[String]) -> Result<()> {
    let cmd = Command::new("sbs worker", "run a standalone shard (decode or prefill)")
        .flag("decode", "serve decode DP units")
        .flag("prefill", "serve prefill instances")
        .opt(
            "listen",
            "bind address (e.g. 127.0.0.1:7501; port 0 = ephemeral)",
            Some("127.0.0.1:7501"),
        )
        .opt("units", "DP units / instances in this shard", Some("1"))
        .opt("batch", "decode slots per unit (decode shards)", Some("8"))
        .opt("engine", "pjrt | mock", Some("mock"))
        .opt("artifacts", "artifact directory (pjrt engine)", Some("artifacts"))
        .opt("mock-decode-ms", "mock engine: one decode step, milliseconds", Some("4"))
        .opt("mock-jitter", "mock engine: execution-time jitter fraction", Some("0.1"))
        .opt(
            "mock-kv-elems",
            "mock engine: synthetic KV elements per prompt token (per cache half)",
            Some("16"),
        )
        .opt("seed", "rng seed", Some("17"));
    let args = cmd.parse(argv).map_err(|e| anyhow!("{e}"))?;
    let role = match (args.flag("decode"), args.flag("prefill")) {
        (true, false) => ShardRole::Decode,
        (false, true) => ShardRole::Prefill,
        _ => {
            return Err(anyhow!(
                "`sbs worker` serves exactly one plane: pass --decode or --prefill"
            ))
        }
    };
    let engine = match args.str_or("engine", "mock").as_str() {
        "pjrt" => EngineSpec::Pjrt {
            artifacts: std::path::PathBuf::from(
                args.str_or("artifacts", artifacts_dir().to_str().unwrap_or("artifacts")),
            ),
        },
        "mock" => {
            let step_ms: f64 = args.parse_or("mock-decode-ms", 4.0).map_err(|e| anyhow!("{e}"))?;
            let jitter: f64 = args.parse_or("mock-jitter", 0.1).map_err(|e| anyhow!("{e}"))?;
            let kv_elems: usize =
                args.parse_or("mock-kv-elems", 16usize).map_err(|e| anyhow!("{e}"))?;
            EngineSpec::Mock(MockEngineConfig {
                t_decode_step: step_ms / 1e3,
                jitter,
                kv_elems_per_token: kv_elems,
                ..Default::default()
            })
        }
        other => return Err(anyhow!("unknown engine '{other}'")),
    };
    let cfg = ShardConfig {
        role,
        units: args.parse_or("units", 1u32).map_err(|e| anyhow!("{e}"))?,
        batch: args.parse_or("batch", 8u32).map_err(|e| anyhow!("{e}"))?,
        engine,
        sampling: Sampling::Greedy,
        seed: args.parse_or("seed", 17u64).map_err(|e| anyhow!("{e}"))?,
    };
    let listener = bind_with_retry(&args.str_or("listen", "127.0.0.1:7501"))?;
    // Announce the bound address on stdout so a parent that asked for an
    // ephemeral port (`:0`) can learn it.
    println!("LISTENING {}", listener.local_addr()?);
    std::io::stdout().flush().ok();
    run_shard(cfg, listener)
}

/// Bind a listener with a bounded retry: a replacement shard reusing its
/// predecessor's fixed address can race the kernel's release of the port
/// (`TIME_WAIT`, a dying process), which a blind bind turns into a
/// startup failure and a flaky test. Ephemeral binds (`:0`) succeed on
/// the first attempt.
fn bind_with_retry(addr: &str) -> Result<TcpListener> {
    const ATTEMPTS: u32 = 20;
    let mut last = None;
    for i in 0..ATTEMPTS {
        match TcpListener::bind(addr) {
            Ok(l) => return Ok(l),
            Err(e) => {
                log::debug!("bind {addr} attempt {}/{ATTEMPTS} failed: {e}", i + 1);
                last = Some(e);
                std::thread::sleep(Duration::from_millis(250));
            }
        }
    }
    Err(last.unwrap()).with_context(|| format!("binding {addr} after {ATTEMPTS} attempts"))
}

/// Shard-internal outbound queue entry: pre-framed wire bytes (the
/// KV-handoff hot path — already length-prefixed, borrow-encoded into
/// one buffer per chunk), plain frames (everything else), plus a flush
/// marker used to fence a new connection behind everything the units
/// queued before their abort ack (stale frames must be *dropped* while
/// no connection is attached, never flushed to the new scheduler).
enum Outbound {
    Frame(Frame),
    Bytes(Vec<u8>),
    Flush(Sender<()>),
}

/// Marks buffered past this point are shed (and counted): tracing is
/// best-effort and must never grow without bound when the scheduler
/// connection is slow or absent.
const TRACE_BUF_CAP: usize = 4096;

/// Shard-side TTFT trace buffer. Marks are stamped on the shard's local
/// monotonic clock and aligned to the *scheduler's* clock with the
/// offset observed from heartbeat pings (`Frame::Ping` carries the
/// scheduler-clock send time, so `offset = t_ping - t_local` is right to
/// within the one-way delay, ≈ the link RTT). Aligned marks batch up in
/// a capped buffer and leave as best-effort [`Frame::TraceSpans`] on the
/// shard's single outbound queue — flushed *before* each terminal frame
/// so a request's marks reach the scheduler no later than the event that
/// finalizes its trace, and periodically from the connection loop for
/// everything else. Marks stamped before the first ping (offset
/// unknown) or past the cap are shed and counted, never blocked on.
struct ShardTraceBuf {
    clock: Arc<RealClock>,
    /// Scheduler-clock µs minus shard-clock µs at the last heartbeat;
    /// `i64::MIN` = no ping observed yet.
    offset_us: AtomicI64,
    buf: Mutex<Vec<TraceMark>>,
    /// Marks shed since the last flush that carried any.
    dropped: AtomicU32,
}

impl ShardTraceBuf {
    fn new(clock: Arc<RealClock>) -> Self {
        ShardTraceBuf {
            clock,
            offset_us: AtomicI64::new(i64::MIN),
            buf: Mutex::new(Vec::new()),
            dropped: AtomicU32::new(0),
        }
    }

    fn local_us(&self) -> i64 {
        (self.clock.now_s() * 1e6) as i64
    }

    /// Re-anchor the clock alignment from a scheduler heartbeat.
    fn observe_ping(&self, sched_t_us: u64) {
        let off = (sched_t_us as i64).saturating_sub(self.local_us());
        self.offset_us.store(off, Ordering::Relaxed);
    }

    /// Stamp one mark at "now" on the scheduler's timebase.
    fn push(&self, id: u64, mark: Mark, unit: u32) {
        let off = self.offset_us.load(Ordering::Relaxed);
        if off == i64::MIN {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let t_us = self.local_us().saturating_add(off).max(0) as u64;
        let mut buf = self.buf.lock().unwrap();
        if buf.len() >= TRACE_BUF_CAP {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        buf.push(TraceMark { id, mark, t_us, unit });
    }

    /// Queue the buffered marks as one `TraceSpans` frame. A no-op while
    /// the buffer is empty (shed counts accumulate and ride with the
    /// next real batch), so shards whose scheduler never pings — and
    /// therefore sheds every mark — emit no trace frames at all.
    fn flush(&self, out: &Sender<Outbound>) {
        let marks = std::mem::take(&mut *self.buf.lock().unwrap());
        if marks.is_empty() {
            return;
        }
        let dropped = self.dropped.swap(0, Ordering::Relaxed);
        let _ = out.send(Outbound::Frame(Frame::TraceSpans { dropped, marks }));
    }
}

/// Outbound frame sink for one decode unit thread: every engine event
/// becomes a wire frame. Timestamps and request metrics stay shard-local
/// and are *not* sent — the scheduler re-stamps terminal events on its
/// own clock.
struct WireSink {
    out: Sender<Outbound>,
    /// This unit's index, carried in trace marks.
    unit: u32,
    /// Codec negotiated with the current scheduler connection (migration
    /// KV leaves coded like every other KV stream).
    codec: Arc<AtomicU8>,
    trace: Arc<ShardTraceBuf>,
}

impl DecodeEventSink for WireSink {
    fn token(&self, id: u64, index: u32, token: i32, _t: f64) {
        let _ = self.out.send(Outbound::Frame(Frame::Token { id, index, token }));
    }

    fn extracted(&self, id: u64, seq: Option<ExtractedSeq>) {
        // Everything rides the shard's single FIFO outbound queue, so
        // every Token frame the unit emitted before releasing the slot
        // is on the wire *before* this ack — the scheduler can treat the
        // ack's token history as the complete, final word on what the
        // source produced (exactly-once across the move).
        self.trace.flush(&self.out);
        let Some(ex) = seq else {
            let _ = self.out.send(Outbound::Frame(Frame::MigrateAck {
                id,
                found: false,
                kv_len: 0,
                remaining: 0,
                tokens: Vec::new(),
            }));
            return;
        };
        // The sequence's KV leaves as the same coded chunked KvSegment
        // stream as a prefill handoff, on the job's stream id, committed
        // by the MigrateAck.
        let codec = load_codec(&self.codec);
        let mut buf = Vec::new();
        let sent = proto::each_kv_segment(
            &mut buf,
            codec,
            proto::job_stream(id),
            id,
            config::KV_SEGMENT_ELEMS,
            &ex.k,
            &ex.v,
            |bytes| self.out.send(Outbound::Bytes(bytes.to_vec())).map_err(|_| ()),
        );
        if sent.is_err() {
            // Shard draining: the scheduler's eviction of its pending ids
            // terminalizes the job.
            return;
        }
        let _ = self.out.send(Outbound::Frame(Frame::MigrateAck {
            id,
            found: true,
            kv_len: ex.kv_len,
            remaining: ex.remaining,
            tokens: ex.tokens,
        }));
    }

    fn done(&self, id: u64, tokens: Vec<i32>, _metrics: RequestMetrics) {
        // Flush ahead of the terminal: the scheduler retires the
        // request's trace when `Done` lands, so any buffered marks must
        // precede it on the (FIFO) outbound queue.
        self.trace.flush(&self.out);
        let _ = self.out.send(Outbound::Frame(Frame::Done { id, tokens }));
    }

    fn rejected(&self, id: u64) {
        self.trace.flush(&self.out);
        let _ = self.out.send(Outbound::Frame(Frame::Rejected { id }));
    }

    fn trace(&self, id: u64, mark: Mark) {
        self.trace.push(id, mark, self.unit);
    }
}

/// Load a codec out of the shard's connection-scoped atomic (set at each
/// scheduler handshake; frames are self-describing, so a mid-switch race
/// is harmless).
fn load_codec(codec: &AtomicU8) -> KvCodec {
    KvCodec::from_wire(codec.load(Ordering::Relaxed)).unwrap_or(KvCodec::Raw)
}

/// Outbound sink for one prefill instance thread. A finished prefill
/// leaves either **directly** — streamed to the target decode shard's
/// peer listener, the scheduler seeing only a lightweight
/// `HandoffCommit` — or as the **relayed** chunked `KvSegment` stream +
/// `PrefillDone` (no target, or the peer path failed). Passes emit
/// `EndForward` carrying the instance's real remaining backlog.
struct PrefillWireSink {
    out: Sender<Outbound>,
    peers: Arc<PeerMux>,
    /// Codec negotiated with the current scheduler connection.
    codec: Arc<AtomicU8>,
    /// This instance's index, carried in trace marks.
    unit: u32,
    trace: Arc<ShardTraceBuf>,
}

impl PrefillWireSink {
    /// The relay path: stream the KV to the scheduler, chunked (same
    /// framing as the direct path via `proto::each_kv_segment`).
    fn relay(&self, id: u64, outcome: &PrefillOutcome) {
        let codec = load_codec(&self.codec);
        let mut buf = Vec::new();
        let sent = proto::each_kv_segment(
            &mut buf,
            codec,
            proto::job_stream(id),
            id,
            config::KV_SEGMENT_ELEMS,
            &outcome.k,
            &outcome.v,
            // The writer thread owns each queued chunk; the shard is
            // draining if the queue is gone.
            |bytes| self.out.send(Outbound::Bytes(bytes.to_vec())).map_err(|_| ()),
        );
        if sent.is_err() {
            return;
        }
        // The scheduler stamps `KvCommit`/`FirstToken` when this frame
        // lands; the shard's prefill marks must already be there.
        self.trace.flush(&self.out);
        let _ = self.out.send(Outbound::Frame(Frame::PrefillDone {
            id,
            first_token: outcome.first_token,
            kv_len: outcome.len as u32,
            exec_time: outcome.exec_time,
        }));
    }
}

impl PrefillEventSink for PrefillWireSink {
    fn prefilled(
        &self,
        id: u64,
        outcome: PrefillOutcome,
        max_new: u32,
        class: SloClass,
        _metrics: RequestMetrics,
        target: Option<DirectTarget>,
    ) {
        // End of prefill execution; the KV transfer (direct or relayed)
        // starts here, closed by the scheduler's `KvCommit` stamp.
        self.trace.push(id, Mark::PrefillEnd, self.unit);
        if let Some(t) = target.filter(|_| max_new > 1) {
            let codec = load_codec(&self.codec);
            match self.peers.handoff(codec, &t, id, &outcome, max_new - 1, class) {
                Ok(()) => {
                    // Acked by the decode shard: tell the scheduler with
                    // the lightweight commit — no KV on this connection.
                    // Trace marks flush first (the commit finalizes the
                    // scheduler-side TTFT stamps).
                    self.trace.flush(&self.out);
                    let _ = self.out.send(Outbound::Frame(Frame::HandoffCommit {
                        unit: t.unit,
                        id,
                        first_token: outcome.first_token,
                        kv_len: outcome.len as u32,
                        max_new: max_new - 1,
                        class,
                        exec_time: outcome.exec_time,
                    }));
                    return;
                }
                Err(e) => {
                    log::warn!(
                        "direct handoff of job {id} to {}#{} failed ({e:#}); \
                         falling back to scheduler relay",
                        t.addr,
                        t.unit
                    );
                }
            }
        }
        self.relay(id, &outcome);
    }

    fn failed(&self, id: u64) {
        let _ = self.out.send(Outbound::Frame(Frame::PrefillFailed { id }));
    }

    fn end_forward(&self, instance: u32, t_measured: f64, remaining: u32) {
        let _ = self.out.send(Outbound::Frame(Frame::EndForward {
            instance,
            t_measured,
            remaining: Some(remaining),
        }));
    }

    fn trace(&self, id: u64, mark: Mark) {
        self.trace.push(id, mark, self.unit);
    }
}

/// The shard's unit channels + gauges, shaped by its role.
enum UnitChannels {
    Decode {
        txs: Vec<Sender<UnitMsg>>,
        gauges: Vec<Arc<UnitGauges>>,
    },
    Prefill {
        txs: Vec<Sender<PrefillMsg>>,
        gauges: Vec<Arc<PrefillGauges>>,
    },
}

impl UnitChannels {
    fn len(&self) -> usize {
        match self {
            UnitChannels::Decode { txs, .. } => txs.len(),
            UnitChannels::Prefill { txs, .. } => txs.len(),
        }
    }

    /// Tell every unit to silently drop state a superseded connection
    /// left behind; returns one ack receiver covering all of them.
    fn send_aborts(&self) -> std::sync::mpsc::Receiver<()> {
        let (ack_tx, ack_rx) = channel::<()>();
        match self {
            UnitChannels::Decode { txs, .. } => {
                for tx in txs {
                    let _ = tx.send(UnitMsg::Abort { ack: ack_tx.clone() });
                }
            }
            UnitChannels::Prefill { txs, .. } => {
                for tx in txs {
                    let _ = tx.send(PrefillMsg::Abort { ack: ack_tx.clone() });
                }
            }
        }
        ack_rx
    }

    fn send_stops(&self) {
        match self {
            UnitChannels::Decode { txs, .. } => {
                for tx in txs {
                    let _ = tx.send(UnitMsg::Stop);
                }
            }
            UnitChannels::Prefill { txs, .. } => {
                for tx in txs {
                    let _ = tx.send(PrefillMsg::Stop);
                }
            }
        }
    }

    /// Role-appropriate per-unit loads for `StatsReply`: decode units
    /// report residency/slots/KV, prefill instances report queued jobs
    /// (as `active`) and queued prompt tokens (as `kv_tokens`).
    fn unit_loads(&self, batch: u32) -> Vec<UnitLoad> {
        match self {
            UnitChannels::Decode { gauges, .. } => gauges
                .iter()
                .map(|g| {
                    let used = g.slots_used.load(Ordering::Relaxed);
                    UnitLoad {
                        active: g.active.load(Ordering::Relaxed),
                        free_slots: batch.saturating_sub(used),
                        kv_tokens: g.kv_tokens.load(Ordering::Relaxed),
                    }
                })
                .collect(),
            UnitChannels::Prefill { gauges, .. } => gauges
                .iter()
                .map(|g| UnitLoad {
                    active: g.queued_jobs.load(Ordering::Relaxed),
                    free_slots: 0,
                    kv_tokens: g.queued_tokens.load(Ordering::Relaxed),
                })
                .collect(),
        }
    }
}

/// Run a shard on an already-bound listener until a scheduler sends
/// `Stop` (tests use this with an ephemeral port; `cli_worker` binds
/// from the CLI flags).
pub fn run_shard(cfg: ShardConfig, listener: TcpListener) -> Result<()> {
    let cfg = ShardConfig {
        units: cfg.units.max(1),
        // slots = 0 would advertise a unit that can never admit — every
        // placement would pend forever with no terminal event.
        batch: cfg.batch.max(1),
        ..cfg
    };
    let units = cfg.units;
    let clock = Arc::new(RealClock::new());
    // TTFT trace marks, aligned to the scheduler clock via heartbeat
    // pings and piggybacked on the control stream (best-effort).
    let trace = Arc::new(ShardTraceBuf::new(clock.clone()));
    let (ev_tx, ev_rx) = channel::<Outbound>();
    let (ready_tx, ready_rx) = channel::<bool>();
    // Codec negotiated with the current scheduler connection (what this
    // shard's senders produce; receivers decode self-describing blocks
    // regardless).
    let codec = Arc::new(AtomicU8::new(KvCodec::Raw.to_wire()));
    // Inbound-KV byte accounting (relay admits + direct peer handoffs),
    // reported to the scheduler in every StatsReply.
    let kv_in: Arc<KvWireCounters> = Arc::default();
    // Direct-transfer peer mux (prefill role only; created unconditionally
    // so the sink type stays uniform). One driver-owned connection per
    // decode peer, shared by all instance threads — concurrent handoffs
    // multiplex on per-job streams instead of serializing.
    let peers = Arc::new(PeerMux::new(
        config::KV_SEGMENT_ELEMS,
        Duration::from_secs(10),
    ));
    // Ids already admitted through the peer path (decode role). A
    // prefill shard whose HandoffAck was lost re-streams the same job on
    // a fresh connection; the re-commit must be acked *without*
    // re-admitting or re-emitting its first token. Cleared whenever a
    // new scheduler connection aborts the shard's state (fresh id
    // space).
    let direct_seen: Arc<Mutex<HashSet<u64>>> = Arc::default();
    let stop_flag = Arc::new(AtomicBool::new(false));
    let mut unit_threads = Vec::new();
    let channels = match cfg.role {
        ShardRole::Decode => {
            let mut txs = Vec::new();
            let mut gauges = Vec::new();
            for u in 0..units {
                let (tx, rx) = channel::<UnitMsg>();
                txs.push(tx);
                let g = Arc::new(UnitGauges::default());
                gauges.push(g.clone());
                let spec = cfg.engine.clone();
                let sink = WireSink {
                    out: ev_tx.clone(),
                    unit: u,
                    codec: codec.clone(),
                    trace: trace.clone(),
                };
                let clock = clock.clone();
                let (sampling, batch) = (cfg.sampling, cfg.batch);
                let seed = cfg.seed.wrapping_add(7000 + u as u64);
                let ready = ready_tx.clone();
                unit_threads.push(std::thread::spawn(move || {
                    run_decode_unit(
                        &format!("shard-unit:{u}"),
                        &spec,
                        batch,
                        sampling,
                        seed,
                        rx,
                        sink,
                        move || clock.now_s(),
                        Some(&g),
                        ready,
                    );
                }));
            }
            UnitChannels::Decode { txs, gauges }
        }
        ShardRole::Prefill => {
            let mut txs = Vec::new();
            let mut gauges = Vec::new();
            for u in 0..units {
                let (tx, rx) = channel::<PrefillMsg>();
                txs.push(tx);
                let g = Arc::new(PrefillGauges::default());
                gauges.push(g.clone());
                let spec = cfg.engine.clone();
                let sink = PrefillWireSink {
                    out: ev_tx.clone(),
                    peers: peers.clone(),
                    codec: codec.clone(),
                    unit: u,
                    trace: trace.clone(),
                };
                let seed = cfg.seed.wrapping_add(8000 + u as u64);
                let ready = ready_tx.clone();
                unit_threads.push(std::thread::spawn(move || {
                    run_prefill_unit(
                        &format!("shard-prefill:{u}"),
                        u,
                        &spec,
                        seed,
                        rx,
                        sink,
                        Some(&g),
                        ready,
                    );
                }));
            }
            UnitChannels::Prefill { txs, gauges }
        }
    };
    drop(ready_tx);
    for _ in 0..units {
        match ready_rx.recv_timeout(Duration::from_secs(600)) {
            Ok(true) => {}
            _ => return Err(anyhow!("a shard unit failed to build its engine (see log)")),
        }
    }

    // Decode shards additionally serve a *peer listener*: the endpoint
    // prefill shards stream direct KV handoffs into. Bound on the same
    // interface as the scheduler listener, ephemeral port, advertised in
    // every HelloAck. Peer connections are concurrent (one thread each)
    // and independent of the single-scheduler accept loop below.
    let peer_port = match cfg.role {
        ShardRole::Decode => {
            let ip = listener.local_addr()?.ip();
            let peer_listener = TcpListener::bind((ip, 0))?;
            let port = peer_listener.local_addr()?.port();
            peer_listener.set_nonblocking(true)?;
            let peer_channels = match &channels {
                UnitChannels::Decode { txs, .. } => txs.clone(),
                UnitChannels::Prefill { .. } => unreachable!("decode role"),
            };
            let (ev_tx, kv_in, stop) = (ev_tx.clone(), kv_in.clone(), stop_flag.clone());
            let seen = direct_seen.clone();
            std::thread::spawn(move || {
                peer_accept_loop(peer_listener, peer_channels, ev_tx, kv_in, seen, stop)
            });
            port
        }
        ShardRole::Prefill => 0,
    };
    log::info!(
        "{} shard ready: {units} units{}",
        cfg.role.name(),
        match cfg.role {
            ShardRole::Decode => format!(" × {} slots, peer port {peer_port}", cfg.batch),
            ShardRole::Prefill => String::new(),
        }
    );

    // One writer serializes every outbound frame onto the current
    // connection; with no connection, events are dropped (their owners
    // evicted them).
    let current: Arc<Mutex<Option<TcpStream>>> = Arc::new(Mutex::new(None));
    let writer = {
        let current = current.clone();
        std::thread::spawn(move || {
            while let Ok(out) = ev_rx.recv() {
                let (bytes, is_bye) = match out {
                    Outbound::Frame(f) => {
                        let mut buf = Vec::new();
                        proto::write_frame(&mut buf, &f).expect("Vec write cannot fail");
                        (buf, matches!(f, Frame::Bye))
                    }
                    // Pre-framed wire bytes (the KV-handoff hot path).
                    Outbound::Bytes(b) => (b, false),
                    Outbound::Flush(ack) => {
                        // Everything queued before this marker has been
                        // drained (written or dropped); tell the fence.
                        let _ = ack.send(());
                        continue;
                    }
                };
                {
                    let mut cur = current.lock().unwrap();
                    if let Some(conn) = cur.as_mut() {
                        if conn.write_all(&bytes).is_err() {
                            // The scheduler hung up (or the write timed
                            // out mid-frame): shut the socket so the peer
                            // sees the failure now, not after its silence
                            // guard.
                            let _ = conn.shutdown(std::net::Shutdown::Both);
                            *cur = None;
                        }
                    }
                }
                if is_bye {
                    break;
                }
            }
        })
    };

    let mut stopping = false;
    while !stopping {
        let (conn, peer) = match listener.accept() {
            Ok(x) => x,
            Err(e) => {
                log::warn!("accept failed: {e}");
                continue;
            }
        };
        log::info!("scheduler connected from {peer}");
        // A failed handshake/setup on one connection must never take the
        // whole shard down — drop it and keep accepting.
        stopping = match serve_connection(
            conn, &cfg, &channels, &ev_tx, &current, &codec, &kv_in, &direct_seen, peer_port,
            &trace,
        ) {
            Ok(stop) => stop,
            Err(e) => {
                log::warn!("connection setup failed: {e:#}");
                false
            }
        };
    }

    // Graceful drain: units finish their active work (flushing terminal
    // frames through the writer), then Bye closes the stream. The peer
    // accept thread observes the stop flag and exits on its next tick;
    // driver-owned peer connections close themselves on theirs.
    stop_flag.store(true, Ordering::SeqCst);
    channels.send_stops();
    for t in unit_threads {
        let _ = t.join();
    }
    peers.close_all();
    let _ = ev_tx.send(Outbound::Frame(Frame::Bye));
    let _ = writer.join();
    log::info!("{} shard drained; exiting", cfg.role.name());
    Ok(())
}

/// Serve one scheduler connection. Returns `Ok(true)` when the scheduler
/// asked the shard to stop, `Ok(false)` on disconnect (go back to
/// accepting).
#[allow(clippy::too_many_arguments)]
fn serve_connection(
    conn: TcpStream,
    cfg: &ShardConfig,
    channels: &UnitChannels,
    ev_tx: &Sender<Outbound>,
    current: &Arc<Mutex<Option<TcpStream>>>,
    codec: &AtomicU8,
    kv_in: &KvWireCounters,
    direct_seen: &Mutex<HashSet<u64>>,
    peer_port: u16,
    trace: &ShardTraceBuf,
) -> Result<bool> {
    conn.set_nodelay(true)?;
    conn.set_read_timeout(Some(Duration::from_millis(250)))?;
    // Bound writes too: a wedged scheduler socket must error out of the
    // writer thread (which then detaches the connection), not block it.
    conn.set_write_timeout(Some(Duration::from_secs(5)))?;
    let mut rd = conn.try_clone()?;
    let mut reader = FrameReader::new();
    // Handshake: Hello must arrive promptly, then HelloAck is written
    // directly (before the writer thread can interleave unit events).
    let hello = {
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            match reader.poll(&mut rd) {
                Ok(Some(f)) => break f,
                Ok(None) if std::time::Instant::now() < deadline => continue,
                Ok(None) => return Ok(false),
                Err(e) => {
                    log::warn!("handshake read failed: {e}");
                    return Ok(false);
                }
            }
        }
    };
    let kv_wire = match hello {
        Frame::Hello { version, kv_wire } if version == PROTO_VERSION => kv_wire,
        Frame::Hello { version, .. } => {
            log::warn!("scheduler speaks protocol v{version}, we speak v{PROTO_VERSION}");
            return Ok(false);
        }
        other => {
            log::warn!("expected Hello, got {other:?}");
            return Ok(false);
        }
    };
    // Adopt the scheduler's codec for everything this shard produces
    // (and for the peer handshakes its prefill instances open).
    codec.store(kv_wire.to_wire(), Ordering::Relaxed);
    {
        let mut w = conn.try_clone()?;
        proto::write_frame(
            &mut w,
            &Frame::HelloAck {
                version: PROTO_VERSION,
                role: cfg.role,
                units: channels.len() as u32,
                slots: match cfg.role {
                    ShardRole::Decode => cfg.batch,
                    // Prefill instances are gated single-pass engines;
                    // "slots" only exists for the shape check.
                    ShardRole::Prefill => 1,
                },
                kv_wire,
                peer_port,
            },
        )?;
    }
    // A new scheduler owns the shard from here: silently drop whatever a
    // previous connection left tracked (its scheduler already evicted
    // that state), and *wait for the abort to land* before attaching the
    // connection — a unit mid-step could otherwise emit a stale id that
    // collides with the new scheduler's fresh id space. One engine pass
    // bounds how long a unit takes to see the abort.
    {
        let ack_rx = channels.send_aborts();
        for _ in 0..channels.len() {
            if ack_rx.recv_timeout(Duration::from_secs(60)).is_err() {
                log::warn!("a unit did not acknowledge the abort in time");
                break;
            }
        }
        // The new scheduler brings a fresh id space: the peer-path dedup
        // set guards only against re-streamed handoffs within one
        // scheduler epoch.
        direct_seen.lock().unwrap().clear();
        // The acks fence unit *state*; frames a unit queued just before
        // its abort could still sit in the outbound queue. Drain the
        // queue (dropped — no connection attached) behind a flush
        // marker before the new connection can receive anything.
        let (flush_tx, flush_rx) = channel::<()>();
        if ev_tx.send(Outbound::Flush(flush_tx)).is_ok()
            && flush_rx.recv_timeout(Duration::from_secs(10)).is_err()
        {
            log::warn!("outbound queue flush timed out");
        }
    }
    *current.lock().unwrap() = Some(conn.try_clone()?);

    // A healthy scheduler heartbeats every second (transport pings), so
    // prolonged byte-silence (see `proto::IdleGuard`) means it is gone
    // without an EOF/RST (black-holed link, or its FIN was lost). Time
    // the connection out so the accept loop frees up for the
    // scheduler's reconnect — without this, a half-open connection
    // wedges the shard forever.
    const CONN_DEAD_AFTER: Duration = Duration::from_secs(6);
    /// Non-terminal trace marks (e.g. `DecodeAdmit` instants) leave on
    /// this cadence; terminal-adjacent marks flush inline at their sink.
    const TRACE_FLUSH_EVERY: Duration = Duration::from_millis(250);
    let mut idle = proto::IdleGuard::new(&reader);
    let mut consumed_at_last_frame = reader.consumed();
    let mut last_trace_flush = Instant::now();
    let result = loop {
        if idle.idle_for(&reader) >= CONN_DEAD_AFTER {
            log::warn!("scheduler silent for {CONN_DEAD_AFTER:?}; dropping the connection");
            break false;
        }
        if last_trace_flush.elapsed() >= TRACE_FLUSH_EVERY {
            trace.flush(ev_tx);
            last_trace_flush = Instant::now();
        }
        match reader.poll(&mut rd) {
            Ok(Some(frame)) => {
                idle.touch();
                let wire_len = reader.consumed() - consumed_at_last_frame;
                consumed_at_last_frame = reader.consumed();
                if handle_scheduler_frame(frame, wire_len, cfg, channels, ev_tx, kv_in, trace) {
                    break true;
                }
            }
            Ok(None) => continue,
            Err(ProtoError::Closed) => {
                log::info!("scheduler disconnected");
                break false;
            }
            Err(e) => {
                log::warn!("connection failed: {e}");
                break false;
            }
        }
    };
    // Detach the writer from this connection; on Stop it stays attached
    // so the drain's terminal/Bye frames flush to the scheduler.
    if !result {
        *current.lock().unwrap() = None;
    }
    Ok(result)
}

/// Handle one inbound frame on an established scheduler connection.
/// Returns `true` when the frame was `Stop` (drain and exit).
fn handle_scheduler_frame(
    frame: Frame,
    wire_len: u64,
    cfg: &ShardConfig,
    channels: &UnitChannels,
    ev_tx: &Sender<Outbound>,
    kv_in: &KvWireCounters,
    trace: &ShardTraceBuf,
) -> bool {
    match frame {
        Frame::Admit {
            unit,
            id,
            first_token,
            kv_len,
            max_new,
            class,
            resume,
            k,
            v,
        } => {
            let UnitChannels::Decode { txs, .. } = channels else {
                // Role was checked at handshake; an admit here is a
                // protocol violation, not a crash.
                log::warn!("admit sent to a prefill shard; rejecting job {id}");
                let _ = ev_tx.send(Outbound::Frame(Frame::Rejected { id }));
                return false;
            };
            // Relay-path inbound KV: the whole frame crossed the wire for
            // this sequence's caches.
            kv_in.record(wire_len, 4 * (k.len() as u64 + v.len() as u64));
            let job = AdmitJob {
                id,
                outcome: Box::new(PrefillOutcome {
                    first_token,
                    len: kv_len as usize,
                    k,
                    v,
                    exec_time: 0.0,
                    passes: 0,
                }),
                max_new,
                class,
                resume,
                // Shard-local bookkeeping only (KV gauge); real metrics
                // stay with the scheduler.
                metrics: RequestMetrics::arrive(0.0, kv_len),
            };
            match txs.get(unit as usize) {
                Some(tx) => {
                    if tx.send(UnitMsg::Admit(job)).is_err() {
                        let _ = ev_tx.send(Outbound::Frame(Frame::Rejected { id }));
                    }
                }
                None => {
                    log::warn!("admit for unknown unit {unit}");
                    let _ = ev_tx.send(Outbound::Frame(Frame::Rejected { id }));
                }
            }
        }
        Frame::PrefillDispatch { unit, jobs } => {
            let UnitChannels::Prefill { txs, .. } = channels else {
                log::warn!("prefill dispatch sent to a decode shard; failing the batch");
                for j in &jobs {
                    let _ = ev_tx.send(Outbound::Frame(Frame::PrefillFailed { id: j.id }));
                }
                return false;
            };
            let work: Vec<PrefillWork> = jobs
                .into_iter()
                .map(|j| {
                    // Receipt at the shard closes the dispatch-transit
                    // stage and opens the in-engine queue stage.
                    trace.push(j.id, Mark::PrefillRecv, unit);
                    let len = j.prompt.len() as u32;
                    PrefillWork {
                        id: j.id,
                        prompt: j.prompt,
                        max_new: j.max_new,
                        class: j.class,
                        // Shard-local bookkeeping only; the scheduler
                        // keeps the real wall-clock metrics.
                        metrics: RequestMetrics::arrive(0.0, len),
                        target: j.target,
                    }
                })
                .collect();
            match txs.get(unit as usize) {
                Some(tx) => {
                    let ids: Vec<u64> = work.iter().map(|w| w.id).collect();
                    if tx.send(PrefillMsg::Work(work)).is_err() {
                        for id in ids {
                            let _ = ev_tx.send(Outbound::Frame(Frame::PrefillFailed { id }));
                        }
                    }
                }
                None => {
                    log::warn!("prefill dispatch for unknown instance {unit}");
                    for w in work {
                        let _ = ev_tx.send(Outbound::Frame(Frame::PrefillFailed { id: w.id }));
                    }
                }
            }
        }
        Frame::Migrate { unit, id } => {
            // Rescue extraction: the unit releases the slot and answers
            // through its sink (KvSegment stream + MigrateAck). A target
            // this shard cannot serve answers not-found immediately so
            // the scheduler's rescue does not dangle.
            let not_found = || {
                let _ = ev_tx.send(Outbound::Frame(Frame::MigrateAck {
                    id,
                    found: false,
                    kv_len: 0,
                    remaining: 0,
                    tokens: Vec::new(),
                }));
            };
            let UnitChannels::Decode { txs, .. } = channels else {
                log::warn!("migrate sent to a prefill shard; job {id} reported not-found");
                not_found();
                return false;
            };
            match txs.get(unit as usize) {
                Some(tx) => {
                    if tx.send(UnitMsg::Extract { id }).is_err() {
                        not_found();
                    }
                }
                None => {
                    log::warn!("migrate for unknown unit {unit}");
                    not_found();
                }
            }
        }
        Frame::Ping { nonce, t_us } => {
            // The heartbeat carries the scheduler's clock: (re-)anchor
            // the trace alignment before echoing it back.
            trace.observe_ping(t_us);
            let _ = ev_tx.send(Outbound::Frame(Frame::Pong { nonce, t_us }));
        }
        Frame::StatsRequest => {
            let units = channels.unit_loads(cfg.batch);
            let (kv_wire_bytes, kv_raw_bytes) = kv_in.snapshot();
            let _ = ev_tx.send(Outbound::Frame(Frame::StatsReply {
                units,
                kv_wire_bytes,
                kv_raw_bytes,
            }));
        }
        Frame::Stop => return true,
        other => log::debug!("ignoring frame {other:?}"),
    }
    false
}

/// Accept loop of a decode shard's peer listener: each accepted
/// connection is one prefill shard streaming multiplexed direct KV
/// handoffs. Connections are handed to the process-global
/// [`NetDriver`] — no thread per peer; the accept thread is the peer
/// plane's only dedicated thread regardless of cluster size.
fn peer_accept_loop(
    listener: TcpListener,
    txs: Vec<Sender<UnitMsg>>,
    ev_tx: Sender<Outbound>,
    kv_in: Arc<KvWireCounters>,
    direct_seen: Arc<Mutex<HashSet<u64>>>,
    stop: Arc<AtomicBool>,
) {
    loop {
        match listener.accept() {
            Ok((conn, peer)) => {
                log::info!("direct-transfer peer connected from {peer}");
                let handler = PeerServerHandler {
                    peer: peer.to_string(),
                    hello_done: false,
                    txs: txs.clone(),
                    ev_tx: ev_tx.clone(),
                    kv_in: kv_in.clone(),
                    direct_seen: direct_seen.clone(),
                    stop: stop.clone(),
                    assembling: HashMap::new(),
                    poisoned: HashSet::new(),
                };
                if let Err(e) =
                    NetDriver::global().add(conn, Box::new(handler), ConnOptions::default())
                {
                    log::warn!("peer {peer}: driver registration failed: {e}");
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(e) => {
                log::warn!("peer accept failed: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// One KV cache pair being reassembled from a peer's `KvSegment` stream.
struct PeerAssembly {
    k: Vec<f32>,
    v: Vec<f32>,
    /// Last segment arrival, for abandoned-assembly GC.
    touched: Instant,
}

/// How long an assembly may sit without progress before GC reclaims it.
/// Far past the prefill side's ack timeout: by then the sender has
/// fallen back to relay and will never commit this copy.
const ASSEMBLY_GC_AFTER: Duration = Duration::from_secs(30);

/// Driver-side handler for one accepted direct-transfer peer connection:
/// `PeerHello` handshake, then interleaved per-job `KvSegment` streams
/// (keyed by request id — stream multiplexing means segments of
/// different jobs arrive interleaved) committed by `HandoffCommit`,
/// each commit admitting the assembled sequence into its unit and acked
/// back on the priority lane. A dying connection drops its partial
/// assemblies — nothing was admitted, so the prefill side's relay
/// fallback (or the scheduler's eviction of the decode registration)
/// terminalizes the job.
struct PeerServerHandler {
    peer: String,
    hello_done: bool,
    txs: Vec<Sender<UnitMsg>>,
    ev_tx: Sender<Outbound>,
    kv_in: Arc<KvWireCounters>,
    direct_seen: Arc<Mutex<HashSet<u64>>>,
    stop: Arc<AtomicBool>,
    /// Per-job KV assembly (keyed by request id, both halves).
    assembling: HashMap<u64, PeerAssembly>,
    /// Jobs whose KV stream was malformed: their assembly is dropped and
    /// the eventual commit is *not* acked, so the sender's ack timeout
    /// routes the job to relay. Scoped to the job, not the connection —
    /// one bad stream must not kill the other handoffs multiplexed on
    /// this socket.
    poisoned: HashSet<u64>,
}

impl ConnHandler for PeerServerHandler {
    fn on_frame(&mut self, io: &mut ConnIo<'_>, _stream: StreamId, frame: Frame, wire_len: u64) {
        if !self.hello_done {
            match frame {
                Frame::PeerHello { version, .. } if version == PROTO_VERSION => {
                    self.hello_done = true;
                    io.enqueue_priority(proto::frame_bytes_on(
                        STREAM_CONTROL,
                        &Frame::PeerHelloAck { version: PROTO_VERSION },
                    ));
                }
                Frame::PeerHello { version, .. } => {
                    log::warn!(
                        "peer {} speaks v{version}, we speak v{PROTO_VERSION}; dropping",
                        self.peer
                    );
                    io.close();
                }
                other => {
                    log::warn!("peer {}: expected PeerHello, got {other:?}", self.peer);
                    io.close();
                }
            }
            return;
        }
        match frame {
            Frame::KvSegment {
                id,
                half,
                offset,
                total,
                data,
            } => {
                self.kv_in.record(wire_len, 4 * data.len() as u64);
                if self.poisoned.contains(&id) {
                    return;
                }
                let entry = self.assembling.entry(id).or_insert_with(|| PeerAssembly {
                    k: Vec::new(),
                    v: Vec::new(),
                    touched: Instant::now(),
                });
                entry.touched = Instant::now();
                if let Err(why) =
                    proto::apply_kv_segment(&mut entry.k, &mut entry.v, half, offset, total, &data)
                {
                    // Malformed stream: poison the *job*. Its commit will
                    // go unacked, so the sender's timeout falls back to
                    // relay; sibling handoffs on this connection are
                    // untouched.
                    log::warn!(
                        "peer {}: malformed KV segment for job {id} ({why}); \
                         poisoning the job",
                        self.peer
                    );
                    self.assembling.remove(&id);
                    self.poisoned.insert(id);
                }
            }
            Frame::HandoffCommit {
                unit,
                id,
                first_token,
                kv_len,
                max_new,
                class,
                exec_time,
            } => {
                if self.poisoned.remove(&id) {
                    log::warn!(
                        "peer {}: withholding ack for poisoned job {id} \
                         (sender will fall back to relay)",
                        self.peer
                    );
                    return;
                }
                if !self.direct_seen.lock().unwrap().insert(id) {
                    // A prefill shard whose ack was lost re-streamed a
                    // handoff this shard already owns: ack again, admit
                    // nothing, emit nothing — the original sequence's
                    // stream is already running.
                    log::info!("duplicate direct handoff for job {id}; re-acking only");
                    self.assembling.remove(&id);
                    io.enqueue_priority(proto::frame_bytes_on(
                        STREAM_CONTROL,
                        &Frame::HandoffAck { id },
                    ));
                    return;
                }
                let (k, v) = self
                    .assembling
                    .remove(&id)
                    .map(|a| (a.k, a.v))
                    .unwrap_or_default();
                let job = AdmitJob {
                    id,
                    outcome: Box::new(PrefillOutcome {
                        first_token,
                        len: kv_len as usize,
                        k,
                        v,
                        exec_time,
                        passes: 1,
                    }),
                    max_new,
                    class,
                    resume: Vec::new(),
                    // Shard-local bookkeeping only (KV gauge); real
                    // metrics live scheduler-side in the direct
                    // registration made at dispatch.
                    metrics: RequestMetrics::arrive(0.0, kv_len),
                };
                let admitted = match self.txs.get(unit as usize) {
                    Some(tx) => {
                        // Token index 0 *before* the admit: both ride the
                        // shard's single outbound queue, so the first
                        // token precedes every decode-step token on the
                        // scheduler connection.
                        let _ = self.ev_tx.send(Outbound::Frame(Frame::Token {
                            id,
                            index: 0,
                            token: first_token,
                        }));
                        tx.send(UnitMsg::Admit(job)).is_ok()
                    }
                    None => false,
                };
                if !admitted {
                    log::warn!(
                        "direct handoff for job {id} names unknown unit {unit}; rejecting"
                    );
                    let _ = self.ev_tx.send(Outbound::Frame(Frame::Rejected { id }));
                }
                // Ack either way: the handoff reached a terminal owner
                // (the unit, or a Rejected on the scheduler stream) and
                // must not be relayed a second time.
                io.enqueue_priority(proto::frame_bytes_on(
                    STREAM_CONTROL,
                    &Frame::HandoffAck { id },
                ));
            }
            Frame::Ping { nonce, t_us } => {
                io.enqueue_priority(proto::frame_bytes_on(
                    STREAM_CONTROL,
                    &Frame::Pong { nonce, t_us },
                ));
            }
            other => log::debug!("peer: ignoring frame {other:?}"),
        }
    }

    fn on_tick(&mut self, io: &mut ConnIo<'_>) {
        if self.stop.load(Ordering::SeqCst) {
            io.close();
            return;
        }
        // Reclaim assemblies whose sender gave up (never committed —
        // e.g. segments that kept arriving for a job already routed to
        // relay, or a stale StreamId's leftovers).
        self.assembling.retain(|id, a| {
            let keep = a.touched.elapsed() < ASSEMBLY_GC_AFTER;
            if !keep {
                log::debug!("peer {}: GC of abandoned KV assembly for job {id}", self.peer);
            }
            keep
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_mock() -> EngineSpec {
        EngineSpec::Mock(MockEngineConfig {
            t_prefill_base: 0.0,
            t_prefill_per_token: 0.0,
            t_decode_step: 0.001,
            chunk: 128,
            jitter: 0.0,
            kv_elems_per_token: 4,
        })
    }

    struct ShardClient {
        w: TcpStream,
        rd: TcpStream,
        reader: FrameReader,
    }

    impl ShardClient {
        fn connect(addr: std::net::SocketAddr) -> ShardClient {
            let conn = TcpStream::connect(addr).unwrap();
            conn.set_nodelay(true).unwrap();
            conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            ShardClient {
                w: conn.try_clone().unwrap(),
                rd: conn.try_clone().unwrap(),
                reader: FrameReader::new(),
            }
        }

        fn send(&mut self, f: &Frame) {
            proto::write_frame(&mut self.w, f).unwrap();
        }

        fn recv(&mut self) -> Frame {
            loop {
                if let Some(f) = self.reader.poll(&mut self.rd).expect("read frame") {
                    return f;
                }
            }
        }

        /// Handshake as a scheduler; returns the advertised
        /// `(units, slots, peer_port)`.
        fn handshake(&mut self, role: ShardRole, kv_wire: KvCodec) -> (u32, u32, u16) {
            self.send(&Frame::Hello {
                version: PROTO_VERSION,
                kv_wire,
            });
            match self.recv() {
                Frame::HelloAck {
                    version,
                    role: r,
                    units,
                    slots,
                    kv_wire: acked,
                    peer_port,
                } => {
                    assert_eq!(version, PROTO_VERSION);
                    assert_eq!(r, role);
                    assert_eq!(acked, kv_wire, "shard must echo the proposed codec");
                    (units, slots, peer_port)
                }
                other => panic!("expected HelloAck, got {other:?}"),
            }
        }
    }

    /// Raw protocol smoke against an in-thread decode shard: handshake,
    /// admit, stream to Done, stats, clean Stop/Bye drain.
    #[test]
    fn decode_shard_serves_the_frame_protocol_end_to_end() {
        let cfg = ShardConfig {
            role: ShardRole::Decode,
            units: 2,
            batch: 4,
            engine: fast_mock(),
            sampling: Sampling::Greedy,
            seed: 3,
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let shard = std::thread::spawn(move || run_shard(cfg, listener));

        let mut c = ShardClient::connect(addr);
        let (units, slots, peer_port) = c.handshake(ShardRole::Decode, KvCodec::Raw);
        assert_eq!((units, slots), (2, 4));
        assert_ne!(peer_port, 0, "decode shards must advertise a peer listener");

        c.send(&Frame::Admit {
            unit: 1,
            id: 42,
            first_token: 0x30,
            kv_len: 5,
            max_new: 3,
            class: SloClass::Standard,
            resume: Vec::new(),
            k: Vec::new(),
            v: Vec::new(),
        });
        let mut tokens = Vec::new();
        let done = loop {
            match c.recv() {
                Frame::Token { id, index, token } => {
                    assert_eq!(id, 42);
                    assert_eq!(index as usize, tokens.len() + 1, "indices continue past prefill");
                    tokens.push(token);
                }
                Frame::Done { id, tokens: all } => {
                    assert_eq!(id, 42);
                    break all;
                }
                other => panic!("unexpected frame {other:?}"),
            }
        };
        assert_eq!(done.len(), 4, "prefill token + 3 generated");
        assert_eq!(done[0], 0x30);
        assert_eq!(&done[1..], &tokens[..]);

        c.send(&Frame::Ping { nonce: 9, t_us: 123 });
        assert_eq!(c.recv(), Frame::Pong { nonce: 9, t_us: 123 });

        c.send(&Frame::StatsRequest);
        match c.recv() {
            Frame::StatsReply { units, .. } => assert_eq!(units.len(), 2),
            other => panic!("unexpected frame {other:?}"),
        }

        c.send(&Frame::Stop);
        assert_eq!(c.recv(), Frame::Bye);
        shard.join().unwrap().unwrap();
    }

    /// The full migration round-trip against a live decode shard:
    /// admit → tokens → `Migrate` → coded KV stream + `MigrateAck`
    /// (whose token history must be exactly the streamed prefix — the
    /// FIFO outbound queue is the exactly-once guarantee) → re-admit on
    /// another unit seeded with the history → the stream continues
    /// contiguously to `Done` with no token lost or duplicated.
    #[test]
    fn migration_moves_a_live_sequence_between_units_without_reordering() {
        let cfg = ShardConfig {
            role: ShardRole::Decode,
            units: 2,
            batch: 4,
            engine: fast_mock(),
            sampling: Sampling::Greedy,
            seed: 3,
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let shard = std::thread::spawn(move || run_shard(cfg, listener));
        let mut c = ShardClient::connect(addr);
        c.handshake(ShardRole::Decode, KvCodec::Lz);

        // Real prompt KV so coded segments cross the wire on the way out.
        let k: Vec<f32> = (0..40).map(|i| i as f32 * 0.5).collect();
        let v: Vec<f32> = (0..40).map(|i| i as f32 * -0.25).collect();
        c.send(&Frame::Admit {
            unit: 0,
            id: 42,
            first_token: 0x30,
            kv_len: 10,
            max_new: 64,
            class: SloClass::Interactive,
            resume: Vec::new(),
            k: k.clone(),
            v: v.clone(),
        });
        // Let a few tokens flow, then ask for the move.
        let mut streamed = vec![0x30];
        while streamed.len() < 4 {
            match c.recv() {
                Frame::Token { id, index, token } => {
                    assert_eq!(id, 42);
                    assert_eq!(index as usize, streamed.len());
                    streamed.push(token);
                }
                other => panic!("unexpected frame {other:?}"),
            }
        }
        c.send(&Frame::Migrate { unit: 0, id: 42 });
        // Until the ack lands, the unit may step a few more times; every
        // such token must precede the ack on the wire.
        let (mut mk, mut mv) = (Vec::new(), Vec::new());
        let (kv_len, remaining, tokens) = loop {
            match c.recv() {
                Frame::Token { id, index, token } => {
                    assert_eq!(id, 42);
                    assert_eq!(index as usize, streamed.len());
                    streamed.push(token);
                }
                Frame::KvSegment { id, half, offset, total, data } => {
                    assert_eq!(id, 42);
                    proto::apply_kv_segment(&mut mk, &mut mv, half, offset, total, &data)
                        .unwrap();
                }
                Frame::MigrateAck { id, found, kv_len, remaining, tokens } => {
                    assert_eq!(id, 42);
                    assert!(found);
                    break (kv_len, remaining, tokens);
                }
                other => panic!("unexpected frame {other:?}"),
            }
        };
        assert_eq!(
            tokens, streamed,
            "the ack's history is exactly the streamed prefix — nothing lost, nothing extra"
        );
        assert_eq!(kv_len, 10);
        assert_eq!(remaining as usize, 64 - (streamed.len() - 1));
        assert_eq!(mk, k, "prompt KV survives the coded migration round-trip");
        assert_eq!(mv, v);

        // Re-admit on the other unit, seeded with the history.
        c.send(&Frame::Admit {
            unit: 1,
            id: 42,
            first_token: *tokens.last().unwrap(),
            kv_len,
            max_new: remaining,
            class: SloClass::Interactive,
            resume: tokens.clone(),
            k: mk,
            v: mv,
        });
        let mut all = tokens;
        let done = loop {
            match c.recv() {
                Frame::Token { id, index, token } => {
                    assert_eq!(id, 42);
                    assert_eq!(
                        index as usize,
                        all.len(),
                        "indices continue contiguously across the move"
                    );
                    let expect = 0x20 + (all.last().unwrap() - 0x20 + 1).rem_euclid(0x5f);
                    assert_eq!(token, expect, "the deterministic chain continues unbroken");
                    all.push(token);
                }
                Frame::Done { id, tokens } => {
                    assert_eq!(id, 42);
                    break tokens;
                }
                other => panic!("unexpected frame {other:?}"),
            }
        };
        assert_eq!(done, all, "terminal history = resume + post-move tokens");
        assert_eq!(done.len(), 65, "1 prefill + 64 generated, exactly once each");

        // A migrate for a sequence the shard no longer holds answers
        // not-found (the scheduler re-places from its own registration).
        c.send(&Frame::Migrate { unit: 1, id: 42 });
        assert_eq!(
            c.recv(),
            Frame::MigrateAck {
                id: 42,
                found: false,
                kv_len: 0,
                remaining: 0,
                tokens: Vec::new()
            }
        );

        c.send(&Frame::Stop);
        assert_eq!(c.recv(), Frame::Bye);
        shard.join().unwrap().unwrap();
    }

    /// Admits for an out-of-range unit come back Rejected instead of
    /// wedging the scheduler's ledger.
    #[test]
    fn unknown_unit_admit_is_rejected() {
        let cfg = ShardConfig {
            role: ShardRole::Decode,
            units: 1,
            batch: 2,
            engine: fast_mock(),
            sampling: Sampling::Greedy,
            seed: 3,
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let shard = std::thread::spawn(move || run_shard(cfg, listener));
        let mut c = ShardClient::connect(addr);
        c.handshake(ShardRole::Decode, KvCodec::Raw);
        c.send(&Frame::Admit {
            unit: 5,
            id: 1,
            first_token: 0x30,
            kv_len: 2,
            max_new: 2,
            class: SloClass::Interactive,
            resume: Vec::new(),
            k: Vec::new(),
            v: Vec::new(),
        });
        assert_eq!(c.recv(), Frame::Rejected { id: 1 });
        c.send(&Frame::Stop);
        assert_eq!(c.recv(), Frame::Bye);
        shard.join().unwrap().unwrap();
    }

    /// Raw protocol smoke against an in-thread *prefill* shard: the
    /// dispatch→KvSegment*→PrefillDone relay handoff plus EndForward
    /// backlog feedback, stats, and a clean drain. The mock engine
    /// synthesizes KV (4 elements/token here), so real coded segments
    /// cross the wire ahead of each commit.
    #[test]
    fn prefill_shard_streams_the_kv_handoff_end_to_end() {
        let cfg = ShardConfig {
            role: ShardRole::Prefill,
            units: 1,
            batch: 8, // ignored for prefill; HelloAck must advertise 1
            engine: fast_mock(),
            sampling: Sampling::Greedy,
            seed: 3,
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let shard = std::thread::spawn(move || run_shard(cfg, listener));

        let mut c = ShardClient::connect(addr);
        let (units, slots, peer_port) = c.handshake(ShardRole::Prefill, KvCodec::Lz);
        assert_eq!((units, slots), (1, 1));
        assert_eq!(peer_port, 0, "prefill shards have no peer listener");

        c.send(&Frame::PrefillDispatch {
            unit: 0,
            jobs: vec![
                proto::PrefillJobWire {
                    id: 7,
                    max_new: 4,
                    class: SloClass::Standard,
                    prompt: vec![1, 2, 3, 4, 5],
                    target: None,
                },
                proto::PrefillJobWire {
                    id: 8,
                    max_new: 4,
                    class: SloClass::Batch,
                    prompt: vec![9; 12],
                    target: None,
                },
            ],
        });
        let mut done_ids = Vec::new();
        let mut segments = 0u32;
        let mut end_forwards = 0u32;
        while done_ids.len() < 2 || end_forwards < 2 {
            match c.recv() {
                Frame::KvSegment { id, offset, total, data, .. } => {
                    assert!(id == 7 || id == 8);
                    assert!(offset as usize + data.len() <= total as usize);
                    segments += 1;
                }
                Frame::PrefillDone { id, kv_len, .. } => {
                    let expect_len = if id == 7 { 5 } else { 12 };
                    assert_eq!(kv_len, expect_len, "kv_len echoes the prompt length");
                    done_ids.push(id);
                }
                Frame::EndForward { instance, remaining, .. } => {
                    assert_eq!(instance, 0);
                    assert!(remaining.is_some(), "prefill shards report real backlog");
                    end_forwards += 1;
                }
                other => panic!("unexpected frame {other:?}"),
            }
        }
        assert_eq!(done_ids.len(), 2);
        assert!(segments >= 2, "synthesized KV must cross the wire as segments");

        c.send(&Frame::StatsRequest);
        match c.recv() {
            Frame::StatsReply { units, .. } => {
                assert_eq!(units.len(), 1);
                assert_eq!(units[0].active, 0, "queue drained");
            }
            other => panic!("unexpected frame {other:?}"),
        }

        // An admit against a prefill shard is rejected, not served.
        c.send(&Frame::Admit {
            unit: 0,
            id: 99,
            first_token: 0,
            kv_len: 1,
            max_new: 1,
            class: SloClass::Standard,
            resume: Vec::new(),
            k: Vec::new(),
            v: Vec::new(),
        });
        assert_eq!(c.recv(), Frame::Rejected { id: 99 });

        c.send(&Frame::Stop);
        assert_eq!(c.recv(), Frame::Bye);
        shard.join().unwrap().unwrap();
    }

    /// Dispatches for an out-of-range prefill instance come back
    /// PrefillFailed instead of silently vanishing.
    #[test]
    fn unknown_prefill_instance_dispatch_fails_the_jobs() {
        let cfg = ShardConfig {
            role: ShardRole::Prefill,
            units: 1,
            batch: 1,
            engine: fast_mock(),
            sampling: Sampling::Greedy,
            seed: 3,
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let shard = std::thread::spawn(move || run_shard(cfg, listener));
        let mut c = ShardClient::connect(addr);
        c.handshake(ShardRole::Prefill, KvCodec::Raw);
        c.send(&Frame::PrefillDispatch {
            unit: 3,
            jobs: vec![proto::PrefillJobWire {
                id: 11,
                max_new: 2,
                class: SloClass::Standard,
                prompt: vec![1, 2],
                target: None,
            }],
        });
        assert_eq!(c.recv(), Frame::PrefillFailed { id: 11 });
        c.send(&Frame::Stop);
        assert_eq!(c.recv(), Frame::Bye);
        shard.join().unwrap().unwrap();
    }
}
