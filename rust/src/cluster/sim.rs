//! Discrete-event simulation of a P/D-disaggregated DP+EP serving
//! cluster.
//!
//! This is the experimental substrate standing in for the paper's H800
//! production cluster: gated prefill engines with per-DP device queues and
//! sync barriers ([`super::prefill`]), synchronized decode engines
//! ([`super::decode`]), a KV-transfer fabric, and either the staggered
//! batch scheduler or an immediate-dispatch baseline in the control plane.
//! Time is virtual; every run is deterministic given the workload seed.
//!
//! All scheduling decisions — prefill dispatch *and* decode placement —
//! go through the shared [`DispatchCore`]; this module only owns the
//! virtual transport (event queue), the engine models and the metrics.
//! The threaded real cluster ([`super::workers`]) drives the same core
//! over sockets and threads.

use super::costmodel::{DecodeCostModel, DpStepLoad, KvTransferModel, PrefillCostModel};
use super::decode::{DecodeCaps, DecodeEngine};
use super::dispatch::{
    DecodeAdmission, DecodeJoin, DecodePolicy, DispatchCore, DispatchCoreConfig,
    EndForwardBacklog, RescueConfig,
};
use super::events::EventQueue;
use super::prefill::PrefillEngine;
use crate::metrics::{DecodePoolStats, LatencyRecorder, RequestMetrics, ServingReport};
use crate::scheduler::baseline::ImmediatePolicy;
use crate::scheduler::decode::DecodeSchedConfig;
use crate::scheduler::pbaa::Assignment;
use crate::scheduler::staggered::{SchedulerAction, StaggeredConfig};
use crate::json::Json;
use crate::scheduler::types::{DpUnitId, Request, SloClass};
use crate::trace::{Mark, TraceCollector};
use crate::workload::WorkloadSpec;

pub use super::dispatch::SchedMode;

/// Decode placement mode (§4.3 vs baselines). Thin figure-facing alias
/// over the dispatch core's [`DecodePolicy`].
#[derive(Debug, Clone)]
pub enum DecodePlacement {
    /// Algorithm 3: IQR masking + lexicographic ⟨B, K⟩.
    IqrLex(DecodeSchedConfig),
    /// Algorithm 3 with per-request deadline urgency folded into the
    /// lexicographic key (classed workloads; class-less requests fall
    /// back to pure load).
    DeadlineAware(DecodeSchedConfig),
    /// Blind hash/random routing (the Fig. 7–8 baseline).
    Random,
    /// Blind strict round-robin (ablation).
    RoundRobin,
}

impl DecodePlacement {
    /// The dispatch-core policy this placement mode maps to.
    pub fn policy(&self) -> DecodePolicy {
        match self {
            DecodePlacement::IqrLex(c) => DecodePolicy::LoadAware(c.clone()),
            DecodePlacement::DeadlineAware(c) => DecodePolicy::DeadlineAware(c.clone()),
            DecodePlacement::Random => DecodePolicy::Random,
            DecodePlacement::RoundRobin => DecodePolicy::RoundRobin,
        }
    }
}

/// Cluster shape.
#[derive(Debug, Clone)]
pub struct SimTopology {
    /// Prefill instances in the pool.
    pub n_prefill: u32,
    /// DP-Attention units per prefill instance.
    pub dp_prefill: u32,
    /// Prefill chunk size (tokens per DP per pass).
    pub c_chunk: u32,
    /// Decode instances.
    pub n_decode: u32,
    /// DP units per decode instance.
    pub dp_decode: u32,
}

impl SimTopology {
    /// The paper's §5.1 topology: 3P1D, prefill DP=8, decode DP=32.
    pub fn paper_3p1d(c_chunk: u32) -> Self {
        SimTopology {
            n_prefill: 3,
            dp_prefill: 8,
            c_chunk,
            n_decode: 1,
            dp_decode: 32,
        }
    }
}

/// Full simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Cluster shape.
    pub topology: SimTopology,
    /// Request workload.
    pub workload: WorkloadSpec,
    /// Prefill control plane.
    pub mode: SchedMode,
    /// Decode placement.
    pub decode: DecodePlacement,
    /// Prefill execution-time model.
    pub prefill_cost: PrefillCostModel,
    /// Decode execution-time model.
    pub decode_cost: DecodeCostModel,
    /// P→D KV transfer model.
    pub kv_transfer: KvTransferModel,
    /// Scheduler→instance network latency (s).
    pub l_net: f64,
    /// Engine batch-formation delay: after a pass completes, the engine
    /// gathers its device queue for this long before launching the next
    /// pass (lets an EndForward-triggered dispatch merge with leftovers).
    pub formation_delay: f64,
    /// Ignore metrics for requests arriving before this time (s).
    pub warmup: f64,
    /// Fig. 7 sampling interval for decode KV snapshots (0 = off).
    pub kv_sample_interval: f64,
    /// Hard wall time to stop even if requests remain (safety).
    pub max_time: f64,
    /// Fault injection: probability that an instance's EndForward signal
    /// is silently lost (exercises the §4.1.2 watchdog safety path).
    pub fault_lose_endforward: f64,
    /// Per-DP decode resource caps (batch slots / KV memory).
    pub decode_caps: DecodeCaps,
    /// SLO-violation rescue (decode preemption + migration at step
    /// boundaries). Disabled by default; [`RescueConfig::on`] enables it.
    pub rescue: RescueConfig,
}

impl SimConfig {
    /// Paper Fig. 6(a) setup at `load` × the calibrated baseline peak QPS
    /// (150 QPS — the immediate-dispatch SLO point found by the Table 1
    /// search; see `crate::config::FIG6A_BASELINE_PEAK_QPS`).
    pub fn paper_fig6a(load: f64) -> Self {
        let qps = 150.0 * load;
        SimConfig {
            topology: SimTopology::paper_3p1d(3072),
            workload: WorkloadSpec::paper_short(qps, 120.0, 42),
            mode: SchedMode::Staggered(StaggeredConfig::default()),
            decode: DecodePlacement::IqrLex(DecodeSchedConfig::default()),
            prefill_cost: PrefillCostModel::default(),
            decode_cost: DecodeCostModel::default(),
            kv_transfer: KvTransferModel::default(),
            l_net: 0.002,
            formation_delay: 0.004,
            warmup: 20.0,
            kv_sample_interval: 0.0,
            max_time: 1.0e4,
            fault_lose_endforward: 0.0,
            decode_caps: DecodeCaps::default(),
            rescue: RescueConfig::default(),
        }
    }

    /// Switch to the immediate-dispatch baseline.
    pub fn with_immediate(mut self, policy: ImmediatePolicy) -> Self {
        self.mode = SchedMode::Immediate(policy);
        self
    }

    fn core_config(&self) -> DispatchCoreConfig {
        let t = &self.topology;
        DispatchCoreConfig {
            mode: self.mode.clone(),
            n_prefill: t.n_prefill,
            dp_prefill: t.dp_prefill,
            c_chunk: t.c_chunk,
            n_decode: t.n_decode,
            dp_decode: t.dp_decode,
            decode_policy: self.decode.policy(),
            seed: self.workload.seed ^ 0xDECD_E000,
        }
    }
}

/// Engine-backed admission for the DES: hard KV/batch caps checked
/// against — and joins committed to — the decode engines, so
/// admissibility stays exact within one placement cycle.
struct EngineAdmission<'a> {
    decode: &'a mut Vec<DecodeEngine>,
}

impl DecodeAdmission for EngineAdmission<'_> {
    fn admissible(&mut self, state: &crate::scheduler::state::DpState, join: &DecodeJoin) -> bool {
        let unit = state.id;
        self.decode[unit.instance as usize].can_accept(unit.dp as usize, join.kv_tokens)
    }

    fn commit(&mut self, unit: DpUnitId, join: &DecodeJoin) {
        self.decode[unit.instance as usize].join(
            unit.dp as usize,
            join.request_id as usize,
            join.kv_tokens,
            join.remaining_out,
        );
    }
}

/// Simulation events.
enum Ev {
    Arrival(usize),
    SchedTimer,
    Deliver {
        instance: u32,
        assignments: Vec<Assignment>,
        dispatched_at: f64,
    },
    PassDone {
        instance: u32,
    },
    /// Batch-formation window elapsed: the engine may launch its next pass.
    TryStart {
        instance: u32,
    },
    KvReady(usize),
    StepDone {
        instance: u32,
    },
    KvSample,
}

/// Track label for every DES-emitted trace mark (one virtual process).
const TRACK_SIM: &str = "sim";

/// Simulation output.
#[derive(Debug)]
pub struct SimReport {
    /// Aggregate serving metrics (TTFT, queue decomposition, throughput,
    /// chunk utilization).
    pub report: ServingReport,
    /// Decode KV snapshots `(t, per-unit loads)` for Fig. 7.
    pub kv_series: Vec<(f64, Vec<DpStepLoad>)>,
    /// Per-DP decode occupancy + imbalance gauges from the dispatch core.
    pub decode_pool: DecodePoolStats,
    /// Total prefill forward passes executed.
    pub prefill_passes: u64,
    /// Total decode steps executed.
    pub decode_steps: u64,
    /// Seconds of decode execution (Σ step durations, post-warmup).
    pub decode_busy_s: f64,
    /// Decode tokens generated post-warmup.
    pub decode_tokens: u64,
    /// Accumulated straggler DP-seconds (Fig. 3 "waste").
    pub straggler_waste_s: f64,
    /// Final adaptive interval (SBS mode; 0 for baselines).
    pub i_opt_final: f64,
    /// Requests completed.
    pub completed: usize,
    /// Requests generated.
    pub offered: usize,
    /// EndForward signals eaten by fault injection.
    pub lost_signals: u64,
    /// Virtual time at simulation end.
    pub t_end: f64,
    /// Per-stage TTFT decomposition (the same span vocabulary the live
    /// cluster traces emit, so sim and live reports are comparable).
    pub ttft_stages: Json,
    /// Requests shed or rejected, indexed by [`SloClass::rank`].
    pub rejected_by_class: [u64; 3],
    /// Post-warmup TTFT per SLO class, indexed by [`SloClass::rank`].
    pub ttft_by_class: [LatencyRecorder; 3],
    /// Post-warmup completions that met their deadline, per SLO class
    /// (requests without a deadline count in neither array).
    pub deadline_met_by_class: [u64; 3],
    /// Post-warmup completions that missed their deadline, per SLO class.
    pub deadline_violated_by_class: [u64; 3],
}

impl SimReport {
    /// Mean/σ of per-unit KV across the sampled series (Fig. 7 band).
    pub fn kv_band(&self) -> (f64, f64) {
        let mut all_means = Vec::new();
        let mut all_stds = Vec::new();
        for (_, loads) in &self.kv_series {
            let xs: Vec<f64> = loads.iter().map(|l| l.kv_tokens as f64).collect();
            all_means.push(crate::util::stats::mean(&xs));
            all_stds.push(crate::util::stats::stddev(&xs));
        }
        (
            crate::util::stats::mean(&all_means),
            crate::util::stats::mean(&all_stds),
        )
    }
}

/// The simulation driver.
pub struct Simulation {
    cfg: SimConfig,
    q: EventQueue<Ev>,
    requests: Vec<Request>,
    metrics: Vec<RequestMetrics>,
    effective: Vec<u32>, // prefill tokens after cache hits
    /// The shared dispatch core (all scheduling decisions).
    core: DispatchCore,
    // Prefill plane.
    prefill: Vec<PrefillEngine>,
    inflight_pass: Vec<Option<(super::prefill::PassRecord, f64)>>,
    // Decode plane.
    decode: Vec<DecodeEngine>,
    pending_joins: Vec<DecodeJoin>,
    /// Cumulative decode-token emissions per request (feeds the rescue
    /// layer's per-token rate model; monotone across migrations).
    decode_emitted: Vec<u32>,
    fault_rng: crate::util::Rng,
    /// EndForward signals eaten by fault injection.
    pub lost_signals: u64,
    // Accounting.
    report: ServingReport,
    kv_series: Vec<(f64, Vec<DpStepLoad>)>,
    prefill_passes: u64,
    decode_steps: u64,
    decode_busy_s: f64,
    decode_tokens: u64,
    straggler_waste_s: f64,
    completed: usize,
    rejected: u64,
    rejected_by_class: [u64; 3],
    ttft_by_class: [LatencyRecorder; 3],
    deadline_met_by_class: [u64; 3],
    deadline_violated_by_class: [u64; 3],
    /// TTFT stage decomposition over virtual time (stats only, no
    /// Perfetto retention — the DES has nothing to export per-process).
    trace: TraceCollector,
}

impl Simulation {
    /// Run the configured simulation to completion.
    pub fn run(cfg: &SimConfig) -> SimReport {
        let requests = cfg.workload.generate();
        Self::run_trace(cfg, requests)
    }

    /// Run against an explicit request trace (replay path) instead of
    /// generating from `cfg.workload`.
    pub fn run_trace(cfg: &SimConfig, requests: Vec<Request>) -> SimReport {
        let mut sim = Simulation::new(cfg.clone(), requests);
        sim.prime();
        sim.drive();
        sim.finish()
    }

    fn new(cfg: SimConfig, requests: Vec<Request>) -> Self {
        let metrics = requests
            .iter()
            .map(|r| RequestMetrics::arrive(r.arrival, r.input_tokens))
            .collect();
        let effective = requests.iter().map(|r| r.input_tokens).collect();
        let t = &cfg.topology;
        let prefill = (0..t.n_prefill)
            .map(|_| PrefillEngine::new(t.dp_prefill, t.c_chunk, cfg.prefill_cost.clone()))
            .collect();
        let inflight_pass = (0..t.n_prefill).map(|_| None).collect();
        let decode = (0..t.n_decode)
            .map(|_| DecodeEngine::with_caps(t.dp_decode, cfg.decode_cost.clone(), cfg.decode_caps))
            .collect();
        let mut core = DispatchCore::new(&cfg.core_config());
        core.set_rescue(cfg.rescue.clone());
        let decode_emitted = vec![0; requests.len()];
        Simulation {
            q: EventQueue::new(),
            requests,
            metrics,
            effective,
            core,
            prefill,
            inflight_pass,
            decode,
            pending_joins: Vec::new(),
            decode_emitted,
            fault_rng: crate::util::Rng::new(cfg.workload.seed ^ 0xFA17_0000),
            lost_signals: 0,
            report: ServingReport::new(0.0),
            kv_series: Vec::new(),
            prefill_passes: 0,
            decode_steps: 0,
            decode_busy_s: 0.0,
            decode_tokens: 0,
            straggler_waste_s: 0.0,
            completed: 0,
            rejected: 0,
            rejected_by_class: [0; 3],
            ttft_by_class: SloClass::ALL.map(|c| LatencyRecorder::new(c.name())),
            deadline_met_by_class: [0; 3],
            deadline_violated_by_class: [0; 3],
            trace: TraceCollector::new(0),
            cfg,
        }
    }

    /// Whether request `i` participates in the stage decomposition —
    /// mirrors the report's warmup gate so `ttft_stages` and `ttft`
    /// describe the same population.
    fn traced(&self, i: usize) -> bool {
        self.requests[i].arrival >= self.cfg.warmup
    }

    fn prime(&mut self) {
        for i in 0..self.requests.len() {
            self.q.push(self.requests[i].arrival, Ev::Arrival(i));
        }
        if self.cfg.kv_sample_interval > 0.0 {
            self.q.push(self.cfg.kv_sample_interval, Ev::KvSample);
        }
    }

    fn drive(&mut self) {
        let total = self.requests.len();
        while let Some((now, ev)) = self.q.pop() {
            if now > self.cfg.max_time {
                log::warn!("simulation hit max_time={} with {} requests unfinished",
                    self.cfg.max_time, total - self.completed);
                break;
            }
            match ev {
                Ev::Arrival(i) => self.on_arrival(i, now),
                Ev::SchedTimer => {
                    let actions = self.core.on_timer(now);
                    self.apply_actions(actions);
                }
                Ev::Deliver {
                    instance,
                    assignments,
                    dispatched_at,
                } => self.on_deliver(instance, assignments, dispatched_at, now),
                Ev::PassDone { instance } => self.on_pass_done(instance, now),
                Ev::TryStart { instance } => self.try_start_pass(instance, now),
                Ev::KvReady(i) => self.on_kv_ready(i, now),
                Ev::StepDone { instance } => self.on_step_done(instance, now),
                Ev::KvSample => {
                    // Only steady-state samples: past warmup, before the
                    // arrival horizon ends (the drain tail would bias the
                    // dispersion estimate down).
                    if now >= self.cfg.warmup && now <= self.cfg.workload.duration {
                        let mut snapshot = Vec::new();
                        for e in &self.decode {
                            snapshot.extend(e.unit_loads());
                        }
                        self.kv_series.push((now, snapshot));
                    }
                    if self.completed < total && now <= self.cfg.workload.duration {
                        self.q
                            .push(now + self.cfg.kv_sample_interval, Ev::KvSample);
                    }
                }
            }
            if self.completed == total {
                break;
            }
        }
    }

    fn on_arrival(&mut self, i: usize, now: f64) {
        if self.traced(i) {
            self.trace.mark(TRACK_SIM, i as u64, Mark::Arrival, 0, now);
        }
        let req = self.requests[i].clone();
        let actions = self.core.on_arrival(req, now);
        self.apply_actions(actions);
    }

    /// Execute dispatch-core decisions on the simulated transport.
    fn apply_actions(&mut self, actions: Vec<SchedulerAction>) {
        for act in actions {
            match act {
                SchedulerAction::Dispatch(batch) => {
                    for a in &batch.assignments {
                        let i = a.request.id as usize;
                        self.metrics[i].t_dispatch = batch.at;
                        if self.traced(i) {
                            self.trace.mark(
                                TRACK_SIM,
                                i as u64,
                                Mark::Dispatch,
                                batch.instance,
                                batch.at,
                            );
                        }
                    }
                    self.q.push(
                        batch.at + self.cfg.l_net,
                        Ev::Deliver {
                            instance: batch.instance,
                            assignments: batch.assignments,
                            dispatched_at: batch.at,
                        },
                    );
                }
                SchedulerAction::ArmTimer { at } => {
                    self.q.push(at, Ev::SchedTimer);
                }
                SchedulerAction::Reject(r) => {
                    self.rejected += 1;
                    self.rejected_by_class[r.class.rank()] += 1;
                    // Mark as completed-with-rejection so the run drains.
                    self.completed += 1;
                    // No first token will ever come: drop the trace record.
                    self.trace.discard(r.id);
                }
                SchedulerAction::Watchdog(_) => {}
            }
        }
    }

    fn on_deliver(
        &mut self,
        instance: u32,
        assignments: Vec<Assignment>,
        _dispatched_at: f64,
        now: f64,
    ) {
        for a in &assignments {
            let i = a.request.id as usize;
            if self.traced(i) {
                // Tokens landed on the prefill device: in-flight ends.
                self.trace
                    .mark(TRACK_SIM, i as u64, Mark::PrefillRecv, instance, now);
            }
            let eff = a.request.input_tokens - a.cached_tokens;
            self.effective[i] = eff.max(1);
            // Tokens have physically arrived on the device: flight→queued.
            self.core.on_deliver_ack(a.unit, self.effective[i]);
            self.prefill[instance as usize].enqueue(
                a.unit.dp as usize,
                i,
                self.effective[i],
                a.cached_tokens,
            );
        }
        self.try_start_pass(instance, now);
    }

    fn try_start_pass(&mut self, instance: u32, now: f64) {
        let engine = &mut self.prefill[instance as usize];
        if let Some(pass) = engine.start_pass() {
            // Device-side queueing ends now for first-chunk items.
            for item in &pass.items {
                if item.first_chunk {
                    let m = &mut self.metrics[item.req];
                    if m.t_exec_start < 0.0 {
                        m.t_exec_start = now;
                        if self.requests[item.req].arrival >= self.cfg.warmup {
                            self.trace.mark(
                                TRACK_SIM,
                                item.req as u64,
                                Mark::PrefillStart,
                                instance,
                                now,
                            );
                        }
                    }
                }
            }
            let done_at = now + pass.duration;
            self.inflight_pass[instance as usize] = Some((pass, now));
            self.q.push(done_at, Ev::PassDone { instance });
        }
    }

    fn on_pass_done(&mut self, instance: u32, now: f64) {
        let (pass, _started) = self.inflight_pass[instance as usize]
            .take()
            .expect("pass done without inflight pass");
        self.prefill[instance as usize].finish_pass();
        self.prefill_passes += 1;
        let after_warmup = now >= self.cfg.warmup;
        if after_warmup {
            self.report
                .chunk_util
                .record_pass(pass.used_tokens as u64, pass.capacity as u64);
            self.straggler_waste_s += pass.straggler_waste;
            self.report
                .throughput
                .add_tokens(now, pass.used_tokens as u64, 0);
        }
        // Consumption feedback to the control plane's capacity model.
        for item in &pass.items {
            let unit = DpUnitId::new(instance, item.dp as u32);
            self.core.on_prefill_consumed(unit, item.tokens);
        }
        // First tokens + decode handoff.
        for item in &pass.items {
            if item.finishes {
                let i = item.req;
                self.metrics[i].t_first_token = now;
                if self.traced(i) {
                    // The DES emits the first token at prefill completion
                    // (the KV copy overlaps decode admission), so the
                    // commit and first-token boundaries coincide here —
                    // exactly the live relay path's semantics.
                    let id = i as u64;
                    self.trace.mark(TRACK_SIM, id, Mark::PrefillEnd, instance, now);
                    self.trace.mark(TRACK_SIM, id, Mark::KvCommit, instance, now);
                    self.trace.mark(TRACK_SIM, id, Mark::FirstToken, instance, now);
                }
                let out = self.requests[i].output_tokens;
                if out <= 1 {
                    self.complete_request(i, now, 1);
                } else {
                    let transfer =
                        self.cfg.kv_transfer.transfer_time(self.requests[i].input_tokens);
                    self.q.push(now + transfer, Ev::KvReady(i));
                }
            }
        }
        // Feedback to the scheduler — unless fault injection eats the
        // signal (network partition / silent instance fault, §4.1.2; the
        // watchdog must recover liveness).
        let lost = self.cfg.fault_lose_endforward > 0.0
            && self.fault_rng.chance(self.cfg.fault_lose_endforward);
        if lost {
            self.lost_signals += 1;
        } else {
            let backlog = self.prefill[instance as usize].backlog_tokens();
            let actions = self.core.on_end_forward(
                instance,
                pass.duration,
                EndForwardBacklog::Remaining(backlog),
                now,
            );
            self.apply_actions(actions);
        }
        // The gated engine keeps chewing its device queue autonomously,
        // after a short batch-formation window so an EndForward-triggered
        // dispatch can merge with any leftover backlog (avoids degenerate
        // spillover passes).
        self.q
            .push(now + self.cfg.formation_delay, Ev::TryStart { instance });
    }

    fn on_kv_ready(&mut self, i: usize, now: f64) {
        if self.traced(i) {
            // Timeline instant only (post-TTFT in the DES model).
            self.trace
                .mark(TRACK_SIM, i as u64, Mark::DecodeAdmit, 0, now);
        }
        self.pending_joins.push(DecodeJoin {
            request_id: i as u64,
            kv_tokens: self.requests[i].input_tokens,
            remaining_out: self.requests[i].output_tokens - 1,
            class: self.requests[i].class,
            deadline: self.requests[i].deadline,
        });
        self.place_joins(now);
        for inst in 0..self.decode.len() {
            self.try_start_step(inst as u32, now);
        }
    }

    /// Place all pending joins across the pooled decode DP units through
    /// the dispatch core, respecting each unit's hard batch/KV caps.
    /// Joins with no admissible unit stay parked (retried at the next step
    /// boundary) — this is the decode-side admission backpressure a real
    /// engine's KV-block budget enforces.
    fn place_joins(&mut self, now: f64) {
        if self.pending_joins.is_empty() {
            return;
        }
        // Refresh the core's pooled DP ledger from engine ground truth.
        let mut loads = Vec::new();
        for e in &self.decode {
            loads.extend(e.unit_loads());
        }
        self.core.sync_decode_loads(&loads);
        let joins = std::mem::take(&mut self.pending_joins);
        let mut adm = EngineAdmission {
            decode: &mut self.decode,
        };
        let out = self.core.place_decode(joins, now, &mut adm);
        self.pending_joins = out.parked;
    }

    fn try_start_step(&mut self, instance: u32, now: f64) {
        if let Some(duration) = self.decode[instance as usize].start_step() {
            if now >= self.cfg.warmup {
                self.decode_busy_s += duration;
            }
            self.q.push(now + duration, Ev::StepDone { instance });
        }
    }

    fn on_step_done(&mut self, instance: u32, now: f64) {
        let out = self.decode[instance as usize].finish_step();
        self.decode_steps += 1;
        if now >= self.cfg.warmup {
            self.report.throughput.add_tokens(now, 0, out.tokens as u64);
            self.decode_tokens += out.tokens as u64;
        }
        for (req, finished) in out.emissions {
            // Progress feeds the rescue layer's per-token rate model —
            // the cumulative emission index is monotone across
            // migrations, exactly like the live token stream's index.
            self.decode_emitted[req] += 1;
            self.core.on_decode_progress(req as u64, self.decode_emitted[req]);
            if finished {
                // Finish (not leave): scores the deadline outcome before
                // releasing the ledger charge, like the live scheduler's
                // DecodeDone path.
                self.core.on_decode_finish(req as u64, now);
                let total_out = self.requests[req].output_tokens;
                self.complete_request(req, now, total_out);
            }
        }
        self.rescue_sim(now);
        self.place_joins(now);
        // A rescue migration (or a parked join) may have landed on an
        // idle instance other than the one whose step just completed —
        // kick them all (no-op for busy/empty engines).
        for inst in 0..self.decode.len() {
            self.try_start_step(inst as u32, now);
        }
    }

    /// Step-boundary rescue pass — the DES twin of the live scheduler's
    /// post-placement scan ([`super::workers`]). The shared core elects
    /// the extractions; this driver performs them on the engine models
    /// and re-parks each sequence with its progress intact, so the next
    /// `place_joins` re-places it through the ordinary ledger path.
    fn rescue_sim(&mut self, now: f64) {
        if !self.cfg.rescue.enabled {
            return;
        }
        let mut loads = Vec::new();
        for e in &self.decode {
            loads.extend(e.unit_loads());
        }
        self.core.sync_decode_loads(&loads);
        let actions = self.core.rescue_scan(
            now,
            &mut EngineAdmission {
                decode: &mut self.decode,
            },
        );
        for a in actions {
            let (inst, dp) = (a.unit.instance as usize, a.unit.dp as usize);
            // The engine is ground truth: a sequence that finished in
            // the same step the scan flagged it is simply gone.
            let Some(seq) = self.decode[inst].remove(dp, a.id as usize) else {
                continue;
            };
            // Leave (not finish): the sequence is moving, not done.
            self.core.on_decode_leave(a.id, now);
            let i = a.id as usize;
            self.pending_joins.push(DecodeJoin {
                request_id: a.id,
                kv_tokens: seq.kv,
                remaining_out: seq.remaining,
                class: self.requests[i].class,
                deadline: self.requests[i].deadline,
            });
        }
    }

    fn complete_request(&mut self, i: usize, now: f64, tokens_out: u32) {
        if self.traced(i) {
            self.trace.mark(TRACK_SIM, i as u64, Mark::Done, 0, now);
        }
        let m = &mut self.metrics[i];
        m.t_done = now;
        m.output_tokens = tokens_out;
        self.completed += 1;
        if self.requests[i].arrival >= self.cfg.warmup {
            let m = self.metrics[i];
            self.report.absorb(&m);
            if let Some(t) = m.ttft() {
                self.ttft_by_class[self.requests[i].class.rank()].record(t);
            }
            if let Some(d) = self.requests[i].deadline {
                let rank = self.requests[i].class.rank();
                if now <= d {
                    self.deadline_met_by_class[rank] += 1;
                } else {
                    self.deadline_violated_by_class[rank] += 1;
                }
            }
        }
    }

    fn finish(mut self) -> SimReport {
        self.report.rejected = self.rejected;
        SimReport {
            report: self.report,
            kv_series: self.kv_series,
            decode_pool: self.core.decode_stats(self.q.now()),
            prefill_passes: self.prefill_passes,
            decode_steps: self.decode_steps,
            decode_busy_s: self.decode_busy_s,
            decode_tokens: self.decode_tokens,
            straggler_waste_s: self.straggler_waste_s,
            i_opt_final: self.core.i_opt(),
            completed: self.completed,
            offered: self.requests.len(),
            lost_signals: self.lost_signals,
            t_end: self.q.now(),
            ttft_stages: self.trace.to_json(),
            rejected_by_class: self.rejected_by_class,
            ttft_by_class: self.ttft_by_class,
            deadline_met_by_class: self.deadline_met_by_class,
            deadline_violated_by_class: self.deadline_violated_by_class,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(qps: f64, staggered: bool) -> SimConfig {
        let mut cfg = SimConfig::paper_fig6a(1.0);
        cfg.workload = WorkloadSpec::paper_short(qps, 30.0, 7);
        cfg.warmup = 5.0;
        if !staggered {
            cfg = cfg.with_immediate(ImmediatePolicy::RoundRobin);
        }
        cfg
    }

    #[test]
    fn sbs_run_completes_all_requests() {
        let cfg = small_cfg(10.0, true);
        let r = Simulation::run(&cfg);
        assert_eq!(r.completed, r.offered, "all requests finish");
        assert!(r.report.ttft.count() > 0);
        assert!(r.prefill_passes > 0);
        assert!(r.decode_steps > 0);
        assert!(r.i_opt_final > 0.0);
    }

    #[test]
    fn immediate_run_completes_all_requests() {
        let cfg = small_cfg(10.0, false);
        let r = Simulation::run(&cfg);
        assert_eq!(r.completed, r.offered);
        assert!(r.report.ttft.count() > 0);
    }

    #[test]
    fn sbs_beats_immediate_on_device_queue() {
        // The core §3.2 claim: SBS shifts waiting out of the device queue.
        let sbs = Simulation::run(&small_cfg(16.0, true));
        let imm = Simulation::run(&small_cfg(16.0, false));
        assert!(
            sbs.report.device_queue.mean() < imm.report.device_queue.mean(),
            "SBS device queue {:.4}s vs immediate {:.4}s",
            sbs.report.device_queue.mean(),
            imm.report.device_queue.mean()
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Simulation::run(&small_cfg(8.0, true));
        let b = Simulation::run(&small_cfg(8.0, true));
        assert_eq!(a.prefill_passes, b.prefill_passes);
        assert!((a.report.ttft.mean() - b.report.ttft.mean()).abs() < 1e-12);
    }

    #[test]
    fn kv_sampling_produces_series() {
        let mut cfg = small_cfg(10.0, true);
        cfg.kv_sample_interval = 0.5;
        let r = Simulation::run(&cfg);
        assert!(r.kv_series.len() > 10);
        let (mean, std) = r.kv_band();
        assert!(mean >= 0.0 && std >= 0.0);
    }

    #[test]
    fn ttft_stage_decomposition_matches_measured_ttft() {
        let r = Simulation::run(&small_cfg(10.0, true));
        let j = &r.ttft_stages;
        let n = j.f64_at(&["requests"]).unwrap();
        assert!(n > 0.0, "no finalized traces");
        assert_eq!(n as u64, r.report.ttft.count(), "trace/report populations");
        // Virtual time has no clock skew: the stage decomposition must
        // reproduce the measured TTFT to timestamp-quantization precision
        // (marks are stored in integer microseconds).
        let sum_ms = j.f64_at(&["sum_mean_ms"]).unwrap();
        let ttft_ms = r.report.ttft.mean() * 1e3;
        assert!(
            (sum_ms - ttft_ms).abs() < 1e-2,
            "stage sum {sum_ms}ms != measured ttft {ttft_ms}ms"
        );
        assert_eq!(j.f64_at(&["skew_clamped"]), Some(0.0));
        // Dispatch→deliver is modeled by l_net, so the device-receipt
        // stage must be populated (not collapsed away).
        let sd = j.f64_at(&["stages", "sched_dispatch", "mean_ms"]).unwrap();
        assert!(sd > 0.0, "l_net never showed up in sched_dispatch");
    }

    #[test]
    fn deadline_aware_matches_load_aware_without_deadlines() {
        // Class-less traffic must make the urgency term inert: identical
        // placement, identical metrics.
        let mut cfg = small_cfg(10.0, true);
        cfg.decode = DecodePlacement::DeadlineAware(DecodeSchedConfig::default());
        let da = Simulation::run(&cfg);
        let la = Simulation::run(&small_cfg(10.0, true));
        assert_eq!(da.decode_pool.policy, "deadline-aware");
        assert_eq!(da.completed, da.offered);
        assert!((da.report.ttft.mean() - la.report.ttft.mean()).abs() < 1e-12);
    }

    #[test]
    fn overload_sheds_batch_before_interactive() {
        // A single cramped prefill unit, overloaded but sized so
        // interactive + standard traffic alone (70% of 10 QPS) fits the
        // ~7.5 req/s capacity (chunk 1024, mean 1K-token prompts, pass
        // ≈ 0.13 s) while the full offered load does not: class-ordered
        // batch formation serves batch only from the leftover, so batch
        // both completes some work (TTFT comparable) *and* starves into
        // the N_limit overflow, while interactive always wins placement.
        let mut cfg = small_cfg(0.0, true);
        cfg.topology = SimTopology {
            n_prefill: 1,
            dp_prefill: 1,
            c_chunk: 1024,
            n_decode: 1,
            dp_decode: 4,
        };
        if let SchedMode::Staggered(sc) = &mut cfg.mode {
            sc.pbaa.n_limit = 4;
        }
        cfg.warmup = 0.0;
        cfg.max_time = 500.0;
        cfg.workload = WorkloadSpec::paper_short(10.0, 20.0, 21);
        cfg.workload.class_mix = Some([0.2, 0.5, 0.3]);
        let r = Simulation::run_trace(&cfg, cfg.workload.generate());
        let shed = r.rejected_by_class;
        assert!(
            shed[SloClass::Batch.rank()] > 0,
            "overload never shed batch: {shed:?}"
        );
        assert_eq!(
            shed[SloClass::Interactive.rank()],
            0,
            "interactive shed while batch was admitted: {shed:?}"
        );
        // The TTFT ordering the classes exist for.
        let i = &r.ttft_by_class[SloClass::Interactive.rank()];
        let b = &r.ttft_by_class[SloClass::Batch.rank()];
        assert!(i.count() > 0 && b.count() > 0, "both classes must finish some work");
        assert!(
            i.percentile(99.0) < b.percentile(99.0),
            "interactive p99 {:.3}s !< batch p99 {:.3}s",
            i.percentile(99.0),
            b.percentile(99.0)
        );
    }

    #[test]
    fn rescue_migration_saves_interactive_deadline() {
        // Deterministic rescue A/B on a crafted classed trace. Topology:
        // one prefill unit (fast, sequential) feeding two single-DP
        // decode instances under blind round-robin placement. Loaders
        // ids 0..6 alternate units: the even ids (short 30-token
        // outputs) clear unit 0 early, while the odd ids (3000-token
        // long-runners, ~38 s of decode) pin unit 1. Round-robin's 8th
        // placement then lands the deadline-carrying interactive
        // request on the loaded unit 1, where its observed token rate
        // (~13.4 ms/tok at B=4, K≈7K) projects past the deadline; the
        // empty unit 0 (~10.5 ms/tok) meets it with ~10% slack either
        // side. With rescue off the deadline is violated; with rescue
        // on the scan migrates the sequence (its standard-class
        // co-residents are not preemptable) and the deadline is met —
        // the ISSUE's strictly-lower-violations acceptance.
        fn cfg(rescue_on: bool) -> SimConfig {
            let mut cfg = SimConfig::paper_fig6a(1.0);
            cfg.topology = SimTopology {
                n_prefill: 1,
                dp_prefill: 1,
                c_chunk: 4096,
                n_decode: 2,
                dp_decode: 1,
            };
            cfg.decode = DecodePlacement::RoundRobin;
            cfg.warmup = 0.0;
            cfg.max_time = 500.0;
            if rescue_on {
                cfg.rescue = RescueConfig::on();
            }
            cfg
        }
        fn trace() -> Vec<Request> {
            let mut reqs = Vec::new();
            // 0.4 s apart so each prefill pass (~0.26 s) drains before
            // the next arrival — no backlog, no shedding, join order =
            // id order.
            for i in 0..7u64 {
                let out = if i % 2 == 0 { 30 } else { 3000 };
                reqs.push(Request::new(i, 2048, out, 0.4 * i as f64));
            }
            reqs.push(
                Request::new(7, 256, 600, 3.0)
                    .with_class(SloClass::Interactive)
                    .with_deadline(3.0 + 7.2),
            );
            reqs
        }
        let off = Simulation::run_trace(&cfg(false), trace());
        let on = Simulation::run_trace(&cfg(true), trace());
        assert_eq!(off.completed, off.offered);
        assert_eq!(on.completed, on.offered);
        let rank = SloClass::Interactive.rank();
        assert_eq!(
            off.deadline_violated_by_class[rank], 1,
            "without rescue the loaded unit must miss the deadline"
        );
        assert_eq!(off.deadline_met_by_class[rank], 0);
        assert_eq!(
            on.deadline_violated_by_class[rank], 0,
            "rescue must migrate the endangered sequence in time"
        );
        assert_eq!(on.deadline_met_by_class[rank], 1);
        assert!(
            on.deadline_violated_by_class[rank] < off.deadline_violated_by_class[rank],
            "rescue on must strictly lower interactive deadline violations"
        );
        // Gauge plumbing: the move and its outcome are both counted.
        assert!(!off.decode_pool.rescue.enabled);
        assert_eq!(off.decode_pool.rescue.migrated, 0);
        assert!(on.decode_pool.rescue.enabled);
        assert!(on.decode_pool.rescue.migrated >= 1, "no migration counted");
        assert!(
            on.decode_pool.rescue.rescue_deadline_met >= 1,
            "the rescued sequence met its deadline but was not scored"
        );
    }

    #[test]
    fn decode_pool_gauges_populated() {
        let r = Simulation::run(&small_cfg(10.0, true));
        let t = SimConfig::paper_fig6a(1.0).topology;
        assert_eq!(r.decode_pool.units.len(), (t.n_decode * t.dp_decode) as usize);
        assert!(r.decode_pool.total_placed() > 0);
        assert!(r.decode_pool.imbalance() >= 1.0);
        assert_eq!(r.decode_pool.policy, "load-aware");
    }
}
