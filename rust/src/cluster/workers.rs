//! Threaded *real* mini-cluster: the same SBS control plane driving
//! actual engine forward passes (no discrete-event simulation on this
//! path).
//!
//! Topology (P/D-separated): a prefill pool and a decode DP pool, each
//! reached purely through transports. The prefill pool mixes `n_prefill`
//! in-process workers (one gated engine thread each — DP=1 per
//! instance) with the instances of any remote prefill shards in
//! [`RealClusterConfig::remote_prefill`] (`sbs worker --prefill`
//! processes, whose prompt-KV handoff crosses the wire as a chunked
//! `KvSegment` stream and whose `EndForward` carries real engine
//! backlog into the staggered trigger). The decode pool mixes
//! `n_decode` in-process batched engine threads with the units of any
//! remote decode shards in [`RealClusterConfig::remote_decode`]. The
//! scheduler thread runs the shared [`DispatchCore`] — the identical
//! state machine the simulator drives — receiving real `EndForward`
//! signals over channels/sockets and arming real timers via
//! `recv_timeout`. Prefill completions are placed onto a decode DP unit
//! by the core's [`DecodePolicy`] (Algorithm 3 load-aware allocation,
//! or the round-robin / random baselines) regardless of where either
//! phase ran, so the paper's claims are measurable end to end across
//! real process boundaries.
//!
//! ## Completion path (concurrent frontend architecture)
//!
//! Submission and completion routing are split: any number of frontend
//! threads hold a cloned [`ClusterHandle`] and submit concurrently, while
//! a dedicated **router** thread fans worker events out to per-job update
//! channels — per job, regardless of which decode DP unit owns the
//! sequence. Workers publish every generated token as a [`JobUpdate`], so
//! a streaming frontend observes TTFT on the wire the moment prefill
//! completes — not after the full generation. The
//! [`AdmissionController`] (Algorithm 2 phase 3) guards
//! [`ClusterHandle::try_submit`]: overload surfaces as [`Admission::Busy`]
//! instead of unbounded queueing.
//!
//! Engines are built per-thread from an [`EngineSpec`] — either real PJRT
//! (artifacts + `pjrt` feature) or the sleep-based mock, which makes the
//! whole stack runnable on a bare checkout.

use super::dispatch::{
    DecodeAdmission, DecodeJoin, DecodePolicy, DispatchCore, DispatchCoreConfig,
    EndForwardBacklog, RescueConfig,
};
use crate::engine::mock::{MockEngine, MockEngineConfig};
use crate::engine::sampler::Sampling;
use crate::engine::{EngineBackend, MiniEngine, PrefillOutcome};
use crate::json::Json;
use crate::metrics::{DecodePoolStats, KvWireGauge, RequestMetrics, ServingReport};
use crate::trace::{Mark, TraceCollector};
use crate::runtime::Runtime;
use crate::scheduler::decode::DecodeSchedConfig;
use crate::scheduler::flow::{AdmissionController, AdmissionDecision, FlowPolicy};
use crate::scheduler::interval::IntervalConfig;
use crate::scheduler::pbaa::PbaaConfig;
use crate::scheduler::staggered::{SchedulerAction, StaggeredConfig};
use crate::scheduler::state::DpState;
use crate::scheduler::types::{DpUnitId, Request, SloClass};
use crate::transport::proto::{DirectTarget, UnitLoad};
use crate::transport::remote::{connect_prefill_shard, connect_shard, RemoteShardConfig};
use crate::transport::{
    AdmitJob, DecodeTransport, ExtractedSeq, KvCodec, KvWireCounters, LocalPrefill, LocalUnit,
    PrefillMsg, PrefillSinks, PrefillTransport, PrefillWork, ShardSinks, UnitMsg,
};
use crate::util::{Clock, RealClock};
use anyhow::{anyhow, Result};
use std::collections::{HashMap, HashSet, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Control-plane choice for the real cluster — the same [`SchedMode`] the
/// simulator consumes, re-exported under its historical name.
pub use super::dispatch::SchedMode as RealSchedMode;

/// How worker threads execute forward passes.
#[derive(Debug, Clone)]
pub enum EngineSpec {
    /// Real PJRT engines loading AOT artifacts from this directory (each
    /// worker thread loads its own client — PJRT handles are not `Send`,
    /// mirroring the process-per-instance deployment model). Requires the
    /// `pjrt` feature.
    Pjrt {
        /// Artifact directory (`make artifacts`).
        artifacts: PathBuf,
    },
    /// Sleep-based mock engines: no artifacts, no `xla`, but real
    /// wall-clock contention (CI / loadgen / integration tests).
    Mock(MockEngineConfig),
}

#[derive(Clone, Copy)]
enum EngineRole {
    Prefill,
    Decode,
}

impl EngineSpec {
    /// Build one engine for `role` on the calling thread.
    fn build(
        &self,
        role: EngineRole,
        decode_batch: u32,
        sampling: Sampling,
        seed: u64,
    ) -> Result<Box<dyn EngineBackend>> {
        match self {
            EngineSpec::Pjrt { artifacts } => {
                let kinds: &[&str] = match role {
                    EngineRole::Prefill => &["prefill", "decode"],
                    EngineRole::Decode => &["decode"],
                };
                let rt = Runtime::load_filtered(artifacts, Some(kinds)).map(Arc::new)?;
                let batch = match role {
                    // Prefill workers never decode; any compiled batch
                    // variant satisfies the engine's constructor.
                    EngineRole::Prefill => rt
                        .decode_batches()
                        .first()
                        .copied()
                        .ok_or_else(|| anyhow!("no compiled decode variants"))?,
                    EngineRole::Decode => decode_batch,
                };
                Ok(Box::new(MiniEngine::new(rt, batch, sampling, seed)?))
            }
            EngineSpec::Mock(cfg) => {
                let batch = match role {
                    EngineRole::Prefill => 1,
                    EngineRole::Decode => decode_batch,
                };
                Ok(Box::new(MockEngine::new(*cfg, batch, seed)))
            }
        }
    }
}

/// Frontend admission-control knobs (see
/// [`crate::scheduler::flow::AdmissionController`]).
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Maximum jobs in flight (queued + executing) before `BUSY`.
    pub max_inflight: u64,
    /// Reject-only or throttling behaviour after overload.
    pub policy: FlowPolicy,
    /// Fraction of new arrivals shed during a throttle cool-down.
    pub shed_fraction: f64,
    /// Cool-down duration, seconds.
    pub cooldown: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            max_inflight: 256,
            policy: FlowPolicy::Throttle,
            shed_fraction: 0.25,
            cooldown: 1.0,
        }
    }
}

/// Real-cluster configuration.
#[derive(Debug, Clone)]
pub struct RealClusterConfig {
    /// Prefill instances (one engine thread each).
    pub n_prefill: u32,
    /// *Local* decode DP workers (one batched engine thread each). May be
    /// 0 when `remote_decode` supplies the pool.
    pub n_decode: u32,
    /// Decode batch size per local decode worker (must be a compiled
    /// variant in PJRT mode; remote shards advertise their own).
    pub decode_batch: u32,
    /// Scheduler-visible per-instance token budget per dispatch cycle.
    pub c_chunk: u32,
    /// Control plane.
    pub mode: RealSchedMode,
    /// Decode placement policy across the DP pool.
    pub decode_policy: DecodePolicy,
    /// Sampling policy for generation.
    pub sampling: Sampling,
    /// RNG seed.
    pub seed: u64,
    /// Execution backend for the worker threads.
    pub engine: EngineSpec,
    /// Frontend admission control.
    pub admission: AdmissionConfig,
    /// Remote decode shard addresses (`sbs worker --decode --listen`);
    /// each shard's units join the pool behind the same dispatch core.
    pub remote_decode: Vec<String>,
    /// Remote prefill shard addresses (`sbs worker --prefill --listen`);
    /// each shard's instances join the prefill pool behind the same
    /// staggered trigger, with the KV handoff streamed back over the
    /// wire. May fully replace the local workers (`n_prefill = 0`).
    pub remote_prefill: Vec<String>,
    /// Per-DP-unit KV-token budget for decode admissibility (the live
    /// mirror of the DES's `DecodeCaps::kv_max`): a join reserves its
    /// expected resident length (`prompt + max_new`) and parks when no
    /// unit has room. 0 disables the budget (slot-count only).
    pub kv_budget: u64,
    /// KV wire codec this deployment produces (`--kv-wire`): negotiated
    /// with every shard at handshake, used for relayed admits and the
    /// prefill shards' segment streams.
    pub kv_wire: KvCodec,
    /// Whether finished prefills on remote shards may stream their KV
    /// straight to the target decode shard (`HandoffCommit` to the
    /// scheduler) instead of relaying through it. `false` forces the
    /// relay path everywhere (the comparison baseline, and a fallback
    /// switch).
    pub direct_handoff: bool,
    /// Whether draining this cluster also stops its remote shard
    /// processes (the serving default). `false` merely disconnects them,
    /// leaving the shards running for another cluster — e.g. the example
    /// binary, which runs two clusters back to back over one shard set.
    pub stop_shards_on_drain: bool,
    /// Completed per-request TTFT traces retained for Perfetto export
    /// (`sbs serve --trace-out`). 0 keeps the aggregate stage histograms
    /// only — the always-on `ttft_stages` gauge costs one mark batch per
    /// request either way.
    pub trace_retain: usize,
    /// SLO-violation rescue: scan resident decode sequences for
    /// projected deadline misses and preempt a batch victim or
    /// live-migrate the endangered sequence (`--rescue on`). Disabled by
    /// default — rescue moves sequences between engines mid-generation.
    pub rescue: RescueConfig,
}

impl Default for RealClusterConfig {
    fn default() -> Self {
        // Real CPU-PJRT passes take ~0.5–2 s; seed the interval
        // controller accordingly so the watchdog doesn't misfire during
        // the first pass, and scale N_limit to real pass cadence (cycles
        // here are seconds, not the simulator's ~100 ms).
        let sc = StaggeredConfig {
            interval: IntervalConfig {
                t_default: 1.5,
                ..Default::default()
            },
            pbaa: PbaaConfig {
                n_limit: 10_000,
                ..Default::default()
            },
            decode: DecodeSchedConfig::default(),
        };
        RealClusterConfig {
            n_prefill: 2,
            n_decode: 1,
            decode_batch: 4,
            c_chunk: 256,
            mode: RealSchedMode::Staggered(sc),
            decode_policy: DecodePolicy::LoadAware(DecodeSchedConfig::default()),
            sampling: Sampling::Greedy,
            seed: 7,
            engine: EngineSpec::Pjrt {
                artifacts: PathBuf::from("artifacts"),
            },
            admission: AdmissionConfig::default(),
            remote_decode: Vec::new(),
            remote_prefill: Vec::new(),
            kv_budget: crate::config::LIVE_KV_BUDGET_TOKENS,
            kv_wire: KvCodec::Raw,
            direct_handoff: true,
            stop_shards_on_drain: true,
            trace_retain: 0,
            rescue: RescueConfig::default(),
        }
    }
}

/// One submitted generation job — the first-class request descriptor
/// ([`JobSpec`](crate::scheduler::types::JobSpec)) under its historical
/// name: id, prompt, generation cap, SLO class and optional deadline
/// travel together from the frontend down to Algorithm 3 placement.
/// Use [`ClusterHandle::next_id`] for the id unless the caller manages
/// its own id space end to end.
pub use crate::scheduler::types::JobSpec as Job;

/// Completed generation.
#[derive(Debug, Clone)]
pub struct Completion {
    /// Job id.
    pub id: u64,
    /// Generated token ids (first token included).
    pub tokens: Vec<i32>,
    /// Lifecycle metrics (timestamps on the real clock).
    pub metrics: RequestMetrics,
}

/// Streaming per-job event delivered on the channel returned by
/// [`ClusterHandle::try_submit`].
#[derive(Debug, Clone)]
pub enum JobUpdate {
    /// One generated token. `index == 0` is the first token — receiving it
    /// is the wire-observable TTFT moment.
    Token {
        /// Token id.
        token: i32,
        /// 0-based position in the generation.
        index: u32,
        /// Cluster-clock timestamp, seconds.
        t: f64,
    },
    /// Terminal: generation finished.
    Done(Completion),
    /// Terminal: dropped by scheduler-side flow control or an engine
    /// failure; no further updates will arrive.
    Rejected {
        /// Job id.
        id: u64,
    },
}

/// Why [`ClusterHandle::try_submit`] refused a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BusyReason {
    /// In-flight window is full (hard overload).
    QueueFull,
    /// Shed during a post-overload throttle cool-down.
    Throttled,
}

/// Result of a flow-controlled submission.
pub enum Admission {
    /// Admitted: stream updates from `updates`.
    Accepted {
        /// Assigned job id.
        id: u64,
        /// Per-job update stream (tokens, then one terminal event).
        updates: Receiver<JobUpdate>,
    },
    /// Refused by admission control — reply `BUSY` upstream.
    Busy(BusyReason),
}

enum SchedMsg {
    Submit(Job, f64),
    EndForward {
        instance: u32,
        t_measured: f64,
        /// Engine-reported backlog still queued behind the pass. `None`
        /// for in-process workers (they consume each dispatch wholesale
        /// before signalling); `Some` when the report crossed the wire
        /// from a prefill shard — real engine truth for `C_avail`.
        remaining: Option<u32>,
    },
    /// A prefill worker finished a job that still needs decode: hand it
    /// to the scheduler thread for placement onto a decode DP unit.
    PrefillDone {
        id: u64,
        outcome: Box<PrefillOutcome>,
        max_new: u32,
        class: SloClass,
        metrics: RequestMetrics,
    },
    /// A decode unit released a sequence (finished or rejected): free
    /// its slot and ledger charge.
    DecodeDone {
        id: u64,
    },
    /// A decode unit emitted one token for a resident sequence: feed the
    /// rescue layer's per-token progress model (`index` is the
    /// cumulative emission index of the stream).
    Progress {
        id: u64,
        index: u32,
    },
    /// A rescue extraction completed: `Some` carries the live state to
    /// re-park for placement (progress intact), `None` means the
    /// sequence already terminalized (or the extraction failed) and the
    /// rescue is a no-op.
    Migrated {
        id: u64,
        seq: Option<ExtractedSeq>,
    },
    /// A remote decode shard died with these sequences resident: release
    /// their ledger charges and reject them upstream so nothing leaks.
    Evict {
        ids: Vec<u64>,
    },
    /// A remote prefill shard died with these jobs queued or
    /// mid-handoff: reject them upstream (they hold no decode ledger
    /// charge — unless pre-placed for direct transfer, which the
    /// handler unwinds).
    PrefillEvict {
        ids: Vec<u64>,
    },
    /// A remote prefill shard reported one job's prefill failed
    /// terminally: reject upstream, unwinding any direct pre-placement.
    PrefillFailed {
        id: u64,
    },
    /// A decode shard's engine-truth gauges arrived (`StatsReply`):
    /// cross-check them against the scheduler's own ledger. `base` is
    /// the flat pool index of the shard's first unit.
    ShardStats {
        base: usize,
        loads: Vec<UnitLoad>,
        /// The shard's inbound-KV wire accounting (see `KvWireGauge`).
        kv_wire_bytes: u64,
        kv_raw_bytes: u64,
    },
    /// A direct prefill→decode handoff committed (`HandoffCommit` from
    /// the prefill shard, decode-acked): the KV skipped the scheduler;
    /// stamp first-token metrics onto the decode-side registration.
    DirectCommit {
        id: u64,
        exec_time: f64,
    },
    Drain,
}

enum RouterMsg {
    Register { id: u64, tx: Sender<JobUpdate> },
    Update { id: u64, update: JobUpdate },
    Shutdown,
}

#[derive(Default)]
struct Ledger {
    /// Jobs submitted but not yet terminal.
    inflight: u64,
    /// Finished generations awaiting collection.
    completions: Vec<Completion>,
    /// Scheduler-side flow-control rejections observed by the router.
    rejected: u64,
    /// Ids of rejected jobs, so `wait_for` can fail fast instead of
    /// blocking out its timeout.
    rejected_ids: Vec<u64>,
}

/// Trace track (≈ Perfetto process) for marks stamped by the scheduler
/// process itself; shard-emitted marks are tracked under their address.
const TRACK_SCHED: &str = "sched";
/// Track for in-process decode DP units.
const TRACK_LOCAL_DECODE: &str = "local-decode";
/// Track for in-process prefill instances.
const TRACK_LOCAL_PREFILL: &str = "local-prefill";

struct ClusterShared {
    clock: RealClock,
    ledger: Mutex<Ledger>,
    done_cv: Condvar,
    admission: Mutex<AdmissionController>,
    /// Latest decode-pool occupancy snapshot, published by the scheduler
    /// thread after every placement/release (read by `STATS`).
    decode_stats: Mutex<DecodePoolStats>,
    /// Per-request TTFT stage decomposition (marks from every process;
    /// see [`crate::trace`]).
    trace: TraceCollector,
    /// Ledger/engine-truth divergences that persisted across 3
    /// consecutive shard stat polls (the cross-check in the `ShardStats`
    /// handler) — promoted from a log line to a counted gauge so drift
    /// is visible in `STATS` and the loadgen report.
    ledger_divergence: AtomicU64,
    next_id: AtomicU64,
}

/// Cloneable, thread-safe submission handle: the concurrent frontend's
/// view of the cluster. All clones share one ledger, admission controller
/// and id space.
#[derive(Clone)]
pub struct ClusterHandle {
    to_sched: Sender<SchedMsg>,
    router: Sender<RouterMsg>,
    shared: Arc<ClusterShared>,
}

// mpsc senders are Send but not Sync; each frontend thread owns a clone.
impl ClusterHandle {
    /// Seconds since the cluster clock's epoch.
    pub fn now_s(&self) -> f64 {
        self.shared.clock.now_s()
    }

    /// Allocate a fresh job id (shared atomic counter).
    pub fn next_id(&self) -> u64 {
        self.shared.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Jobs submitted but not yet completed or rejected.
    pub fn inflight(&self) -> u64 {
        self.shared.ledger.lock().unwrap().inflight
    }

    /// Requests refused by frontend admission control so far.
    pub fn admission_rejected(&self) -> u64 {
        self.shared.admission.lock().unwrap().rejected()
    }

    /// Latest per-DP decode occupancy + imbalance gauges.
    pub fn decode_stats(&self) -> DecodePoolStats {
        self.shared.decode_stats.lock().unwrap().clone()
    }

    /// The full `STATS` payload: the decode-pool snapshot plus the TTFT
    /// stage decomposition (`ttft_stages`) and the persistent
    /// ledger/engine-truth divergence counter (`ledger_divergence`).
    pub fn stats_json(&self) -> Json {
        let mut j = self.decode_stats().to_json();
        if let Json::Obj(map) = &mut j {
            map.insert("ttft_stages".to_string(), self.shared.trace.to_json());
            map.insert(
                "ledger_divergence".to_string(),
                Json::from(self.shared.ledger_divergence.load(Ordering::Relaxed)),
            );
            let (overload, shed) = {
                let adm = self.shared.admission.lock().unwrap();
                (adm.rejected_overload(), adm.rejected_shed())
            };
            map.insert("rejected_overload".to_string(), per_class_json(overload));
            map.insert("rejected_shed".to_string(), per_class_json(shed));
        }
        j
    }

    /// TTFT stage-decomposition snapshot (see [`crate::trace`]).
    pub fn ttft_stages(&self) -> Json {
        self.shared.trace.to_json()
    }

    /// Requests with a complete TTFT stage decomposition so far.
    pub fn trace_finalized(&self) -> u64 {
        self.shared.trace.finalized()
    }

    /// Write the retained per-request traces as Chrome/Perfetto
    /// `trace_event` JSON (`sbs serve --trace-out`); returns the event
    /// count. Retention is bounded by
    /// [`RealClusterConfig::trace_retain`].
    pub fn write_trace(&self, path: &std::path::Path) -> std::io::Result<usize> {
        self.shared.trace.write_perfetto(path)
    }

    /// Flow-controlled streaming submission — the serving-frontend path.
    /// Consults the [`AdmissionController`] first: at capacity (or while
    /// shedding during a cool-down) the request never reaches the
    /// scheduler and the caller must reply `BUSY`. Shedding is
    /// class-ordered: `Batch` sheds first, and `Interactive` is never
    /// refused while a lower class is still admitted.
    pub fn try_submit_spec(
        &self,
        prompt: Vec<i32>,
        max_new: u32,
        class: SloClass,
        deadline_ms: Option<f64>,
    ) -> Admission {
        let now = self.now_s();
        {
            // Decide and reserve the in-flight slot under the ledger lock
            // so a concurrent burst cannot over-admit past the window
            // (lock order ledger → admission, as in `finish`).
            let mut led = self.shared.ledger.lock().unwrap();
            let mut adm = self.shared.admission.lock().unwrap();
            let probe =
                Request::new(u64::MAX, prompt.len() as u32, max_new, now).with_class(class);
            match adm.try_admit(now, led.inflight, probe) {
                AdmissionDecision::Admit => led.inflight += 1,
                AdmissionDecision::RejectQueueFull => {
                    return Admission::Busy(BusyReason::QueueFull)
                }
                AdmissionDecision::Shed => return Admission::Busy(BusyReason::Throttled),
            }
        }
        let id = self.next_id();
        // Registration is sent before the scheduler submission, so the
        // router is guaranteed to see `Register` before any worker update
        // for this id (the update is causally after the submit).
        let (tx, rx) = channel();
        let _ = self.router.send(RouterMsg::Register { id, tx });
        let mut job = Job::new(id, prompt, max_new).with_class(class);
        job.deadline_ms = deadline_ms;
        self.send_job(job);
        Admission::Accepted { id, updates: rx }
    }

    /// Legacy `(prompt, max_new)` submission: a standard-class spec with
    /// no deadline — byte-identical behaviour for unannotated clients.
    pub fn try_submit(&self, prompt: Vec<i32>, max_new: u32) -> Admission {
        self.try_submit_spec(prompt, max_new, SloClass::default(), None)
    }

    /// Fire-and-forget submission; the result lands in the cluster ledger
    /// (collected by [`RealCluster::finish`] / [`RealCluster::wait_for`]).
    pub fn submit(&self, job: Job) {
        self.shared.ledger.lock().unwrap().inflight += 1;
        self.send_job(job);
    }

    fn send_job(&self, job: Job) {
        let _ = self.to_sched.send(SchedMsg::Submit(job, self.now_s()));
    }
}

/// A per-class counter array ([`SloClass::rank`]-indexed) as a
/// `{class name: count}` JSON object.
fn per_class_json(counts: [u64; 3]) -> Json {
    let mut m = std::collections::BTreeMap::new();
    for c in SloClass::ALL {
        m.insert(c.name().to_string(), Json::from(counts[c.rank()]));
    }
    Json::Obj(m)
}

/// The running cluster: hand out [`ClusterHandle`]s to frontend threads,
/// then [`RealCluster::finish`] to drain and collect the report.
pub struct RealCluster {
    handle: ClusterHandle,
    threads: Vec<JoinHandle<()>>,
    router_thread: Option<JoinHandle<()>>,
}

impl RealCluster {
    /// Start router + scheduler + worker threads; each engine thread
    /// builds its own backend from `cfg.engine`. Remote shards
    /// (`cfg.remote_decode` / `cfg.remote_prefill`) are connected
    /// synchronously, so a wrong address fails startup fast; drops
    /// *after* startup are handled by the transport's
    /// evict-and-reconnect path instead.
    pub fn start(cfg: RealClusterConfig) -> Result<RealCluster> {
        let mut admission =
            AdmissionController::new(cfg.admission.policy, cfg.admission.max_inflight);
        admission.flow_mut().shed_fraction = cfg.admission.shed_fraction;
        admission.flow_mut().cooldown = cfg.admission.cooldown;
        // With remote shards configured, zero local decode workers is a
        // valid topology; otherwise keep at least one.
        let n_local = if cfg.remote_decode.is_empty() {
            cfg.n_decode.max(1)
        } else {
            cfg.n_decode
        };
        let shared = Arc::new(ClusterShared {
            clock: RealClock::new(),
            ledger: Mutex::new(Ledger::default()),
            done_cv: Condvar::new(),
            admission: Mutex::new(admission),
            // Placeholder until the pool shape (local + remote units) is
            // known below; replaced by a shaped zero snapshot.
            decode_stats: Mutex::new(DecodePoolStats::empty(cfg.decode_policy.name())),
            trace: TraceCollector::new(cfg.trace_retain),
            ledger_divergence: AtomicU64::new(0),
            next_id: AtomicU64::new(0),
        });
        let (to_sched, sched_rx) = channel::<SchedMsg>();
        let (router_tx, router_rx) = channel::<RouterMsg>();
        let (ready_tx, ready_rx) = channel::<bool>();
        let mut threads = Vec::new();
        let mut transports: Vec<Box<dyn DecodeTransport>> = Vec::new();
        for i in 0..n_local {
            let (tx, rx) = channel::<UnitMsg>();
            transports.push(Box::new(LocalUnit::new(i, tx, cfg.decode_batch)));
            let spec = cfg.engine.clone();
            let sink = LocalSink {
                to_sched: to_sched.clone(),
                router: router_tx.clone(),
                shared: shared.clone(),
                unit: i,
            };
            let shared = shared.clone();
            let (sampling, batch) = (cfg.sampling, cfg.decode_batch);
            let seed = cfg.seed.wrapping_add(1000 + i as u64);
            let ready = ready_tx.clone();
            threads.push(std::thread::spawn(move || {
                run_decode_unit(
                    &format!("local:{i}"),
                    &spec,
                    batch,
                    sampling,
                    seed,
                    rx,
                    sink,
                    move || shared.clock.now_s(),
                    None,
                    ready,
                );
            }));
        }

        // With remote prefill shards configured, zero local prefill
        // workers is a valid topology; otherwise keep at least one.
        let n_local_prefill = if cfg.remote_prefill.is_empty() {
            cfg.n_prefill.max(1)
        } else {
            cfg.n_prefill
        };
        let mut prefills: Vec<Box<dyn PrefillTransport>> = Vec::new();
        for i in 0..n_local_prefill {
            let (tx, rx) = channel::<PrefillMsg>();
            prefills.push(Box::new(LocalPrefill::new(i, tx)));
            let spec = cfg.engine.clone();
            let sink = LocalPrefillSink {
                to_sched: to_sched.clone(),
                router: router_tx.clone(),
                shared: shared.clone(),
                unit: i,
            };
            let seed = cfg.seed.wrapping_add(1 + i as u64);
            let ready = ready_tx.clone();
            threads.push(std::thread::spawn(move || {
                run_prefill_unit(&format!("prefill:{i}"), i, &spec, seed, rx, sink, None, ready);
            }));
        }

        // Block until every engine thread has built its backend: jobs
        // submitted before readiness would charge engine construction
        // (e.g. PJRT artifact compilation) to TTFT. Workers report build
        // failures explicitly so a misconfigured cluster fails fast
        // instead of sitting out the timeout.
        drop(ready_tx);
        for _ in 0..(n_local_prefill + n_local) {
            match ready_rx.recv_timeout(Duration::from_secs(600)) {
                Ok(true) => {}
                Ok(false) => {
                    return Err(anyhow!(
                        "a worker failed to build its engine (see log; artifacts \
                         built? `pjrt` feature enabled? or use the mock engine)"
                    ))
                }
                Err(_) => return Err(anyhow!("worker failed to become ready (artifacts built?)")),
            }
        }

        // Join the remote shards to their pools. Duplicate addresses —
        // within a list or *across* the two lists (one shard serves one
        // role) — are a config error worth naming: the second connect
        // would otherwise sit in the shard's single-scheduler backlog
        // and fail as a misleading handshake timeout. Compare *resolved*
        // addresses so aliases (localhost vs 127.0.0.1) are caught too.
        let mut seen = std::collections::HashSet::new();
        let release_all =
            |transports: &mut Vec<Box<dyn DecodeTransport>>,
             prefills: &mut Vec<Box<dyn PrefillTransport>>| {
                // Release everything already connected: the net driver
                // closes the connections and the shards go back to
                // accepting, so a retried start() in this process can
                // succeed.
                for t in transports.iter_mut() {
                    t.detach();
                }
                for p in prefills.iter_mut() {
                    p.detach();
                }
            };
        for (addr, flag) in cfg
            .remote_decode
            .iter()
            .map(|a| (a, "--remote-decode"))
            .chain(cfg.remote_prefill.iter().map(|a| (a, "--remote-prefill")))
        {
            use std::net::ToSocketAddrs;
            let key = addr
                .to_socket_addrs()
                .ok()
                .and_then(|mut it| it.next())
                .map(|sa| sa.to_string())
                .unwrap_or_else(|| addr.clone());
            if !seen.insert(key) {
                release_all(&mut transports, &mut prefills);
                return Err(anyhow!("duplicate shard address {addr} in {flag}"));
            }
        }
        // Relay-path KV accounting, shared by every shard connection and
        // published in the `kv_wire` gauge.
        let relay_kv: Arc<KvWireCounters> = Arc::default();
        let shard_cfg = |addr: &str| {
            let mut rc = RemoteShardConfig::new(addr);
            rc.kv_wire = cfg.kv_wire;
            // The heartbeat pinger shares the cluster clock's epoch, so
            // its `Ping { t_us }` carries scheduler-clock time — what the
            // shard's trace alignment anchors to.
            rc.epoch = shared.clock.epoch();
            rc
        };
        for addr in &cfg.remote_decode {
            // The shard's units join the flat pool after everything
            // connected so far; the stats sink needs that base index to
            // map its shard-local `StatsReply` onto pool units.
            let base = transports.len();
            let sinks =
                shard_sinks(to_sched.clone(), router_tx.clone(), shared.clone(), base, addr);
            let units = match connect_shard(shard_cfg(addr), sinks, relay_kv.clone()) {
                Ok(units) => units,
                Err(e) => {
                    release_all(&mut transports, &mut prefills);
                    return Err(e);
                }
            };
            log::info!("shard {addr}: {} decode DP units joined the pool", units.len());
            for u in units {
                transports.push(Box::new(u));
            }
        }
        for addr in &cfg.remote_prefill {
            let base = prefills.len() as u32;
            let sinks = prefill_shard_sinks(
                to_sched.clone(),
                router_tx.clone(),
                shared.clone(),
                base,
                addr,
            );
            let units = match connect_prefill_shard(shard_cfg(addr), sinks, relay_kv.clone()) {
                Ok(units) => units,
                Err(e) => {
                    release_all(&mut transports, &mut prefills);
                    return Err(e);
                }
            };
            log::info!(
                "prefill shard {addr}: {} instances joined the pool",
                units.len()
            );
            for u in units {
                prefills.push(Box::new(u));
            }
        }
        if transports.is_empty() {
            release_all(&mut transports, &mut prefills);
            return Err(anyhow!("decode pool is empty (no local workers, no shards)"));
        }
        if prefills.is_empty() {
            release_all(&mut transports, &mut prefills);
            return Err(anyhow!("prefill pool is empty (no local workers, no shards)"));
        }
        log::info!(
            "all workers ready ({} prefill instances, {} decode DP units)",
            prefills.len(),
            transports.len()
        );

        // Shaped all-zero snapshot: STATS reports both pool shapes (and
        // per-shard transports) even before the first placement.
        {
            let mut stats = DecodePoolStats::zeroed(
                cfg.decode_policy.name(),
                (0..transports.len() as u32)
                    .map(|i| DpUnitId::new(i, 0).to_string())
                    .collect(),
            );
            decorate_stats(&mut stats, &transports, &HashMap::new());
            decorate_prefill_stats(&mut stats, &prefills, &[]);
            stats.kv_wire.codec = cfg.kv_wire.name().to_string();
            *shared.decode_stats.lock().unwrap() = stats;
        }

        {
            let cfg2 = cfg.clone();
            let router = router_tx.clone();
            let shared = shared.clone();
            let relay_kv = relay_kv.clone();
            threads.push(std::thread::spawn(move || {
                scheduler_loop(cfg2, sched_rx, prefills, transports, router, shared, relay_kv);
            }));
        }

        let router_thread = {
            let shared = shared.clone();
            std::thread::spawn(move || router_loop(router_rx, shared))
        };

        Ok(RealCluster {
            handle: ClusterHandle {
                to_sched,
                router: router_tx,
                shared,
            },
            threads,
            router_thread: Some(router_thread),
        })
    }

    /// A cloneable submission handle for frontend threads.
    pub fn handle(&self) -> ClusterHandle {
        self.handle.clone()
    }

    /// Submit one generation job (arrival timestamped now).
    pub fn submit(&self, job: Job) {
        self.handle.submit(job);
    }

    /// Block until the completion for `id` arrives in the ledger (other
    /// completions stay there for [`RealCluster::finish`]).
    pub fn wait_for(&self, id: u64, timeout: Duration) -> Result<Completion> {
        let deadline = Instant::now() + timeout;
        let mut led = self.handle.shared.ledger.lock().unwrap();
        loop {
            if let Some(i) = led.completions.iter().position(|c| c.id == id) {
                return Ok(led.completions.swap_remove(i));
            }
            if led.rejected_ids.contains(&id) {
                return Err(anyhow!("job {id} was rejected by flow control"));
            }
            let left = deadline
                .checked_duration_since(Instant::now())
                .ok_or_else(|| anyhow!("timed out waiting for job {id}"))?;
            let (l, _) = self.handle.shared.done_cv.wait_timeout(led, left).unwrap();
            led = l;
        }
    }

    /// Wait for every in-flight job to reach a terminal state, stop the
    /// cluster, and return the remaining collected completions plus an
    /// aggregate report (admission + flow-control rejections included).
    pub fn finish(mut self) -> Result<(Vec<Completion>, ServingReport)> {
        let shared = self.handle.shared.clone();
        {
            let mut led = shared.ledger.lock().unwrap();
            while led.inflight > 0 {
                let (l, timed_out) = shared
                    .done_cv
                    .wait_timeout(led, Duration::from_secs(600))
                    .unwrap();
                led = l;
                if timed_out.timed_out() && led.inflight > 0 {
                    return Err(anyhow!("timed out draining {} in-flight jobs", led.inflight));
                }
            }
        }
        let _ = self.handle.to_sched.send(SchedMsg::Drain);
        for t in std::mem::take(&mut self.threads) {
            let _ = t.join();
        }
        // Workers are gone; stop the router explicitly (frontend handle
        // clones may still be alive elsewhere, so channel-closure alone
        // is not a reliable shutdown signal).
        let _ = self.handle.router.send(RouterMsg::Shutdown);
        if let Some(r) = self.router_thread.take() {
            let _ = r.join();
        }
        let mut led = shared.ledger.lock().unwrap();
        let out = std::mem::take(&mut led.completions);
        let mut report = ServingReport::new(0.0);
        for c in &out {
            report.absorb(&c.metrics);
        }
        report.rejected = led.rejected + shared.admission.lock().unwrap().rejected();
        Ok((out, report))
    }
}

/// Router thread: fans worker events out to per-job subscribers and keeps
/// the shared ledger (in-flight count, completions, rejections) — the
/// completion half of the submit/complete split.
fn router_loop(rx: Receiver<RouterMsg>, shared: Arc<ClusterShared>) {
    let mut subs: HashMap<u64, Sender<JobUpdate>> = HashMap::new();
    while let Ok(msg) = rx.recv() {
        match msg {
            RouterMsg::Register { id, tx } => {
                subs.insert(id, tx);
            }
            RouterMsg::Update { id, update } => {
                let terminal = matches!(update, JobUpdate::Done(_) | JobUpdate::Rejected { .. });
                if terminal {
                    // Close the request's trace here — every terminal,
                    // local or remote, routes through this thread, so one
                    // site covers them all. A rejection will never grow a
                    // first token: discard instead of leaking a pending
                    // record.
                    match &update {
                        JobUpdate::Done(c) => {
                            shared
                                .trace
                                .mark(TRACK_SCHED, id, Mark::Done, 0, c.metrics.t_done)
                        }
                        JobUpdate::Rejected { .. } => shared.trace.discard(id),
                        JobUpdate::Token { .. } => {}
                    }
                    let mut led = shared.ledger.lock().unwrap();
                    match &update {
                        JobUpdate::Done(c) => led.completions.push(c.clone()),
                        JobUpdate::Rejected { .. } => {
                            led.rejected += 1;
                            led.rejected_ids.push(id);
                        }
                        JobUpdate::Token { .. } => {}
                    }
                    led.inflight = led.inflight.saturating_sub(1);
                    shared.done_cv.notify_all();
                }
                if let Some(tx) = subs.get(&id) {
                    // Subscriber may have hung up (client disconnect) —
                    // terminal accounting above already happened.
                    let _ = tx.send(update);
                }
                if terminal {
                    subs.remove(&id);
                }
            }
            RouterMsg::Shutdown => break,
        }
    }
}

/// A prefilled job waiting for decode placement (the scheduler thread's
/// payload store behind the core's parked [`DecodeJoin`]s).
struct JoinPayload {
    outcome: Box<PrefillOutcome>,
    max_new: u32,
    class: SloClass,
    /// Token history for a sequence re-parked by a rescue extraction
    /// (empty for fresh joins): the destination unit seeds its emission
    /// index past it, keeping the client-visible stream contiguous.
    resume: Vec<i32>,
    metrics: RequestMetrics,
}

/// Live-pool decode admission over the core's *own* per-unit ledger
/// (`state` carries the unit's charged `⟨B, K⟩`, updated by the core as
/// each join in the cycle is placed — no second ledger to keep in
/// sync). A unit is admissible when all three hold:
///
/// * its transport is alive (a dead shard is never placed onto),
/// * it has a free engine slot (`state.batch < slots`),
/// * the join's expected resident length fits the per-unit KV-token
///   budget — the live mirror of the DES's `DecodeCaps::kv_max` check,
///   so parked-join backpressure is byte-accurate, not slot-count-only.
struct PoolAdmission<'a> {
    /// Engine slots per unit (local batch size / shard-advertised).
    slots: &'a [u32],
    /// Per-unit KV-token budget; 0 disables the check.
    kv_budget: u64,
    /// Transport liveness snapshot, taken at cycle start.
    alive: &'a [bool],
    /// When set, additionally require the unit to be a direct-transfer
    /// peer (the dispatch-time pre-placement for direct handoffs; a
    /// unit without a peer listener simply isn't a candidate — the job
    /// falls back to relay placement at prefill completion).
    peer_only: Option<&'a [bool]>,
}

impl DecodeAdmission for PoolAdmission<'_> {
    fn admissible(&mut self, state: &DpState, join: &DecodeJoin) -> bool {
        let u = state.id.instance as usize;
        self.alive[u]
            && match self.peer_only {
                Some(peers) => peers[u],
                None => true,
            }
            && state.batch < self.slots[u]
            && (self.kv_budget == 0
                || state.kv_tokens + join.total_len() as u64 <= self.kv_budget)
    }

    fn commit(&mut self, _unit: DpUnitId, _join: &DecodeJoin) {}
}

/// Park one prefilled job for decode placement (join + engine payload).
#[allow(clippy::too_many_arguments)]
fn park_join(
    parked: &mut Vec<DecodeJoin>,
    payloads: &mut HashMap<u64, JoinPayload>,
    id: u64,
    outcome: Box<PrefillOutcome>,
    max_new: u32,
    class: SloClass,
    deadline: Option<f64>,
    metrics: RequestMetrics,
) {
    parked.push(DecodeJoin {
        request_id: id,
        kv_tokens: outcome.len as u32,
        remaining_out: max_new,
        class,
        deadline,
    });
    payloads.insert(
        id,
        JoinPayload {
            outcome,
            max_new,
            class,
            resume: Vec::new(),
            metrics,
        },
    );
}

/// Terminally reject a join that was never placed (no ledger charge to
/// release): drop its engine payload and route the rejection upstream.
fn reject_unplaced(
    payloads: &mut HashMap<u64, JoinPayload>,
    router: &Sender<RouterMsg>,
    id: u64,
) {
    payloads.remove(&id);
    let _ = router.send(RouterMsg::Update {
        id,
        update: JobUpdate::Rejected { id },
    });
}

/// Undo a placement that could not be shipped and terminalize the job so
/// it cannot hang the ledger.
fn unwind_placement(core: &mut DispatchCore, router: &Sender<RouterMsg>, id: u64, now: f64) {
    core.on_decode_leave(id, now);
    let _ = router.send(RouterMsg::Update {
        id,
        update: JobUpdate::Rejected { id },
    });
}

/// How long an all-transports-dead pool keeps parked joins alive before
/// terminally rejecting them: long enough for the 500 ms-backoff
/// reconnect loop to revive a blipped shard, short enough that a truly
/// dead pool fails requests promptly instead of timing out drains.
const ALL_DEAD_GRACE: Duration = Duration::from_secs(10);

/// Place parked joins through the dispatch core and commit the placed
/// ones to their transports (local channel or remote shard). Returns
/// whether any ledger state changed (so the caller can skip republishing
/// the gauges).
#[allow(clippy::too_many_arguments)]
fn place_parked(
    core: &mut DispatchCore,
    parked: &mut Vec<DecodeJoin>,
    payloads: &mut HashMap<u64, JoinPayload>,
    slots: &[u32],
    kv_budget: u64,
    transports: &mut [Box<dyn DecodeTransport>],
    router: &Sender<RouterMsg>,
    all_dead_since: &mut Option<Instant>,
    now: f64,
) -> bool {
    // Track the pool's all-dead episode continuously (this runs every
    // scheduler tick), so the grace window below always measures the
    // *current* outage — a timestamp left over from a past outage must
    // never zero out a fresh one's grace.
    let alive: Vec<bool> = transports.iter().map(|t| t.alive()).collect();
    if alive.iter().any(|&a| a) {
        *all_dead_since = None;
    } else if all_dead_since.is_none() {
        *all_dead_since = Some(Instant::now());
    }
    if parked.is_empty() {
        return false;
    }
    let mut joins = std::mem::take(parked);
    let mut changed = false;
    // A join whose full resident length exceeds the per-unit budget can
    // never fit on *any* unit: reject it now instead of parking it
    // forever (which would hang the request and the drain).
    if kv_budget > 0 {
        joins.retain(|j| {
            if j.total_len() as u64 <= kv_budget {
                return true;
            }
            log::warn!(
                "join {} needs {} KV tokens, over the {kv_budget}-token unit budget; rejecting",
                j.request_id,
                j.total_len(),
            );
            reject_unplaced(payloads, router, j.request_id);
            false
        });
        if joins.is_empty() {
            return false;
        }
    }
    // With every transport dead there is nowhere for a join to go *right
    // now* — but a blipped shard may be mid-reconnect, so park through a
    // grace window first; only a pool that stays dead past it has its
    // parked work terminally rejected (instead of holding the drain
    // hostage until its timeout).
    if alive.iter().all(|a| !a) {
        let since = all_dead_since.unwrap_or_else(Instant::now);
        if since.elapsed() < ALL_DEAD_GRACE {
            *parked = joins;
            return false;
        }
        log::error!(
            "every decode transport dead for {ALL_DEAD_GRACE:?}; rejecting {} joins",
            joins.len()
        );
        for j in joins {
            reject_unplaced(payloads, router, j.request_id);
        }
        return false;
    }
    let mut adm = PoolAdmission {
        slots,
        kv_budget,
        alive: &alive,
        peer_only: None,
    };
    let out = core.place_decode(joins, now, &mut adm);
    changed |= !out.placed.is_empty();
    for (j, unit) in out.placed {
        let inst = unit.instance as usize;
        let Some(p) = payloads.remove(&j.request_id) else {
            // No engine payload (duplicate id): undo and terminalize.
            unwind_placement(core, router, j.request_id, now);
            continue;
        };
        let job = AdmitJob {
            id: j.request_id,
            outcome: p.outcome,
            max_new: p.max_new,
            class: p.class,
            resume: p.resume,
            metrics: p.metrics,
        };
        if transports[inst].admit(job).is_err() {
            // Transport is gone: terminalize instead of hanging the job.
            unwind_placement(core, router, j.request_id, now);
        }
    }
    *parked = out.parked;
    changed
}

/// Overlay per-unit transport identity, liveness, RTT and the latest
/// engine-truth KV sample onto the core's gauges before publishing them
/// (the core itself is transport-blind).
fn decorate_stats(
    stats: &mut DecodePoolStats,
    transports: &[Box<dyn DecodeTransport>],
    engine_truth: &HashMap<usize, UnitLoad>,
) {
    for (i, (g, t)) in stats.units.iter_mut().zip(transports).enumerate() {
        g.transport = t.label();
        g.alive = t.alive();
        g.rtt_ms = t.rtt_ms();
        g.engine_kv_tokens = engine_truth.get(&i).map(|l| l.kv_tokens);
    }
}

/// Fill the snapshot's KV wire gauge: the scheduler's own relay
/// accounting plus the sum of the decode shards' reported inbound-KV
/// counters.
fn decorate_kv_stats(
    stats: &mut DecodePoolStats,
    codec: KvCodec,
    relay: &KvWireCounters,
    shard_kv: &HashMap<usize, (u64, u64)>,
) {
    let (relay_wire_bytes, relay_raw_bytes) = relay.snapshot();
    let (wire_bytes, raw_bytes) = shard_kv
        .values()
        .fold((0, 0), |(w, r), (sw, sr)| (w + sw, r + sr));
    stats.kv_wire = KvWireGauge {
        codec: codec.name().to_string(),
        wire_bytes,
        raw_bytes,
        relay_wire_bytes,
        relay_raw_bytes,
    };
}

/// Fill the snapshot's prefill section from the prefill transports and
/// the scheduler's per-instance dispatch counters.
fn decorate_prefill_stats(
    stats: &mut DecodePoolStats,
    prefills: &[Box<dyn PrefillTransport>],
    dispatched: &[u64],
) {
    stats.prefill = prefills
        .iter()
        .enumerate()
        .map(|(i, p)| crate::metrics::PrefillUnitGauge {
            unit: format!("p{i}"),
            transport: p.label(),
            alive: p.alive(),
            rtt_ms: p.rtt_ms(),
            dispatched: dispatched.get(i).copied().unwrap_or(0),
        })
        .collect();
}

/// One submitted job awaiting prefill dispatch, with its re-dispatch
/// budget (a dispatch that fails because its prefill transport died is
/// requeued onto the surviving instances, not instantly rejected).
struct PendingJob {
    job: Job,
    t_arrive: f64,
    attempts: u32,
}

/// Re-dispatch attempts before a job whose prefill dispatches keep
/// landing on dead transports is terminally rejected (bounds the
/// requeue loop when the whole prefill pool is gone).
const MAX_PREFILL_ATTEMPTS: u32 = 5;

/// Scheduler thread: the shared [`DispatchCore`] on real time. Owns both
/// planes — prefill dispatch (SBS dual trigger or immediate baseline)
/// across the prefill pool via [`PrefillTransport`]s, and decode
/// placement across the DP pool via [`DecodeTransport`]s (local engine
/// threads and remote shards mix freely on both planes behind the same
/// core).
#[allow(clippy::too_many_arguments)]
fn scheduler_loop(
    cfg: RealClusterConfig,
    rx: Receiver<SchedMsg>,
    mut prefills: Vec<Box<dyn PrefillTransport>>,
    mut transports: Vec<Box<dyn DecodeTransport>>,
    router: Sender<RouterMsg>,
    shared: Arc<ClusterShared>,
    relay_kv: Arc<KvWireCounters>,
) {
    let mode = match &cfg.mode {
        RealSchedMode::Staggered(sc) => {
            // PJRT-mode clamps: dispatch cycles there are seconds (CPU
            // PJRT passes), not the simulator's ~100 ms, so simulator-
            // scale flow-control/watchdog defaults would misfire. Mock
            // passes are ~10 ms, so they keep the configured cadence.
            let mut sc = sc.clone();
            if matches!(cfg.engine, EngineSpec::Pjrt { .. }) {
                sc.pbaa.n_limit = sc.pbaa.n_limit.max(10_000);
                sc.interval.t_default = sc.interval.t_default.max(1.0);
            }
            RealSchedMode::Staggered(sc)
        }
        m @ RealSchedMode::Immediate(_) => m.clone(),
    };
    let n_decode = transports.len() as u32;
    let mut core = DispatchCore::new(&DispatchCoreConfig {
        mode,
        n_prefill: prefills.len() as u32,
        dp_prefill: 1,
        c_chunk: cfg.c_chunk,
        n_decode,
        dp_decode: 1,
        decode_policy: cfg.decode_policy.clone(),
        seed: cfg.seed ^ 0xDECD_E000,
    });
    core.set_rescue(cfg.rescue.clone());
    // Job payloads keyed by request id (the scheduler works on Requests).
    let mut jobs: HashMap<u64, PendingJob> = HashMap::new();
    // Absolute completion deadlines (scheduler clock, seconds) for jobs
    // that declared one. Deadlines never cross the wire, so the scheduler
    // keeps them here and re-attaches them to every decode join it builds
    // — the deadline-aware placement policy's input.
    let mut deadlines: HashMap<u64, f64> = HashMap::new();
    // Decode joins awaiting placement + their engine payloads.
    let mut parked: Vec<DecodeJoin> = Vec::new();
    let mut payloads: HashMap<u64, JoinPayload> = HashMap::new();
    // Per-unit slot caps for admission; occupancy itself lives in the
    // core's ledger (one authoritative ⟨B, K⟩ per unit).
    let slots: Vec<u32> = transports.iter().map(|t| t.slots().max(1)).collect();
    // Per-instance prefill dispatch counters (the prefill gauges).
    let mut prefill_dispatched: Vec<u64> = vec![0; prefills.len()];
    // Latest engine-truth per-unit loads from decode shards'
    // `StatsReply`, keyed by flat pool index, plus the consecutive
    // divergence streak behind the logged cross-check.
    let mut engine_truth: HashMap<usize, UnitLoad> = HashMap::new();
    let mut divergent_polls: Vec<u32> = vec![0; transports.len()];
    // Direct-transfer bookkeeping: jobs pre-placed onto a decode unit at
    // dispatch (id → flat pool unit) awaiting their HandoffCommit, and
    // direct jobs already terminalized by their decode shard's death —
    // whose late relay fallback must be dropped, not re-served.
    let mut direct_targets: HashMap<u64, usize> = HashMap::new();
    let mut direct_evicted: HashSet<u64> = HashSet::new();
    // Latest per-shard inbound-KV counters (keyed by the shard's base
    // unit index), summed into the published kv_wire gauge.
    let mut shard_kv: HashMap<usize, (u64, u64)> = HashMap::new();
    let mut next_timer: Option<f64> = None;
    let mut stop = false;
    // Shard liveness/RTT can change without ledger traffic, so pools
    // with remote transports also refresh their gauges on idle ticks;
    // purely local pools keep the cheaper ledger-change-only publishing.
    let has_remote = !cfg.remote_decode.is_empty() || !cfg.remote_prefill.is_empty();
    // Since when every transport has been dead (drives the parked-join
    // grace window in place_parked).
    let mut all_dead_since: Option<Instant> = None;
    // The shaped zero snapshot was published at cluster start; from here
    // on it is refreshed when the ledger changes — and on idle ticks, so
    // shard liveness/RTT stay fresh even without traffic.
    while !stop {
        let now = shared.clock.now_s();
        let timeout = next_timer
            .map(|t| Duration::from_secs_f64((t - now).max(1e-4)))
            .unwrap_or(Duration::from_millis(50));
        let msg = rx.recv_timeout(timeout);
        let now = shared.clock.now_s();
        let mut actions = Vec::new();
        let mut pool_dirty = false;
        match msg {
            Ok(SchedMsg::Submit(job, t_arrive)) => {
                shared
                    .trace
                    .mark(TRACK_SCHED, job.id, Mark::Arrival, 0, t_arrive);
                let mut req =
                    Request::new(job.id, job.prompt.len() as u32, job.max_new, t_arrive)
                        .with_class(job.class);
                if let Some(ms) = job.deadline_ms {
                    let d = t_arrive + ms / 1000.0;
                    deadlines.insert(job.id, d);
                    req = req.with_deadline(d);
                }
                jobs.insert(
                    job.id,
                    PendingJob {
                        job,
                        t_arrive,
                        attempts: 0,
                    },
                );
                actions = core.on_arrival(req, now);
            }
            Ok(SchedMsg::EndForward {
                instance,
                t_measured,
                remaining,
            }) => {
                // Local workers consume each dispatch wholesale before
                // signalling (None → the core clears the capacity model
                // itself); remote prefill shards report their real
                // backlog over the wire (Some → engine truth seeds
                // C_avail).
                let backlog = match remaining {
                    None => EndForwardBacklog::ConsumedAll,
                    Some(r) => EndForwardBacklog::Reported(r),
                };
                actions = core.on_end_forward(instance, t_measured, backlog, now);
            }
            Ok(SchedMsg::PrefillDone {
                id,
                outcome,
                max_new,
                class,
                metrics,
            }) => {
                if direct_evicted.remove(&id) {
                    // Terminally rejected when its decode target died;
                    // the late relay has no live subscriber — drop it.
                    log::debug!("dropping relay fallback for evicted direct job {id}");
                } else if let Some(u) = direct_targets.remove(&id) {
                    // Relay fallback for a direct-dispatched job (the
                    // peer link failed — or only its ack did). Re-admit
                    // on the *pre-placed* unit, keeping the existing
                    // ledger charge: if the direct handoff actually
                    // landed (ack lost), the unit drops the duplicate
                    // admit and the original stream continues under the
                    // re-registered pending entry; any other unit would
                    // risk two engines generating the same id. Only a
                    // dead pre-placed unit falls back to free placement.
                    pool_dirty = true;
                    let mut unplaced = Some(AdmitJob {
                        id,
                        outcome,
                        max_new,
                        class,
                        resume: Vec::new(),
                        metrics,
                    });
                    if transports[u].alive() {
                        match transports[u].admit(unplaced.take().expect("job present")) {
                            Ok(()) => {}
                            Err(job) => unplaced = Some(job),
                        }
                    }
                    if let Some(job) = unplaced {
                        transports[u].cancel_direct(id);
                        core.on_decode_leave(id, now);
                        park_join(
                            &mut parked,
                            &mut payloads,
                            id,
                            job.outcome,
                            job.max_new,
                            job.class,
                            deadlines.get(&id).copied(),
                            job.metrics,
                        );
                    }
                } else {
                    let deadline = deadlines.get(&id).copied();
                    park_join(
                        &mut parked, &mut payloads, id, outcome, max_new, class, deadline, metrics,
                    );
                }
            }
            Ok(SchedMsg::DecodeDone { id }) => {
                direct_targets.remove(&id);
                deadlines.remove(&id);
                // Finish, not leave: terminal completions score their
                // deadline outcome (met / violated / rescue_deadline_met)
                // before the ledger release.
                pool_dirty |= core.on_decode_finish(id, now).is_some();
            }
            Ok(SchedMsg::Progress { id, index }) => {
                core.on_decode_progress(id, index);
            }
            Ok(SchedMsg::Migrated { id, seq }) => match seq {
                Some(ex) => {
                    // The sequence left its unit with its live state in
                    // hand: release the old charge and re-park it for
                    // standard placement, progress intact. Class comes
                    // from the core's resident registry (queried before
                    // the release drops it); the deadline from the
                    // scheduler's own table.
                    let class = core.resident_class(id).unwrap_or(SloClass::Standard);
                    pool_dirty |= core.on_decode_leave(id, now).is_some();
                    let generated = (ex.tokens.len() as u32).saturating_sub(1);
                    parked.push(DecodeJoin {
                        request_id: id,
                        // The destination charge counts the KV the
                        // sequence has actually grown: prompt rows plus
                        // one per generated token.
                        kv_tokens: ex.kv_len + generated,
                        remaining_out: ex.remaining,
                        class,
                        deadline: deadlines.get(&id).copied(),
                    });
                    payloads.insert(
                        id,
                        JoinPayload {
                            outcome: Box::new(PrefillOutcome {
                                first_token: ex.tokens.last().copied().unwrap_or(0),
                                len: ex.kv_len as usize,
                                k: ex.k,
                                v: ex.v,
                                exec_time: 0.0,
                                passes: 1,
                            }),
                            max_new: ex.remaining,
                            class,
                            resume: ex.tokens,
                            metrics: ex.metrics,
                        },
                    );
                }
                // Extraction raced a terminal (or failed shard-side):
                // the sequence already finished or still runs where it
                // was — either way the rescue is a no-op.
                None => log::debug!("rescue extraction for {id} found nothing to move"),
            },
            Ok(SchedMsg::Evict { ids }) => {
                // A shard died owning these sequences: release each from
                // the ledger and reject it upstream. Only ids the core
                // actually still owned are rejected, so a sequence that
                // completed a moment earlier is never double-terminated.
                for id in ids {
                    deadlines.remove(&id);
                    if core.on_decode_leave(id, now).is_some() {
                        pool_dirty = true;
                        if direct_targets.remove(&id).is_some() {
                            // The handoff target died before (or while)
                            // the prefill streamed to it; remember the
                            // id so its relay fallback is dropped.
                            direct_evicted.insert(id);
                        }
                        let _ = router.send(RouterMsg::Update {
                            id,
                            update: JobUpdate::Rejected { id },
                        });
                    }
                }
            }
            Ok(SchedMsg::PrefillEvict { ids }) => {
                // A prefill shard died with these jobs in flight. Jobs
                // pre-placed for direct transfer hold a decode charge
                // and a decode-side registration; everything else holds
                // nothing, so a terminal rejection is the whole release.
                for id in ids {
                    deadlines.remove(&id);
                    if let Some(u) = direct_targets.remove(&id) {
                        transports[u].cancel_direct(id);
                        core.on_decode_leave(id, now);
                        pool_dirty = true;
                    }
                    let _ = router.send(RouterMsg::Update {
                        id,
                        update: JobUpdate::Rejected { id },
                    });
                }
            }
            Ok(SchedMsg::PrefillFailed { id }) => {
                deadlines.remove(&id);
                if let Some(u) = direct_targets.remove(&id) {
                    transports[u].cancel_direct(id);
                    core.on_decode_leave(id, now);
                    pool_dirty = true;
                }
                let _ = router.send(RouterMsg::Update {
                    id,
                    update: JobUpdate::Rejected { id },
                });
            }
            Ok(SchedMsg::DirectCommit { id, exec_time }) => {
                // The decode shard acked the handoff and owns the
                // sequence now; the pre-placement graduated into a
                // normal resident charge (released by DecodeDone). An
                // acked handoff also never falls back to relay, so any
                // tombstone left by a decode-shard death is garbage.
                // The commit is also the scheduler's first observation
                // of the committed KV *and* of the first token (which
                // the decode shard streams itself): both stamps land
                // here, after the shard's prefill marks (flushed ahead
                // of the commit on the same connection).
                shared.trace.mark(TRACK_SCHED, id, Mark::KvCommit, 0, now);
                shared.trace.mark(TRACK_SCHED, id, Mark::FirstToken, 0, now);
                direct_evicted.remove(&id);
                if let Some(u) = direct_targets.remove(&id) {
                    transports[u].patch_direct(id, now, exec_time);
                    pool_dirty = true;
                }
            }
            Ok(SchedMsg::ShardStats {
                base,
                loads,
                kv_wire_bytes,
                kv_raw_bytes,
            }) => {
                shard_kv.insert(base, (kv_wire_bytes, kv_raw_bytes));
                // Engine-truth cross-check: compare the shard's own
                // residency against the scheduler ledger. Transient
                // skew is normal (admits/terminals in flight), so only
                // a *persistent* divergence is promoted to a warning.
                let ledger = core.decode_stats(now);
                for (j, load) in loads.into_iter().enumerate() {
                    let unit = base + j;
                    let Some(g) = ledger.units.get(unit) else { break };
                    if load.active != g.active {
                        divergent_polls[unit] += 1;
                        if divergent_polls[unit] == 3 {
                            shared.ledger_divergence.fetch_add(1, Ordering::Relaxed);
                            log::warn!(
                                "unit {unit} engine-truth divergence: shard reports \
                                 {} active / {} KV tokens, ledger holds {} / {} \
                                 (3 consecutive polls)",
                                load.active,
                                load.kv_tokens,
                                g.active,
                                g.kv_tokens,
                            );
                        } else {
                            log::debug!(
                                "unit {unit}: shard reports {} active, ledger {}",
                                load.active,
                                g.active
                            );
                        }
                    } else {
                        divergent_polls[unit] = 0;
                    }
                    engine_truth.insert(unit, load);
                }
                pool_dirty = true;
            }
            Ok(SchedMsg::Drain) => stop = true,
            Err(_) => {
                next_timer = None;
                pool_dirty = has_remote; // refresh liveness/RTT gauges
                if has_remote {
                    // Poll the decode shards' engine truth (throttled to
                    // one StatsRequest per shard per second internally).
                    for t in &transports {
                        t.request_stats();
                    }
                }
                actions = core.on_timer(now);
            }
        }
        pool_dirty |= place_parked(
            &mut core,
            &mut parked,
            &mut payloads,
            &slots,
            cfg.kv_budget,
            &mut transports,
            &router,
            &mut all_dead_since,
            now,
        );
        // Deadline-rescue scan (self-gated on the configured cadence):
        // endangered residents trigger a batch-victim preemption or
        // their own live migration. Either way the named sequence is
        // extracted through its transport and comes back as
        // `SchedMsg::Migrated` for ledger release + re-placement.
        if cfg.rescue.enabled {
            let alive: Vec<bool> = transports.iter().map(|t| t.alive()).collect();
            let mut adm = PoolAdmission {
                slots: &slots,
                kv_budget: cfg.kv_budget,
                alive: &alive,
                peer_only: None,
            };
            for a in core.rescue_scan(now, &mut adm) {
                let u = a.unit.instance as usize;
                if transports[u].extract(a.id) {
                    log::info!(
                        "rescue: extracting {} from {} ({:?})",
                        a.id,
                        transports[u].label(),
                        a.kind
                    );
                } else {
                    log::warn!(
                        "rescue: {} cannot extract {}; sequence stays put",
                        transports[u].label(),
                        a.id
                    );
                }
            }
        }
        // Work-queue over the actions: a dispatch that lands on a dead
        // prefill transport requeues its jobs through `on_arrival`,
        // whose follow-up actions join the back of the queue (bounded by
        // the per-job attempt budget).
        let mut queue: VecDeque<SchedulerAction> = actions.into();
        while let Some(act) = queue.pop_front() {
            match act {
                SchedulerAction::Dispatch(batch) => {
                    let inst = batch.instance as usize;
                    let mut attempts: HashMap<u64, u32> = HashMap::new();
                    let mut work: Vec<PrefillWork> = batch
                        .assignments
                        .iter()
                        .filter_map(|a| jobs.remove(&a.request.id))
                        .map(|p| {
                            attempts.insert(p.job.id, p.attempts);
                            shared.trace.mark(
                                TRACK_SCHED,
                                p.job.id,
                                Mark::Dispatch,
                                inst as u32,
                                now,
                            );
                            let mut m =
                                RequestMetrics::arrive(p.t_arrive, p.job.prompt.len() as u32);
                            m.t_dispatch = now;
                            PrefillWork {
                                id: p.job.id,
                                prompt: p.job.prompt,
                                max_new: p.job.max_new,
                                class: p.job.class,
                                metrics: m,
                                target: None,
                            }
                        })
                        .collect();
                    if work.is_empty() {
                        continue;
                    }
                    // Direct-transfer pre-placement: decide the Algorithm 3
                    // decode placement *now*, inside the buffering window,
                    // so the prefill shard can stream the KV straight to
                    // its decode peer. Candidates are alive peer-capable
                    // units with slot + KV-budget headroom; jobs with no
                    // candidate (or a single-token budget) dispatch
                    // untargeted and take the relay path at completion.
                    if cfg.direct_handoff && prefills[inst].supports_direct() {
                        let joins: Vec<DecodeJoin> = work
                            .iter()
                            .filter(|w| w.max_new > 1)
                            .map(|w| DecodeJoin {
                                request_id: w.id,
                                kv_tokens: w.prompt.len() as u32,
                                remaining_out: w.max_new - 1,
                                class: w.class,
                                deadline: deadlines.get(&w.id).copied(),
                            })
                            .collect();
                        if !joins.is_empty() {
                            let alive: Vec<bool> =
                                transports.iter().map(|t| t.alive()).collect();
                            let peers: Vec<bool> = transports
                                .iter()
                                .map(|t| t.direct_target().is_some())
                                .collect();
                            let mut adm = PoolAdmission {
                                slots: &slots,
                                kv_budget: cfg.kv_budget,
                                alive: &alive,
                                peer_only: Some(&peers),
                            };
                            let out = core.place_decode(joins, now, &mut adm);
                            for (j, unit) in out.placed {
                                let u = unit.instance as usize;
                                let (Some(t), Some(w)) = (
                                    transports[u].direct_target(),
                                    work.iter_mut().find(|w| w.id == j.request_id),
                                ) else {
                                    // Peer vanished between the check and
                                    // now: undo; relay will re-place.
                                    core.on_decode_leave(j.request_id, now);
                                    continue;
                                };
                                transports[u].expect_direct(w.id, w.metrics);
                                direct_targets.insert(w.id, u);
                                w.target = Some(t);
                                pool_dirty = true;
                            }
                            // out.parked: no admissible peer right now —
                            // those jobs simply dispatch untargeted.
                        }
                    }
                    pool_dirty = true;
                    match prefills[inst].dispatch(work) {
                        Ok(()) => prefill_dispatched[inst] += 1,
                        Err(work) => {
                            // The transport died: requeue each job onto
                            // the surviving instances; terminally reject
                            // only once its attempt budget is spent
                            // (every transport keeps failing — the pool
                            // is gone).
                            log::warn!(
                                "prefill dispatch to {} failed; requeueing {} jobs",
                                prefills[inst].label(),
                                work.len()
                            );
                            for w in work {
                                // The dispatch never left: unwind any
                                // direct pre-placement so the requeue
                                // starts from a clean ledger.
                                if let Some(u) = direct_targets.remove(&w.id) {
                                    transports[u].cancel_direct(w.id);
                                    core.on_decode_leave(w.id, now);
                                }
                                let tries = attempts.get(&w.id).copied().unwrap_or(0) + 1;
                                if tries >= MAX_PREFILL_ATTEMPTS {
                                    log::warn!(
                                        "job {} failed {tries} prefill dispatches; rejecting",
                                        w.id
                                    );
                                    deadlines.remove(&w.id);
                                    let _ = router.send(RouterMsg::Update {
                                        id: w.id,
                                        update: JobUpdate::Rejected { id: w.id },
                                    });
                                    continue;
                                }
                                let t_arrive = w.metrics.t_arrival;
                                let mut req = Request::new(
                                    w.id,
                                    w.prompt.len() as u32,
                                    w.max_new,
                                    t_arrive,
                                )
                                .with_class(w.class);
                                if let Some(&d) = deadlines.get(&w.id) {
                                    req = req.with_deadline(d);
                                }
                                jobs.insert(
                                    w.id,
                                    PendingJob {
                                        job: Job::new(w.id, w.prompt, w.max_new)
                                            .with_class(w.class),
                                        t_arrive,
                                        attempts: tries,
                                    },
                                );
                                queue.extend(core.on_arrival(req, now));
                            }
                        }
                    }
                }
                SchedulerAction::ArmTimer { at } => {
                    next_timer = Some(match next_timer {
                        Some(t) => t.min(at),
                        None => at,
                    });
                }
                SchedulerAction::Reject(r) => {
                    // Terminal rejection: route it so subscribers waiting
                    // on this job observe it instead of hanging.
                    log::warn!("flow control rejected request {}", r.id);
                    jobs.remove(&r.id);
                    deadlines.remove(&r.id);
                    let _ = router.send(RouterMsg::Update {
                        id: r.id,
                        update: JobUpdate::Rejected { id: r.id },
                    });
                }
                SchedulerAction::Watchdog(w) => log::warn!("watchdog: {w:?}"),
            }
        }
        if pool_dirty {
            let mut stats = core.decode_stats(now);
            decorate_stats(&mut stats, &transports, &engine_truth);
            decorate_prefill_stats(&mut stats, &prefills, &prefill_dispatched);
            decorate_kv_stats(&mut stats, cfg.kv_wire, &relay_kv, &shard_kv);
            *shared.decode_stats.lock().unwrap() = stats;
        }
    }
    // Drain guard: `Drain` is only sent once the ledger's in-flight count
    // has reached zero, and a parked join always belongs to an in-flight
    // job — the main loop's place_parked/DecodeDone servicing is what
    // guarantees no job hangs when a decode DP unit drains last. If a
    // future caller ever sends Drain early, terminalize whatever is still
    // parked so subscribers and the ledger drain instead of hanging.
    if !parked.is_empty() {
        log::warn!("drain with {} unplaced decode joins; rejecting them", parked.len());
        for j in parked.drain(..) {
            reject_unplaced(&mut payloads, &router, j.request_id);
        }
    }
    {
        let mut stats = core.decode_stats(shared.clock.now_s());
        decorate_stats(&mut stats, &transports, &engine_truth);
        decorate_prefill_stats(&mut stats, &prefills, &prefill_dispatched);
        decorate_kv_stats(&mut stats, cfg.kv_wire, &relay_kv, &shard_kv);
        *shared.decode_stats.lock().unwrap() = stats;
    }
    // In-process units always stop (their threads must exit with the
    // cluster); detach() only differs for remote shards, which it
    // disconnects without terminating when the config says so.
    for p in prefills.iter_mut() {
        if cfg.stop_shards_on_drain {
            p.stop();
        } else {
            p.detach();
        }
    }
    for t in transports.iter_mut() {
        if cfg.stop_shards_on_drain {
            t.stop();
        } else {
            t.detach();
        }
    }
}

/// Where a prefill engine runner reports its events — the prefill-plane
/// sibling of [`DecodeEventSink`]. The in-process pool routes them
/// straight onto the scheduler/router channels ([`LocalPrefillSink`]); a
/// prefill shard serializes them onto the wire (`cluster::shard`'s
/// sink: chunked `KvSegment` stream + `PrefillDone`) for the
/// scheduler-side transport to re-deliver through the *same* channels.
pub(crate) trait PrefillEventSink {
    /// Prefill finished: the outcome plus the job's dispatch-time state.
    /// `target` is the scheduler's direct-transfer pre-placement, when
    /// one was made (honored by the shard-side wire sink; in-process
    /// sinks ignore it — a local handoff has no wire to skip).
    fn prefilled(
        &self,
        id: u64,
        outcome: PrefillOutcome,
        max_new: u32,
        class: SloClass,
        metrics: RequestMetrics,
        target: Option<DirectTarget>,
    );
    /// Terminal prefill failure.
    fn failed(&self, id: u64);
    /// A pass completed; `remaining` is the runner's queued backlog in
    /// prompt tokens (the `EndForward` payload of Fig. 5).
    fn end_forward(&self, instance: u32, t_measured: f64, remaining: u32);
    /// A TTFT trace boundary observed by this runner (work receipt,
    /// pass start). Best-effort; the default discards it.
    fn trace(&self, _id: u64, _mark: Mark) {}
}

/// Route one finished prefill into the cluster: stamp the first token on
/// the scheduler clock, stream it, and either terminalize (single-token
/// jobs) or park the sequence for decode placement. Shared by the
/// in-process sink and the remote-shard sink, so where prefill ran is
/// invisible downstream.
#[allow(clippy::too_many_arguments)]
fn deliver_prefilled(
    to_sched: &Sender<SchedMsg>,
    router: &Sender<RouterMsg>,
    id: u64,
    outcome: Box<PrefillOutcome>,
    max_new: u32,
    class: SloClass,
    mut metrics: RequestMetrics,
    t_first: f64,
) {
    metrics.t_first_token = t_first;
    // Engine execution is a duration, so it maps onto the scheduler
    // clock even for remote shards: the pass started ~exec_time before
    // its first token surfaced.
    metrics.t_exec_start = (t_first - outcome.exec_time).max(metrics.t_dispatch);
    let first_token = outcome.first_token;
    let _ = router.send(RouterMsg::Update {
        id,
        update: JobUpdate::Token {
            token: first_token,
            index: 0,
            t: t_first,
        },
    });
    if max_new <= 1 {
        metrics.t_done = t_first;
        metrics.output_tokens = 1;
        // A single-token job terminates at prefill without the scheduler
        // ever seeing a decode release: tell it anyway so per-job state
        // (deadline bookkeeping) is dropped — `on_decode_leave` is a
        // no-op for an id that never held a decode charge.
        let _ = to_sched.send(SchedMsg::DecodeDone { id });
        let _ = router.send(RouterMsg::Update {
            id,
            update: JobUpdate::Done(Completion {
                id,
                tokens: vec![first_token],
                metrics,
            }),
        });
    } else {
        let _ = to_sched.send(SchedMsg::PrefillDone {
            id,
            outcome,
            max_new: max_new - 1,
            class,
            metrics,
        });
    }
}

/// In-process prefill sink: events go straight onto the cluster
/// channels, timestamps from the shared cluster clock.
struct LocalPrefillSink {
    to_sched: Sender<SchedMsg>,
    router: Sender<RouterMsg>,
    shared: Arc<ClusterShared>,
    /// This instance's index within the prefill pool (trace attribution).
    unit: u32,
}

impl PrefillEventSink for LocalPrefillSink {
    fn prefilled(
        &self,
        id: u64,
        outcome: PrefillOutcome,
        max_new: u32,
        class: SloClass,
        metrics: RequestMetrics,
        _target: Option<DirectTarget>,
    ) {
        let t_first = self.shared.clock.now_s();
        // An in-process handoff has no wire hop: prefill end, KV commit
        // and the first token coincide on the scheduler clock (the
        // kv_transfer / decode_queue stages are genuinely zero here).
        let tr = &self.shared.trace;
        tr.mark(TRACK_LOCAL_PREFILL, id, Mark::PrefillEnd, self.unit, t_first);
        tr.mark(TRACK_SCHED, id, Mark::KvCommit, 0, t_first);
        tr.mark(TRACK_SCHED, id, Mark::FirstToken, 0, t_first);
        deliver_prefilled(
            &self.to_sched,
            &self.router,
            id,
            Box::new(outcome),
            max_new,
            class,
            metrics,
            t_first,
        );
    }

    fn failed(&self, id: u64) {
        // Terminal failure — surface it so subscribers and the ledger
        // drain instead of hanging (the scheduler-side watchdog recovers
        // the instance's capacity state).
        let _ = self.router.send(RouterMsg::Update {
            id,
            update: JobUpdate::Rejected { id },
        });
    }

    fn end_forward(&self, instance: u32, t_measured: f64, _remaining: u32) {
        // In-process workers keep the historical wholesale-consumption
        // semantics (`None` → the core clears the capacity model); only
        // the wire path reports granular backlog.
        let _ = self.to_sched.send(SchedMsg::EndForward {
            instance,
            t_measured,
            remaining: None,
        });
    }

    fn trace(&self, id: u64, mark: Mark) {
        let t = self.shared.clock.now_s();
        self.shared
            .trace
            .mark(TRACK_LOCAL_PREFILL, id, mark, self.unit, t);
    }
}

/// Per-instance gauges a prefill shard exposes over `StatsReply` (the
/// in-process pool reads the scheduler's own state instead and passes
/// `None`). Refreshed when the runner's queue changes.
#[derive(Default)]
pub(crate) struct PrefillGauges {
    /// Jobs waiting in the runner's queue (the in-flight pass excluded).
    pub queued_jobs: AtomicU32,
    /// Prompt tokens waiting in the runner's queue.
    pub queued_tokens: AtomicU64,
}

/// Prefill instance runner: gated, non-preemptive prefill of dispatched
/// batches, shared verbatim by the in-process pool and the prefill
/// shard process — the engine loop cannot drift between deployments.
/// Each finished pass reports `EndForward` with the queue still behind
/// it; an `Abort` clears the queue even when it arrived behind stale
/// work (the runner drains every pending message before each pass, so
/// one engine pass bounds abort latency).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_prefill_unit<S: PrefillEventSink>(
    label: &str,
    instance: u32,
    spec: &EngineSpec,
    seed: u64,
    rx: Receiver<PrefillMsg>,
    sink: S,
    gauges: Option<&PrefillGauges>,
    ready: Sender<bool>,
) {
    let mut engine = match spec.build(EngineRole::Prefill, 0, Sampling::Greedy, seed) {
        Ok(e) => e,
        Err(e) => {
            log::error!("prefill unit {label}: {e:#}");
            let _ = ready.send(false);
            return;
        }
    };
    let _ = ready.send(true);
    let publish = |queue: &VecDeque<PrefillWork>| {
        let Some(g) = gauges else { return };
        g.queued_jobs.store(queue.len() as u32, Ordering::Relaxed);
        g.queued_tokens.store(
            queue.iter().map(|w| w.prompt.len() as u64).sum(),
            Ordering::Relaxed,
        );
    };
    let mut queue: VecDeque<PrefillWork> = VecDeque::new();
    let mut stopping = false;
    loop {
        // Drain every available message before the next engine pass, so
        // an Abort queued behind stale Work is honored without
        // prefilling the work in front of it first.
        let mut changed = false;
        loop {
            let msg = if queue.is_empty() && !stopping {
                match rx.recv_timeout(Duration::from_millis(100)) {
                    Ok(m) => m,
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        stopping = true;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        stopping = true;
                        break;
                    }
                }
            };
            match msg {
                PrefillMsg::Work(w) => {
                    // Work receipt closes the dispatch-transit stage (for
                    // shard-hosted runners the wire receipt already
                    // stamped it — first write wins there).
                    for job in &w {
                        sink.trace(job.id, Mark::PrefillRecv);
                    }
                    queue.extend(w);
                    changed = true;
                }
                PrefillMsg::Abort { ack } => {
                    // A new owner superseded whoever dispatched these
                    // jobs: drop them *silently* (the old scheduler
                    // already evicted them on its side).
                    if !queue.is_empty() {
                        log::info!(
                            "prefill unit {label}: aborting {} queued jobs",
                            queue.len()
                        );
                    }
                    queue.clear();
                    changed = true;
                    let _ = ack.send(());
                }
                PrefillMsg::Stop => stopping = true,
            }
        }
        if changed {
            publish(&queue);
        }
        let Some(w) = queue.pop_front() else {
            if stopping {
                break;
            }
            continue;
        };
        // Gauges reflect the post-pop queue while the pass runs.
        publish(&queue);
        // The in-engine queue wait ends here; the pass itself begins.
        sink.trace(w.id, Mark::PrefillStart);
        match engine.prefill(&w.prompt) {
            Ok(outcome) => {
                let t_measured = outcome.exec_time;
                sink.prefilled(w.id, outcome, w.max_new, w.class, w.metrics, w.target);
                let remaining: u32 = queue.iter().map(|q| q.prompt.len() as u32).sum();
                sink.end_forward(instance, t_measured, remaining);
            }
            Err(e) => {
                log::error!("prefill unit {label}: prefill failed for job {}: {e:#}", w.id);
                sink.failed(w.id);
            }
        }
    }
}

/// Where a decode engine runner reports its per-sequence events. The
/// in-process pool routes them straight onto the scheduler/router
/// channels ([`LocalSink`]); a remote shard serializes them onto the
/// wire (`cluster::shard`'s frame sink) for the scheduler-side
/// transport to re-deliver through the *same* channels.
pub(crate) trait DecodeEventSink {
    /// One generated token at runner-clock time `t`.
    fn token(&self, id: u64, index: u32, token: i32, t: f64);
    /// Terminal success with the full generation (ledger release).
    fn done(&self, id: u64, tokens: Vec<i32>, metrics: RequestMetrics);
    /// Terminal failure (ledger release).
    fn rejected(&self, id: u64);
    /// A rescue extraction completed on this runner: `Some` with the
    /// live state (removed from the engine, no further emissions),
    /// `None` when the sequence was not resident (already terminal).
    fn extracted(&self, _id: u64, _seq: Option<ExtractedSeq>) {}
    /// A TTFT trace boundary observed by this runner (engine admission).
    /// Best-effort; the default discards it.
    fn trace(&self, _id: u64, _mark: Mark) {}
}

/// In-process sink: the decode half of the historical worker wiring.
#[derive(Clone)]
struct LocalSink {
    to_sched: Sender<SchedMsg>,
    router: Sender<RouterMsg>,
    shared: Arc<ClusterShared>,
    /// Flat pool index of the unit this sink serves (trace attribution).
    unit: u32,
}

impl DecodeEventSink for LocalSink {
    fn token(&self, id: u64, index: u32, token: i32, t: f64) {
        // Progress feeds the rescue layer's per-token rate model; the
        // router update is the client-visible stream. Remote shards
        // route through this same sink (shard_sinks wraps it), so one
        // site covers both planes.
        let _ = self.to_sched.send(SchedMsg::Progress { id, index });
        let _ = self.router.send(RouterMsg::Update {
            id,
            update: JobUpdate::Token { token, index, t },
        });
    }

    fn done(&self, id: u64, tokens: Vec<i32>, metrics: RequestMetrics) {
        // DecodeDone before Done: the router update is what decrements
        // inflight, so a Drain sent after the pool looks empty is
        // guaranteed to sit behind this release in the scheduler's
        // queue (exact final gauges).
        let _ = self.to_sched.send(SchedMsg::DecodeDone { id });
        let _ = self.router.send(RouterMsg::Update {
            id,
            update: JobUpdate::Done(Completion { id, tokens, metrics }),
        });
    }

    fn rejected(&self, id: u64) {
        let _ = self.to_sched.send(SchedMsg::DecodeDone { id });
        let _ = self.router.send(RouterMsg::Update {
            id,
            update: JobUpdate::Rejected { id },
        });
    }

    fn extracted(&self, id: u64, seq: Option<ExtractedSeq>) {
        let _ = self.to_sched.send(SchedMsg::Migrated { id, seq });
    }

    fn trace(&self, id: u64, mark: Mark) {
        let t = self.shared.clock.now_s();
        self.shared
            .trace
            .mark(TRACK_LOCAL_DECODE, id, mark, self.unit, t);
    }
}

/// Scheduler-side sinks for one remote decode shard: terminal events are
/// re-stamped on the cluster clock here, so every timestamp a client
/// sees comes from one clock regardless of where the sequence decoded.
/// `base` is the flat pool index the shard's first unit will occupy
/// (maps its shard-local `StatsReply` onto pool units).
fn shard_sinks(
    to_sched: Sender<SchedMsg>,
    router: Sender<RouterMsg>,
    shared: Arc<ClusterShared>,
    base: usize,
    addr: &str,
) -> ShardSinks {
    let sink = LocalSink {
        to_sched: to_sched.clone(),
        router,
        shared: shared.clone(),
        unit: base as u32,
    };
    let (tok, don, rej) = (sink.clone(), sink.clone(), sink);
    let clock = shared.clone();
    let stats_sched = to_sched.clone();
    let mig_sched = to_sched.clone();
    let trace_shared = shared.clone();
    let track = format!("decode:{addr}");
    ShardSinks {
        on_token: Box::new(move |id, index, token| {
            tok.token(id, index, token, clock.clock.now_s());
        }),
        on_done: Box::new(move |id, tokens, mut metrics| {
            metrics.t_done = shared.clock.now_s();
            metrics.output_tokens = tokens.len() as u32;
            if metrics.t_first_token < 0.0 {
                // A direct-transfer sequence whose Done outran the
                // HandoffCommit's metrics patch (the decode shard owns
                // the whole stream, so nothing else stamps it):
                // conservatively count TTFT as completion time rather
                // than reporting it absent.
                metrics.t_first_token = metrics.t_done;
                metrics.t_exec_start = metrics.t_exec_start.max(metrics.t_dispatch);
            }
            don.done(id, tokens, metrics);
        }),
        on_rejected: Box::new(move |id| rej.rejected(id)),
        on_evicted: Box::new(move |ids| {
            // The scheduler decides which of these are still live in the
            // ledger and rejects exactly those upstream.
            let _ = to_sched.send(SchedMsg::Evict { ids });
        }),
        on_stats: Box::new(move |loads, kv_wire_bytes, kv_raw_bytes| {
            let _ = stats_sched.send(SchedMsg::ShardStats {
                base,
                loads,
                kv_wire_bytes,
                kv_raw_bytes,
            });
        }),
        on_migrated: Box::new(move |id, seq| {
            let _ = mig_sched.send(SchedMsg::Migrated { id, seq });
        }),
        on_trace: Box::new(move |dropped, marks| {
            trace_shared.trace.record(&track, dropped, &marks);
        }),
    }
}

/// Scheduler-side sinks for one remote *prefill* shard: handoffs and
/// first tokens are re-stamped on the cluster clock and re-delivered
/// through the same channels as the in-process pool, and the shard's
/// `EndForward` instances are re-based into the global prefill pool.
fn prefill_shard_sinks(
    to_sched: Sender<SchedMsg>,
    router: Sender<RouterMsg>,
    shared: Arc<ClusterShared>,
    base: u32,
    addr: &str,
) -> PrefillSinks {
    let (prefilled_sched, prefilled_router) = (to_sched.clone(), router.clone());
    drop(router);
    let failed_sched = to_sched.clone();
    let ef_sched = to_sched.clone();
    let handoff_sched = to_sched.clone();
    let trace_shared = shared.clone();
    let track = format!("prefill:{addr}");
    PrefillSinks {
        on_prefilled: Box::new(move |id, outcome, max_new, class, metrics| {
            let t_first = shared.clock.now_s();
            // Relay path: the first token is synthesized here, so the
            // KV-commit and first-token boundaries coincide with it.
            shared.trace.mark(TRACK_SCHED, id, Mark::KvCommit, 0, t_first);
            shared
                .trace
                .mark(TRACK_SCHED, id, Mark::FirstToken, 0, t_first);
            deliver_prefilled(
                &prefilled_sched,
                &prefilled_router,
                id,
                outcome,
                max_new,
                class,
                metrics,
                t_first,
            );
        }),
        on_handoff: Box::new(move |id, exec_time| {
            // The KV skipped the scheduler; the decode shard already
            // emits the token stream (index 0 included). All that's left
            // is graduating the pre-placement and stamping TTFT.
            let _ = handoff_sched.send(SchedMsg::DirectCommit { id, exec_time });
        }),
        on_failed: Box::new(move |id| {
            // Through the scheduler thread: a direct-dispatched job's
            // pre-placement must be unwound with the rejection.
            let _ = failed_sched.send(SchedMsg::PrefillFailed { id });
        }),
        on_end_forward: Box::new(move |instance, t_measured, remaining| {
            let _ = ef_sched.send(SchedMsg::EndForward {
                instance: base + instance,
                t_measured,
                remaining,
            });
        }),
        on_evicted: Box::new(move |ids| {
            let _ = to_sched.send(SchedMsg::PrefillEvict { ids });
        }),
        on_trace: Box::new(move |dropped, marks| {
            trace_shared.trace.record(&track, dropped, &marks);
        }),
    }
}

/// Per-unit occupancy gauges a shard exposes over `StatsReply` (the
/// in-process pool reads the core ledger instead and passes no gauges).
/// Refreshed when the tracked set changes (admit / done / abort), so the
/// KV figure is a snapshot from the last membership change, not
/// per-token exact — a deliberate trade for a quiet hot loop.
#[derive(Default)]
pub(crate) struct UnitGauges {
    /// Routable (tracked) sequences.
    pub active: AtomicU32,
    /// Engine slots occupied.
    pub slots_used: AtomicU32,
    /// Approximate resident KV tokens across tracked sequences.
    pub kv_tokens: AtomicU64,
}

/// Decode DP engine runner: continuous batched stepping with slot
/// admission, shared verbatim by the in-process pool and the remote
/// shard process — the engine loop cannot drift between deployments.
/// Every emitted token goes to the sink; every released sequence (done
/// or rejected) is a terminal sink event so the owning scheduler's pool
/// ledger stays exact.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_decode_unit<S: DecodeEventSink, F: Fn() -> f64>(
    label: &str,
    spec: &EngineSpec,
    batch: u32,
    sampling: Sampling,
    seed: u64,
    rx: Receiver<UnitMsg>,
    sink: S,
    now_fn: F,
    gauges: Option<&UnitGauges>,
    ready: Sender<bool>,
) {
    let mut engine = match spec.build(EngineRole::Decode, batch, sampling, seed) {
        Ok(e) => e,
        Err(e) => {
            log::error!("decode unit {label}: {e:#}");
            let _ = ready.send(false);
            return;
        }
    };
    let _ = ready.send(true);
    struct Track {
        tokens: Vec<i32>,
        /// Prompt-KV length plus the prompt K/V planes, retained for the
        /// lifetime of the sequence: engines do not expose KV readback,
        /// so a rescue extraction re-streams the copy kept here.
        kv_len: u32,
        k: Vec<f32>,
        v: Vec<f32>,
        metrics: RequestMetrics,
    }
    let mut tracks: HashMap<u64, Track> = HashMap::new();
    let mut pending: Vec<AdmitJob> = Vec::new();
    let mut stopping = false;
    let mut failed = false;
    // Gauges exist only for shard-hosted units (a `StatsReply` consumer);
    // the in-process pool reads the core ledger instead and passes None.
    let publish_gauges = |tracks: &HashMap<u64, Track>, engine_active: usize| {
        let Some(g) = gauges else { return };
        g.active.store(tracks.len() as u32, Ordering::Relaxed);
        g.slots_used.store(engine_active as u32, Ordering::Relaxed);
        let kv: u64 = tracks
            .values()
            .map(|t| t.metrics.input_tokens as u64 + t.tokens.len() as u64)
            .sum();
        g.kv_tokens.store(kv, Ordering::Relaxed);
    };
    loop {
        // Gauges republish only when the tracked set changes — per-token
        // growth between changes is not worth hot-loop recomputation.
        let mut membership_changed = false;
        // Admit as many pending sequences as there are free slots.
        let mut rest = Vec::new();
        for job in pending.drain(..) {
            if tracks.contains_key(&job.id) {
                // Duplicate id: a direct handoff whose ack was presumed
                // lost can be re-admitted by the relay fallback while
                // the original is still generating. The engine already
                // serves it — drop the duplicate silently (one token
                // stream, one terminal).
                log::warn!("decode unit {label}: dropping duplicate admit for {}", job.id);
                continue;
            }
            if engine.free_slots() == 0 {
                rest.push(job);
                continue;
            }
            if let Err(e) = engine.admit(&job.outcome, job.max_new, job.id) {
                log::error!("decode unit {label}: admit failed: {e:#}");
                sink.rejected(job.id);
                continue;
            }
            // Timeline instant: the sequence reached a decode engine —
            // one hook covers the local, relay and direct-handoff paths.
            sink.trace(job.id, Mark::DecodeAdmit);
            let AdmitJob {
                id,
                outcome,
                resume,
                metrics,
                ..
            } = job;
            // A migrated sequence resumes with its full emission history
            // so token indices continue exactly where the source unit
            // stopped; a fresh sequence starts from the prefill's first
            // token.
            let tokens = if resume.is_empty() {
                vec![outcome.first_token]
            } else {
                resume
            };
            tracks.insert(
                id,
                Track {
                    tokens,
                    kv_len: outcome.len as u32,
                    k: outcome.k,
                    v: outcome.v,
                    metrics,
                },
            );
            membership_changed = true;
        }
        pending = rest;

        // Pull new messages (non-blocking while active, blocking idle).
        // A disconnected channel means the owner is gone — treat it as
        // Stop so the thread cannot spin forever.
        loop {
            let msg = if engine.active() > 0 || stopping {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        stopping = true;
                        break;
                    }
                }
            } else {
                match rx.recv_timeout(Duration::from_millis(100)) {
                    Ok(m) => m,
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        stopping = true;
                        break;
                    }
                }
            };
            match msg {
                UnitMsg::Stop => stopping = true,
                UnitMsg::Admit(job) => pending.push(job),
                UnitMsg::Abort { ack } => {
                    // A new owner superseded whoever admitted these
                    // sequences: drop them *silently* (the old scheduler
                    // already evicted them) and free their engine slots
                    // right away — stale ids must not keep generating,
                    // or they could collide with the new owner's ids.
                    if !tracks.is_empty() || !pending.is_empty() {
                        log::info!(
                            "decode unit {label}: aborting {} tracked + {} pending sequences",
                            tracks.len(),
                            pending.len()
                        );
                    }
                    engine.abort_all();
                    tracks.clear();
                    pending.clear();
                    membership_changed = true;
                    let _ = ack.send(());
                }
                UnitMsg::Extract { id } => {
                    // Rescue extraction: release the engine slot and hand
                    // the live state (emission history + prompt KV) back
                    // to the owner. After this point the unit emits
                    // nothing further for `id`, so the extraction event —
                    // delivered through the same FIFO sink as tokens —
                    // is strictly ordered after every token it covers.
                    let extracted = match engine.release(id) {
                        Some(remaining) => tracks.remove(&id).map(|tr| {
                            membership_changed = true;
                            ExtractedSeq {
                                tokens: tr.tokens,
                                remaining,
                                kv_len: tr.kv_len,
                                k: tr.k,
                                v: tr.v,
                                metrics: tr.metrics,
                            }
                        }),
                        None => None,
                    };
                    sink.extracted(id, extracted);
                }
            }
        }
        if membership_changed {
            publish_gauges(&tracks, engine.active());
        }

        if engine.active() == 0 {
            if stopping && pending.is_empty() {
                break;
            }
            continue;
        }
        match engine.step() {
            Ok((emissions, _t)) => {
                let now = now_fn();
                let mut finished = false;
                for e in emissions {
                    if let Some(tr) = tracks.get_mut(&e.request_id) {
                        tr.tokens.push(e.token);
                        sink.token(e.request_id, (tr.tokens.len() - 1) as u32, e.token, now);
                        if e.done {
                            let mut tr = tracks.remove(&e.request_id).unwrap();
                            tr.metrics.t_done = now;
                            tr.metrics.output_tokens = tr.tokens.len() as u32;
                            sink.done(e.request_id, tr.tokens, tr.metrics);
                            finished = true;
                        }
                    }
                }
                if finished {
                    publish_gauges(&tracks, engine.active());
                }
            }
            Err(e) => {
                log::error!("decode unit {label}: step failed: {e:#}");
                // Terminalize everything this unit owns so streaming
                // clients, the ledger and the pool accounting drain
                // instead of hanging.
                for id in tracks.keys().copied().collect::<Vec<_>>() {
                    sink.rejected(id);
                }
                tracks.clear();
                for job in pending.drain(..) {
                    sink.rejected(job.id);
                }
                publish_gauges(&tracks, 0);
                failed = true;
                break;
            }
        }
    }
    if failed {
        // The engine is dead but the owner may still place onto this
        // unit: keep rejecting (and releasing the ledger) until told to
        // stop so later jobs terminate too.
        while let Ok(msg) = rx.recv() {
            match msg {
                UnitMsg::Admit(job) => sink.rejected(job.id),
                UnitMsg::Abort { ack } => {
                    let _ = ack.send(());
                }
                UnitMsg::Extract { id } => sink.extracted(id, None),
                UnitMsg::Stop => break,
            }
        }
    }
}
