//! Threaded *real* mini-cluster: the same SBS control plane driving
//! actual PJRT forward passes (no simulation on this path).
//!
//! Topology: `n_prefill` prefill workers (one gated engine thread each —
//! DP=1 per instance; sub-instance DP balancing is exercised at scale in
//! the DES) and one batched decode worker. The scheduler thread runs the
//! identical [`StaggeredScheduler`] state machine the simulator uses,
//! receiving real `EndForward` signals over channels and arming real
//! timers via `recv_timeout` — the end-to-end proof that L3, L2 and L1
//! compose.

use crate::engine::sampler::Sampling;
use crate::engine::{MiniEngine, PrefillOutcome};
use crate::metrics::{RequestMetrics, ServingReport};
use crate::runtime::Runtime;
use std::path::PathBuf;
use crate::scheduler::baseline::{ImmediatePolicy, ImmediateScheduler};
use crate::scheduler::staggered::{
    SchedulerAction, SchedulerEvent, StaggeredConfig, StaggeredScheduler,
};
use crate::scheduler::types::Request;
use crate::util::{Clock, RealClock};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Control-plane choice for the real cluster.
#[derive(Debug, Clone)]
pub enum RealSchedMode {
    /// Staggered batch scheduling (the paper).
    Staggered(StaggeredConfig),
    /// Immediate dispatch baseline.
    Immediate(ImmediatePolicy),
}

/// Real-cluster configuration.
#[derive(Debug, Clone)]
pub struct RealClusterConfig {
    /// Prefill instances (one engine thread each).
    pub n_prefill: u32,
    /// Decode batch size (one decode engine; must be a compiled variant).
    pub decode_batch: u32,
    /// Scheduler-visible per-instance token budget per dispatch cycle.
    pub c_chunk: u32,
    /// Control plane.
    pub mode: RealSchedMode,
    /// Sampling policy for generation.
    pub sampling: Sampling,
    /// RNG seed.
    pub seed: u64,
    /// Artifact directory (each worker thread loads its own PJRT client —
    /// the xla crate's handles are not Send, mirroring the
    /// process-per-instance deployment model).
    pub artifacts: PathBuf,
}

impl Default for RealClusterConfig {
    fn default() -> Self {
        // Real CPU-PJRT passes take ~0.5–2 s; seed the interval
        // controller accordingly so the watchdog doesn't misfire during
        // the first pass, and scale N_limit to real pass cadence (cycles
        // here are seconds, not the simulator's ~100 ms).
        let mut sc = StaggeredConfig::default();
        sc.interval.t_default = 1.5;
        sc.pbaa.n_limit = 10_000;
        RealClusterConfig {
            n_prefill: 2,
            decode_batch: 4,
            c_chunk: 256,
            mode: RealSchedMode::Staggered(sc),
            sampling: Sampling::Greedy,
            seed: 7,
            artifacts: PathBuf::from("artifacts"),
        }
    }
}

/// One submitted generation job.
pub struct Job {
    /// Unique id.
    pub id: u64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Max tokens to generate.
    pub max_new: u32,
}

/// Completed generation.
#[derive(Debug, Clone)]
pub struct Completion {
    /// Job id.
    pub id: u64,
    /// Generated token ids (first token included).
    pub tokens: Vec<i32>,
    /// Lifecycle metrics (timestamps on the real clock).
    pub metrics: RequestMetrics,
}

enum SchedMsg {
    Submit(Job, f64),
    EndForward { instance: u32, t_measured: f64 },
    Drain,
}

enum PrefillMsg {
    Work(Vec<(Job, f64)>),
    Stop,
}

enum DecodeMsg {
    Admit {
        id: u64,
        outcome: Box<PrefillOutcome>,
        max_new: u32,
        metrics: RequestMetrics,
    },
    Stop,
}

/// The running cluster: submit jobs, then `finish()` to collect results.
pub struct RealCluster {
    to_sched: Sender<SchedMsg>,
    completions: Receiver<Completion>,
    threads: Vec<JoinHandle<()>>,
    clock: Arc<RealClock>,
    submitted: u64,
    collected: Vec<Completion>,
}

impl RealCluster {
    /// Start scheduler + worker threads; each engine thread loads its own
    /// runtime from `cfg.artifacts`.
    pub fn start(cfg: RealClusterConfig) -> Result<RealCluster> {
        let clock = Arc::new(RealClock::new());
        let (to_sched, sched_rx) = channel::<SchedMsg>();
        let (done_tx, completions) = channel::<Completion>();

        let (decode_tx, decode_rx) = channel::<DecodeMsg>();
        let (ready_tx, ready_rx) = channel::<()>();
        let mut threads = Vec::new();
        {
            let clock = clock.clone();
            let done_tx = done_tx.clone();
            let (sampling, batch, seed) = (cfg.sampling, cfg.decode_batch, cfg.seed);
            let dir = cfg.artifacts.clone();
            let ready = ready_tx.clone();
            threads.push(std::thread::spawn(move || {
                decode_worker(dir, batch, sampling, seed, decode_rx, done_tx, clock, ready);
            }));
        }

        let mut prefill_txs = Vec::new();
        for i in 0..cfg.n_prefill {
            let (tx, rx) = channel::<PrefillMsg>();
            prefill_txs.push(tx);
            let clock = clock.clone();
            let to_sched = to_sched.clone();
            let decode_tx = decode_tx.clone();
            let done_tx = done_tx.clone();
            let dir = cfg.artifacts.clone();
            let ready = ready_tx.clone();
            threads.push(std::thread::spawn(move || {
                prefill_worker(i, dir, rx, to_sched, decode_tx, done_tx, clock, ready);
            }));
        }

        // Block until every engine thread has loaded its runtime: jobs
        // submitted before readiness would charge artifact compilation to
        // TTFT.
        for _ in 0..(cfg.n_prefill + 1) {
            ready_rx
                .recv_timeout(Duration::from_secs(600))
                .map_err(|_| anyhow!("worker failed to become ready (artifacts built?)"))?;
        }
        log::info!("all workers ready");

        {
            let cfg2 = cfg.clone();
            let clock = clock.clone();
            let done_tx = done_tx.clone();
            threads.push(std::thread::spawn(move || {
                scheduler_loop(cfg2, sched_rx, prefill_txs, decode_tx, done_tx, clock);
            }));
        }
        Ok(RealCluster {
            to_sched,
            completions,
            threads,
            clock,
            submitted: 0,
            collected: Vec::new(),
        })
    }

    /// Submit one generation job (arrival timestamped now).
    pub fn submit(&mut self, job: Job) {
        self.submitted += 1;
        let _ = self.to_sched.send(SchedMsg::Submit(job, self.clock.now_s()));
    }

    /// Block until the completion for `id` arrives (other completions are
    /// stashed for `finish`). Used by the synchronous TCP frontend.
    pub fn wait_for(&mut self, id: u64, timeout: Duration) -> Result<Completion> {
        if let Some(i) = self.collected.iter().position(|c| c.id == id) {
            return Ok(self.collected.swap_remove(i));
        }
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let left = deadline
                .checked_duration_since(std::time::Instant::now())
                .ok_or_else(|| anyhow!("timed out waiting for job {id}"))?;
            let c = self
                .completions
                .recv_timeout(left)
                .map_err(|_| anyhow!("timed out waiting for job {id}"))?;
            if c.id == id {
                return Ok(c);
            }
            self.collected.push(c);
        }
    }

    /// Wait for all submitted jobs, stop the cluster, and return the
    /// completions plus an aggregate report.
    pub fn finish(mut self) -> Result<(Vec<Completion>, ServingReport)> {
        let mut out = std::mem::take(&mut self.collected);
        while (out.len() as u64) < self.submitted {
            let c = self
                .completions
                .recv_timeout(Duration::from_secs(600))
                .map_err(|_| anyhow!("timed out waiting for completions"))?;
            out.push(c);
        }
        let _ = self.to_sched.send(SchedMsg::Drain);
        for t in self.threads {
            let _ = t.join();
        }
        let mut report = ServingReport::new(0.0);
        for c in &out {
            report.absorb(&c.metrics);
        }
        Ok((out, report))
    }
}

/// Scheduler thread: the SBS (or baseline) state machine on real time.
fn scheduler_loop(
    cfg: RealClusterConfig,
    rx: Receiver<SchedMsg>,
    prefill_txs: Vec<Sender<PrefillMsg>>,
    decode_tx: Sender<DecodeMsg>,
    done_tx: Sender<Completion>,
    clock: Arc<RealClock>,
) {
    let n = cfg.n_prefill;
    // Job payloads keyed by request id (the scheduler works on Requests).
    let mut jobs: HashMap<u64, (Job, f64)> = HashMap::new();
    let mut sbs = match &cfg.mode {
        RealSchedMode::Staggered(sc) => {
            // Real-mode clamps: dispatch cycles here are seconds (PJRT
            // passes), not the simulator's ~100 ms, so simulator-scale
            // flow-control/watchdog defaults would misfire.
            let mut sc = sc.clone();
            sc.pbaa.n_limit = sc.pbaa.n_limit.max(10_000);
            sc.interval.t_default = sc.interval.t_default.max(1.0);
            Some(StaggeredScheduler::new(sc, n, 1, cfg.c_chunk))
        }
        RealSchedMode::Immediate(_) => None,
    };
    let mut imm = match &cfg.mode {
        RealSchedMode::Immediate(p) => Some(ImmediateScheduler::new(*p, n, 1, cfg.c_chunk)),
        RealSchedMode::Staggered(_) => None,
    };
    let mut next_timer: Option<f64> = None;
    let mut stop = false;
    while !stop {
        let now = clock.now_s();
        let timeout = next_timer
            .map(|t| Duration::from_secs_f64((t - now).max(1e-4)))
            .unwrap_or(Duration::from_millis(50));
        let msg = rx.recv_timeout(timeout);
        let now = clock.now_s();
        let mut actions = Vec::new();
        match msg {
            Ok(SchedMsg::Submit(job, t_arrive)) => {
                let req = Request::new(job.id, job.prompt.len() as u32, job.max_new, t_arrive);
                jobs.insert(job.id, (job, t_arrive));
                if let Some(s) = sbs.as_mut() {
                    actions = s.on_event(SchedulerEvent::Arrival { request: req, now });
                } else if let Some(im) = imm.as_mut() {
                    let a = im.dispatch(req);
                    if let Some(jt) = jobs.remove(&a.request.id) {
                        let _ = prefill_txs[a.unit.instance as usize]
                            .send(PrefillMsg::Work(vec![jt]));
                    }
                }
            }
            Ok(SchedMsg::EndForward {
                instance,
                t_measured,
            }) => {
                if let Some(s) = sbs.as_mut() {
                    // The engine fully consumed its dispatched batch
                    // before signalling: clear the capacity model (the
                    // simulator gets this via per-pass on_ack/on_consumed;
                    // the real engine reports completion wholesale).
                    for dp in s.state.instance_dps_mut(instance) {
                        let backlog = dp.u_flight + dp.r_queued;
                        dp.on_ack(dp.u_flight);
                        dp.on_consumed(backlog);
                    }
                    actions = s.on_event(SchedulerEvent::EndForward {
                        instance,
                        t_measured,
                        remaining: Some(0),
                        now,
                    });
                } else if let Some(im) = imm.as_mut() {
                    im.on_end_forward(instance, now);
                }
            }
            Ok(SchedMsg::Drain) => stop = true,
            Err(_) => {
                next_timer = None;
                if let Some(s) = sbs.as_mut() {
                    actions = s.on_event(SchedulerEvent::Timer { now });
                }
            }
        }
        for act in actions {
            match act {
                SchedulerAction::Dispatch(batch) => {
                    let work: Vec<(Job, f64)> = batch
                        .assignments
                        .iter()
                        .filter_map(|a| jobs.remove(&a.request.id))
                        .collect();
                    if !work.is_empty() {
                        let _ =
                            prefill_txs[batch.instance as usize].send(PrefillMsg::Work(work));
                    }
                }
                SchedulerAction::ArmTimer { at } => {
                    next_timer = Some(match next_timer {
                        Some(t) => t.min(at),
                        None => at,
                    });
                }
                SchedulerAction::Reject(r) => {
                    // Surface the rejection as an (empty) completion so
                    // callers waiting on this job don't hang.
                    log::warn!("flow control rejected request {}", r.id);
                    jobs.remove(&r.id);
                    let _ = done_tx.send(Completion {
                        id: r.id,
                        tokens: Vec::new(),
                        metrics: RequestMetrics::arrive(r.arrival, r.input_tokens),
                    });
                }
                SchedulerAction::Watchdog(w) => log::warn!("watchdog: {w:?}"),
            }
        }
    }
    for tx in &prefill_txs {
        let _ = tx.send(PrefillMsg::Stop);
    }
    let _ = decode_tx.send(DecodeMsg::Stop);
}

/// Prefill worker: gated, non-preemptive chunked prefill of each batch.
fn prefill_worker(
    instance: u32,
    dir: PathBuf,
    rx: Receiver<PrefillMsg>,
    to_sched: Sender<SchedMsg>,
    decode_tx: Sender<DecodeMsg>,
    done_tx: Sender<Completion>,
    clock: Arc<RealClock>,
    ready: Sender<()>,
) {
    let engine = match Runtime::load_filtered(&dir, Some(&["prefill", "decode"]))
        .map(Arc::new)
        .and_then(|rt| {
            let b = rt.decode_batches()[0];
            MiniEngine::new(rt, b, Sampling::Greedy, 1)
        }) {
        Ok(e) => e,
        Err(e) => {
            log::error!("prefill worker {instance}: {e:#}");
            return;
        }
    };
    let _ = ready.send(());
    while let Ok(PrefillMsg::Work(batch)) = rx.recv() {
        for (job, t_arrive) in batch {
            let t_dispatch = clock.now_s();
            match engine.prefill(&job.prompt) {
                Ok(outcome) => {
                    let t_first = clock.now_s();
                    let mut m = RequestMetrics::arrive(t_arrive, job.prompt.len() as u32);
                    m.t_dispatch = t_dispatch;
                    m.t_exec_start = t_dispatch;
                    m.t_first_token = t_first;
                    let exec = outcome.exec_time;
                    if job.max_new <= 1 {
                        m.t_done = t_first;
                        m.output_tokens = 1;
                        let _ = done_tx.send(Completion {
                            id: job.id,
                            tokens: vec![outcome.first_token],
                            metrics: m,
                        });
                    } else {
                        let _ = decode_tx.send(DecodeMsg::Admit {
                            id: job.id,
                            outcome: Box::new(outcome),
                            max_new: job.max_new - 1,
                            metrics: m,
                        });
                    }
                    let _ = to_sched.send(SchedMsg::EndForward {
                        instance,
                        t_measured: exec,
                    });
                }
                Err(e) => log::error!("prefill failed for job {}: {e:#}", job.id),
            }
        }
    }
}

/// Decode worker: continuous batched stepping with slot admission.
fn decode_worker(
    dir: PathBuf,
    batch: u32,
    sampling: Sampling,
    seed: u64,
    rx: Receiver<DecodeMsg>,
    done_tx: Sender<Completion>,
    clock: Arc<RealClock>,
    ready: Sender<()>,
) {
    let mut engine = match Runtime::load_filtered(&dir, Some(&["decode"]))
        .map(Arc::new)
        .and_then(|rt| MiniEngine::new(rt, batch, sampling, seed))
    {
        Ok(e) => e,
        Err(e) => {
            log::error!("decode worker: {e:#}");
            return;
        }
    };
    let _ = ready.send(());
    struct Track {
        tokens: Vec<i32>,
        metrics: RequestMetrics,
    }
    let mut tracks: HashMap<u64, Track> = HashMap::new();
    let mut pending: Vec<DecodeMsg> = Vec::new();
    let mut stopping = false;
    loop {
        // Admit as many pending sequences as there are free slots.
        let mut rest = Vec::new();
        for msg in pending.drain(..) {
            match msg {
                DecodeMsg::Admit {
                    id,
                    outcome,
                    max_new,
                    metrics,
                } if engine.free_slots() > 0 => {
                    if let Err(e) = engine.admit(&outcome, max_new, id) {
                        log::error!("admit failed: {e:#}");
                        continue;
                    }
                    tracks.insert(
                        id,
                        Track {
                            tokens: vec![outcome.first_token],
                            metrics,
                        },
                    );
                }
                other => rest.push(other),
            }
        }
        pending = rest;

        // Pull new messages (non-blocking while active, blocking idle).
        loop {
            let msg = if engine.active() > 0 || stopping {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            } else {
                match rx.recv_timeout(Duration::from_millis(100)) {
                    Ok(m) => m,
                    Err(_) => break,
                }
            };
            match msg {
                DecodeMsg::Stop => stopping = true,
                m => pending.push(m),
            }
        }

        if engine.active() == 0 {
            if stopping && pending.is_empty() {
                break;
            }
            continue;
        }
        match engine.step() {
            Ok((emissions, _t)) => {
                let now = clock.now_s();
                for e in emissions {
                    if let Some(tr) = tracks.get_mut(&e.request_id) {
                        tr.tokens.push(e.token);
                        if e.done {
                            let mut tr = tracks.remove(&e.request_id).unwrap();
                            tr.metrics.t_done = now;
                            tr.metrics.output_tokens = tr.tokens.len() as u32;
                            let _ = done_tx.send(Completion {
                                id: e.request_id,
                                tokens: tr.tokens,
                                metrics: tr.metrics,
                            });
                        }
                    }
                }
            }
            Err(e) => {
                log::error!("decode step failed: {e:#}");
                break;
            }
        }
    }
}
