//! Simulated decode engine: synchronized autoregressive stepping across DP
//! units (§4.3).
//!
//! All DP units of a decode instance step together (EP all-to-all barrier);
//! step time is bound by the heaviest unit's batch size and KV residency
//! ([`DecodeCostModel`]). Sequences join at step boundaries (continuous
//! batching) and leave when their output budget is exhausted, freeing KV.

use super::costmodel::{DecodeCostModel, DpStepLoad};

/// An active decode sequence on a DP unit.
#[derive(Debug, Clone)]
pub struct ActiveSeq {
    /// Workload index of the request.
    pub req: usize,
    /// Output tokens still to generate.
    pub remaining: u32,
    /// Current KV length (grows by 1 per step).
    pub kv: u32,
}

/// Token emissions of one completed step.
#[derive(Debug, Clone)]
pub struct StepOutcome {
    /// `(req, finished)` per token emitted this step.
    pub emissions: Vec<(usize, bool)>,
    /// Tokens generated (= active sequences at step start).
    pub tokens: u32,
}

/// Hard per-DP-unit resource caps (batch slots and KV memory), matching
/// real engines' max-num-seqs and KV-block budgets.
#[derive(Debug, Clone, Copy)]
pub struct DecodeCaps {
    /// Max concurrent sequences per unit.
    pub b_max: u32,
    /// Max resident KV tokens per unit.
    pub kv_max: u64,
}

impl Default for DecodeCaps {
    fn default() -> Self {
        // Sized for the paper's decode workload: ~35–40 seqs × ~2.5K
        // tokens pins units near the KV budget (the §4.3.1 "memory
        // imbalance" regime). Admission checks resident KV at join time;
        // K then *grows* one token per seq per step, so an imbalanced
        // policy overshoots the budget on its heaviest units — exactly
        // the straggler dynamics Fig. 7 visualizes.
        // One number shared with the live pool's admission budget so the
        // DES and the serving path cannot drift.
        DecodeCaps {
            b_max: 64,
            kv_max: crate::config::LIVE_KV_BUDGET_TOKENS,
        }
    }
}

/// Simulated decode engine for one instance.
#[derive(Debug)]
pub struct DecodeEngine {
    units: Vec<Vec<ActiveSeq>>,
    stepping: bool,
    cost: DecodeCostModel,
    caps: DecodeCaps,
}

impl DecodeEngine {
    /// New engine with `n_dp` DP units.
    pub fn new(n_dp: u32, cost: DecodeCostModel) -> Self {
        Self::with_caps(n_dp, cost, DecodeCaps::default())
    }

    /// New engine with explicit resource caps.
    pub fn with_caps(n_dp: u32, cost: DecodeCostModel, caps: DecodeCaps) -> Self {
        DecodeEngine {
            units: (0..n_dp).map(|_| Vec::new()).collect(),
            stepping: false,
            cost,
            caps,
        }
    }

    /// Whether unit `dp` can admit a sequence of `kv` resident tokens
    /// without violating its batch/KV caps.
    pub fn can_accept(&self, dp: usize, kv: u32) -> bool {
        let u = &self.units[dp];
        u.len() < self.caps.b_max as usize
            && u.iter().map(|s| s.kv as u64).sum::<u64>() + kv as u64 <= self.caps.kv_max
    }

    /// Number of DP units.
    pub fn n_dp(&self) -> usize {
        self.units.len()
    }

    /// Whether a step is executing.
    pub fn stepping(&self) -> bool {
        self.stepping
    }

    /// Active sequences across all units.
    pub fn active(&self) -> usize {
        self.units.iter().map(Vec::len).sum()
    }

    /// Per-unit `(batch, kv_tokens)` snapshot — Fig. 7's observable.
    pub fn unit_loads(&self) -> Vec<DpStepLoad> {
        self.units
            .iter()
            .map(|u| DpStepLoad {
                batch: u.len() as u32,
                kv_tokens: u.iter().map(|s| s.kv as u64).sum(),
            })
            .collect()
    }

    /// A sequence joins unit `dp` with `kv` resident tokens (its prompt)
    /// and `remaining` output tokens to generate.
    pub fn join(&mut self, dp: usize, req: usize, kv: u32, remaining: u32) {
        self.units[dp].push(ActiveSeq { req, remaining, kv });
    }

    /// Extract a sequence mid-generation (rescue preemption/migration):
    /// remove it from unit `dp` and return its live state so the caller
    /// can re-park it with its progress intact. `None` if the request is
    /// not resident there. Extraction happens at step boundaries only
    /// (the DES driver acts between `finish_step` and the next
    /// `start_step`), matching the live engines' slot-release semantics.
    pub fn remove(&mut self, dp: usize, req: usize) -> Option<ActiveSeq> {
        let i = self.units[dp].iter().position(|s| s.req == req)?;
        Some(self.units[dp].remove(i))
    }

    /// Start a synchronized step; returns its duration if any sequence is
    /// active and the engine is idle.
    pub fn start_step(&mut self) -> Option<f64> {
        if self.stepping || self.active() == 0 {
            return None;
        }
        self.stepping = true;
        Some(self.cost.step_time(&self.unit_loads()))
    }

    /// Finish the in-flight step: every active sequence emits one token
    /// and grows its KV by one; exhausted sequences leave.
    pub fn finish_step(&mut self) -> StepOutcome {
        debug_assert!(self.stepping);
        self.stepping = false;
        let mut emissions = Vec::new();
        for unit in &mut self.units {
            for s in unit.iter_mut() {
                s.kv += 1;
                s.remaining -= 1;
                emissions.push((s.req, s.remaining == 0));
            }
            unit.retain(|s| s.remaining > 0);
        }
        StepOutcome {
            tokens: emissions.len() as u32,
            emissions,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(n: u32) -> DecodeEngine {
        DecodeEngine::new(n, DecodeCostModel::default())
    }

    #[test]
    fn no_step_when_empty() {
        let mut e = engine(2);
        assert!(e.start_step().is_none());
    }

    #[test]
    fn sequence_lifecycle() {
        let mut e = engine(1);
        e.join(0, 42, 100, 3);
        assert_eq!(e.active(), 1);
        for step in 0..3 {
            let d = e.start_step().unwrap();
            assert!(d > 0.0);
            assert!(e.start_step().is_none(), "locked mid-step");
            let out = e.finish_step();
            assert_eq!(out.tokens, 1);
            let (req, done) = out.emissions[0];
            assert_eq!(req, 42);
            assert_eq!(done, step == 2);
        }
        assert_eq!(e.active(), 0);
        assert!(e.start_step().is_none());
    }

    #[test]
    fn kv_grows_per_step() {
        let mut e = engine(1);
        e.join(0, 1, 100, 5);
        e.start_step().unwrap();
        e.finish_step();
        let loads = e.unit_loads();
        assert_eq!(loads[0].kv_tokens, 101);
    }

    #[test]
    fn step_time_bound_by_heaviest_unit() {
        let mut even = engine(2);
        even.join(0, 1, 50_000, 10);
        even.join(1, 2, 50_000, 10);
        let t_even = even.start_step().unwrap();

        let mut skew = engine(2);
        skew.join(0, 1, 100_000, 10);
        skew.join(0, 2, 0, 10);
        let t_skew = skew.start_step().unwrap();
        assert!(t_skew > t_even);
    }

    #[test]
    fn caps_limit_admission() {
        let caps = DecodeCaps {
            b_max: 2,
            kv_max: 1000,
        };
        let e2 = DecodeEngine::with_caps(1, DecodeCostModel::default(), caps);
        assert!(e2.can_accept(0, 900));
        assert!(!e2.can_accept(0, 1100)); // kv cap
        let mut e3 = DecodeEngine::with_caps(1, DecodeCostModel::default(), caps);
        e3.join(0, 1, 100, 5);
        e3.join(0, 2, 100, 5);
        assert!(!e3.can_accept(0, 10)); // batch cap
    }

    #[test]
    fn remove_extracts_live_state_and_frees_the_unit() {
        let mut e = engine(1);
        e.join(0, 7, 100, 5);
        e.start_step().unwrap();
        e.finish_step();
        let s = e.remove(0, 7).expect("resident");
        assert_eq!(s.kv, 101, "KV grew by the one step taken");
        assert_eq!(s.remaining, 4);
        assert_eq!(e.active(), 0);
        assert!(e.remove(0, 7).is_none(), "double extraction is safe");
    }

    #[test]
    fn joins_between_steps_take_effect() {
        let mut e = engine(2);
        e.join(0, 1, 10, 2);
        e.start_step().unwrap();
        e.finish_step();
        e.join(1, 2, 10, 2);
        e.start_step().unwrap();
        let out = e.finish_step();
        assert_eq!(out.tokens, 2);
    }
}
