//! Simulated prefill engine: a gated, non-preemptive, chunked batch
//! processor with per-DP device queues and a DP sync barrier (§3.2's
//! "Discrete Gated Service").
//!
//! Each DP unit owns a FIFO device queue of chunk work. A forward pass
//! takes up to `C_chunk` tokens from every DP queue simultaneously; its
//! duration is straggler-bound via [`PrefillCostModel`]. While a pass
//! runs the engine is locked — newly delivered work waits in the device
//! queue (the HOL blocking immediate dispatch suffers from).

use super::costmodel::{DpPassLoad, PrefillCostModel};
use std::collections::VecDeque;

/// One request's prefill work as queued on a DP unit.
#[derive(Debug, Clone)]
pub struct ChunkWork {
    /// Workload index of the request.
    pub req: usize,
    /// Prefill tokens still to process (cached prefix already excluded).
    pub remaining: u32,
    /// Tokens already processed (attention context accumulated so far,
    /// including any cached prefix).
    pub processed: u32,
    /// Whether any pass has taken tokens from this work yet.
    pub started: bool,
}

/// One (request, tokens) slice executed in a pass on a DP unit.
#[derive(Debug, Clone)]
pub struct PassItem {
    /// DP rank within the instance.
    pub dp: usize,
    /// Workload index of the request.
    pub req: usize,
    /// Tokens of this request processed in this pass.
    pub tokens: u32,
    /// True if this is the first pass containing tokens of the request
    /// (ends its device-side queueing).
    pub first_chunk: bool,
    /// True if the request's prefill completes in this pass (first token
    /// is produced at pass end).
    pub finishes: bool,
}

/// Statistics and contents of one forward pass.
#[derive(Debug, Clone)]
pub struct PassRecord {
    /// Work slices executed.
    pub items: Vec<PassItem>,
    /// Pass duration from the cost model.
    pub duration: f64,
    /// Tokens actually processed.
    pub used_tokens: u32,
    /// Theoretical capacity (`C_chunk × n_dp`) — for chunk utilization.
    pub capacity: u32,
    /// DP-seconds wasted at the sync barrier (straggler bubbles).
    pub straggler_waste: f64,
}

/// The simulated prefill engine for one instance.
#[derive(Debug)]
pub struct PrefillEngine {
    /// Per-DP device queues.
    queues: Vec<VecDeque<ChunkWork>>,
    /// Max tokens per DP per pass.
    c_chunk: u32,
    /// Whether a pass is currently executing (engine locked).
    busy: bool,
    cost: PrefillCostModel,
}

impl PrefillEngine {
    /// New idle engine with `n_dp` DP units.
    pub fn new(n_dp: u32, c_chunk: u32, cost: PrefillCostModel) -> Self {
        PrefillEngine {
            queues: (0..n_dp).map(|_| VecDeque::new()).collect(),
            c_chunk,
            busy: false,
            cost,
        }
    }

    /// Number of DP units.
    pub fn n_dp(&self) -> usize {
        self.queues.len()
    }

    /// Whether the engine is mid-pass.
    pub fn busy(&self) -> bool {
        self.busy
    }

    /// Total tokens waiting in device queues.
    pub fn backlog_tokens(&self) -> u32 {
        self.queues
            .iter()
            .flat_map(|q| q.iter())
            .map(|w| w.remaining)
            .sum()
    }

    /// Tokens waiting on one DP unit.
    pub fn dp_backlog(&self, dp: usize) -> u32 {
        self.queues[dp].iter().map(|w| w.remaining).sum()
    }

    /// Deliver work to a DP unit's device queue. `effective_tokens` is the
    /// prefill still to compute (prefix-cache hits excluded);
    /// `already_cached` seeds the attention context.
    pub fn enqueue(&mut self, dp: usize, req: usize, effective_tokens: u32, already_cached: u32) {
        self.queues[dp].push_back(ChunkWork {
            req,
            remaining: effective_tokens,
            processed: already_cached,
            started: false,
        });
    }

    /// Attempt to start a forward pass at `now`. Returns the pass record
    /// (with `duration`) if the engine was idle and had work; the caller
    /// schedules completion at `now + duration` and then calls
    /// [`Self::finish_pass`].
    pub fn start_pass(&mut self) -> Option<PassRecord> {
        if self.busy {
            return None;
        }
        let mut items = Vec::new();
        let mut loads = vec![DpPassLoad::default(); self.queues.len()];
        let mut used = 0u32;
        for (dp, queue) in self.queues.iter_mut().enumerate() {
            let mut budget = self.c_chunk;
            let mut ctx_weighted = 0.0f64;
            let mut taken = 0u32;
            while budget > 0 {
                let Some(front) = queue.front_mut() else { break };
                let take = front.remaining.min(budget);
                let is_first = !front.started;
                front.started = true;
                // Mean attention context of these tokens: processed so far
                // plus half the slice (causal attention grows linearly).
                let mean_ctx = front.processed as f64 + take as f64 / 2.0;
                ctx_weighted += mean_ctx * take as f64;
                front.remaining -= take;
                front.processed += take;
                let finishes = front.remaining == 0;
                items.push(PassItem {
                    dp,
                    req: front.req,
                    tokens: take,
                    first_chunk: is_first,
                    finishes,
                });
                budget -= take;
                taken += take;
                if finishes {
                    queue.pop_front();
                } else {
                    break; // chunk budget exhausted mid-request
                }
            }
            if taken > 0 {
                loads[dp] = DpPassLoad {
                    tokens: taken,
                    mean_ctx: ctx_weighted / taken as f64,
                };
                used += taken;
            }
        }
        if used == 0 {
            return None;
        }
        self.busy = true;
        Some(PassRecord {
            duration: self.cost.pass_time(&loads),
            straggler_waste: self.cost.straggler_waste(&loads),
            used_tokens: used,
            capacity: self.c_chunk * self.queues.len() as u32,
            items,
        })
    }

    /// Mark the in-flight pass complete (engine unlocks).
    pub fn finish_pass(&mut self) {
        debug_assert!(self.busy);
        self.busy = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(n_dp: u32, chunk: u32) -> PrefillEngine {
        PrefillEngine::new(n_dp, chunk, PrefillCostModel::default())
    }

    #[test]
    fn idle_engine_with_no_work_does_not_start() {
        let mut e = engine(2, 1000);
        assert!(e.start_pass().is_none());
    }

    #[test]
    fn single_request_single_pass() {
        let mut e = engine(1, 1000);
        e.enqueue(0, 7, 600, 0);
        let p = e.start_pass().unwrap();
        assert_eq!(p.used_tokens, 600);
        assert_eq!(p.items.len(), 1);
        assert!(p.items[0].finishes);
        assert_eq!(p.capacity, 1000);
        assert!(e.busy());
        assert!(e.start_pass().is_none(), "locked while busy");
        e.finish_pass();
        assert!(!e.busy());
        assert_eq!(e.backlog_tokens(), 0);
    }

    #[test]
    fn long_request_spans_passes() {
        let mut e = engine(1, 1000);
        e.enqueue(0, 1, 2500, 0);
        let p1 = e.start_pass().unwrap();
        assert_eq!(p1.used_tokens, 1000);
        assert!(!p1.items[0].finishes);
        e.finish_pass();
        let p2 = e.start_pass().unwrap();
        assert_eq!(p2.used_tokens, 1000);
        e.finish_pass();
        let p3 = e.start_pass().unwrap();
        assert_eq!(p3.used_tokens, 500);
        assert!(p3.items[0].finishes);
        e.finish_pass();
        assert!(e.start_pass().is_none());
    }

    #[test]
    fn multiple_requests_pack_into_chunk() {
        let mut e = engine(1, 1000);
        e.enqueue(0, 1, 400, 0);
        e.enqueue(0, 2, 300, 0);
        e.enqueue(0, 3, 600, 0);
        let p = e.start_pass().unwrap();
        assert_eq!(p.used_tokens, 1000); // 400 + 300 + 300 (partial)
        assert_eq!(p.items.len(), 3);
        assert!(p.items[0].finishes && p.items[1].finishes);
        assert!(!p.items[2].finishes);
        e.finish_pass();
        let p2 = e.start_pass().unwrap();
        assert_eq!(p2.used_tokens, 300);
        assert!(p2.items[0].finishes);
    }

    #[test]
    fn straggler_bound_duration() {
        let mut balanced = engine(2, 2000);
        balanced.enqueue(0, 1, 1000, 0);
        balanced.enqueue(1, 2, 1000, 0);
        let pb = balanced.start_pass().unwrap();

        let mut skewed = engine(2, 2000);
        skewed.enqueue(0, 1, 1000, 0);
        skewed.enqueue(0, 2, 1000, 0);
        let ps = skewed.start_pass().unwrap();

        assert_eq!(pb.used_tokens, ps.used_tokens);
        assert!(ps.duration > pb.duration, "{} vs {}", ps.duration, pb.duration);
        assert!(ps.straggler_waste > pb.straggler_waste);
    }

    #[test]
    fn cached_prefix_seeds_context() {
        // Same compute tokens, but the cached variant attends over more
        // context — slightly longer pass.
        let mut cold = engine(1, 4000);
        cold.enqueue(0, 1, 1000, 0);
        let pc = cold.start_pass().unwrap();
        let mut warm = engine(1, 4000);
        warm.enqueue(0, 1, 1000, 2000);
        let pw = warm.start_pass().unwrap();
        assert!(pw.duration > pc.duration);
        assert_eq!(pw.used_tokens, pc.used_tokens);
    }

    #[test]
    fn utilization_reflects_imbalance() {
        let mut e = engine(4, 1000);
        e.enqueue(0, 1, 1000, 0); // only DP0 has work
        let p = e.start_pass().unwrap();
        assert_eq!(p.used_tokens, 1000);
        assert_eq!(p.capacity, 4000);
        // 25% chunk utilization — the Table 1 effect.
    }
}
