//! Analytic execution-time model for simulated DP+EP engines.
//!
//! The paper's effects hinge on two structural properties (§3.2):
//!
//! 1. **Gated batch service** — a forward pass is non-preemptive.
//! 2. **Straggler-bounded latency** — under the DP sync barrier the pass
//!    time is dominated by the *heaviest* DP unit plus synchronization
//!    overhead, and is otherwise largely batch-size-insensitive.
//!
//! We model a prefill pass as
//! `T = t_sync + max_d (s_token · n_d + s_attn · n_d · c̄_d / 1024)`
//! where `n_d` is the tokens DP unit `d` processes this pass and `c̄_d` the
//! mean attention context of those tokens, and a decode step as
//! `T = t_sync + s_batch · max_d B_d + s_kv · max_d K_d / 1024`
//! (memory-bound: KV reads dominate).
//!
//! Default constants are calibrated so a full 3K-token chunk pass lands
//! around 0.3–0.4 s and a 35-deep decode step around 50 ms — the scale the
//! paper's H800/DeepSeek-V3 numbers imply (TTFT SLO 0.8 s at mean input
//! 1K). `calibrate_*` constructors rescale from measured PJRT timings of
//! the real nano-MoE engine so the threaded real mode and the simulator
//! agree.

/// Per-DP prefill workload for one forward pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct DpPassLoad {
    /// Tokens this DP unit processes in the pass.
    pub tokens: u32,
    /// Mean attention context length of those tokens.
    pub mean_ctx: f64,
}

/// Cost model for prefill instances.
#[derive(Debug, Clone)]
pub struct PrefillCostModel {
    /// Fixed synchronization / all-to-all overhead per pass (s).
    pub t_sync: f64,
    /// Seconds per prefill token (dense + expert FLOPs).
    pub s_token: f64,
    /// Seconds per token per 1024 tokens of attention context.
    pub s_attn: f64,
}

impl Default for PrefillCostModel {
    fn default() -> Self {
        // Full 3072-token chunk at ~1K mean context:
        // 0.03 + 3072·1.0e-4 + 3072·(1.0/1.024)·1.2e-5 ≈ 0.375 s.
        PrefillCostModel {
            t_sync: 0.03,
            s_token: 1.0e-4,
            s_attn: 1.2e-5,
        }
    }
}

impl PrefillCostModel {
    /// Time of one pass given every DP unit's load (empty slice: no pass).
    pub fn pass_time(&self, loads: &[DpPassLoad]) -> f64 {
        let worst = loads
            .iter()
            .map(|l| {
                self.s_token * l.tokens as f64
                    + self.s_attn * l.tokens as f64 * l.mean_ctx / 1024.0
            })
            .fold(0.0_f64, f64::max);
        self.t_sync + worst
    }

    /// The straggler waste of a pass: total DP-seconds idled at the
    /// barrier, `Σ_d (T_worst − T_d)` (the "Waste" of paper Fig. 3).
    pub fn straggler_waste(&self, loads: &[DpPassLoad]) -> f64 {
        let per: Vec<f64> = loads
            .iter()
            .map(|l| {
                self.s_token * l.tokens as f64
                    + self.s_attn * l.tokens as f64 * l.mean_ctx / 1024.0
            })
            .collect();
        let worst = per.iter().copied().fold(0.0_f64, f64::max);
        per.iter().map(|t| worst - t).sum()
    }

    /// Rescale so that a full chunk of `c_chunk` tokens at `ctx` mean
    /// context takes `measured_s` seconds (calibration from real PJRT
    /// timings; keeps the t_sync/compute split).
    pub fn calibrated(c_chunk: u32, ctx: f64, measured_s: f64) -> Self {
        let base = PrefillCostModel::default();
        let model_full = base.pass_time(&[DpPassLoad {
            tokens: c_chunk,
            mean_ctx: ctx,
        }]);
        let k = measured_s / model_full;
        PrefillCostModel {
            t_sync: base.t_sync * k,
            s_token: base.s_token * k,
            s_attn: base.s_attn * k,
        }
    }
}

/// Per-DP decode state snapshot for one step.
#[derive(Debug, Clone, Copy, Default)]
pub struct DpStepLoad {
    /// Active batch size on this unit.
    pub batch: u32,
    /// Resident KV tokens on this unit.
    pub kv_tokens: u64,
}

/// Cost model for decode instances.
#[derive(Debug, Clone)]
pub struct DecodeCostModel {
    /// Fixed synchronization / all-to-all overhead per step (s).
    pub t_sync: f64,
    /// Seconds per step per unit of max batch size (kernel launch, MoE
    /// dispatch width).
    pub s_batch: f64,
    /// Seconds per step per 1024 resident KV tokens on the heaviest unit
    /// (HBM bandwidth bound).
    pub s_kv: f64,
}

impl Default for DecodeCostModel {
    fn default() -> Self {
        // B=35, K≈87.5K (35 seqs × 2.5K tok):
        // 0.01 + 35·2e-4 + 85·3.5e-4 ≈ 0.047 s/step  (~21 tok/s/seq).
        DecodeCostModel {
            t_sync: 0.010,
            s_batch: 2.0e-4,
            s_kv: 3.5e-4,
        }
    }
}

impl DecodeCostModel {
    /// Time of one synchronized decode step across the instance.
    pub fn step_time(&self, loads: &[DpStepLoad]) -> f64 {
        let b_max = loads.iter().map(|l| l.batch).max().unwrap_or(0) as f64;
        let k_max = loads.iter().map(|l| l.kv_tokens).max().unwrap_or(0) as f64;
        self.t_sync + self.s_batch * b_max + self.s_kv * k_max / 1024.0
    }
}

/// P→D KV-cache transfer model: fixed RTT plus per-token wire time.
#[derive(Debug, Clone)]
pub struct KvTransferModel {
    /// Fixed per-transfer latency (s).
    pub t_fixed: f64,
    /// Seconds per 1024 tokens transferred.
    pub s_per_k: f64,
}

impl Default for KvTransferModel {
    fn default() -> Self {
        // NVLink/RDMA-class: ~5 ms + ~2 ms per 1K tokens.
        KvTransferModel {
            t_fixed: 0.005,
            s_per_k: 0.002,
        }
    }
}

impl KvTransferModel {
    /// Transfer latency for a sequence of `tokens` KV entries.
    pub fn transfer_time(&self, tokens: u32) -> f64 {
        self.t_fixed + self.s_per_k * tokens as f64 / 1024.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pass_time_is_straggler_bound() {
        let m = PrefillCostModel::default();
        let balanced = m.pass_time(&[
            DpPassLoad { tokens: 1500, mean_ctx: 750.0 },
            DpPassLoad { tokens: 1500, mean_ctx: 750.0 },
        ]);
        let skewed = m.pass_time(&[
            DpPassLoad { tokens: 3000, mean_ctx: 1500.0 },
            DpPassLoad { tokens: 0, mean_ctx: 0.0 },
        ]);
        assert!(skewed > balanced, "{skewed} vs {balanced}");
        // Same total tokens, roughly double the time when fully skewed.
        assert!(skewed / balanced > 1.6);
    }

    #[test]
    fn batch_insensitive_within_one_dp() {
        // Two requests of 500 vs one of 1000 on a single DP: identical.
        let m = PrefillCostModel::default();
        let a = m.pass_time(&[DpPassLoad { tokens: 1000, mean_ctx: 500.0 }]);
        let b = m.pass_time(&[DpPassLoad { tokens: 1000, mean_ctx: 500.0 }]);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_pass_costs_sync_only() {
        let m = PrefillCostModel::default();
        assert_eq!(m.pass_time(&[]), m.t_sync);
    }

    #[test]
    fn full_chunk_in_plausible_range() {
        let m = PrefillCostModel::default();
        let t = m.pass_time(&[DpPassLoad { tokens: 3072, mean_ctx: 1000.0 }]);
        assert!((0.2..0.6).contains(&t), "full 3K chunk pass = {t}");
    }

    #[test]
    fn straggler_waste_zero_when_balanced() {
        let m = PrefillCostModel::default();
        let loads = [
            DpPassLoad { tokens: 1000, mean_ctx: 500.0 },
            DpPassLoad { tokens: 1000, mean_ctx: 500.0 },
        ];
        assert!(m.straggler_waste(&loads) < 1e-12);
        let skew = [
            DpPassLoad { tokens: 2000, mean_ctx: 500.0 },
            DpPassLoad { tokens: 0, mean_ctx: 0.0 },
        ];
        assert!(m.straggler_waste(&skew) > 0.1);
    }

    #[test]
    fn calibration_hits_target() {
        let m = PrefillCostModel::calibrated(3072, 1000.0, 0.5);
        let t = m.pass_time(&[DpPassLoad { tokens: 3072, mean_ctx: 1000.0 }]);
        assert!((t - 0.5).abs() < 1e-9);
    }

    #[test]
    fn decode_step_scales_with_worst_unit() {
        let m = DecodeCostModel::default();
        let even = m.step_time(&[
            DpStepLoad { batch: 30, kv_tokens: 80_000 },
            DpStepLoad { batch: 30, kv_tokens: 80_000 },
        ]);
        let skew = m.step_time(&[
            DpStepLoad { batch: 30, kv_tokens: 150_000 },
            DpStepLoad { batch: 30, kv_tokens: 10_000 },
        ]);
        assert!(skew > even);
    }

    #[test]
    fn decode_step_plausible() {
        let m = DecodeCostModel::default();
        let t = m.step_time(&[DpStepLoad { batch: 35, kv_tokens: 87_500 }]);
        assert!((0.02..0.1).contains(&t), "decode step = {t}");
    }

    #[test]
    fn kv_transfer_linear() {
        let m = KvTransferModel::default();
        let t1 = m.transfer_time(1024);
        let t2 = m.transfer_time(2048);
        assert!((t2 - t1 - 0.002).abs() < 1e-12);
    }
}
