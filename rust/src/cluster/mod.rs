//! The resource plane: simulated DP+EP engines (discrete-event) and the
//! threaded real-engine fabric.
//!
//! * [`costmodel`] — analytic execution-time models calibrated against the
//!   real PJRT engine (H800 substitute; see DESIGN.md §2).
//! * [`prefill`] / [`decode`] — gated batch engine models with DP sync
//!   barriers.
//! * [`dispatch`] — the transport-agnostic dispatch core: the shared
//!   scheduler-driving state machine (prefill dispatch + decode DP
//!   placement + per-DP ledger) that both drivers below execute.
//! * [`sim`] — the discrete-event driver reproducing the paper's cluster
//!   experiments.
//! * [`workers`] — threads running *actual* PJRT forward passes behind the
//!   same dispatch core, proving the control plane end-to-end.
//! * [`shard`] — the standalone decode shard process (`sbs worker`),
//!   serving decode DP units to a remote scheduler over the
//!   [`crate::transport`] wire protocol.

pub mod costmodel;
pub mod decode;
pub mod dispatch;
pub mod events;
pub mod prefill;
pub mod shard;
pub mod sim;
pub mod workers;
