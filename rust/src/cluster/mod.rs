//! The resource plane: simulated DP+EP engines (discrete-event) and the
//! threaded real-engine fabric.
//!
//! * [`costmodel`] — analytic execution-time models calibrated against the
//!   real PJRT engine (H800 substitute; see DESIGN.md §2).
//! * [`prefill`] / [`decode`] — gated batch engine models with DP sync
//!   barriers.
//! * [`sim`] — the discrete-event driver reproducing the paper's cluster
//!   experiments.
//! * [`workers`] — threads running *actual* PJRT forward passes behind the
//!   same scheduler, proving the control plane end-to-end.

pub mod costmodel;
pub mod decode;
pub mod events;
pub mod prefill;
pub mod sim;
pub mod workers;
