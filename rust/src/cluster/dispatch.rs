//! The transport-agnostic **dispatch core**: one scheduler-driving state
//! machine shared by every cluster driver.
//!
//! Before this module existed, the discrete-event simulator
//! ([`super::sim`]) and the threaded real-engine fabric
//! ([`super::workers`]) each carried their own copy of the same loop:
//! feed events into the [`StaggeredScheduler`] (or the immediate-dispatch
//! baseline), execute the returned actions, and keep a per-DP ledger for
//! decode placement. The copies had drifted — the live path ran exactly
//! one decode worker, so the paper's Load-Aware Global Allocation
//! (Algorithm 3) was dead code outside the simulator.
//!
//! [`DispatchCore`] is that loop, extracted. A *driver* owns the
//! transport (virtual event queue or real channels/threads) and the
//! engines; the core owns every scheduling decision:
//!
//! * **Prefill plane** — arrivals, `EndForward` feedback and timer ticks
//!   go through [`DispatchCore::on_arrival`] /
//!   [`DispatchCore::on_end_forward`] / [`DispatchCore::on_timer`], which
//!   return [`SchedulerAction`]s for the driver to execute. Engines that
//!   report their remaining backlog (the DES) pass
//!   [`EndForwardBacklog::Remaining`]; engines that consume each dispatch
//!   wholesale before signalling (the live workers) pass
//!   [`EndForwardBacklog::ConsumedAll`] and the core clears the capacity
//!   model itself.
//! * **Decode plane** — prefill completions become [`DecodeJoin`]s placed
//!   onto the pooled decode DP units by [`DispatchCore::place_decode`]
//!   under the configured [`DecodePolicy`] (Algorithm 3's IQR +
//!   lexicographic rule, or the round-robin / random baselines), gated by
//!   a driver-supplied admissibility check (KV caps in the DES, free
//!   engine slots live). The core keeps the per-DP active-sequence /
//!   KV ledger and the occupancy gauges surfaced as
//!   [`DecodePoolStats`].

use super::costmodel::DpStepLoad;
use crate::metrics::{DecodePoolStats, DpOccupancyGauge, RescueGauge};
use crate::scheduler::baseline::{ImmediatePolicy, ImmediateScheduler};
use crate::scheduler::decode::{schedule_batch, DecodeSchedConfig};
use crate::scheduler::staggered::{
    DispatchBatch, SchedulerAction, SchedulerEvent, StaggeredConfig, StaggeredScheduler,
};
use crate::scheduler::state::DpState;
use crate::scheduler::types::{DpUnitId, Request, SloClass};
use crate::util::Rng;
use std::collections::{HashMap, HashSet};

/// Prefill control-plane choice, shared by the DES and the live cluster.
#[derive(Debug, Clone)]
pub enum SchedMode {
    /// The paper's staggered batch scheduler.
    Staggered(StaggeredConfig),
    /// Immediate dispatch with a classical policy (baseline).
    Immediate(ImmediatePolicy),
}

/// Decode placement policy over the pooled decode DP units (§4.3 vs the
/// Fig. 7–8 baselines).
#[derive(Debug, Clone)]
pub enum DecodePolicy {
    /// Algorithm 3: IQR outlier masking + lexicographic ⟨B, K⟩.
    LoadAware(DecodeSchedConfig),
    /// Algorithm 3 extended with deadline urgency: a join carrying a
    /// deadline is scored `u·B̂ + (1−u)·K̂` over the admissible units
    /// (`u = 1/(1+slack)`), so urgent sequences minimize batch-depth
    /// interference while relaxed ones pack KV headroom. Joins without a
    /// deadline fall back to the pure load-aware rule.
    DeadlineAware(DecodeSchedConfig),
    /// Blind strict round-robin (equal counts, blind to load).
    RoundRobin,
    /// Blind random routing (what session-affinity hashing degenerates
    /// to across DP units). Deterministic given the core's seed.
    Random,
}

impl DecodePolicy {
    /// Stable policy name for reports and CLI round-trips.
    pub fn name(&self) -> &'static str {
        match self {
            DecodePolicy::LoadAware(_) => "load-aware",
            DecodePolicy::DeadlineAware(_) => "deadline-aware",
            DecodePolicy::RoundRobin => "round-robin",
            DecodePolicy::Random => "random",
        }
    }
}

/// Shape + policy configuration of one dispatch core.
#[derive(Debug, Clone)]
pub struct DispatchCoreConfig {
    /// Prefill control plane.
    pub mode: SchedMode,
    /// Prefill instances.
    pub n_prefill: u32,
    /// DP-Attention units per prefill instance.
    pub dp_prefill: u32,
    /// Per-DP prefill chunk capacity (tokens per pass).
    pub c_chunk: u32,
    /// Decode instances.
    pub n_decode: u32,
    /// DP units per decode instance.
    pub dp_decode: u32,
    /// Decode placement policy.
    pub decode_policy: DecodePolicy,
    /// Seed for the random-placement baseline.
    pub seed: u64,
}

/// How the engine reported its device backlog in an `EndForward`.
#[derive(Debug, Clone, Copy)]
pub enum EndForwardBacklog {
    /// The engine reports `tokens` still buffered on the device (the DES
    /// path: per-pass consumption is fed back separately).
    Remaining(u32),
    /// The engine fully consumed everything dispatched to it before
    /// signalling (the local live path: real engines report completion
    /// wholesale, so the core clears the capacity model here).
    ConsumedAll,
    /// The engine consumed the pass it just finished *and* reports
    /// `tokens` still queued behind it — the remote prefill shard path,
    /// where `EndForward` crosses the wire carrying the instance's real
    /// backlog. The core acknowledges everything in flight, then seeds
    /// `R_queued` with the report, so `C_avail` reflects engine truth
    /// instead of per-dispatch bookkeeping.
    Reported(u32),
}

/// One prefilled request waiting for decode placement.
#[derive(Debug, Clone, Copy)]
pub struct DecodeJoin {
    /// Request / job id (driver-scoped).
    pub request_id: u64,
    /// KV tokens resident at join time (the prompt).
    pub kv_tokens: u32,
    /// Output tokens still to generate.
    pub remaining_out: u32,
    /// SLO class (placement order: interactive before batch).
    pub class: SloClass,
    /// Absolute completion deadline on the driver clock, seconds
    /// (deadline-aware placement weight; `None` = pure load).
    pub deadline: Option<f64>,
}

impl DecodeJoin {
    /// Expected resident length once fully decoded — the ledger charge,
    /// and the amount a KV-budget admission must reserve.
    pub fn total_len(&self) -> u32 {
        self.kv_tokens + self.remaining_out
    }
}

/// Result of one [`DispatchCore::place_decode`] cycle.
#[derive(Debug)]
pub struct DecodePlacementOutcome {
    /// `(join, unit)` placements, in placement order.
    pub placed: Vec<(DecodeJoin, DpUnitId)>,
    /// Joins with no admissible unit — park and retry at the next
    /// step/completion boundary (decode-side admission backpressure).
    pub parked: Vec<DecodeJoin>,
}

/// Tunables of the SLO-violation rescue scan ([`DispatchCore::rescue_scan`]).
///
/// The scan runs inside the scheduling tick (the staggered buffering
/// window — off the dispatch hot path) and projects each resident
/// sequence's completion from its observed per-token rate. A sequence
/// whose projection violates its [`DecodeJoin::deadline`] triggers one
/// of two rescue actions: preempt a batch-class sequence on its unit, or
/// live-migrate the endangered sequence to a unit with headroom.
#[derive(Debug, Clone)]
pub struct RescueConfig {
    /// Master switch; disabled cores never scan and never count.
    pub enabled: bool,
    /// Minimum seconds between scans (debounces high-rate tick loops).
    pub scan_every: f64,
    /// Per-sequence grace after a join or a rescue action: the sequence
    /// is left alone this long before (re)considering it, so one slow
    /// sequence cannot thrash the pool with back-to-back extractions.
    pub cooldown: f64,
    /// Pessimism multiplier on the projected remaining time (>1 rescues
    /// earlier, <1 later).
    pub margin: f64,
    /// Assumed seconds per token before a sequence has shown any
    /// progress; 0 = never project (wait for the first observed token).
    pub default_rate: f64,
}

impl Default for RescueConfig {
    fn default() -> Self {
        RescueConfig {
            enabled: false,
            scan_every: 0.05,
            cooldown: 0.25,
            margin: 1.0,
            default_rate: 0.0,
        }
    }
}

impl RescueConfig {
    /// An enabled config with the default cadence.
    pub fn on() -> Self {
        RescueConfig {
            enabled: true,
            ..Default::default()
        }
    }
}

/// What a rescue action does to the sequence it names.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RescueKind {
    /// The sequence is a batch-class victim on an endangered sequence's
    /// unit: extract it to shed load there (it re-parks and re-places
    /// with its progress intact).
    Preempt,
    /// The sequence is itself endangered: extract it so it can re-place
    /// onto a unit with headroom (live migration).
    Migrate,
}

/// One rescue decision from [`DispatchCore::rescue_scan`]: extract the
/// named sequence from `unit`. The driver performs the extraction
/// through its transport; when the extracted state lands, it releases
/// the ledger charge ([`DispatchCore::on_decode_leave`]) and re-parks
/// the sequence for standard placement — both rescue kinds reuse the
/// one placement path, so the DES and the live cluster cannot diverge.
#[derive(Debug, Clone, Copy)]
pub struct RescueAction {
    /// Sequence to extract.
    pub id: u64,
    /// Unit it is resident on.
    pub unit: DpUnitId,
    /// Why it is being extracted.
    pub kind: RescueKind,
}

/// One resident decode sequence as the rescue scan sees it.
#[derive(Debug, Clone)]
struct ResidentSeq {
    /// Flat index into the core's decode ledger.
    unit: usize,
    class: SloClass,
    deadline: Option<f64>,
    /// KV tokens at this join (prompt + any pre-move generation).
    kv_at_join: u32,
    remaining_at_join: u32,
    joined_at: f64,
    /// First emission index observed for this residency; progress is
    /// measured relative to it, so a migrated sequence's cumulative
    /// indexes self-calibrate on the destination.
    first_index: Option<u32>,
    /// Tokens generated during this residency (observed).
    tokens_done: u32,
    /// Last join or rescue action touching this sequence (cooldown).
    last_rescue: f64,
}

/// Rescue + deadline outcome counters (mirrored into [`RescueGauge`]).
#[derive(Debug, Clone, Copy, Default)]
struct RescueCounters {
    preempted: u64,
    migrated: u64,
    deadline_met: u64,
    deadline_violated: u64,
    rescue_deadline_met: u64,
}

/// Driver-side admission control for decode placement.
///
/// `admissible` receives the core's live ledger entry for the unit
/// (`state`) and the full join, so budget-style checks can compare the
/// unit's charged occupancy (`⟨B, K⟩`) against the join's eventual
/// resident length without keeping a second ledger of their own — the
/// core updates `state` the moment each join is placed, so later joins
/// in the same cycle observe earlier placements. Drivers with resource
/// state the core cannot see (the DES's engine-backed KV caps) check
/// that state instead and sync it in `commit`, which is called the
/// moment a join is placed.
pub trait DecodeAdmission {
    /// Whether the unit described by `state` can accept `join`.
    fn admissible(&mut self, state: &DpState, join: &DecodeJoin) -> bool;
    /// A join was placed on `unit`; apply it to any backing state now.
    fn commit(&mut self, unit: DpUnitId, join: &DecodeJoin);
}

/// Adapter: admission from a plain `(unit, kv_tokens)` check with no
/// backing state to sync (tests and always-admissible pools). The
/// wrapped closure is the `admissible` check; `commit` is a no-op.
pub struct FnAdmission<F>(pub F);

impl<F: FnMut(DpUnitId, u32) -> bool> DecodeAdmission for FnAdmission<F> {
    fn admissible(&mut self, state: &DpState, join: &DecodeJoin) -> bool {
        (self.0)(state.id, join.kv_tokens)
    }

    fn commit(&mut self, _unit: DpUnitId, _join: &DecodeJoin) {}
}

/// Per-unit occupancy accounting behind [`DecodePoolStats`].
#[derive(Debug, Clone, Default)]
struct UnitOccupancy {
    placed: u64,
    active: u32,
    peak_active: u32,
    seq_seconds: f64,
    last_t: f64,
}

impl UnitOccupancy {
    /// Integrate `active` over time up to `now`.
    fn advance(&mut self, now: f64) {
        if now > self.last_t {
            self.seq_seconds += self.active as f64 * (now - self.last_t);
            self.last_t = now;
        }
    }

    fn join(&mut self, now: f64) {
        self.advance(now);
        self.placed += 1;
        self.active += 1;
        self.peak_active = self.peak_active.max(self.active);
    }

    fn leave(&mut self, now: f64) {
        self.advance(now);
        self.active = self.active.saturating_sub(1);
    }
}

enum PrefillPlane {
    Staggered(StaggeredScheduler),
    Immediate(ImmediateScheduler),
}

/// The shared scheduler-driving state machine (see module docs).
pub struct DispatchCore {
    prefill: PrefillPlane,
    /// Pooled decode DP ledger (`⟨B_i, K_i⟩` per unit, Algorithm 3).
    decode_states: Vec<DpState>,
    policy: DecodePolicy,
    rr_cursor: usize,
    place_rng: Rng,
    occupancy: Vec<UnitOccupancy>,
    /// request id → (flat unit index, ledger charge) for exact release.
    owners: HashMap<u64, (usize, u32)>,
    /// SLO-violation rescue scan tunables ([`DispatchCore::set_rescue`]).
    rescue_cfg: RescueConfig,
    /// request id → residency facts the rescue scan projects from.
    resident: HashMap<u64, ResidentSeq>,
    /// Sequences a rescue action has touched (survives re-placement, so
    /// `rescue_deadline_met` credits the rescue, not the original spot).
    rescued: HashSet<u64>,
    last_scan: f64,
    rescue_counters: RescueCounters,
}

impl DispatchCore {
    /// Build a core for the given shape and policies.
    pub fn new(cfg: &DispatchCoreConfig) -> Self {
        let prefill = match &cfg.mode {
            SchedMode::Staggered(sc) => PrefillPlane::Staggered(StaggeredScheduler::new(
                sc.clone(),
                cfg.n_prefill,
                cfg.dp_prefill,
                cfg.c_chunk,
            )),
            SchedMode::Immediate(p) => PrefillPlane::Immediate(ImmediateScheduler::new(
                *p,
                cfg.n_prefill,
                cfg.dp_prefill,
                cfg.c_chunk,
            )),
        };
        let mut decode_states = Vec::new();
        for i in 0..cfg.n_decode.max(1) {
            for d in 0..cfg.dp_decode.max(1) {
                decode_states.push(DpState::new(DpUnitId::new(i, d), 0));
            }
        }
        let occupancy = vec![UnitOccupancy::default(); decode_states.len()];
        DispatchCore {
            prefill,
            decode_states,
            policy: cfg.decode_policy.clone(),
            rr_cursor: 0,
            place_rng: Rng::new(cfg.seed),
            occupancy,
            owners: HashMap::new(),
            rescue_cfg: RescueConfig::default(),
            resident: HashMap::new(),
            rescued: HashSet::new(),
            last_scan: f64::NEG_INFINITY,
            rescue_counters: RescueCounters::default(),
        }
    }

    /// Install the rescue-scan tunables (default: disabled). Separate
    /// from [`DispatchCoreConfig`] so existing drivers opt in explicitly.
    pub fn set_rescue(&mut self, cfg: RescueConfig) {
        self.rescue_cfg = cfg;
    }

    // ---- prefill plane -------------------------------------------------

    /// A request arrived at the frontend.
    pub fn on_arrival(&mut self, request: Request, now: f64) -> Vec<SchedulerAction> {
        match &mut self.prefill {
            PrefillPlane::Staggered(s) => s.on_event(SchedulerEvent::Arrival { request, now }),
            PrefillPlane::Immediate(im) => {
                // Immediate dispatch: bind to an instance right now. The
                // decision still flows back as a Dispatch action so both
                // planes drive their drivers through one code path.
                let a = im.dispatch(request);
                vec![SchedulerAction::Dispatch(DispatchBatch {
                    instance: a.unit.instance,
                    assignments: vec![a],
                    at: now,
                })]
            }
        }
    }

    /// A prefill instance finished a forward pass.
    pub fn on_end_forward(
        &mut self,
        instance: u32,
        t_measured: f64,
        backlog: EndForwardBacklog,
        now: f64,
    ) -> Vec<SchedulerAction> {
        let remaining = match backlog {
            EndForwardBacklog::Remaining(b) => b,
            EndForwardBacklog::ConsumedAll => {
                // The engine fully consumed its dispatched batch before
                // signalling: clear the capacity model wholesale (the DES
                // gets this via per-pass ack/consume feedback instead).
                let dps = match &mut self.prefill {
                    PrefillPlane::Staggered(s) => s.state.instance_dps_mut(instance),
                    PrefillPlane::Immediate(im) => im.state.instance_dps_mut(instance),
                };
                for dp in dps {
                    let backlog = dp.u_flight + dp.r_queued;
                    dp.on_ack(dp.u_flight);
                    dp.on_consumed(backlog);
                }
                0
            }
            EndForwardBacklog::Reported(b) => {
                // Engine-truth backlog off the wire: acknowledge every
                // in-flight token (it reached the shard), then seed the
                // device backlog with the report so `C_avail` gates the
                // next dispatch on what the engine actually holds. Live
                // instances run dp=1; with more DPs the report is split
                // evenly (remainder on the first) as the best available
                // approximation.
                let dps = match &mut self.prefill {
                    PrefillPlane::Staggered(s) => s.state.instance_dps_mut(instance),
                    PrefillPlane::Immediate(im) => im.state.instance_dps_mut(instance),
                };
                let n = dps.len().max(1) as u32;
                let (per, extra) = (b / n, b % n);
                for (i, dp) in dps.iter_mut().enumerate() {
                    dp.on_ack(dp.u_flight);
                    dp.r_queued = per + u32::from(i == 0) * extra;
                }
                b
            }
        };
        match &mut self.prefill {
            PrefillPlane::Staggered(s) => s.on_event(SchedulerEvent::EndForward {
                instance,
                t_measured,
                remaining: Some(remaining),
                now,
            }),
            PrefillPlane::Immediate(im) => {
                im.on_end_forward(instance, now);
                Vec::new()
            }
        }
    }

    /// A previously armed timer fired.
    pub fn on_timer(&mut self, now: f64) -> Vec<SchedulerAction> {
        match &mut self.prefill {
            PrefillPlane::Staggered(s) => s.on_event(SchedulerEvent::Timer { now }),
            PrefillPlane::Immediate(_) => Vec::new(),
        }
    }

    /// Dispatched tokens physically arrived on the device: flight→queued.
    pub fn on_deliver_ack(&mut self, unit: DpUnitId, tokens: u32) {
        match &mut self.prefill {
            PrefillPlane::Staggered(s) => s.state.dp_mut(unit).on_ack(tokens),
            PrefillPlane::Immediate(im) => im.state.dp_mut(unit).on_ack(tokens),
        }
    }

    /// A forward pass consumed `tokens` from a unit's device backlog.
    pub fn on_prefill_consumed(&mut self, unit: DpUnitId, tokens: u32) {
        match &mut self.prefill {
            PrefillPlane::Staggered(s) => s.state.dp_mut(unit).on_consumed(tokens),
            PrefillPlane::Immediate(im) => im.state.dp_mut(unit).on_consumed(tokens),
        }
    }

    /// Sum of one prefill instance's per-DP available capacity
    /// (`Σ C_avail`, §4.2.1) — the observable the `EndForward` backlog
    /// variants feed; exposed for gauges and tests.
    pub fn prefill_c_avail(&self, instance: u32) -> i64 {
        let dps = match &self.prefill {
            PrefillPlane::Staggered(s) => s.state.instance_dps(instance),
            PrefillPlane::Immediate(im) => im.state.instance_dps(instance),
        };
        dps.iter().map(|d| d.c_avail()).sum()
    }

    /// Current adaptive interval (0 for the immediate baseline).
    pub fn i_opt(&self) -> f64 {
        match &self.prefill {
            PrefillPlane::Staggered(s) => s.i_opt(),
            PrefillPlane::Immediate(_) => 0.0,
        }
    }

    /// Scheduler-side queued request count (0 for immediate dispatch).
    pub fn queued(&self) -> usize {
        match &self.prefill {
            PrefillPlane::Staggered(s) => s.queued(),
            PrefillPlane::Immediate(_) => 0,
        }
    }

    // ---- decode plane --------------------------------------------------

    /// Number of pooled decode DP units.
    pub fn decode_units(&self) -> usize {
        self.decode_states.len()
    }

    /// Refresh the decode ledger from engine ground truth (flat unit
    /// order). Drivers with observable engines (the DES) call this before
    /// each placement cycle; event-driven drivers rely on the ledger the
    /// core maintains through joins/leaves instead.
    pub fn sync_decode_loads(&mut self, loads: &[DpStepLoad]) {
        for (s, l) in self.decode_states.iter_mut().zip(loads) {
            s.batch = l.batch;
            s.kv_tokens = l.kv_tokens;
        }
    }

    /// Place `joins` across the decode pool under the configured policy.
    ///
    /// Joins with no admissible unit (per [`DecodeAdmission`]) come back
    /// in `parked`. Placement order is SLO class first (interactive
    /// before standard before batch), heaviest-first within a class
    /// ("fill-the-valley", §4.3.2); each placement updates the ledger and
    /// occupancy gauges at time `now` and is committed to the driver via
    /// [`DecodeAdmission::commit`] so intra-cycle admissibility stays
    /// exact.
    pub fn place_decode(
        &mut self,
        mut joins: Vec<DecodeJoin>,
        now: f64,
        admission: &mut dyn DecodeAdmission,
    ) -> DecodePlacementOutcome {
        joins.sort_by(|a, b| {
            a.class
                .rank()
                .cmp(&b.class.rank())
                .then(b.total_len().cmp(&a.total_len()))
        });
        let mut placed = Vec::new();
        let mut parked = Vec::new();
        'joins: for j in joins {
            // Units that failed the commit-time re-check this join: a
            // shard can die between the admissibility snapshot and the
            // commit, so a stale winner is excluded and the join is
            // re-scored over the survivors instead of panicking the
            // scheduler thread (historically an `.unwrap()` here).
            let mut excluded: Vec<usize> = Vec::new();
            loop {
                let admit: Vec<usize> = (0..self.decode_states.len())
                    .filter(|&u| !excluded.contains(&u))
                    .filter(|&u| admission.admissible(&self.decode_states[u], &j))
                    .collect();
                if admit.is_empty() {
                    parked.push(j);
                    continue 'joins;
                }
                // Run the policy over a view of the admissible units; the
                // per-join snapshot semantics of Algorithm 3 are preserved
                // by placing one request at a time.
                let mut view: Vec<DpState> = admit
                    .iter()
                    .map(|&u| self.decode_states[u].clone())
                    .collect();
                let chosen = match &self.policy {
                    DecodePolicy::LoadAware(cfg) => {
                        let req = Request::new(j.request_id, j.kv_tokens, j.remaining_out, 0.0);
                        let a = schedule_batch(cfg, vec![req], &mut view);
                        a.first()
                            .and_then(|a0| view.iter().position(|d| d.id == a0.unit))
                    }
                    DecodePolicy::DeadlineAware(cfg) => match j.deadline {
                        // Deadline-less joins (legacy clients): pure load.
                        None => {
                            let req =
                                Request::new(j.request_id, j.kv_tokens, j.remaining_out, 0.0);
                            let a = schedule_batch(cfg, vec![req], &mut view);
                            a.first()
                                .and_then(|a0| view.iter().position(|d| d.id == a0.unit))
                        }
                        Some(deadline) => {
                            // Urgency interpolates the objective between
                            // batch depth (interference → per-step latency)
                            // and KV occupancy (memory packing). Norms are
                            // over the admissible view; +1 avoids 0/0 on an
                            // idle pool. Ties break to the lower unit index
                            // (deterministic, DES/live parity).
                            let slack = (deadline - now).max(0.0);
                            let urgency = 1.0 / (1.0 + slack);
                            let max_b = view.iter().map(|d| d.batch).max().unwrap_or(0) as f64;
                            let max_k =
                                view.iter().map(|d| d.kv_tokens).max().unwrap_or(0) as f64;
                            let score = |d: &DpState| {
                                urgency * d.batch as f64 / (max_b + 1.0)
                                    + (1.0 - urgency) * d.kv_tokens as f64 / (max_k + 1.0)
                            };
                            let mut best = 0usize;
                            for i in 1..view.len() {
                                if score(&view[i]) < score(&view[best]) {
                                    best = i;
                                }
                            }
                            Some(best)
                        }
                    },
                    DecodePolicy::Random => Some(self.place_rng.index(view.len())),
                    DecodePolicy::RoundRobin => {
                        let i = self.rr_cursor % view.len();
                        self.rr_cursor = self.rr_cursor.wrapping_add(1);
                        Some(i)
                    }
                };
                let Some(chosen) = chosen else {
                    // The scorer named a unit that is no longer in the
                    // view (or assigned nothing): treat as inadmissible
                    // and park rather than panic.
                    parked.push(j);
                    continue 'joins;
                };
                let u = admit[chosen];
                // Commit-time re-check: the snapshot above may have gone
                // stale while the policy scored (the driver's transport
                // can mark a shard dead at any point). A unit that no
                // longer admits is excluded and the join re-scored.
                if !admission.admissible(&self.decode_states[u], &j) {
                    excluded.push(u);
                    continue;
                }
                let charge = j.total_len();
                // Defensive: ids must be unique, but if a duplicate slips
                // in, release the earlier charge instead of leaking it
                // forever.
                if self.owners.contains_key(&j.request_id) {
                    self.on_decode_leave(j.request_id, now);
                }
                self.decode_states[u].on_decode_join(charge);
                self.occupancy[u].join(now);
                self.owners.insert(j.request_id, (u, charge));
                self.resident.insert(
                    j.request_id,
                    ResidentSeq {
                        unit: u,
                        class: j.class,
                        deadline: j.deadline,
                        kv_at_join: j.kv_tokens,
                        remaining_at_join: j.remaining_out,
                        joined_at: now,
                        first_index: None,
                        tokens_done: 0,
                        last_rescue: now,
                    },
                );
                admission.commit(self.decode_states[u].id, &j);
                placed.push((j, self.decode_states[u].id));
                continue 'joins;
            }
        }
        DecodePlacementOutcome { placed, parked }
    }

    /// A placed sequence finished (or was terminally rejected): release
    /// its ledger charge. Returns the owning unit and the released
    /// charge (callers today only test ownership; the charge documents
    /// what the ledger just gave back), `None` for unknown ids (never
    /// placed / already released).
    pub fn on_decode_leave(&mut self, request_id: u64, now: f64) -> Option<(DpUnitId, u32)> {
        let (u, charge) = self.owners.remove(&request_id)?;
        self.resident.remove(&request_id);
        self.decode_states[u].on_decode_leave(charge);
        self.occupancy[u].leave(now);
        Some((self.decode_states[u].id, charge))
    }

    /// A placed sequence finished its generation (terminal `Done`):
    /// score its deadline outcome, then release the ledger charge like
    /// [`DispatchCore::on_decode_leave`]. Sequences a rescue action
    /// touched ([`DispatchCore::rescue_scan`]) that still meet their
    /// deadline count into `rescue_deadline_met`. Rescue extractions
    /// must go through `on_decode_leave` instead — the sequence is
    /// moving, not finishing.
    pub fn on_decode_finish(&mut self, request_id: u64, now: f64) -> Option<(DpUnitId, u32)> {
        if let Some(deadline) = self.resident.get(&request_id).and_then(|s| s.deadline) {
            if now <= deadline {
                self.rescue_counters.deadline_met += 1;
                if self.rescued.contains(&request_id) {
                    self.rescue_counters.rescue_deadline_met += 1;
                }
            } else {
                self.rescue_counters.deadline_violated += 1;
            }
        }
        self.rescued.remove(&request_id);
        self.on_decode_leave(request_id, now)
    }

    /// Feed one generated-token observation for a resident sequence.
    ///
    /// `index` is the *cumulative* emission index of the stream (tokens
    /// emitted so far for the request, monotone across migrations). The
    /// core calibrates against the first index seen in the current
    /// residency, so both the DES (which reports absolute progress) and
    /// a freshly migrated live stream (which resumes mid-count) yield
    /// the same per-residency rate.
    pub fn on_decode_progress(&mut self, request_id: u64, index: u32) {
        if let Some(seq) = self.resident.get_mut(&request_id) {
            let first = *seq.first_index.get_or_insert(index);
            seq.tokens_done = seq.tokens_done.max(index.saturating_sub(first) + 1);
        }
    }

    /// SLO class of a resident sequence (what it was placed with).
    /// Drivers query it before [`DispatchCore::on_decode_leave`] when
    /// re-parking an extracted sequence, so the class survives the move
    /// without a second driver-side registry.
    pub fn resident_class(&self, request_id: u64) -> Option<SloClass> {
        self.resident.get(&request_id).map(|s| s.class)
    }

    /// Scan resident sequences for projected deadline violations and
    /// decide rescue actions (the tentpole of the SLO rescue layer).
    ///
    /// For each endangered sequence — one whose `now + remaining ×
    /// observed_rate × margin` exceeds its deadline — the scan prefers
    /// **preempting** the heaviest batch-class sequence co-resident on
    /// the same unit (shedding interference without moving the urgent
    /// KV), and falls back to **migrating** the endangered sequence
    /// itself when a strictly shallower admissible unit exists. The scan
    /// only *decides*; the driver extracts the named sequences through
    /// its transport, releases their charge via
    /// [`DispatchCore::on_decode_leave`] when the state lands, and
    /// re-parks them into the standard placement path — so the DES and
    /// the live cluster share every rescue decision bit for bit.
    pub fn rescue_scan(
        &mut self,
        now: f64,
        admission: &mut dyn DecodeAdmission,
    ) -> Vec<RescueAction> {
        if !self.rescue_cfg.enabled || now - self.last_scan < self.rescue_cfg.scan_every {
            return Vec::new();
        }
        self.last_scan = now;
        let cfg = self.rescue_cfg.clone();
        let mut actions: Vec<RescueAction> = Vec::new();
        // Sequences already claimed by an action this scan (either as
        // victim or as migrant) — one move per sequence per scan.
        let mut taken: HashSet<u64> = HashSet::new();
        // Deterministic order for DES/live parity.
        let mut ids: Vec<u64> = self.resident.keys().copied().collect();
        ids.sort_unstable();
        for id in ids {
            let (deadline, src, joined_at, tokens_done, remaining_at_join, kv_at_join) = {
                let s = &self.resident[&id];
                let Some(d) = s.deadline else { continue };
                if taken.contains(&id) || now - s.last_rescue < cfg.cooldown {
                    continue;
                }
                (
                    d,
                    s.unit,
                    s.joined_at,
                    s.tokens_done,
                    s.remaining_at_join,
                    s.kv_at_join,
                )
            };
            // Observed seconds per token this residency; before the
            // first token the configured default applies (0 = wait).
            let rate = if tokens_done > 0 {
                (now - joined_at).max(0.0) / tokens_done as f64
            } else if cfg.default_rate > 0.0 {
                cfg.default_rate
            } else {
                continue;
            };
            let remaining = remaining_at_join.saturating_sub(tokens_done);
            if remaining == 0 {
                continue;
            }
            if now + remaining as f64 * rate * cfg.margin <= deadline {
                continue;
            }
            // Endangered. (a) Shed the heaviest batch-class co-resident
            // (most remaining work = most interference relief; ties to
            // the lowest id for determinism).
            let victim = self
                .resident
                .iter()
                .filter(|(vid, v)| {
                    **vid != id
                        && v.unit == src
                        && v.class == SloClass::Batch
                        && !taken.contains(*vid)
                        && now - v.last_rescue >= cfg.cooldown
                })
                .max_by(|(aid, a), (bid, b)| {
                    let ar = a.remaining_at_join.saturating_sub(a.tokens_done);
                    let br = b.remaining_at_join.saturating_sub(b.tokens_done);
                    ar.cmp(&br).then(bid.cmp(aid))
                })
                .map(|(vid, _)| *vid);
            if let Some(vid) = victim {
                taken.insert(vid);
                taken.insert(id);
                self.rescue_counters.preempted += 1;
                self.rescued.insert(id);
                actions.push(RescueAction {
                    id: vid,
                    unit: self.decode_states[src].id,
                    kind: RescueKind::Preempt,
                });
                self.resident.get_mut(&vid).unwrap().last_rescue = now;
                self.resident.get_mut(&id).unwrap().last_rescue = now;
                continue;
            }
            // (b) No batch victim: migrate the endangered sequence if an
            // admissible unit exists that would still be strictly
            // shallower than the source after accepting it.
            let moved = DecodeJoin {
                request_id: id,
                kv_tokens: kv_at_join + tokens_done,
                remaining_out: remaining,
                class: self.resident[&id].class,
                deadline: Some(deadline),
            };
            let src_batch = self.decode_states[src].batch;
            let has_headroom = (0..self.decode_states.len()).any(|u| {
                u != src
                    && self.decode_states[u].batch + 1 < src_batch
                    && admission.admissible(&self.decode_states[u], &moved)
            });
            if has_headroom {
                taken.insert(id);
                self.rescue_counters.migrated += 1;
                self.rescued.insert(id);
                actions.push(RescueAction {
                    id,
                    unit: self.decode_states[src].id,
                    kind: RescueKind::Migrate,
                });
                self.resident.get_mut(&id).unwrap().last_rescue = now;
            }
        }
        actions
    }

    /// Snapshot of the rescue/deadline counters.
    pub fn rescue_gauge(&self) -> RescueGauge {
        RescueGauge {
            enabled: self.rescue_cfg.enabled,
            preempted: self.rescue_counters.preempted,
            migrated: self.rescue_counters.migrated,
            deadline_met: self.rescue_counters.deadline_met,
            deadline_violated: self.rescue_counters.deadline_violated,
            rescue_deadline_met: self.rescue_counters.rescue_deadline_met,
        }
    }

    /// Sequences currently placed on `unit` per the core ledger.
    pub fn unit_active(&self, unit: DpUnitId) -> u32 {
        self.decode_states
            .iter()
            .position(|d| d.id == unit)
            .map(|u| self.occupancy[u].active)
            .unwrap_or(0)
    }

    /// Snapshot of the per-DP occupancy + imbalance gauges at `now`.
    pub fn decode_stats(&self, now: f64) -> DecodePoolStats {
        let units = self
            .decode_states
            .iter()
            .zip(&self.occupancy)
            .map(|(s, o)| DpOccupancyGauge {
                unit: s.id.to_string(),
                placed: o.placed,
                active: o.active,
                peak_active: o.peak_active,
                seq_seconds: o.seq_seconds + o.active as f64 * (now - o.last_t).max(0.0),
                kv_tokens: s.kv_tokens,
                // The core is transport-blind; the driver decorates these
                // (and the prefill section) from its transports before
                // publishing.
                transport: "local".to_string(),
                alive: true,
                rtt_ms: None,
                engine_kv_tokens: None,
            })
            .collect();
        DecodePoolStats {
            policy: self.policy.name().to_string(),
            units,
            prefill: Vec::new(),
            kv_wire: Default::default(),
            rescue: self.rescue_gauge(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::interval::IntervalConfig;

    fn core_cfg(mode: SchedMode, policy: DecodePolicy) -> DispatchCoreConfig {
        DispatchCoreConfig {
            mode,
            n_prefill: 2,
            dp_prefill: 2,
            c_chunk: 2048,
            n_decode: 2,
            dp_decode: 2,
            decode_policy: policy,
            seed: 5,
        }
    }

    fn staggered() -> SchedMode {
        SchedMode::Staggered(StaggeredConfig {
            interval: IntervalConfig {
                t_default: 0.4,
                ..Default::default()
            },
            ..Default::default()
        })
    }

    fn join(id: u64, kv: u32, out: u32) -> DecodeJoin {
        DecodeJoin {
            request_id: id,
            kv_tokens: kv,
            remaining_out: out,
            class: SloClass::Standard,
            deadline: None,
        }
    }

    fn dispatches(actions: &[SchedulerAction]) -> Vec<&DispatchBatch> {
        actions
            .iter()
            .filter_map(|a| match a {
                SchedulerAction::Dispatch(d) => Some(d),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn immediate_arrival_dispatches_through_action_path() {
        let mut c = DispatchCore::new(&core_cfg(
            SchedMode::Immediate(ImmediatePolicy::RoundRobin),
            DecodePolicy::RoundRobin,
        ));
        let acts = c.on_arrival(Request::new(1, 100, 8, 0.0), 0.0);
        let d = dispatches(&acts);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].assignments.len(), 1);
        assert!(c.on_timer(1.0).is_empty());
    }

    #[test]
    fn staggered_cold_start_dispatches() {
        let mut c = DispatchCore::new(&core_cfg(staggered(), DecodePolicy::RoundRobin));
        let acts = c.on_arrival(Request::new(1, 500, 8, 0.0), 0.0);
        assert_eq!(dispatches(&acts).len(), 1);
        assert!(c.i_opt() > 0.0);
    }

    // The sim-style vs live-style EndForward parity (Remaining(0) after
    // per-pass ack/consume ≡ ConsumedAll) is asserted end to end by
    // tests/decode_balance.rs::sim_and_live_drivers_make_identical_dispatch_decisions.

    #[test]
    fn reported_backlog_seeds_capacity_with_engine_truth() {
        let mut c = DispatchCore::new(&core_cfg(staggered(), DecodePolicy::RoundRobin));
        let full = c.prefill_c_avail(0);
        // Cold start dispatches to instance 0 immediately (500 in flight).
        c.on_arrival(Request::new(1, 500, 8, 0.0), 0.0);
        assert_eq!(c.prefill_c_avail(0), full - 500);
        // The remote prefill path: the shard reports 700 tokens still
        // queued — C_avail must reflect the wire report, not the
        // per-dispatch bookkeeping.
        c.on_end_forward(0, 0.3, EndForwardBacklog::Reported(700), 0.4);
        assert_eq!(c.prefill_c_avail(0), full - 700);
        // A zero report (engine drained) restores full capacity.
        c.on_end_forward(0, 0.3, EndForwardBacklog::Reported(0), 0.8);
        assert_eq!(c.prefill_c_avail(0), full);
    }

    #[test]
    fn round_robin_placement_cycles_units() {
        let mut c = DispatchCore::new(&core_cfg(staggered(), DecodePolicy::RoundRobin));
        let joins = (0..4).map(|i| join(i, 100, 10)).collect();
        let out = c.place_decode(joins, 0.0, &mut FnAdmission(|_, _| true));
        assert_eq!(out.placed.len(), 4);
        assert!(out.parked.is_empty());
        let units: std::collections::BTreeSet<_> = out.placed.iter().map(|(_, u)| *u).collect();
        assert_eq!(units.len(), 4, "RR must touch every unit once");
    }

    #[test]
    fn load_aware_avoids_loaded_unit() {
        let mut c = DispatchCore::new(&core_cfg(
            staggered(),
            DecodePolicy::LoadAware(DecodeSchedConfig::default()),
        ));
        // Load up unit i0d0 with two resident sequences.
        let out = c.place_decode(
            vec![join(1, 100, 10), join(2, 100, 10)],
            0.0,
            &mut FnAdmission(|u, _| u == DpUnitId::new(0, 0)),
        );
        assert_eq!(out.placed.len(), 2);
        // The next free placement must go elsewhere (B=0 beats B=2).
        let out = c.place_decode(vec![join(3, 100, 10)], 0.1, &mut FnAdmission(|_, _| true));
        assert_ne!(out.placed[0].1, DpUnitId::new(0, 0));
    }

    #[test]
    fn inadmissible_joins_park_and_ledger_releases_on_leave() {
        let mut c = DispatchCore::new(&core_cfg(staggered(), DecodePolicy::RoundRobin));
        let out = c.place_decode(vec![join(7, 50, 10)], 0.0, &mut FnAdmission(|_, _| false));
        assert!(out.placed.is_empty());
        assert_eq!(out.parked.len(), 1);
        let out = c.place_decode(out.parked, 1.0, &mut FnAdmission(|_, _| true));
        assert_eq!(out.placed.len(), 1);
        let unit = out.placed[0].1;
        assert_eq!(c.unit_active(unit), 1);
        assert_eq!(c.on_decode_leave(7, 2.0), Some((unit, 60)));
        assert_eq!(c.unit_active(unit), 0);
        assert_eq!(c.on_decode_leave(7, 2.0), None, "double release is safe");
    }

    #[test]
    fn occupancy_integrates_active_seconds() {
        let mut c = DispatchCore::new(&core_cfg(staggered(), DecodePolicy::RoundRobin));
        c.place_decode(vec![join(1, 10, 5)], 0.0, &mut FnAdmission(|_, _| true));
        c.on_decode_leave(1, 2.0);
        let stats = c.decode_stats(3.0);
        let busy: f64 = stats.units.iter().map(|u| u.seq_seconds).sum();
        assert!((busy - 2.0).abs() < 1e-9, "1 active seq for 2 s: {busy}");
        assert_eq!(stats.units.iter().map(|u| u.placed).sum::<u64>(), 1);
        assert!(stats.imbalance() >= 1.0);
    }

    #[test]
    fn placement_orders_interactive_before_batch() {
        let mut c = DispatchCore::new(&core_cfg(staggered(), DecodePolicy::RoundRobin));
        let joins = vec![
            DecodeJoin {
                class: SloClass::Batch,
                ..join(1, 900, 10)
            },
            DecodeJoin {
                class: SloClass::Interactive,
                ..join(2, 100, 10)
            },
            join(3, 500, 10),
        ];
        let out = c.place_decode(joins, 0.0, &mut FnAdmission(|_, _| true));
        let order: Vec<u64> = out.placed.iter().map(|(j, _)| j.request_id).collect();
        assert_eq!(order, vec![2, 3, 1], "class rank beats heaviest-first");
    }

    #[test]
    fn deadline_aware_without_deadline_matches_load_aware() {
        let place = |policy: DecodePolicy| {
            let mut c = DispatchCore::new(&core_cfg(staggered(), policy));
            // Pre-load i0d0 so pure load must avoid it.
            c.place_decode(
                vec![join(1, 100, 10), join(2, 100, 10)],
                0.0,
                &mut FnAdmission(|u, _| u == DpUnitId::new(0, 0)),
            );
            let out = c.place_decode(
                (3..9).map(|i| join(i, 100 + i as u32, 10)).collect(),
                0.1,
                &mut FnAdmission(|_, _| true),
            );
            out.placed
                .iter()
                .map(|(j, u)| (j.request_id, *u))
                .collect::<Vec<_>>()
        };
        assert_eq!(
            place(DecodePolicy::LoadAware(DecodeSchedConfig::default())),
            place(DecodePolicy::DeadlineAware(DecodeSchedConfig::default())),
            "class-less joins fall back to the pure load-aware rule"
        );
    }

    #[test]
    fn deadline_aware_urgent_join_prefers_shallow_batch() {
        let mut c = DispatchCore::new(&core_cfg(
            staggered(),
            DecodePolicy::DeadlineAware(DecodeSchedConfig::default()),
        ));
        // i0d0: deep batch (3 short seqs); i0d1: one huge KV resident.
        for i in 0..3 {
            c.place_decode(
                vec![join(i, 50, 5)],
                0.0,
                &mut FnAdmission(|u, _| u == DpUnitId::new(0, 0)),
            );
        }
        c.place_decode(
            vec![join(10, 20_000, 5)],
            0.0,
            &mut FnAdmission(|u, _| u == DpUnitId::new(0, 1)),
        );
        let two = |u: DpUnitId, _| u == DpUnitId::new(0, 0) || u == DpUnitId::new(0, 1);
        // Urgent (deadline now): batch depth dominates → pick i0d1.
        let urgent = DecodeJoin {
            class: SloClass::Interactive,
            deadline: Some(1.0),
            ..join(20, 100, 10)
        };
        let out = c.place_decode(vec![urgent], 1.0, &mut FnAdmission(two));
        assert_eq!(out.placed[0].1, DpUnitId::new(0, 1));
        c.on_decode_leave(20, 1.0);
        // Relaxed (distant deadline): KV packing dominates → pick i0d0.
        let relaxed = DecodeJoin {
            class: SloClass::Batch,
            deadline: Some(1_000.0),
            ..join(21, 100, 10)
        };
        let out = c.place_decode(vec![relaxed], 1.0, &mut FnAdmission(two));
        assert_eq!(out.placed[0].1, DpUnitId::new(0, 0));
    }

    /// Admission that simulates a shard dying *between* the
    /// admissibility snapshot and the commit: every unit admits for the
    /// first `kill_after` `admissible` calls, then `dead` (or, with
    /// `dead == None`, every unit) stops admitting — exactly the window
    /// that used to panic the scheduler via `.unwrap()`.
    struct DyingAdmission {
        dead: Option<DpUnitId>,
        calls: u32,
        kill_after: u32,
    }

    impl DecodeAdmission for DyingAdmission {
        fn admissible(&mut self, state: &DpState, _join: &DecodeJoin) -> bool {
            self.calls += 1;
            if self.calls <= self.kill_after {
                return true;
            }
            match self.dead {
                Some(d) => state.id != d,
                None => false,
            }
        }

        fn commit(&mut self, unit: DpUnitId, _join: &DecodeJoin) {
            if let Some(d) = self.dead {
                assert_ne!(unit, d, "must never commit onto the dead unit");
            }
        }
    }

    #[test]
    fn unit_death_between_snapshot_and_commit_rescores_survivors() {
        let mut c = DispatchCore::new(&core_cfg(
            staggered(),
            DecodePolicy::LoadAware(DecodeSchedConfig::default()),
        ));
        // Load every unit except i0d0 so the scorer must pick i0d0.
        for (i, u) in [(1u64, (0, 1)), (2, (1, 0)), (3, (1, 1))] {
            c.place_decode(
                vec![join(i, 100, 10)],
                0.0,
                &mut FnAdmission(|id, _| id == DpUnitId::new(u.0, u.1)),
            );
        }
        // The snapshot sees all 4 units admissible (4 calls), the policy
        // picks idle i0d0, and the commit-time re-check (call 5) finds
        // it dead. The join must re-score over the survivors and land
        // elsewhere — the old code panicked here.
        let mut adm = DyingAdmission {
            dead: Some(DpUnitId::new(0, 0)),
            calls: 0,
            kill_after: 4,
        };
        let out = c.place_decode(vec![join(9, 100, 10)], 1.0, &mut adm);
        assert_eq!(out.placed.len(), 1);
        assert_ne!(out.placed[0].1, DpUnitId::new(0, 0));
        assert!(out.parked.is_empty());
    }

    #[test]
    fn whole_pool_death_between_snapshot_and_commit_parks() {
        let mut c = DispatchCore::new(&core_cfg(
            staggered(),
            DecodePolicy::LoadAware(DecodeSchedConfig::default()),
        ));
        let mut adm = DyingAdmission {
            dead: None,
            calls: 0,
            kill_after: 4,
        };
        let out = c.place_decode(vec![join(9, 100, 10)], 0.0, &mut adm);
        assert!(out.placed.is_empty());
        assert_eq!(out.parked.len(), 1, "total death parks instead of panicking");
    }

    fn rescue_core() -> DispatchCore {
        let mut c = DispatchCore::new(&core_cfg(
            staggered(),
            DecodePolicy::LoadAware(DecodeSchedConfig::default()),
        ));
        c.set_rescue(RescueConfig::on());
        c
    }

    #[test]
    fn rescue_prefers_preempting_batch_victim_on_hot_unit() {
        let mut c = rescue_core();
        let on_00 = |u: DpUnitId, _| u == DpUnitId::new(0, 0);
        // i0d0 hosts a heavy batch sequence and an endangered
        // interactive one.
        c.place_decode(
            vec![
                DecodeJoin {
                    class: SloClass::Batch,
                    ..join(1, 100, 50)
                },
                DecodeJoin {
                    class: SloClass::Interactive,
                    deadline: Some(2.0),
                    ..join(2, 100, 10)
                },
            ],
            0.0,
            &mut FnAdmission(on_00),
        );
        // One token in one second: 1 s/token, 9 remaining → projected
        // finish ≈ 10 s, deadline 2 s → endangered.
        c.on_decode_progress(2, 0);
        let actions = c.rescue_scan(1.0, &mut FnAdmission(|_, _| true));
        assert_eq!(actions.len(), 1);
        assert_eq!(actions[0].id, 1, "the batch co-resident is the victim");
        assert_eq!(actions[0].kind, RescueKind::Preempt);
        assert_eq!(actions[0].unit, DpUnitId::new(0, 0));
        assert_eq!(c.rescue_gauge().preempted, 1);
        // scan_every gates an immediate rescan; cooldown gates the pair.
        assert!(c.rescue_scan(1.01, &mut FnAdmission(|_, _| true)).is_empty());
        assert!(c.rescue_scan(1.2, &mut FnAdmission(|_, _| true)).is_empty());
        // The rescued sequence finishing inside its deadline credits the
        // rescue.
        c.on_decode_finish(2, 1.8);
        let g = c.rescue_gauge();
        assert_eq!(g.deadline_met, 1);
        assert_eq!(g.rescue_deadline_met, 1);
        assert_eq!(g.deadline_violated, 0);
    }

    #[test]
    fn rescue_migrates_endangered_seq_when_no_batch_victim() {
        let mut c = rescue_core();
        let on_00 = |u: DpUnitId, _| u == DpUnitId::new(0, 0);
        // Two interactive residents on i0d0 (no batch victim); only one
        // carries a deadline.
        c.place_decode(
            vec![
                DecodeJoin {
                    class: SloClass::Interactive,
                    deadline: Some(2.0),
                    ..join(1, 100, 10)
                },
                DecodeJoin {
                    class: SloClass::Interactive,
                    ..join(2, 100, 10)
                },
            ],
            0.0,
            &mut FnAdmission(on_00),
        );
        c.on_decode_progress(1, 0);
        let actions = c.rescue_scan(1.0, &mut FnAdmission(|_, _| true));
        assert_eq!(actions.len(), 1);
        assert_eq!(actions[0].id, 1, "the endangered sequence itself moves");
        assert_eq!(actions[0].kind, RescueKind::Migrate);
        assert_eq!(c.rescue_gauge().migrated, 1);
        // Driver side of the move: release, re-park, re-place. The
        // rescued mark survives the move, so a deadline met after the
        // migration still credits the rescue.
        c.on_decode_leave(1, 1.1);
        let moved = DecodeJoin {
            request_id: 1,
            kv_tokens: 101,
            remaining_out: 9,
            class: SloClass::Interactive,
            deadline: Some(2.0),
        };
        let out = c.place_decode(vec![moved], 1.1, &mut FnAdmission(|_, _| true));
        assert_eq!(out.placed.len(), 1);
        assert_ne!(out.placed[0].1, DpUnitId::new(0, 0), "lands off the hot unit");
        c.on_decode_finish(1, 1.9);
        assert_eq!(c.rescue_gauge().rescue_deadline_met, 1);
    }

    #[test]
    fn rescue_migration_requires_strictly_shallower_destination() {
        let mut c = rescue_core();
        // Endangered sequence alone on its unit: every other unit has
        // equal depth after accepting it, so no migration fires.
        c.place_decode(
            vec![DecodeJoin {
                class: SloClass::Interactive,
                deadline: Some(2.0),
                ..join(1, 100, 10)
            }],
            0.0,
            &mut FnAdmission(|u, _| u == DpUnitId::new(0, 0)),
        );
        c.on_decode_progress(1, 0);
        assert!(
            c.rescue_scan(1.0, &mut FnAdmission(|_, _| true)).is_empty(),
            "moving between equally shallow units is churn, not rescue"
        );
    }

    #[test]
    fn rescue_disabled_scans_nothing_and_counts_nothing() {
        let mut c = DispatchCore::new(&core_cfg(
            staggered(),
            DecodePolicy::LoadAware(DecodeSchedConfig::default()),
        ));
        c.place_decode(
            vec![
                DecodeJoin {
                    class: SloClass::Batch,
                    ..join(1, 100, 50)
                },
                DecodeJoin {
                    class: SloClass::Interactive,
                    deadline: Some(2.0),
                    ..join(2, 100, 10)
                },
            ],
            0.0,
            &mut FnAdmission(|u, _| u == DpUnitId::new(0, 0)),
        );
        c.on_decode_progress(2, 0);
        assert!(c.rescue_scan(1.0, &mut FnAdmission(|_, _| true)).is_empty());
        let g = c.rescue_gauge();
        assert!(!g.enabled);
        assert_eq!(g.preempted + g.migrated, 0);
        // Deadline outcomes still tally (they are observability, not
        // rescue policy).
        c.on_decode_finish(2, 3.0);
        assert_eq!(c.rescue_gauge().deadline_violated, 1);
    }

    #[test]
    fn random_placement_is_deterministic_given_seed() {
        let run = || {
            let mut c = DispatchCore::new(&core_cfg(staggered(), DecodePolicy::Random));
            let joins = (0..16).map(|i| join(i, 100, 10)).collect();
            c.place_decode(joins, 0.0, &mut FnAdmission(|_, _| true))
                .placed
                .iter()
                .map(|(j, u)| (j.request_id, *u))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
