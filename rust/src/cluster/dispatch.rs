//! The transport-agnostic **dispatch core**: one scheduler-driving state
//! machine shared by every cluster driver.
//!
//! Before this module existed, the discrete-event simulator
//! ([`super::sim`]) and the threaded real-engine fabric
//! ([`super::workers`]) each carried their own copy of the same loop:
//! feed events into the [`StaggeredScheduler`] (or the immediate-dispatch
//! baseline), execute the returned actions, and keep a per-DP ledger for
//! decode placement. The copies had drifted — the live path ran exactly
//! one decode worker, so the paper's Load-Aware Global Allocation
//! (Algorithm 3) was dead code outside the simulator.
//!
//! [`DispatchCore`] is that loop, extracted. A *driver* owns the
//! transport (virtual event queue or real channels/threads) and the
//! engines; the core owns every scheduling decision:
//!
//! * **Prefill plane** — arrivals, `EndForward` feedback and timer ticks
//!   go through [`DispatchCore::on_arrival`] /
//!   [`DispatchCore::on_end_forward`] / [`DispatchCore::on_timer`], which
//!   return [`SchedulerAction`]s for the driver to execute. Engines that
//!   report their remaining backlog (the DES) pass
//!   [`EndForwardBacklog::Remaining`]; engines that consume each dispatch
//!   wholesale before signalling (the live workers) pass
//!   [`EndForwardBacklog::ConsumedAll`] and the core clears the capacity
//!   model itself.
//! * **Decode plane** — prefill completions become [`DecodeJoin`]s placed
//!   onto the pooled decode DP units by [`DispatchCore::place_decode`]
//!   under the configured [`DecodePolicy`] (Algorithm 3's IQR +
//!   lexicographic rule, or the round-robin / random baselines), gated by
//!   a driver-supplied admissibility check (KV caps in the DES, free
//!   engine slots live). The core keeps the per-DP active-sequence /
//!   KV ledger and the occupancy gauges surfaced as
//!   [`DecodePoolStats`].

use super::costmodel::DpStepLoad;
use crate::metrics::{DecodePoolStats, DpOccupancyGauge};
use crate::scheduler::baseline::{ImmediatePolicy, ImmediateScheduler};
use crate::scheduler::decode::{schedule_batch, DecodeSchedConfig};
use crate::scheduler::staggered::{
    DispatchBatch, SchedulerAction, SchedulerEvent, StaggeredConfig, StaggeredScheduler,
};
use crate::scheduler::state::DpState;
use crate::scheduler::types::{DpUnitId, Request, SloClass};
use crate::util::Rng;
use std::collections::HashMap;

/// Prefill control-plane choice, shared by the DES and the live cluster.
#[derive(Debug, Clone)]
pub enum SchedMode {
    /// The paper's staggered batch scheduler.
    Staggered(StaggeredConfig),
    /// Immediate dispatch with a classical policy (baseline).
    Immediate(ImmediatePolicy),
}

/// Decode placement policy over the pooled decode DP units (§4.3 vs the
/// Fig. 7–8 baselines).
#[derive(Debug, Clone)]
pub enum DecodePolicy {
    /// Algorithm 3: IQR outlier masking + lexicographic ⟨B, K⟩.
    LoadAware(DecodeSchedConfig),
    /// Algorithm 3 extended with deadline urgency: a join carrying a
    /// deadline is scored `u·B̂ + (1−u)·K̂` over the admissible units
    /// (`u = 1/(1+slack)`), so urgent sequences minimize batch-depth
    /// interference while relaxed ones pack KV headroom. Joins without a
    /// deadline fall back to the pure load-aware rule.
    DeadlineAware(DecodeSchedConfig),
    /// Blind strict round-robin (equal counts, blind to load).
    RoundRobin,
    /// Blind random routing (what session-affinity hashing degenerates
    /// to across DP units). Deterministic given the core's seed.
    Random,
}

impl DecodePolicy {
    /// Stable policy name for reports and CLI round-trips.
    pub fn name(&self) -> &'static str {
        match self {
            DecodePolicy::LoadAware(_) => "load-aware",
            DecodePolicy::DeadlineAware(_) => "deadline-aware",
            DecodePolicy::RoundRobin => "round-robin",
            DecodePolicy::Random => "random",
        }
    }
}

/// Shape + policy configuration of one dispatch core.
#[derive(Debug, Clone)]
pub struct DispatchCoreConfig {
    /// Prefill control plane.
    pub mode: SchedMode,
    /// Prefill instances.
    pub n_prefill: u32,
    /// DP-Attention units per prefill instance.
    pub dp_prefill: u32,
    /// Per-DP prefill chunk capacity (tokens per pass).
    pub c_chunk: u32,
    /// Decode instances.
    pub n_decode: u32,
    /// DP units per decode instance.
    pub dp_decode: u32,
    /// Decode placement policy.
    pub decode_policy: DecodePolicy,
    /// Seed for the random-placement baseline.
    pub seed: u64,
}

/// How the engine reported its device backlog in an `EndForward`.
#[derive(Debug, Clone, Copy)]
pub enum EndForwardBacklog {
    /// The engine reports `tokens` still buffered on the device (the DES
    /// path: per-pass consumption is fed back separately).
    Remaining(u32),
    /// The engine fully consumed everything dispatched to it before
    /// signalling (the local live path: real engines report completion
    /// wholesale, so the core clears the capacity model here).
    ConsumedAll,
    /// The engine consumed the pass it just finished *and* reports
    /// `tokens` still queued behind it — the remote prefill shard path,
    /// where `EndForward` crosses the wire carrying the instance's real
    /// backlog. The core acknowledges everything in flight, then seeds
    /// `R_queued` with the report, so `C_avail` reflects engine truth
    /// instead of per-dispatch bookkeeping.
    Reported(u32),
}

/// One prefilled request waiting for decode placement.
#[derive(Debug, Clone, Copy)]
pub struct DecodeJoin {
    /// Request / job id (driver-scoped).
    pub request_id: u64,
    /// KV tokens resident at join time (the prompt).
    pub kv_tokens: u32,
    /// Output tokens still to generate.
    pub remaining_out: u32,
    /// SLO class (placement order: interactive before batch).
    pub class: SloClass,
    /// Absolute completion deadline on the driver clock, seconds
    /// (deadline-aware placement weight; `None` = pure load).
    pub deadline: Option<f64>,
}

impl DecodeJoin {
    /// Expected resident length once fully decoded — the ledger charge,
    /// and the amount a KV-budget admission must reserve.
    pub fn total_len(&self) -> u32 {
        self.kv_tokens + self.remaining_out
    }
}

/// Result of one [`DispatchCore::place_decode`] cycle.
#[derive(Debug)]
pub struct DecodePlacementOutcome {
    /// `(join, unit)` placements, in placement order.
    pub placed: Vec<(DecodeJoin, DpUnitId)>,
    /// Joins with no admissible unit — park and retry at the next
    /// step/completion boundary (decode-side admission backpressure).
    pub parked: Vec<DecodeJoin>,
}

/// Driver-side admission control for decode placement.
///
/// `admissible` receives the core's live ledger entry for the unit
/// (`state`) and the full join, so budget-style checks can compare the
/// unit's charged occupancy (`⟨B, K⟩`) against the join's eventual
/// resident length without keeping a second ledger of their own — the
/// core updates `state` the moment each join is placed, so later joins
/// in the same cycle observe earlier placements. Drivers with resource
/// state the core cannot see (the DES's engine-backed KV caps) check
/// that state instead and sync it in `commit`, which is called the
/// moment a join is placed.
pub trait DecodeAdmission {
    /// Whether the unit described by `state` can accept `join`.
    fn admissible(&mut self, state: &DpState, join: &DecodeJoin) -> bool;
    /// A join was placed on `unit`; apply it to any backing state now.
    fn commit(&mut self, unit: DpUnitId, join: &DecodeJoin);
}

/// Adapter: admission from a plain `(unit, kv_tokens)` check with no
/// backing state to sync (tests and always-admissible pools). The
/// wrapped closure is the `admissible` check; `commit` is a no-op.
pub struct FnAdmission<F>(pub F);

impl<F: FnMut(DpUnitId, u32) -> bool> DecodeAdmission for FnAdmission<F> {
    fn admissible(&mut self, state: &DpState, join: &DecodeJoin) -> bool {
        (self.0)(state.id, join.kv_tokens)
    }

    fn commit(&mut self, _unit: DpUnitId, _join: &DecodeJoin) {}
}

/// Per-unit occupancy accounting behind [`DecodePoolStats`].
#[derive(Debug, Clone, Default)]
struct UnitOccupancy {
    placed: u64,
    active: u32,
    peak_active: u32,
    seq_seconds: f64,
    last_t: f64,
}

impl UnitOccupancy {
    /// Integrate `active` over time up to `now`.
    fn advance(&mut self, now: f64) {
        if now > self.last_t {
            self.seq_seconds += self.active as f64 * (now - self.last_t);
            self.last_t = now;
        }
    }

    fn join(&mut self, now: f64) {
        self.advance(now);
        self.placed += 1;
        self.active += 1;
        self.peak_active = self.peak_active.max(self.active);
    }

    fn leave(&mut self, now: f64) {
        self.advance(now);
        self.active = self.active.saturating_sub(1);
    }
}

enum PrefillPlane {
    Staggered(StaggeredScheduler),
    Immediate(ImmediateScheduler),
}

/// The shared scheduler-driving state machine (see module docs).
pub struct DispatchCore {
    prefill: PrefillPlane,
    /// Pooled decode DP ledger (`⟨B_i, K_i⟩` per unit, Algorithm 3).
    decode_states: Vec<DpState>,
    policy: DecodePolicy,
    rr_cursor: usize,
    place_rng: Rng,
    occupancy: Vec<UnitOccupancy>,
    /// request id → (flat unit index, ledger charge) for exact release.
    owners: HashMap<u64, (usize, u32)>,
}

impl DispatchCore {
    /// Build a core for the given shape and policies.
    pub fn new(cfg: &DispatchCoreConfig) -> Self {
        let prefill = match &cfg.mode {
            SchedMode::Staggered(sc) => PrefillPlane::Staggered(StaggeredScheduler::new(
                sc.clone(),
                cfg.n_prefill,
                cfg.dp_prefill,
                cfg.c_chunk,
            )),
            SchedMode::Immediate(p) => PrefillPlane::Immediate(ImmediateScheduler::new(
                *p,
                cfg.n_prefill,
                cfg.dp_prefill,
                cfg.c_chunk,
            )),
        };
        let mut decode_states = Vec::new();
        for i in 0..cfg.n_decode.max(1) {
            for d in 0..cfg.dp_decode.max(1) {
                decode_states.push(DpState::new(DpUnitId::new(i, d), 0));
            }
        }
        let occupancy = vec![UnitOccupancy::default(); decode_states.len()];
        DispatchCore {
            prefill,
            decode_states,
            policy: cfg.decode_policy.clone(),
            rr_cursor: 0,
            place_rng: Rng::new(cfg.seed),
            occupancy,
            owners: HashMap::new(),
        }
    }

    // ---- prefill plane -------------------------------------------------

    /// A request arrived at the frontend.
    pub fn on_arrival(&mut self, request: Request, now: f64) -> Vec<SchedulerAction> {
        match &mut self.prefill {
            PrefillPlane::Staggered(s) => s.on_event(SchedulerEvent::Arrival { request, now }),
            PrefillPlane::Immediate(im) => {
                // Immediate dispatch: bind to an instance right now. The
                // decision still flows back as a Dispatch action so both
                // planes drive their drivers through one code path.
                let a = im.dispatch(request);
                vec![SchedulerAction::Dispatch(DispatchBatch {
                    instance: a.unit.instance,
                    assignments: vec![a],
                    at: now,
                })]
            }
        }
    }

    /// A prefill instance finished a forward pass.
    pub fn on_end_forward(
        &mut self,
        instance: u32,
        t_measured: f64,
        backlog: EndForwardBacklog,
        now: f64,
    ) -> Vec<SchedulerAction> {
        let remaining = match backlog {
            EndForwardBacklog::Remaining(b) => b,
            EndForwardBacklog::ConsumedAll => {
                // The engine fully consumed its dispatched batch before
                // signalling: clear the capacity model wholesale (the DES
                // gets this via per-pass ack/consume feedback instead).
                let dps = match &mut self.prefill {
                    PrefillPlane::Staggered(s) => s.state.instance_dps_mut(instance),
                    PrefillPlane::Immediate(im) => im.state.instance_dps_mut(instance),
                };
                for dp in dps {
                    let backlog = dp.u_flight + dp.r_queued;
                    dp.on_ack(dp.u_flight);
                    dp.on_consumed(backlog);
                }
                0
            }
            EndForwardBacklog::Reported(b) => {
                // Engine-truth backlog off the wire: acknowledge every
                // in-flight token (it reached the shard), then seed the
                // device backlog with the report so `C_avail` gates the
                // next dispatch on what the engine actually holds. Live
                // instances run dp=1; with more DPs the report is split
                // evenly (remainder on the first) as the best available
                // approximation.
                let dps = match &mut self.prefill {
                    PrefillPlane::Staggered(s) => s.state.instance_dps_mut(instance),
                    PrefillPlane::Immediate(im) => im.state.instance_dps_mut(instance),
                };
                let n = dps.len().max(1) as u32;
                let (per, extra) = (b / n, b % n);
                for (i, dp) in dps.iter_mut().enumerate() {
                    dp.on_ack(dp.u_flight);
                    dp.r_queued = per + u32::from(i == 0) * extra;
                }
                b
            }
        };
        match &mut self.prefill {
            PrefillPlane::Staggered(s) => s.on_event(SchedulerEvent::EndForward {
                instance,
                t_measured,
                remaining: Some(remaining),
                now,
            }),
            PrefillPlane::Immediate(im) => {
                im.on_end_forward(instance, now);
                Vec::new()
            }
        }
    }

    /// A previously armed timer fired.
    pub fn on_timer(&mut self, now: f64) -> Vec<SchedulerAction> {
        match &mut self.prefill {
            PrefillPlane::Staggered(s) => s.on_event(SchedulerEvent::Timer { now }),
            PrefillPlane::Immediate(_) => Vec::new(),
        }
    }

    /// Dispatched tokens physically arrived on the device: flight→queued.
    pub fn on_deliver_ack(&mut self, unit: DpUnitId, tokens: u32) {
        match &mut self.prefill {
            PrefillPlane::Staggered(s) => s.state.dp_mut(unit).on_ack(tokens),
            PrefillPlane::Immediate(im) => im.state.dp_mut(unit).on_ack(tokens),
        }
    }

    /// A forward pass consumed `tokens` from a unit's device backlog.
    pub fn on_prefill_consumed(&mut self, unit: DpUnitId, tokens: u32) {
        match &mut self.prefill {
            PrefillPlane::Staggered(s) => s.state.dp_mut(unit).on_consumed(tokens),
            PrefillPlane::Immediate(im) => im.state.dp_mut(unit).on_consumed(tokens),
        }
    }

    /// Sum of one prefill instance's per-DP available capacity
    /// (`Σ C_avail`, §4.2.1) — the observable the `EndForward` backlog
    /// variants feed; exposed for gauges and tests.
    pub fn prefill_c_avail(&self, instance: u32) -> i64 {
        let dps = match &self.prefill {
            PrefillPlane::Staggered(s) => s.state.instance_dps(instance),
            PrefillPlane::Immediate(im) => im.state.instance_dps(instance),
        };
        dps.iter().map(|d| d.c_avail()).sum()
    }

    /// Current adaptive interval (0 for the immediate baseline).
    pub fn i_opt(&self) -> f64 {
        match &self.prefill {
            PrefillPlane::Staggered(s) => s.i_opt(),
            PrefillPlane::Immediate(_) => 0.0,
        }
    }

    /// Scheduler-side queued request count (0 for immediate dispatch).
    pub fn queued(&self) -> usize {
        match &self.prefill {
            PrefillPlane::Staggered(s) => s.queued(),
            PrefillPlane::Immediate(_) => 0,
        }
    }

    // ---- decode plane --------------------------------------------------

    /// Number of pooled decode DP units.
    pub fn decode_units(&self) -> usize {
        self.decode_states.len()
    }

    /// Refresh the decode ledger from engine ground truth (flat unit
    /// order). Drivers with observable engines (the DES) call this before
    /// each placement cycle; event-driven drivers rely on the ledger the
    /// core maintains through joins/leaves instead.
    pub fn sync_decode_loads(&mut self, loads: &[DpStepLoad]) {
        for (s, l) in self.decode_states.iter_mut().zip(loads) {
            s.batch = l.batch;
            s.kv_tokens = l.kv_tokens;
        }
    }

    /// Place `joins` across the decode pool under the configured policy.
    ///
    /// Joins with no admissible unit (per [`DecodeAdmission`]) come back
    /// in `parked`. Placement order is SLO class first (interactive
    /// before standard before batch), heaviest-first within a class
    /// ("fill-the-valley", §4.3.2); each placement updates the ledger and
    /// occupancy gauges at time `now` and is committed to the driver via
    /// [`DecodeAdmission::commit`] so intra-cycle admissibility stays
    /// exact.
    pub fn place_decode(
        &mut self,
        mut joins: Vec<DecodeJoin>,
        now: f64,
        admission: &mut dyn DecodeAdmission,
    ) -> DecodePlacementOutcome {
        joins.sort_by(|a, b| {
            a.class
                .rank()
                .cmp(&b.class.rank())
                .then(b.total_len().cmp(&a.total_len()))
        });
        let mut placed = Vec::new();
        let mut parked = Vec::new();
        for j in joins {
            let admit: Vec<usize> = (0..self.decode_states.len())
                .filter(|&u| admission.admissible(&self.decode_states[u], &j))
                .collect();
            if admit.is_empty() {
                parked.push(j);
                continue;
            }
            // Run the policy over a view of the admissible units; the
            // per-join snapshot semantics of Algorithm 3 are preserved by
            // placing one request at a time.
            let mut view: Vec<DpState> = admit
                .iter()
                .map(|&u| self.decode_states[u].clone())
                .collect();
            let chosen = match &self.policy {
                DecodePolicy::LoadAware(cfg) => {
                    let req = Request::new(j.request_id, j.kv_tokens, j.remaining_out, 0.0);
                    let a = schedule_batch(cfg, vec![req], &mut view);
                    view.iter().position(|d| d.id == a[0].unit).unwrap()
                }
                DecodePolicy::DeadlineAware(cfg) => match j.deadline {
                    // Deadline-less joins (legacy clients): pure load.
                    None => {
                        let req = Request::new(j.request_id, j.kv_tokens, j.remaining_out, 0.0);
                        let a = schedule_batch(cfg, vec![req], &mut view);
                        view.iter().position(|d| d.id == a[0].unit).unwrap()
                    }
                    Some(deadline) => {
                        // Urgency interpolates the objective between
                        // batch depth (interference → per-step latency)
                        // and KV occupancy (memory packing). Norms are
                        // over the admissible view; +1 avoids 0/0 on an
                        // idle pool. Ties break to the lower unit index
                        // (deterministic, DES/live parity).
                        let slack = (deadline - now).max(0.0);
                        let urgency = 1.0 / (1.0 + slack);
                        let max_b = view.iter().map(|d| d.batch).max().unwrap_or(0) as f64;
                        let max_k = view.iter().map(|d| d.kv_tokens).max().unwrap_or(0) as f64;
                        let score = |d: &DpState| {
                            urgency * d.batch as f64 / (max_b + 1.0)
                                + (1.0 - urgency) * d.kv_tokens as f64 / (max_k + 1.0)
                        };
                        let mut best = 0usize;
                        for i in 1..view.len() {
                            if score(&view[i]) < score(&view[best]) {
                                best = i;
                            }
                        }
                        best
                    }
                },
                DecodePolicy::Random => self.place_rng.index(view.len()),
                DecodePolicy::RoundRobin => {
                    let i = self.rr_cursor % view.len();
                    self.rr_cursor = self.rr_cursor.wrapping_add(1);
                    i
                }
            };
            let u = admit[chosen];
            let charge = j.total_len();
            // Defensive: ids must be unique, but if a duplicate slips in,
            // release the earlier charge instead of leaking it forever.
            if self.owners.contains_key(&j.request_id) {
                self.on_decode_leave(j.request_id, now);
            }
            self.decode_states[u].on_decode_join(charge);
            self.occupancy[u].join(now);
            self.owners.insert(j.request_id, (u, charge));
            admission.commit(self.decode_states[u].id, &j);
            placed.push((j, self.decode_states[u].id));
        }
        DecodePlacementOutcome { placed, parked }
    }

    /// A placed sequence finished (or was terminally rejected): release
    /// its ledger charge. Returns the owning unit and the released
    /// charge (callers today only test ownership; the charge documents
    /// what the ledger just gave back), `None` for unknown ids (never
    /// placed / already released).
    pub fn on_decode_leave(&mut self, request_id: u64, now: f64) -> Option<(DpUnitId, u32)> {
        let (u, charge) = self.owners.remove(&request_id)?;
        self.decode_states[u].on_decode_leave(charge);
        self.occupancy[u].leave(now);
        Some((self.decode_states[u].id, charge))
    }

    /// Sequences currently placed on `unit` per the core ledger.
    pub fn unit_active(&self, unit: DpUnitId) -> u32 {
        self.decode_states
            .iter()
            .position(|d| d.id == unit)
            .map(|u| self.occupancy[u].active)
            .unwrap_or(0)
    }

    /// Snapshot of the per-DP occupancy + imbalance gauges at `now`.
    pub fn decode_stats(&self, now: f64) -> DecodePoolStats {
        let units = self
            .decode_states
            .iter()
            .zip(&self.occupancy)
            .map(|(s, o)| DpOccupancyGauge {
                unit: s.id.to_string(),
                placed: o.placed,
                active: o.active,
                peak_active: o.peak_active,
                seq_seconds: o.seq_seconds + o.active as f64 * (now - o.last_t).max(0.0),
                kv_tokens: s.kv_tokens,
                // The core is transport-blind; the driver decorates these
                // (and the prefill section) from its transports before
                // publishing.
                transport: "local".to_string(),
                alive: true,
                rtt_ms: None,
                engine_kv_tokens: None,
            })
            .collect();
        DecodePoolStats {
            policy: self.policy.name().to_string(),
            units,
            prefill: Vec::new(),
            kv_wire: Default::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::interval::IntervalConfig;

    fn core_cfg(mode: SchedMode, policy: DecodePolicy) -> DispatchCoreConfig {
        DispatchCoreConfig {
            mode,
            n_prefill: 2,
            dp_prefill: 2,
            c_chunk: 2048,
            n_decode: 2,
            dp_decode: 2,
            decode_policy: policy,
            seed: 5,
        }
    }

    fn staggered() -> SchedMode {
        SchedMode::Staggered(StaggeredConfig {
            interval: IntervalConfig {
                t_default: 0.4,
                ..Default::default()
            },
            ..Default::default()
        })
    }

    fn join(id: u64, kv: u32, out: u32) -> DecodeJoin {
        DecodeJoin {
            request_id: id,
            kv_tokens: kv,
            remaining_out: out,
            class: SloClass::Standard,
            deadline: None,
        }
    }

    fn dispatches(actions: &[SchedulerAction]) -> Vec<&DispatchBatch> {
        actions
            .iter()
            .filter_map(|a| match a {
                SchedulerAction::Dispatch(d) => Some(d),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn immediate_arrival_dispatches_through_action_path() {
        let mut c = DispatchCore::new(&core_cfg(
            SchedMode::Immediate(ImmediatePolicy::RoundRobin),
            DecodePolicy::RoundRobin,
        ));
        let acts = c.on_arrival(Request::new(1, 100, 8, 0.0), 0.0);
        let d = dispatches(&acts);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].assignments.len(), 1);
        assert!(c.on_timer(1.0).is_empty());
    }

    #[test]
    fn staggered_cold_start_dispatches() {
        let mut c = DispatchCore::new(&core_cfg(staggered(), DecodePolicy::RoundRobin));
        let acts = c.on_arrival(Request::new(1, 500, 8, 0.0), 0.0);
        assert_eq!(dispatches(&acts).len(), 1);
        assert!(c.i_opt() > 0.0);
    }

    // The sim-style vs live-style EndForward parity (Remaining(0) after
    // per-pass ack/consume ≡ ConsumedAll) is asserted end to end by
    // tests/decode_balance.rs::sim_and_live_drivers_make_identical_dispatch_decisions.

    #[test]
    fn reported_backlog_seeds_capacity_with_engine_truth() {
        let mut c = DispatchCore::new(&core_cfg(staggered(), DecodePolicy::RoundRobin));
        let full = c.prefill_c_avail(0);
        // Cold start dispatches to instance 0 immediately (500 in flight).
        c.on_arrival(Request::new(1, 500, 8, 0.0), 0.0);
        assert_eq!(c.prefill_c_avail(0), full - 500);
        // The remote prefill path: the shard reports 700 tokens still
        // queued — C_avail must reflect the wire report, not the
        // per-dispatch bookkeeping.
        c.on_end_forward(0, 0.3, EndForwardBacklog::Reported(700), 0.4);
        assert_eq!(c.prefill_c_avail(0), full - 700);
        // A zero report (engine drained) restores full capacity.
        c.on_end_forward(0, 0.3, EndForwardBacklog::Reported(0), 0.8);
        assert_eq!(c.prefill_c_avail(0), full);
    }

    #[test]
    fn round_robin_placement_cycles_units() {
        let mut c = DispatchCore::new(&core_cfg(staggered(), DecodePolicy::RoundRobin));
        let joins = (0..4).map(|i| join(i, 100, 10)).collect();
        let out = c.place_decode(joins, 0.0, &mut FnAdmission(|_, _| true));
        assert_eq!(out.placed.len(), 4);
        assert!(out.parked.is_empty());
        let units: std::collections::BTreeSet<_> = out.placed.iter().map(|(_, u)| *u).collect();
        assert_eq!(units.len(), 4, "RR must touch every unit once");
    }

    #[test]
    fn load_aware_avoids_loaded_unit() {
        let mut c = DispatchCore::new(&core_cfg(
            staggered(),
            DecodePolicy::LoadAware(DecodeSchedConfig::default()),
        ));
        // Load up unit i0d0 with two resident sequences.
        let out = c.place_decode(
            vec![join(1, 100, 10), join(2, 100, 10)],
            0.0,
            &mut FnAdmission(|u, _| u == DpUnitId::new(0, 0)),
        );
        assert_eq!(out.placed.len(), 2);
        // The next free placement must go elsewhere (B=0 beats B=2).
        let out = c.place_decode(vec![join(3, 100, 10)], 0.1, &mut FnAdmission(|_, _| true));
        assert_ne!(out.placed[0].1, DpUnitId::new(0, 0));
    }

    #[test]
    fn inadmissible_joins_park_and_ledger_releases_on_leave() {
        let mut c = DispatchCore::new(&core_cfg(staggered(), DecodePolicy::RoundRobin));
        let out = c.place_decode(vec![join(7, 50, 10)], 0.0, &mut FnAdmission(|_, _| false));
        assert!(out.placed.is_empty());
        assert_eq!(out.parked.len(), 1);
        let out = c.place_decode(out.parked, 1.0, &mut FnAdmission(|_, _| true));
        assert_eq!(out.placed.len(), 1);
        let unit = out.placed[0].1;
        assert_eq!(c.unit_active(unit), 1);
        assert_eq!(c.on_decode_leave(7, 2.0), Some((unit, 60)));
        assert_eq!(c.unit_active(unit), 0);
        assert_eq!(c.on_decode_leave(7, 2.0), None, "double release is safe");
    }

    #[test]
    fn occupancy_integrates_active_seconds() {
        let mut c = DispatchCore::new(&core_cfg(staggered(), DecodePolicy::RoundRobin));
        c.place_decode(vec![join(1, 10, 5)], 0.0, &mut FnAdmission(|_, _| true));
        c.on_decode_leave(1, 2.0);
        let stats = c.decode_stats(3.0);
        let busy: f64 = stats.units.iter().map(|u| u.seq_seconds).sum();
        assert!((busy - 2.0).abs() < 1e-9, "1 active seq for 2 s: {busy}");
        assert_eq!(stats.units.iter().map(|u| u.placed).sum::<u64>(), 1);
        assert!(stats.imbalance() >= 1.0);
    }

    #[test]
    fn placement_orders_interactive_before_batch() {
        let mut c = DispatchCore::new(&core_cfg(staggered(), DecodePolicy::RoundRobin));
        let joins = vec![
            DecodeJoin {
                class: SloClass::Batch,
                ..join(1, 900, 10)
            },
            DecodeJoin {
                class: SloClass::Interactive,
                ..join(2, 100, 10)
            },
            join(3, 500, 10),
        ];
        let out = c.place_decode(joins, 0.0, &mut FnAdmission(|_, _| true));
        let order: Vec<u64> = out.placed.iter().map(|(j, _)| j.request_id).collect();
        assert_eq!(order, vec![2, 3, 1], "class rank beats heaviest-first");
    }

    #[test]
    fn deadline_aware_without_deadline_matches_load_aware() {
        let place = |policy: DecodePolicy| {
            let mut c = DispatchCore::new(&core_cfg(staggered(), policy));
            // Pre-load i0d0 so pure load must avoid it.
            c.place_decode(
                vec![join(1, 100, 10), join(2, 100, 10)],
                0.0,
                &mut FnAdmission(|u, _| u == DpUnitId::new(0, 0)),
            );
            let out = c.place_decode(
                (3..9).map(|i| join(i, 100 + i as u32, 10)).collect(),
                0.1,
                &mut FnAdmission(|_, _| true),
            );
            out.placed
                .iter()
                .map(|(j, u)| (j.request_id, *u))
                .collect::<Vec<_>>()
        };
        assert_eq!(
            place(DecodePolicy::LoadAware(DecodeSchedConfig::default())),
            place(DecodePolicy::DeadlineAware(DecodeSchedConfig::default())),
            "class-less joins fall back to the pure load-aware rule"
        );
    }

    #[test]
    fn deadline_aware_urgent_join_prefers_shallow_batch() {
        let mut c = DispatchCore::new(&core_cfg(
            staggered(),
            DecodePolicy::DeadlineAware(DecodeSchedConfig::default()),
        ));
        // i0d0: deep batch (3 short seqs); i0d1: one huge KV resident.
        for i in 0..3 {
            c.place_decode(
                vec![join(i, 50, 5)],
                0.0,
                &mut FnAdmission(|u, _| u == DpUnitId::new(0, 0)),
            );
        }
        c.place_decode(
            vec![join(10, 20_000, 5)],
            0.0,
            &mut FnAdmission(|u, _| u == DpUnitId::new(0, 1)),
        );
        let two = |u: DpUnitId, _| u == DpUnitId::new(0, 0) || u == DpUnitId::new(0, 1);
        // Urgent (deadline now): batch depth dominates → pick i0d1.
        let urgent = DecodeJoin {
            class: SloClass::Interactive,
            deadline: Some(1.0),
            ..join(20, 100, 10)
        };
        let out = c.place_decode(vec![urgent], 1.0, &mut FnAdmission(two));
        assert_eq!(out.placed[0].1, DpUnitId::new(0, 1));
        c.on_decode_leave(20, 1.0);
        // Relaxed (distant deadline): KV packing dominates → pick i0d0.
        let relaxed = DecodeJoin {
            class: SloClass::Batch,
            deadline: Some(1_000.0),
            ..join(21, 100, 10)
        };
        let out = c.place_decode(vec![relaxed], 1.0, &mut FnAdmission(two));
        assert_eq!(out.placed[0].1, DpUnitId::new(0, 0));
    }

    #[test]
    fn random_placement_is_deterministic_given_seed() {
        let run = || {
            let mut c = DispatchCore::new(&core_cfg(staggered(), DecodePolicy::Random));
            let joins = (0..16).map(|i| join(i, 100, 10)).collect();
            c.place_decode(joins, 0.0, &mut FnAdmission(|_, _| true))
                .placed
                .iter()
                .map(|(j, u)| (j.request_id, *u))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
