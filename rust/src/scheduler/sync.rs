//! §4.1.2 — Robust multi-tier State Synchronization Protocol.
//!
//! Interval estimation alone drifts; the paper supplements it with a
//! triple-check readiness mechanism per instance:
//!
//! 1. **Quiescence polling** (initialization path): observed zero task
//!    depth ⇒ immediately ready. Covers cold start and fast recovery.
//! 2. **Asynchronous `EndForward` signaling** (fast path): the standard
//!    event-driven readiness trigger.
//! 3. **Liveness watchdog** (safety path): a timer armed at dispatch with
//!    threshold `T_timeout = 5 × T̄`; expiration forces a state reset so a
//!    lost EndForward cannot deadlock the cluster. Repeated expirations
//!    mark the instance *suspect* and the system degrades gracefully to
//!    fixed-interval batch dispatch.

use super::state::{GlobalState, InstancePhase};

/// Watchdog multiplier from the paper (`T_timeout = 5 × T̄`).
pub const WATCHDOG_MULTIPLIER: f64 = 5.0;

/// Consecutive watchdog expirations after which an instance is marked
/// suspect rather than silently reset again.
pub const SUSPECT_AFTER_TIMEOUTS: u32 = 3;

/// Per-instance watchdog + readiness bookkeeping.
#[derive(Debug, Clone)]
struct InstanceSync {
    /// Armed watchdog deadline (None when no pass is in flight).
    deadline: Option<f64>,
    /// Consecutive watchdog expirations.
    consecutive_timeouts: u32,
}

/// Outcome of a watchdog sweep.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WatchdogEvent {
    /// Instance timed out and was force-reset to Ready.
    ForcedReset { instance: u32 },
    /// Instance exceeded [`SUSPECT_AFTER_TIMEOUTS`] and is quarantined.
    MarkedSuspect { instance: u32 },
}

/// The synchronization protocol state machine. Owns the instance phases in
/// [`GlobalState`] transitions; callers feed it dispatches, EndForward
/// events, queue-depth observations and periodic watchdog sweeps.
#[derive(Debug, Clone)]
pub struct SyncProtocol {
    per_instance: Vec<InstanceSync>,
    /// True once any instance has been marked suspect — the signal the
    /// outer loop uses to fall back to fixed-interval batch mode.
    degraded: bool,
}

impl SyncProtocol {
    /// Protocol state for `n` instances.
    pub fn new(n: u32) -> Self {
        SyncProtocol {
            per_instance: (0..n)
                .map(|_| InstanceSync {
                    deadline: None,
                    consecutive_timeouts: 0,
                })
                .collect(),
            degraded: false,
        }
    }

    /// Whether graceful degradation (fixed-interval mode) is active.
    pub fn degraded(&self) -> bool {
        self.degraded
    }

    /// Record a dispatch to `instance` at time `now`: the instance becomes
    /// Busy and the watchdog is armed with `5 × t_bar`.
    pub fn on_dispatch(&mut self, g: &mut GlobalState, instance: u32, now: f64, t_bar: f64) {
        let s = &mut g.instances[instance as usize];
        s.phase = InstancePhase::Busy;
        s.last_dispatch = now;
        s.queue_depth += 1;
        self.per_instance[instance as usize].deadline =
            Some(now + WATCHDOG_MULTIPLIER * t_bar.max(1e-6));
    }

    /// Fast path: `EndForward` received from `instance` at `now`. Disarms
    /// the watchdog, clears the timeout streak, and marks Ready when the
    /// device queue has drained.
    ///
    /// Per paper Fig. 5 the EndForward payload carries the instance's
    /// *remaining token count*; engines that report it pass
    /// `remaining = Some(backlog)` and the depth is synced exactly.
    /// `None` falls back to per-dispatch decrement accounting.
    pub fn on_end_forward(
        &mut self,
        g: &mut GlobalState,
        instance: u32,
        now: f64,
        remaining: Option<u32>,
    ) {
        let sync = &mut self.per_instance[instance as usize];
        sync.consecutive_timeouts = 0;
        let s = &mut g.instances[instance as usize];
        s.last_end_forward = now;
        match remaining {
            Some(n) => s.queue_depth = n,
            None => s.queue_depth = s.queue_depth.saturating_sub(1),
        }
        // A completed pass *freed capacity*: the instance is dispatchable
        // again even if backlog remains on-device — how much can actually
        // be sent is governed by the C_avail capacity model (§4.2.1), not
        // by this binary phase. (Suspect instances stay quarantined.)
        if s.phase == InstancePhase::Busy {
            s.phase = InstancePhase::Ready;
        }
        sync.deadline = None;
    }

    /// Initialization path: a queue-depth observation (polling). Zero
    /// depth is an immediate readiness trigger regardless of signals.
    pub fn on_queue_observation(&mut self, g: &mut GlobalState, instance: u32, depth: u32) {
        let s = &mut g.instances[instance as usize];
        s.queue_depth = depth;
        if depth == 0 && s.phase == InstancePhase::Busy {
            s.phase = InstancePhase::Ready;
            self.per_instance[instance as usize].deadline = None;
        }
    }

    /// Safety path: sweep all watchdogs at `now`. Expired instances are
    /// force-reset (preventing distributed deadlock); repeat offenders are
    /// marked suspect and the protocol enters degraded mode.
    pub fn sweep_watchdogs(&mut self, g: &mut GlobalState, now: f64) -> Vec<WatchdogEvent> {
        let mut events = Vec::new();
        for (i, sync) in self.per_instance.iter_mut().enumerate() {
            let Some(deadline) = sync.deadline else {
                continue;
            };
            if now < deadline {
                continue;
            }
            sync.deadline = None;
            sync.consecutive_timeouts += 1;
            let s = &mut g.instances[i];
            if sync.consecutive_timeouts >= SUSPECT_AFTER_TIMEOUTS {
                s.phase = InstancePhase::Suspect;
                self.degraded = true;
                events.push(WatchdogEvent::MarkedSuspect { instance: i as u32 });
            } else {
                // Forced state reset: assume the pass (and anything queued
                // behind it) was lost or will complete unobserved.
                s.phase = InstancePhase::Ready;
                s.queue_depth = 0;
                events.push(WatchdogEvent::ForcedReset { instance: i as u32 });
            }
        }
        events
    }

    /// Re-admit a recovered instance (health check passed): clears suspect
    /// state; degraded mode ends when no suspects remain.
    pub fn reinstate(&mut self, g: &mut GlobalState, instance: u32) {
        let s = &mut g.instances[instance as usize];
        if s.phase == InstancePhase::Suspect {
            s.phase = InstancePhase::Ready;
            s.queue_depth = 0;
        }
        self.per_instance[instance as usize].consecutive_timeouts = 0;
        self.degraded = g
            .instances
            .iter()
            .any(|i| i.phase == InstancePhase::Suspect);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(n: u32) -> (GlobalState, SyncProtocol) {
        (GlobalState::new(n, 2, 1024), SyncProtocol::new(n))
    }

    #[test]
    fn dispatch_then_end_forward_cycle() {
        let (mut g, mut p) = setup(2);
        p.on_dispatch(&mut g, 0, 10.0, 0.5);
        assert_eq!(g.instances[0].phase, InstancePhase::Busy);
        assert_eq!(g.instances[0].queue_depth, 1);
        p.on_end_forward(&mut g, 0, 10.4, None);
        assert_eq!(g.instances[0].phase, InstancePhase::Ready);
        assert_eq!(g.instances[0].queue_depth, 0);
    }

    #[test]
    fn end_forward_frees_capacity_even_with_backlog() {
        let (mut g, mut p) = setup(1);
        p.on_dispatch(&mut g, 0, 0.0, 0.5);
        assert_eq!(g.instances[0].phase, InstancePhase::Busy);
        // EndForward with backlog still pending: dispatchable again — the
        // C_avail model limits how much the next cycle can send.
        p.on_end_forward(&mut g, 0, 0.5, Some(500));
        assert_eq!(g.instances[0].phase, InstancePhase::Ready);
        assert_eq!(g.instances[0].queue_depth, 500);
        p.on_end_forward(&mut g, 0, 1.0, Some(0));
        assert_eq!(g.instances[0].queue_depth, 0);
    }

    #[test]
    fn quiescence_polling_recovers() {
        let (mut g, mut p) = setup(1);
        p.on_dispatch(&mut g, 0, 0.0, 0.5);
        // EndForward lost; an external poll observes an empty device queue.
        p.on_queue_observation(&mut g, 0, 0);
        assert_eq!(g.instances[0].phase, InstancePhase::Ready);
    }

    #[test]
    fn watchdog_threshold_is_5x() {
        let (mut g, mut p) = setup(1);
        p.on_dispatch(&mut g, 0, 0.0, 0.4);
        assert!(p.sweep_watchdogs(&mut g, 1.9).is_empty()); // 5×0.4 = 2.0
        let ev = p.sweep_watchdogs(&mut g, 2.0);
        assert_eq!(ev, vec![WatchdogEvent::ForcedReset { instance: 0 }]);
        assert_eq!(g.instances[0].phase, InstancePhase::Ready);
        assert_eq!(g.instances[0].queue_depth, 0);
    }

    #[test]
    fn repeated_timeouts_mark_suspect_and_degrade() {
        let (mut g, mut p) = setup(2);
        for k in 0..SUSPECT_AFTER_TIMEOUTS {
            p.on_dispatch(&mut g, 0, k as f64 * 10.0, 0.1);
            let ev = p.sweep_watchdogs(&mut g, k as f64 * 10.0 + 1.0);
            if k + 1 < SUSPECT_AFTER_TIMEOUTS {
                assert_eq!(ev, vec![WatchdogEvent::ForcedReset { instance: 0 }]);
            } else {
                assert_eq!(ev, vec![WatchdogEvent::MarkedSuspect { instance: 0 }]);
            }
        }
        assert!(p.degraded());
        assert_eq!(g.instances[0].phase, InstancePhase::Suspect);
        assert_eq!(g.n_active(), 1);

        p.reinstate(&mut g, 0);
        assert!(!p.degraded());
        assert_eq!(g.instances[0].phase, InstancePhase::Ready);
    }

    #[test]
    fn end_forward_clears_timeout_streak() {
        let (mut g, mut p) = setup(1);
        p.on_dispatch(&mut g, 0, 0.0, 0.1);
        p.sweep_watchdogs(&mut g, 1.0); // one timeout
        p.on_dispatch(&mut g, 0, 2.0, 0.1);
        p.on_end_forward(&mut g, 0, 2.1, None); // healthy again
        p.on_dispatch(&mut g, 0, 3.0, 0.1);
        let ev = p.sweep_watchdogs(&mut g, 4.0);
        // Streak restarted: this is timeout #1, not #2.
        assert_eq!(ev, vec![WatchdogEvent::ForcedReset { instance: 0 }]);
        assert!(!p.degraded());
    }
}
