//! Token-level radix (prefix) tree for cache-aware allocation (§4.2.2).
//!
//! The cache-aware PBAA variant scores a DP unit by *effective
//! computational cost*: `C_avail − (Len(r) − Len_hit(r, d))`. `Len_hit` is
//! the longest prefix of the request already resident in the unit's KV
//! cache. We track residency with one radix tree per DP unit, in the style
//! of SGLang's RadixAttention / SGL-Router's approximate tree, with
//! LRU-by-leaf eviction under a token budget.

use std::collections::HashMap;

/// One radix-tree node: an edge label (token run) plus children keyed by
/// their first token.
#[derive(Debug)]
struct Node {
    /// Token run on the edge leading into this node.
    edge: Vec<u32>,
    children: HashMap<u32, usize>, // first token -> node index
    /// Last-touch logical timestamp for LRU eviction.
    last_touch: u64,
}

/// Radix tree over token sequences with a token budget and LRU eviction.
#[derive(Debug)]
pub struct RadixTree {
    nodes: Vec<Node>,
    /// Total tokens resident (sum of edge lengths).
    resident: u64,
    /// Token budget; inserts beyond it evict least-recently-used leaves.
    budget: u64,
    tick: u64,
}

impl RadixTree {
    /// Empty tree with a residency budget in tokens (`u64::MAX` =
    /// unbounded).
    pub fn new(budget: u64) -> Self {
        RadixTree {
            nodes: vec![Node {
                edge: Vec::new(),
                children: HashMap::new(),
                last_touch: 0,
            }],
            resident: 0,
            budget,
            tick: 0,
        }
    }

    /// Tokens currently resident.
    pub fn resident_tokens(&self) -> u64 {
        self.resident
    }

    /// Longest cached prefix of `tokens`, in tokens. Touches the path for
    /// LRU purposes.
    pub fn match_prefix(&mut self, tokens: &[u32]) -> u32 {
        self.tick += 1;
        let tick = self.tick;
        let mut node = 0usize;
        let mut matched = 0usize;
        self.nodes[0].last_touch = tick;
        while matched < tokens.len() {
            let Some(&child) = self.nodes[node].children.get(&tokens[matched]) else {
                break;
            };
            let edge_len = self.nodes[child].edge.len();
            let avail = &tokens[matched..];
            let common = common_len(&self.nodes[child].edge, avail);
            matched += common;
            self.nodes[child].last_touch = tick;
            if common < edge_len {
                break; // partial edge match: stop inside the edge
            }
            node = child;
        }
        matched as u32
    }

    /// Insert `tokens` (idempotent for already-resident prefixes); returns
    /// the number of *new* tokens added. Evicts LRU leaves if over budget.
    pub fn insert(&mut self, tokens: &[u32]) -> u64 {
        self.tick += 1;
        let tick = self.tick;
        let mut node = 0usize;
        let mut pos = 0usize;
        let mut added = 0u64;
        self.nodes[0].last_touch = tick;
        while pos < tokens.len() {
            let first = tokens[pos];
            match self.nodes[node].children.get(&first).copied() {
                None => {
                    // New leaf with the whole remainder.
                    let rest = tokens[pos..].to_vec();
                    added += rest.len() as u64;
                    self.resident += rest.len() as u64;
                    let idx = self.nodes.len();
                    self.nodes.push(Node {
                        edge: rest,
                        children: HashMap::new(),
                        last_touch: tick,
                    });
                    self.nodes[node].children.insert(first, idx);
                    break;
                }
                Some(child) => {
                    let common = common_len(&self.nodes[child].edge, &tokens[pos..]);
                    let edge_len = self.nodes[child].edge.len();
                    self.nodes[child].last_touch = tick;
                    if common == edge_len {
                        // Full edge consumed; descend.
                        node = child;
                        pos += common;
                    } else {
                        // Split the edge at `common`.
                        let tail = self.nodes[child].edge.split_off(common);
                        let grandchild_children = std::mem::take(&mut self.nodes[child].children);
                        let g_idx = self.nodes.len();
                        self.nodes.push(Node {
                            edge: tail.clone(),
                            children: grandchild_children,
                            last_touch: self.nodes[child].last_touch,
                        });
                        self.nodes[child].children.insert(tail[0], g_idx);
                        node = child;
                        pos += common;
                        // Loop continues: remainder (if any) becomes a new
                        // sibling leaf on the next iteration.
                    }
                }
            }
        }
        self.evict_to_budget();
        added
    }

    /// Evict least-recently-touched leaves until within budget.
    fn evict_to_budget(&mut self) {
        while self.resident > self.budget {
            // Find the LRU leaf (excluding root).
            let mut lru: Option<(usize, u64)> = None;
            for (i, n) in self.nodes.iter().enumerate().skip(1) {
                if n.children.is_empty() && !n.edge.is_empty() {
                    match lru {
                        Some((_, t)) if n.last_touch >= t => {}
                        _ => lru = Some((i, n.last_touch)),
                    }
                }
            }
            let Some((leaf, _)) = lru else { break };
            let removed = self.nodes[leaf].edge.len() as u64;
            // Unlink from parent.
            let first = self.nodes[leaf].edge[0];
            for n in self.nodes.iter_mut() {
                if n.children.get(&first) == Some(&leaf) {
                    n.children.remove(&first);
                    break;
                }
            }
            self.nodes[leaf].edge.clear();
            self.resident -= removed;
        }
    }
}

fn common_len(a: &[u32], b: &[u32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

/// Per-DP-unit prefix cache model used by cache-aware PBAA. Maps each DP
/// unit to a radix tree; requests carry (group, prefix_len) and the tree
/// stores the group's synthetic token stream.
#[derive(Debug)]
pub struct PrefixCacheModel {
    trees: Vec<RadixTree>,
    /// Index offset: callers holding an instance-local DP slice set this
    /// so their slice-local indices resolve to pool-global units.
    base: usize,
}

impl PrefixCacheModel {
    /// One tree per DP unit with the given per-unit token budget.
    pub fn new(n_units: usize, budget_per_unit: u64) -> Self {
        PrefixCacheModel {
            trees: (0..n_units).map(|_| RadixTree::new(budget_per_unit)).collect(),
            base: 0,
        }
    }

    /// Set the slice-local → pool-global index offset for subsequent
    /// `len_hit` / `admit` calls.
    pub fn set_base(&mut self, base: usize) {
        self.base = base;
    }

    /// Deterministic synthetic token stream for a prefix group. The DES
    /// has no real token text; this makes distinct groups occupy disjoint
    /// tree paths while identical groups collide perfectly — exactly the
    /// property `Len_hit` needs.
    pub fn group_tokens(group: u64, len: u32) -> Vec<u32> {
        let mut state = group ^ 0x9E37_79B9_7F4A_7C15;
        (0..len)
            .map(|i| {
                // Mix group and position; stay deterministic.
                let x = crate::util::prng::splitmix64(&mut state);
                ((x >> 17) as u32) ^ i
            })
            .collect()
    }

    /// `Len_hit(r, d)` for a request with prefix `(group, len)` on unit
    /// `d` (index relative to the current base).
    pub fn len_hit(&mut self, unit: usize, group: u64, prefix_len: u32) -> u32 {
        if prefix_len == 0 {
            return 0;
        }
        let toks = Self::group_tokens(group, prefix_len);
        let i = self.base + unit;
        self.trees[i].match_prefix(&toks)
    }

    /// Record that unit `d` (base-relative) now holds the prefix.
    pub fn admit(&mut self, unit: usize, group: u64, prefix_len: u32) {
        if prefix_len == 0 {
            return;
        }
        let toks = Self::group_tokens(group, prefix_len);
        let i = self.base + unit;
        self.trees[i].insert(&toks);
    }

    /// Resident tokens on a unit (base-relative; for tests/metrics).
    pub fn resident(&self, unit: usize) -> u64 {
        self.trees[self.base + unit].resident_tokens()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_tree_matches_nothing() {
        let mut t = RadixTree::new(u64::MAX);
        assert_eq!(t.match_prefix(&[1, 2, 3]), 0);
    }

    #[test]
    fn insert_then_full_match() {
        let mut t = RadixTree::new(u64::MAX);
        assert_eq!(t.insert(&[1, 2, 3, 4]), 4);
        assert_eq!(t.match_prefix(&[1, 2, 3, 4]), 4);
        assert_eq!(t.match_prefix(&[1, 2, 3, 4, 5]), 4);
        assert_eq!(t.match_prefix(&[1, 2]), 2);
        assert_eq!(t.match_prefix(&[2, 2]), 0);
    }

    #[test]
    fn insert_is_idempotent() {
        let mut t = RadixTree::new(u64::MAX);
        t.insert(&[5, 6, 7]);
        assert_eq!(t.insert(&[5, 6, 7]), 0);
        assert_eq!(t.resident_tokens(), 3);
    }

    #[test]
    fn edge_split_on_divergence() {
        let mut t = RadixTree::new(u64::MAX);
        t.insert(&[1, 2, 3, 4]);
        let added = t.insert(&[1, 2, 9, 9]);
        assert_eq!(added, 2);
        assert_eq!(t.resident_tokens(), 6);
        assert_eq!(t.match_prefix(&[1, 2, 3, 4]), 4);
        assert_eq!(t.match_prefix(&[1, 2, 9, 9]), 4);
        assert_eq!(t.match_prefix(&[1, 2]), 2);
    }

    #[test]
    fn extension_of_existing_path() {
        let mut t = RadixTree::new(u64::MAX);
        t.insert(&[1, 2]);
        assert_eq!(t.insert(&[1, 2, 3, 4]), 2);
        assert_eq!(t.match_prefix(&[1, 2, 3, 4]), 4);
    }

    #[test]
    fn eviction_respects_budget() {
        let mut t = RadixTree::new(6);
        t.insert(&[1, 1, 1]);
        t.insert(&[2, 2, 2]);
        assert_eq!(t.resident_tokens(), 6);
        // Touch [1,1,1] so [2,2,2] is LRU.
        t.match_prefix(&[1, 1, 1]);
        t.insert(&[3, 3, 3]);
        assert!(t.resident_tokens() <= 6);
        assert_eq!(t.match_prefix(&[1, 1, 1]), 3); // survivor
        assert_eq!(t.match_prefix(&[2, 2, 2]), 0); // evicted
    }

    #[test]
    fn cache_model_group_hit() {
        let mut m = PrefixCacheModel::new(2, u64::MAX);
        assert_eq!(m.len_hit(0, 42, 100), 0);
        m.admit(0, 42, 100);
        assert_eq!(m.len_hit(0, 42, 100), 100);
        assert_eq!(m.len_hit(1, 42, 100), 0); // other unit cold
        assert_eq!(m.len_hit(0, 43, 100), 0); // other group disjoint
        // Shorter prefix of the same group still hits fully.
        assert_eq!(m.len_hit(0, 42, 60), 60);
    }

    #[test]
    fn group_tokens_deterministic_and_prefix_stable() {
        let a = PrefixCacheModel::group_tokens(7, 50);
        let b = PrefixCacheModel::group_tokens(7, 50);
        assert_eq!(a, b);
        let c = PrefixCacheModel::group_tokens(7, 30);
        assert_eq!(&a[..30], &c[..]);
        let d = PrefixCacheModel::group_tokens(8, 50);
        assert_ne!(a, d);
    }
}
