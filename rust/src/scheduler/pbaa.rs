//! Algorithm 2 — Prioritized Batch Allocation Algorithm (PBAA).
//!
//! Maps a buffered batch of prefill requests onto DP units in three
//! phases:
//!
//! 1. **Starvation prevention** — requests left over from previous cycles
//!    (`Q_pending`) are allocated first, enforcing FCFS fairness.
//! 2. **Straggler-aware bin packing** — within each phase, requests are
//!    sorted by length descending and each goes to the DP unit with the
//!    highest available capacity ("water-filling"), proactively levelling
//!    the load the DP sync barrier will see.
//! 3. **Overload protection** — requests that fail allocation `N_limit`
//!    cycles in a row trigger flow control.
//!
//! Cache-aware mode replaces raw capacity with effective computational
//! cost: `C_avail − (Len(r) − Len_hit(r, d))`.

use super::prefix::PrefixCacheModel;
use super::state::DpState;
use super::types::{DpUnitId, Request, SloClass};

/// PBAA configuration.
#[derive(Debug, Clone)]
pub struct PbaaConfig {
    /// Maximum tolerable waiting cycles before flow control (`N_limit`).
    pub n_limit: u32,
    /// Use the cache-aware objective (§4.2.2 "Optimization for Context
    /// Caching").
    pub cache_aware: bool,
}

impl Default for PbaaConfig {
    fn default() -> Self {
        PbaaConfig {
            n_limit: 32,
            cache_aware: false,
        }
    }
}

/// One allocation decision.
#[derive(Debug, Clone)]
pub struct Assignment {
    /// The request being placed.
    pub request: Request,
    /// Chosen DP unit.
    pub unit: DpUnitId,
    /// Tokens of the request already cached on that unit (0 in basic
    /// mode); the engine only recomputes `input_tokens − cached_tokens`.
    pub cached_tokens: u32,
}

/// Result of one PBAA cycle.
#[derive(Debug, Default)]
pub struct PbaaOutcome {
    /// Request → DP assignments for this dispatch.
    pub assignments: Vec<Assignment>,
    /// Requests that could not be placed (carried to the next cycle with
    /// incremented wait counters).
    pub next_queue: Vec<Request>,
    /// Requests whose wait count exceeded `N_limit` this cycle (flow
    /// control must throttle/reject).
    pub overloaded: Vec<Request>,
}

/// Run one PBAA cycle over the pending (legacy) queue and new arrivals,
/// against the DP units in `dps` (their `u_flight` is updated in place for
/// assigned requests). `cache` supplies `Len_hit` when configured.
pub fn allocate(
    cfg: &PbaaConfig,
    pending: Vec<Request>,
    new_arrivals: Vec<Request>,
    dps: &mut [DpState],
    mut cache: Option<&mut PrefixCacheModel>,
) -> PbaaOutcome {
    let mut out = PbaaOutcome::default();

    // Phase 1: prioritize legacy; Phase 2: new arrivals.
    greedy_dispatch(cfg, pending, dps, cache.as_deref_mut(), &mut out);
    greedy_dispatch(cfg, new_arrivals, dps, cache.as_deref_mut(), &mut out);

    // Phase 3: overload detection on everything that failed to place.
    // Class-ordered shedding: `Interactive` requests are never surrendered
    // to flow control. Strict class priority in `greedy_dispatch` means an
    // interactive request only lingers past `N_limit` when interactive
    // load *alone* exceeds capacity, and the SLO contract prefers degraded
    // latency over refusal there. Standard/batch overflow at `N_limit` as
    // in the paper — batch, dispatched last, starves into it first.
    let mut survivors = Vec::with_capacity(out.next_queue.len());
    for mut r in out.next_queue.drain(..) {
        r.wait_cycles += 1;
        if r.wait_cycles > cfg.n_limit && r.class != SloClass::Interactive {
            out.overloaded.push(r);
        } else {
            survivors.push(r);
        }
    }
    out.next_queue = survivors;
    out
}

/// The paper's `GreedyDispatch(Q)`, made SLO-aware: order the buffering
/// window by class first (interactive before standard before batch), by
/// length descending within a class (reduce fragmentation), then
/// water-fill. Under sustained overload this starves batch traffic into
/// the `N_limit` overflow first, so flow control sheds it first.
fn greedy_dispatch(
    cfg: &PbaaConfig,
    mut queue: Vec<Request>,
    dps: &mut [DpState],
    mut cache: Option<&mut PrefixCacheModel>,
    out: &mut PbaaOutcome,
) {
    // Stable sort: equal (class, length) keys keep FCFS order.
    queue.sort_by(|a, b| {
        a.class
            .rank()
            .cmp(&b.class.rank())
            .then(b.input_tokens.cmp(&a.input_tokens))
    });

    for r in queue {
        // `Capacity(r, d)` for every unit; pick the argmax.
        let mut best: Option<(usize, i64, u32)> = None; // (idx, score, hit)
        for (i, d) in dps.iter().enumerate() {
            let (score, hit) = capacity(cfg, &r, d, i, cache.as_deref_mut());
            match best {
                Some((_, s, _)) if s >= score => {}
                _ => best = Some((i, score, hit)),
            }
        }
        let Some((idx, _score, hit)) = best else {
            out.next_queue.push(r);
            continue;
        };
        // Assign only while the chosen unit has positive headroom; a long
        // request may drive C_avail negative after assignment (it spans
        // multiple chunked-prefill passes), matching Alg. 2 line 8–10.
        if dps[idx].c_avail() > 0 {
            let effective = r.input_tokens.saturating_sub(hit);
            dps[idx].on_dispatch(effective);
            if let Some(c) = cache.as_deref_mut() {
                if let Some(g) = r.prefix_group {
                    c.admit(idx, g, r.prefix_len);
                }
            }
            out.assignments.push(Assignment {
                unit: dps[idx].id,
                cached_tokens: hit,
                request: r,
            });
        } else {
            out.next_queue.push(r);
        }
    }
}

/// `Capacity(r, d)` — basic: `C_avail − L(r)`; cache-aware:
/// `C_avail − (L(r) − L_hit(r, d))`. Returns `(score, len_hit)`.
fn capacity(
    cfg: &PbaaConfig,
    r: &Request,
    d: &DpState,
    unit_index: usize,
    cache: Option<&mut PrefixCacheModel>,
) -> (i64, u32) {
    let hit = if cfg.cache_aware {
        match (cache, r.prefix_group) {
            (Some(c), Some(g)) => c.len_hit(unit_index, g, r.prefix_len),
            _ => 0,
        }
    } else {
        0
    };
    let effective = r.input_tokens.saturating_sub(hit) as i64;
    (d.c_avail() - effective, hit)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn units(caps: &[u32]) -> Vec<DpState> {
        caps.iter()
            .enumerate()
            .map(|(i, &c)| DpState::new(DpUnitId::new(0, i as u32), c))
            .collect()
    }

    fn reqs(lens: &[u32]) -> Vec<Request> {
        lens.iter()
            .enumerate()
            .map(|(i, &l)| Request::new(i as u64, l, 10, 0.0))
            .collect()
    }

    #[test]
    fn water_filling_balances_load() {
        let mut dps = units(&[3000, 3000]);
        let out = allocate(
            &PbaaConfig::default(),
            vec![],
            reqs(&[1000, 900, 800, 700]),
            &mut dps,
            None,
        );
        assert_eq!(out.assignments.len(), 4);
        // Longest→emptiest: 1000→d0, 900→d1, 800→d1(2100>2000), 700→d0.
        let load0: u32 = dps[0].u_flight;
        let load1: u32 = dps[1].u_flight;
        assert_eq!(load0 + load1, 3400);
        assert!((load0 as i64 - load1 as i64).abs() <= 100, "{load0} vs {load1}");
    }

    #[test]
    fn legacy_requests_have_priority() {
        let mut dps = units(&[1000]);
        let legacy = reqs(&[1000]); // exactly fills the chunk
        let fresh = reqs(&[900]); // must NOT jump the queue
        let out = allocate(&PbaaConfig::default(), legacy, fresh, &mut dps, None);
        assert_eq!(out.assignments.len(), 1);
        assert_eq!(out.assignments[0].request.input_tokens, 1000);
        assert_eq!(out.next_queue.len(), 1);
        assert_eq!(out.next_queue[0].input_tokens, 900);
    }

    #[test]
    fn no_assignment_without_headroom() {
        let mut dps = units(&[500]);
        dps[0].on_dispatch(500); // saturated
        let out = allocate(&PbaaConfig::default(), vec![], reqs(&[100]), &mut dps, None);
        assert!(out.assignments.is_empty());
        assert_eq!(out.next_queue.len(), 1);
        assert_eq!(out.next_queue[0].wait_cycles, 1);
    }

    #[test]
    fn long_request_spans_chunks() {
        // A request longer than the chunk still places on a unit with
        // positive headroom (chunked prefill executes it across passes).
        let mut dps = units(&[1000]);
        let out = allocate(&PbaaConfig::default(), vec![], reqs(&[2500]), &mut dps, None);
        assert_eq!(out.assignments.len(), 1);
        assert_eq!(dps[0].c_avail(), -1500);
    }

    #[test]
    fn overload_triggers_after_n_limit() {
        let cfg = PbaaConfig {
            n_limit: 2,
            cache_aware: false,
        };
        let mut dps = units(&[10]);
        dps[0].on_dispatch(10);
        let mut pending = reqs(&[100]);
        for cycle in 0..3 {
            let out = allocate(&cfg, pending, vec![], &mut dps, None);
            if cycle < 2 {
                assert_eq!(out.next_queue.len(), 1, "cycle {cycle}");
                assert!(out.overloaded.is_empty());
                pending = out.next_queue;
            } else {
                assert!(out.next_queue.is_empty());
                assert_eq!(out.overloaded.len(), 1);
                assert_eq!(out.overloaded[0].wait_cycles, 3);
                pending = vec![];
            }
        }
        assert!(pending.is_empty());
    }

    #[test]
    fn cache_aware_prefers_warm_unit() {
        let cfg = PbaaConfig {
            n_limit: 8,
            cache_aware: true,
        };
        let mut cache = PrefixCacheModel::new(2, u64::MAX);
        cache.admit(1, 77, 600); // unit 1 holds the prefix
        let mut dps = units(&[3000, 3000]);
        // Slightly load unit 1 so raw capacity would pick unit 0.
        dps[1].on_dispatch(200);
        let r = vec![Request::new(1, 1000, 10, 0.0).with_prefix(77, 600)];
        let out = allocate(&cfg, vec![], r, &mut dps, Some(&mut cache));
        assert_eq!(out.assignments.len(), 1);
        // Unit 1 score: (3000-200) - (1000-600) = 2400; unit 0: 3000-1000 = 2000.
        assert_eq!(out.assignments[0].unit, DpUnitId::new(0, 1));
        assert_eq!(out.assignments[0].cached_tokens, 600);
        // Only the uncached tokens hit the device budget.
        assert_eq!(dps[1].u_flight, 200 + 400);
    }

    #[test]
    fn cache_admission_happens_on_assignment() {
        let cfg = PbaaConfig {
            n_limit: 8,
            cache_aware: true,
        };
        let mut cache = PrefixCacheModel::new(1, u64::MAX);
        let mut dps = units(&[3000]);
        let r1 = vec![Request::new(1, 1000, 10, 0.0).with_prefix(9, 500)];
        allocate(&cfg, vec![], r1, &mut dps, Some(&mut cache));
        // Second request of the same group now hits.
        assert_eq!(cache.len_hit(0, 9, 500), 500);
    }

    #[test]
    fn stable_fcfs_among_equal_lengths() {
        let mut dps = units(&[10_000]);
        let rs: Vec<Request> = (0..4).map(|i| Request::new(i, 100, 1, i as f64)).collect();
        let out = allocate(&PbaaConfig::default(), vec![], rs, &mut dps, None);
        let ids: Vec<u64> = out.assignments.iter().map(|a| a.request.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn batch_formation_orders_by_class_then_length() {
        use crate::scheduler::types::SloClass;
        let mut dps = units(&[10_000]);
        // Arrival order: long batch, short interactive, mid standard,
        // long interactive. Expected dispatch order: interactive (long,
        // short), standard, batch.
        let rs = vec![
            Request::new(0, 900, 1, 0.0).with_class(SloClass::Batch),
            Request::new(1, 100, 1, 0.1).with_class(SloClass::Interactive),
            Request::new(2, 500, 1, 0.2),
            Request::new(3, 800, 1, 0.3).with_class(SloClass::Interactive),
        ];
        let out = allocate(&PbaaConfig::default(), vec![], rs, &mut dps, None);
        let ids: Vec<u64> = out.assignments.iter().map(|a| a.request.id).collect();
        assert_eq!(ids, vec![3, 1, 2, 0]);
    }

    #[test]
    fn interactive_never_overflows() {
        let cfg = PbaaConfig {
            n_limit: 1,
            cache_aware: false,
        };
        let mut dps = units(&[10]);
        dps[0].on_dispatch(10); // saturated: nothing can place
        let mut pending = vec![
            Request::new(0, 100, 1, 0.0).with_class(SloClass::Interactive),
            Request::new(1, 100, 1, 0.0).with_class(SloClass::Batch),
        ];
        let mut overflowed = Vec::new();
        for _ in 0..5 {
            let out = allocate(&cfg, pending, vec![], &mut dps, None);
            overflowed.extend(out.overloaded);
            pending = out.next_queue;
        }
        assert!(overflowed.iter().all(|r| r.class == SloClass::Batch));
        assert_eq!(overflowed.len(), 1);
        // The interactive request rides the pending queue indefinitely
        // instead of being shed.
        assert_eq!(pending.len(), 1);
        assert_eq!(pending[0].class, SloClass::Interactive);
        assert!(pending[0].wait_cycles >= 5);
    }

    #[test]
    fn batch_class_waits_when_interactive_takes_capacity() {
        use crate::scheduler::types::SloClass;
        let cfg = PbaaConfig {
            n_limit: 1,
            cache_aware: false,
        };
        // Capacity for exactly one 500-token request per cycle; the
        // interactive request wins it, the batch one waits and overflows.
        let mut dps = units(&[500]);
        let rs = vec![
            Request::new(0, 500, 1, 0.0).with_class(SloClass::Batch),
            Request::new(1, 500, 1, 0.1).with_class(SloClass::Interactive),
        ];
        let out = allocate(&cfg, vec![], rs, &mut dps, None);
        assert_eq!(out.assignments.len(), 1);
        assert_eq!(out.assignments[0].request.id, 1);
        assert_eq!(out.next_queue.len(), 1);
        assert_eq!(out.next_queue[0].class, SloClass::Batch);
    }
}
