//! The paper's contribution: Staggered Batch Scheduling.
//!
//! Everything in this module is a *pure state machine*: no clocks, no
//! threads, no I/O. Timestamps come in through event arguments and
//! decisions go out as action values, so the same scheduler code is driven
//! by the discrete-event simulator ([`crate::cluster::sim`]) for the
//! paper's cluster-scale experiments and by the threaded real-engine
//! fabric ([`crate::cluster::workers`]) for end-to-end serving.
//!
//! Map from the paper:
//!
//! | Paper | Module |
//! |---|---|
//! | §4.1.1 Algorithm 1 (adaptive interval)      | [`interval`]  |
//! | §4.1.2 multi-tier state synchronization     | [`sync`]      |
//! | §4.2 Algorithm 2 (PBAA, water-filling)      | [`pbaa`]      |
//! | §4.2.2 cache-aware capacity                 | [`prefix`]    |
//! | §4.3 Algorithm 3 (IQR + lexicographic)      | [`decode`]    |
//! | Fig. 5 main schedule loop (dual trigger)    | [`staggered`] |
//! | §3.2 immediate-dispatch baselines           | [`baseline`]  |
//! | global state matrix ⟨C_avail, B_i, K_i⟩     | [`state`]     |
//! | §4.2.2 phase-3 overload protection          | [`flow`]      |

pub mod baseline;
pub mod decode;
pub mod flow;
pub mod interval;
pub mod pbaa;
pub mod prefix;
pub mod state;
pub mod staggered;
pub mod sync;
pub mod types;

pub use types::{DpUnitId, JobSpec, Request, RequestId, SloClass};
