//! Algorithm 1 — Throughput-Adaptive Interval Control Loop.
//!
//! The staggered dispatch cadence `I_opt = (T̄_fwd + L_net) / N_active`
//! matches the arrival rate the scheduler is willing to admit to the
//! cluster's aggregate service rate: with `N_active` gated engines each
//! taking `T̄_fwd` per pass (plus distribution latency `L_net`), one engine
//! becomes ready every `I_opt` seconds in steady state.
//!
//! `T̄_fwd` is smoothed with a sliding-window moving average (W_stats) fed
//! by `EndForward` payloads; `N_active` tracks auto-scaling/health events.

use crate::util::SlidingWindow;

/// Configuration for the interval controller.
#[derive(Debug, Clone)]
pub struct IntervalConfig {
    /// Maximum samples in the execution-time window (`W_size`).
    pub window_size: usize,
    /// Estimated request-distribution network latency (`L_net`), seconds.
    pub l_net: f64,
    /// Initial fallback forward time from offline stress testing
    /// (`T_default`), seconds.
    pub t_default: f64,
    /// Adaptive updates enabled (set false for the static-interval
    /// ablation: `I_opt` stays at `(T_default + L_net)/N`).
    pub adaptive: bool,
}

impl Default for IntervalConfig {
    fn default() -> Self {
        IntervalConfig {
            window_size: 64,
            l_net: 0.002,
            t_default: 0.25,
            adaptive: true,
        }
    }
}

/// The Algorithm 1 state machine.
#[derive(Debug, Clone)]
pub struct IntervalController {
    cfg: IntervalConfig,
    window: SlidingWindow,
    n_active: u32,
    i_opt: f64,
}

impl IntervalController {
    /// Initialize with the offline-calibrated default and the starting
    /// instance count.
    pub fn new(cfg: IntervalConfig, n_active: u32) -> Self {
        let mut c = IntervalController {
            window: SlidingWindow::new(cfg.window_size),
            cfg,
            n_active,
            i_opt: 0.0,
        };
        c.recompute();
        c
    }

    /// Smoothed forward time `T̄_fwd` (falls back to `T_default` before any
    /// sample arrives — Alg. 1 initialization).
    pub fn t_fwd(&self) -> f64 {
        self.window.mean().unwrap_or(self.cfg.t_default)
    }

    /// Current optimal dispatch interval `I_opt`.
    pub fn i_opt(&self) -> f64 {
        self.i_opt
    }

    /// Current active-instance count.
    pub fn n_active(&self) -> u32 {
        self.n_active
    }

    /// Number of samples currently in W_stats.
    pub fn samples(&self) -> usize {
        self.window.len()
    }

    /// Alg. 1 `RecomputeInterval`.
    fn recompute(&mut self) {
        if self.n_active > 0 {
            self.i_opt = (self.t_fwd() + self.cfg.l_net) / self.n_active as f64;
        }
        // n_active == 0: keep the previous interval; dispatch is gated on
        // readiness anyway and the watchdog path recovers instances.
    }

    /// Alg. 1 `OnEndForward(t_measured)`: push the sample, refresh the
    /// moving average, recompute the timer.
    pub fn on_end_forward(&mut self, t_measured: f64) {
        if self.cfg.adaptive && t_measured.is_finite() && t_measured >= 0.0 {
            self.window.push(t_measured);
        }
        self.recompute();
    }

    /// Alg. 1 `OnTopologyChange(N_new)`: immediate adaptation to capacity
    /// shifts from the auto-scaler or health checker.
    pub fn on_topology_change(&mut self, n_new: u32) {
        self.n_active = n_new;
        self.recompute();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl(n: u32) -> IntervalController {
        IntervalController::new(
            IntervalConfig {
                window_size: 4,
                l_net: 0.0,
                t_default: 1.0,
                adaptive: true,
            },
            n,
        )
    }

    #[test]
    fn initial_interval_uses_default() {
        let c = ctl(4);
        assert!((c.i_opt() - 0.25).abs() < 1e-12); // 1.0 / 4
        assert!((c.t_fwd() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn converges_to_measured_mean() {
        let mut c = ctl(2);
        for _ in 0..8 {
            c.on_end_forward(0.5);
        }
        assert!((c.t_fwd() - 0.5).abs() < 1e-12);
        assert!((c.i_opt() - 0.25).abs() < 1e-12); // 0.5 / 2
    }

    #[test]
    fn window_evicts_old_samples() {
        let mut c = ctl(1);
        for _ in 0..4 {
            c.on_end_forward(1.0);
        }
        for _ in 0..4 {
            c.on_end_forward(2.0); // fully displaces the 1.0s
        }
        assert!((c.t_fwd() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn topology_change_recomputes_immediately() {
        let mut c = ctl(4);
        c.on_end_forward(0.8);
        let before = c.i_opt();
        c.on_topology_change(8);
        assert!((c.i_opt() - before / 2.0).abs() < 1e-12);
        assert_eq!(c.n_active(), 8);
    }

    #[test]
    fn zero_active_keeps_previous_interval() {
        let mut c = ctl(4);
        let before = c.i_opt();
        c.on_topology_change(0);
        assert_eq!(c.i_opt(), before);
    }

    #[test]
    fn l_net_included() {
        let c = IntervalController::new(
            IntervalConfig {
                window_size: 4,
                l_net: 0.1,
                t_default: 0.9,
                adaptive: true,
            },
            2,
        );
        assert!((c.i_opt() - 0.5).abs() < 1e-12); // (0.9 + 0.1)/2
    }

    #[test]
    fn rejects_garbage_samples() {
        let mut c = ctl(1);
        c.on_end_forward(f64::NAN);
        c.on_end_forward(-3.0);
        assert_eq!(c.samples(), 0);
        assert!((c.t_fwd() - 1.0).abs() < 1e-12);
    }
}
