//! Immediate-dispatch baseline schedulers (§3.2's "traditional
//! continuous-service assumption").
//!
//! These dispatch every request the moment it arrives, choosing an
//! instance by a classical load-balancing policy and a DP unit by
//! instantaneous greedy headroom. Because the engine is a non-preemptive
//! gated batch processor, requests pushed to a busy instance accumulate in
//! its device-side queue — the HOL blocking SBS eliminates. These are the
//! baselines for Fig. 6, Table 1 and Figs. 7–8.

use super::pbaa::Assignment;
use super::state::GlobalState;
use super::types::Request;

/// Instance-selection policy for immediate dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ImmediatePolicy {
    /// Cycle through instances regardless of state.
    RoundRobin,
    /// Least outstanding work: minimal total in-flight + queued tokens.
    LeastOutstanding,
    /// Join-shortest-queue: minimal device queue depth (batches).
    JoinShortestQueue,
}

/// Immediate-dispatch scheduler over a pool.
pub struct ImmediateScheduler {
    /// Policy in force.
    pub policy: ImmediatePolicy,
    /// Pool state (updated on dispatch/feedback like the SBS state plane,
    /// but *not* consulted for readiness — that is the point).
    pub state: GlobalState,
    rr_cursor: u32,
    dp_cursor: Vec<u32>,
}

impl ImmediateScheduler {
    /// Build for `n_instances × dp_per_instance` with chunk capacity.
    pub fn new(
        policy: ImmediatePolicy,
        n_instances: u32,
        dp_per_instance: u32,
        c_chunk: u32,
    ) -> Self {
        ImmediateScheduler {
            policy,
            state: GlobalState::new(n_instances, dp_per_instance, c_chunk),
            rr_cursor: 0,
            dp_cursor: vec![0; n_instances as usize],
        }
    }

    /// Dispatch one request *now*; always succeeds (that is the failure
    /// mode). Returns the chosen assignment.
    pub fn dispatch(&mut self, request: Request) -> Assignment {
        let instance = self.pick_instance();
        // DP choice: round-robin, blind to chunk-level state. This is the
        // paper's §4.2 "granularity mismatch": traditional schedulers
        // perceive instances coarsely (request counts / total lengths)
        // and never model per-DP chunk occupancy, so DP placement inside
        // the engine is effectively arrival-order striping.
        let n_dp = self.state.dp_per_instance;
        let cursor = &mut self.dp_cursor[instance as usize];
        let dp = *cursor % n_dp;
        *cursor = cursor.wrapping_add(1);
        let unit = self.state.instance_dps(instance)[dp as usize].id;
        let tokens = request.input_tokens;
        self.state.dp_mut(unit).on_dispatch(tokens);
        let inst = &mut self.state.instances[instance as usize];
        inst.queue_depth += 1;
        Assignment {
            request,
            unit,
            cached_tokens: 0,
        }
    }

    /// Engine feedback: a forward pass completed on `instance`.
    pub fn on_end_forward(&mut self, instance: u32, now: f64) {
        let inst = &mut self.state.instances[instance as usize];
        inst.queue_depth = inst.queue_depth.saturating_sub(1);
        inst.last_end_forward = now;
    }

    fn pick_instance(&mut self) -> u32 {
        let n = self.state.n_instances();
        match self.policy {
            ImmediatePolicy::RoundRobin => {
                let i = self.rr_cursor % n;
                self.rr_cursor = self.rr_cursor.wrapping_add(1);
                i
            }
            ImmediatePolicy::LeastOutstanding => {
                let mut best = 0u32;
                let mut best_load = i64::MAX;
                for i in 0..n {
                    let load: i64 = self
                        .state
                        .instance_dps(i)
                        .iter()
                        .map(|d| d.u_flight as i64 + d.r_queued as i64)
                        .sum();
                    if load < best_load {
                        best_load = load;
                        best = i;
                    }
                }
                best
            }
            ImmediatePolicy::JoinShortestQueue => {
                let mut best = 0u32;
                let mut best_depth = u32::MAX;
                for (i, inst) in self.state.instances.iter().enumerate() {
                    if inst.queue_depth < best_depth {
                        best_depth = inst.queue_depth;
                        best = i as u32;
                    }
                }
                best
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, len: u32) -> Request {
        Request::new(id, len, 16, 0.0)
    }

    #[test]
    fn round_robin_cycles() {
        let mut s = ImmediateScheduler::new(ImmediatePolicy::RoundRobin, 3, 2, 3072);
        let instances: Vec<u32> = (0..6).map(|i| s.dispatch(req(i, 100)).unit.instance).collect();
        assert_eq!(instances, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn dispatches_even_to_busy_instances() {
        // The defining flaw: requests keep landing on a saturated target.
        let mut s = ImmediateScheduler::new(ImmediatePolicy::RoundRobin, 1, 1, 100);
        for i in 0..5 {
            s.dispatch(req(i, 100));
        }
        assert_eq!(s.state.instances[0].queue_depth, 5);
        assert!(s.state.dps[0].c_avail() < 0);
    }

    #[test]
    fn least_outstanding_prefers_idle() {
        let mut s = ImmediateScheduler::new(ImmediatePolicy::LeastOutstanding, 2, 1, 3072);
        let a = s.dispatch(req(0, 1000));
        let b = s.dispatch(req(1, 100));
        assert_ne!(a.unit.instance, b.unit.instance);
    }

    #[test]
    fn jsq_follows_queue_depth() {
        let mut s = ImmediateScheduler::new(ImmediatePolicy::JoinShortestQueue, 2, 1, 3072);
        s.dispatch(req(0, 10));
        s.dispatch(req(1, 10));
        s.on_end_forward(0, 1.0);
        let c = s.dispatch(req(2, 10));
        assert_eq!(c.unit.instance, 0);
    }

    #[test]
    fn dp_choice_is_blind_round_robin() {
        let mut s = ImmediateScheduler::new(ImmediatePolicy::RoundRobin, 1, 2, 3072);
        let a = s.dispatch(req(0, 2000));
        let b = s.dispatch(req(1, 10));
        let c = s.dispatch(req(2, 2000));
        // Striped in arrival order regardless of load: dp0, dp1, dp0.
        assert_eq!(a.unit.dp, 0);
        assert_eq!(b.unit.dp, 1);
        assert_eq!(c.unit.dp, 0);
    }
}
