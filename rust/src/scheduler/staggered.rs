//! The Staggered Batch Scheduler main loop (paper Fig. 5).
//!
//! A pure event-driven state machine around three coordinated planes:
//!
//! * **Control plane** — the schedule loop itself. Dispatch fires on the
//!   *dual trigger*: the adaptive interval `I_opt` has elapsed **and** a
//!   target instance is ready (EndForward received / quiescent). Requests
//!   buffer in the scheduler-side queue meanwhile — the deliberate wait
//!   that eliminates device-side HOL blocking (§3.2).
//! * **State plane** — [`GlobalState`] updated by instance feedback, the
//!   Algorithm 1 interval controller, and the §4.1.2 sync protocol.
//! * **Resource plane** — abstract here: dispatch decisions are returned
//!   as [`SchedulerAction`]s for the driver (simulator or real fabric) to
//!   execute.
//!
//! Degraded mode: when the sync protocol marks instances suspect, the loop
//! reverts to fixed-interval batch dispatch over the surviving instances
//! (graceful degradation, §4.1.2).

use super::decode::DecodeSchedConfig;
use super::interval::{IntervalConfig, IntervalController};
use super::pbaa::{self, Assignment, PbaaConfig};
use super::prefix::PrefixCacheModel;
use super::state::{GlobalState, InstancePhase};
use super::sync::{SyncProtocol, WatchdogEvent};
use super::types::Request;

/// Events fed to the scheduler by its driver.
#[derive(Debug, Clone)]
pub enum SchedulerEvent {
    /// A request arrived at the frontend.
    Arrival { request: Request, now: f64 },
    /// An instance finished a forward pass and reported its measured
    /// execution time and remaining backlog (the `EndForward` payload of
    /// Fig. 5). `remaining = None` means the engine does not report
    /// backlog (per-dispatch accounting is used instead).
    EndForward {
        instance: u32,
        t_measured: f64,
        remaining: Option<u32>,
        now: f64,
    },
    /// The timer previously armed via [`SchedulerAction::ArmTimer`] fired.
    Timer { now: f64 },
    /// Queue-depth observation from the polling path (§4.1.2 tier 1).
    QueueObservation {
        instance: u32,
        depth: u32,
        now: f64,
    },
    /// Auto-scaler / health-checker topology change (Alg. 1
    /// `OnTopologyChange`).
    TopologyChange { n_active: u32, now: f64 },
}

/// A batch dispatch to all DP units of one instance.
#[derive(Debug, Clone)]
pub struct DispatchBatch {
    /// Target instance.
    pub instance: u32,
    /// Per-request DP assignments (from PBAA).
    pub assignments: Vec<Assignment>,
    /// Dispatch timestamp.
    pub at: f64,
}

/// Decisions returned to the driver.
#[derive(Debug, Clone)]
pub enum SchedulerAction {
    /// Send this batch to the instance.
    Dispatch(DispatchBatch),
    /// Deliver a [`SchedulerEvent::Timer`] at (or shortly after) `at`.
    ArmTimer { at: f64 },
    /// Reject this request upstream (flow control).
    Reject(Request),
    /// Informational: watchdog fired (drivers may log / fault-inject).
    Watchdog(WatchdogEvent),
}

/// Scheduler configuration.
#[derive(Debug, Clone, Default)]
pub struct StaggeredConfig {
    /// Algorithm 1 knobs.
    pub interval: IntervalConfig,
    /// Algorithm 2 knobs.
    pub pbaa: PbaaConfig,
    /// Algorithm 3 knobs for decode-side placement. The prefill loop
    /// never reads these; the cluster dispatch core consumes them when
    /// its decode policy is load-aware, so one `StaggeredConfig` carries
    /// the paper's full knob set.
    pub decode: DecodeSchedConfig,
}

/// The staggered batch scheduler for a prefill pool.
pub struct StaggeredScheduler {
    cfg: StaggeredConfig,
    /// Global state matrix for the pool.
    pub state: GlobalState,
    interval: IntervalController,
    sync: SyncProtocol,
    /// Scheduler-side queue: fresh arrivals since the last cycle.
    buffer: Vec<Request>,
    /// Unassigned leftovers from previous PBAA cycles (`Q_pending`).
    pending: Vec<Request>,
    /// Optional per-DP prefix-cache model (cache-aware PBAA).
    cache: Option<PrefixCacheModel>,
    /// Requests staged for rejection by flow control.
    overflow: Vec<Request>,
    /// Total input tokens sitting in `buffer` + `pending` (size trigger).
    queued_tokens: u64,
    /// Per-DP chunk capacity (for the batch-formed early trigger).
    chunk_capacity: u32,
    last_dispatch: f64,
    /// Round-robin cursor for target selection among ready instances.
    target_cursor: u32,
    timer_armed_at: f64,
}

impl StaggeredScheduler {
    /// Build a scheduler for `n_instances × dp_per_instance` units with
    /// chunk capacity `c_chunk`.
    pub fn new(cfg: StaggeredConfig, n_instances: u32, dp_per_instance: u32, c_chunk: u32) -> Self {
        let state = GlobalState::new(n_instances, dp_per_instance, c_chunk);
        let interval = IntervalController::new(cfg.interval.clone(), n_instances);
        let cache = cfg.pbaa.cache_aware.then(|| {
            // Budget: hold ~32 chunks of prefix per DP unit before LRU
            // eviction; enough for realistic multi-tenant prefix reuse.
            PrefixCacheModel::new(
                (n_instances * dp_per_instance) as usize,
                32 * c_chunk as u64,
            )
        });
        StaggeredScheduler {
            cfg,
            state,
            interval,
            sync: SyncProtocol::new(n_instances),
            buffer: Vec::new(),
            pending: Vec::new(),
            cache,
            overflow: Vec::new(),
            queued_tokens: 0,
            chunk_capacity: c_chunk,
            last_dispatch: f64::NEG_INFINITY,
            target_cursor: 0,
            timer_armed_at: f64::NEG_INFINITY,
        }
    }

    /// Current adaptive interval (exposed for metrics/tests).
    pub fn i_opt(&self) -> f64 {
        self.interval.i_opt()
    }

    /// Buffered + pending request count (scheduler-side queue length).
    pub fn queued(&self) -> usize {
        self.buffer.len() + self.pending.len()
    }

    /// Whether degraded fixed-interval mode is active.
    pub fn degraded(&self) -> bool {
        self.sync.degraded()
    }

    /// Feed one event; returns the actions the driver must execute.
    pub fn on_event(&mut self, ev: SchedulerEvent) -> Vec<SchedulerAction> {
        let mut actions = Vec::new();
        match ev {
            SchedulerEvent::Arrival { request, now } => {
                self.queued_tokens += request.input_tokens as u64;
                self.buffer.push(request);
                self.try_dispatch(now, &mut actions);
                self.ensure_timer(now, &mut actions);
            }
            SchedulerEvent::EndForward {
                instance,
                t_measured,
                remaining,
                now,
            } => {
                self.interval.on_end_forward(t_measured);
                self.sync
                    .on_end_forward(&mut self.state, instance, now, remaining);
                self.try_dispatch(now, &mut actions);
                self.ensure_timer(now, &mut actions);
            }
            SchedulerEvent::Timer { now } => {
                self.timer_armed_at = f64::NEG_INFINITY;
                for w in self.sync.sweep_watchdogs(&mut self.state, now) {
                    actions.push(SchedulerAction::Watchdog(w));
                }
                self.try_dispatch(now, &mut actions);
                self.ensure_timer(now, &mut actions);
            }
            SchedulerEvent::QueueObservation {
                instance,
                depth,
                now,
            } => {
                self.sync.on_queue_observation(&mut self.state, instance, depth);
                self.try_dispatch(now, &mut actions);
            }
            SchedulerEvent::TopologyChange { n_active, now } => {
                self.interval.on_topology_change(n_active);
                self.try_dispatch(now, &mut actions);
                self.ensure_timer(now, &mut actions);
            }
        }
        actions
    }

    /// The dual-trigger dispatch check. Fires at most one batch per call
    /// per ready target (loops while both triggers hold and work remains —
    /// e.g. after a long drain several instances may be ready).
    fn try_dispatch(&mut self, now: f64, actions: &mut Vec<SchedulerAction>) {
        loop {
            if self.buffer.is_empty() && self.pending.is_empty() {
                return;
            }
            // Trigger 1: interval elapsed since the last dispatch — OR an
            // optimal batch has already formed (≥ one instance's full
            // chunk budget buffered). The window exists to *form optimal
            // batches* (§3.2); once one is formed, waiting adds latency
            // without improving the batch.
            let chunk_budget = (self.state.dp_per_instance as u64) * self.chunk_capacity as u64;
            let interval_ok = now - self.last_dispatch >= self.interval.i_opt();
            let batch_formed = self.queued_tokens >= chunk_budget;
            if !interval_ok && !batch_formed {
                return;
            }
            // Trigger 2: a target instance signalled readiness — unless
            // degraded mode, where fixed-interval dispatch proceeds on the
            // least-recently-dispatched live instance. A ready target with
            // no capacity headroom yields an empty PBAA cycle; try the
            // next ready instance before giving up.
            let mut dispatched = false;
            for _ in 0..self.state.n_instances() {
                let target = if self.sync.degraded() {
                    self.pick_degraded_target()
                } else {
                    self.pick_ready_target()
                };
                let Some(instance) = target else { break };
                let assignments = self.run_pbaa(instance);
                // Flow-control rejections may arise even on empty cycles.
                while let Some(r) = self.overflow.pop() {
                    actions.push(SchedulerAction::Reject(r));
                }
                if assignments.is_empty() {
                    continue; // no headroom here; try another ready target
                }
                self.last_dispatch = now;
                self.sync
                    .on_dispatch(&mut self.state, instance, now, self.interval.t_fwd());
                actions.push(SchedulerAction::Dispatch(DispatchBatch {
                    instance,
                    assignments,
                    at: now,
                }));
                dispatched = true;
                break;
            }
            if !dispatched {
                return;
            }
        }
    }

    /// Round-robin over instances currently in the Ready phase.
    fn pick_ready_target(&mut self) -> Option<u32> {
        let n = self.state.n_instances();
        for k in 0..n {
            let i = (self.target_cursor + k) % n;
            if self.state.instances[i as usize].phase == InstancePhase::Ready {
                self.target_cursor = i + 1;
                return Some(i);
            }
        }
        None
    }

    /// Degraded mode target: least-recently-dispatched non-suspect
    /// instance regardless of Busy state (fixed-interval batch mode).
    fn pick_degraded_target(&mut self) -> Option<u32> {
        self.state
            .instances
            .iter()
            .filter(|i| i.phase != InstancePhase::Suspect)
            .min_by(|a, b| a.last_dispatch.partial_cmp(&b.last_dispatch).unwrap())
            .map(|i| i.index)
    }

    /// Run PBAA over (pending, buffer) against the target instance's DP
    /// units; refills `pending` with leftovers and stages overloads.
    fn run_pbaa(&mut self, instance: u32) -> Vec<Assignment> {
        let pending = std::mem::take(&mut self.pending);
        let fresh = std::mem::take(&mut self.buffer);
        let a = (instance * self.state.dp_per_instance) as usize;
        let b = a + self.state.dp_per_instance as usize;
        // PBAA receives an instance-local DP slice; the pool-global cache
        // model is told the slice's base so `len_hit(i, ..)` resolves to
        // the right global unit.
        let cache = self.cache.as_mut().map(|c| {
            c.set_base(a);
            c
        });
        let outcome = pbaa::allocate(
            &self.cfg.pbaa,
            pending,
            fresh,
            &mut self.state.dps[a..b],
            cache,
        );
        self.pending = outcome.next_queue;
        self.overflow.extend(outcome.overloaded);
        self.queued_tokens = self
            .pending
            .iter()
            .map(|r| r.input_tokens as u64)
            .sum();
        outcome.assignments
    }

    /// Arm the driver timer for the next interval boundary (idempotent —
    /// at most one outstanding timer).
    fn ensure_timer(&mut self, now: f64, actions: &mut Vec<SchedulerAction>) {
        if self.buffer.is_empty() && self.pending.is_empty() {
            return; // nothing to dispatch; EndForward/Arrival will re-arm
        }
        // Never arm sub-interval timers: when the interval is already
        // overdue (waiting on instance readiness, not time), spinning at
        // microsecond cadence would only burn cycles and race the
        // flow-control wait counters. Wake at half an interval for
        // dispatch retries, capped by T̄ for watchdog sweeps.
        let retry = (self.interval.i_opt() * 0.5).max(1e-3);
        let next = (self.last_dispatch + self.interval.i_opt()).max(now + retry);
        let next = next.min(now + self.interval.t_fwd().max(1e-3));
        if self.timer_armed_at > now && self.timer_armed_at <= next {
            return; // an earlier-or-equal timer is already armed
        }
        self.timer_armed_at = next;
        actions.push(SchedulerAction::ArmTimer { at: next });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(n: u32, dp: u32) -> StaggeredScheduler {
        let cfg = StaggeredConfig {
            interval: IntervalConfig {
                window_size: 8,
                l_net: 0.0,
                t_default: 0.4,
                adaptive: true,
            },
            pbaa: PbaaConfig::default(),
            decode: DecodeSchedConfig::default(),
        };
        StaggeredScheduler::new(cfg, n, dp, 3072)
    }

    fn req(id: u64, len: u32, t: f64) -> Request {
        Request::new(id, len, 16, t)
    }

    fn dispatches(actions: &[SchedulerAction]) -> Vec<&DispatchBatch> {
        actions
            .iter()
            .filter_map(|a| match a {
                SchedulerAction::Dispatch(d) => Some(d),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn first_arrival_dispatches_immediately() {
        // Cold start: all instances ready, no prior dispatch — the dual
        // trigger is satisfied at once (quiescence path).
        let mut s = sched(2, 4);
        let acts = s.on_event(SchedulerEvent::Arrival {
            request: req(1, 1000, 0.0),
            now: 0.0,
        });
        let d = dispatches(&acts);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].assignments.len(), 1);
        assert_eq!(s.queued(), 0);
    }

    #[test]
    fn second_arrival_buffers_until_interval() {
        let mut s = sched(2, 4);
        s.on_event(SchedulerEvent::Arrival {
            request: req(1, 1000, 0.0),
            now: 0.0,
        });
        // i_opt = 0.4/2 = 0.2; an arrival at 0.1 must buffer.
        let acts = s.on_event(SchedulerEvent::Arrival {
            request: req(2, 800, 0.1),
            now: 0.1,
        });
        assert!(dispatches(&acts).is_empty());
        assert_eq!(s.queued(), 1);
        // Timer fires at the interval boundary → dispatch to the other
        // (still-ready) instance.
        let acts = s.on_event(SchedulerEvent::Timer { now: 0.2 });
        let d = dispatches(&acts);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].instance, 1);
    }

    #[test]
    fn no_dispatch_when_all_busy() {
        let mut s = sched(1, 2);
        s.on_event(SchedulerEvent::Arrival {
            request: req(1, 500, 0.0),
            now: 0.0,
        });
        // Instance 0 is now busy; next arrival can't go anywhere even
        // after the interval.
        s.on_event(SchedulerEvent::Arrival {
            request: req(2, 500, 0.5),
            now: 0.5,
        });
        let acts = s.on_event(SchedulerEvent::Timer { now: 1.0 });
        assert!(dispatches(&acts).is_empty());
        assert_eq!(s.queued(), 1);
        // EndForward releases it.
        let acts = s.on_event(SchedulerEvent::EndForward {
            instance: 0,
            t_measured: 0.4,
            remaining: None,
            now: 1.1,
        });
        assert_eq!(dispatches(&acts).len(), 1);
    }

    #[test]
    fn interval_adapts_to_end_forward_times() {
        let mut s = sched(4, 1);
        let before = s.i_opt(); // 0.4 / 4 = 0.1
        assert!((before - 0.1).abs() < 1e-12);
        s.on_event(SchedulerEvent::Arrival {
            request: req(1, 100, 0.0),
            now: 0.0,
        });
        for k in 0..8 {
            s.on_event(SchedulerEvent::EndForward {
                instance: 0,
                t_measured: 0.8,
                remaining: None,
                now: 0.1 * k as f64,
            });
        }
        assert!((s.i_opt() - 0.2).abs() < 1e-12); // 0.8 / 4
    }

    #[test]
    fn watchdog_recovers_lost_end_forward() {
        let mut s = sched(1, 1);
        s.on_event(SchedulerEvent::Arrival {
            request: req(1, 100, 0.0),
            now: 0.0,
        });
        // EndForward never arrives. Watchdog threshold = 5 × 0.4 = 2.0.
        s.on_event(SchedulerEvent::Arrival {
            request: req(2, 100, 0.5),
            now: 0.5,
        });
        let acts = s.on_event(SchedulerEvent::Timer { now: 2.5 });
        let saw_watchdog = acts
            .iter()
            .any(|a| matches!(a, SchedulerAction::Watchdog(_)));
        assert!(saw_watchdog, "{acts:?}");
        // The forced reset makes the instance ready again → dispatch.
        assert_eq!(dispatches(&acts).len(), 1);
    }

    #[test]
    fn topology_change_halves_interval() {
        let mut s = sched(2, 1);
        let i2 = s.i_opt();
        s.on_event(SchedulerEvent::TopologyChange {
            n_active: 4,
            now: 0.0,
        });
        assert!((s.i_opt() - i2 / 2.0).abs() < 1e-12);
    }

    #[test]
    fn round_robin_target_rotation() {
        let mut s = sched(3, 1);
        let mut targets = Vec::new();
        let mut t = 0.0;
        for id in 0..3 {
            let acts = s.on_event(SchedulerEvent::Arrival {
                request: req(id, 100, t),
                now: t,
            });
            for d in dispatches(&acts) {
                targets.push(d.instance);
            }
            t += 0.2; // ≥ i_opt = 0.4/3
        }
        assert_eq!(targets, vec![0, 1, 2]);
    }
}
