//! Shared request/identifier types used across scheduling policies, the
//! simulator and the real engine.

/// Globally unique request identifier.
pub type RequestId = u64;

/// Service-level objective class of a request, ordered by latency
/// sensitivity. Under overload the flow controller sheds strictly in
/// reverse order: `Batch` first, `Standard` next, `Interactive` never
/// while a lower class is still being admitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum SloClass {
    /// Latency-critical (chat-style) traffic; never shed by throttling.
    Interactive,
    /// Default class for unannotated requests (legacy clients).
    #[default]
    Standard,
    /// Deadline-tolerant offline work; first to be shed under overload.
    Batch,
}

impl SloClass {
    /// Every class, in shed-priority order (`rank()` order).
    pub const ALL: [SloClass; 3] = [SloClass::Interactive, SloClass::Standard, SloClass::Batch];

    /// Stable small integer used to index per-class counter arrays and
    /// to order batch formation (lower = more latency-sensitive).
    pub fn rank(self) -> usize {
        match self {
            SloClass::Interactive => 0,
            SloClass::Standard => 1,
            SloClass::Batch => 2,
        }
    }

    /// Canonical lowercase name (wire text, report keys, CLI values).
    pub fn name(self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Standard => "standard",
            SloClass::Batch => "batch",
        }
    }

    /// Parse a canonical name; `None` for anything unknown.
    pub fn parse(s: &str) -> Option<SloClass> {
        match s {
            "interactive" => Some(SloClass::Interactive),
            "standard" => Some(SloClass::Standard),
            "batch" => Some(SloClass::Batch),
            _ => None,
        }
    }

    /// Single-byte wire encoding (frame protocol v6).
    pub fn to_wire(self) -> u8 {
        self.rank() as u8
    }

    /// Decode the wire byte; `None` rejects out-of-domain values.
    pub fn from_wire(b: u8) -> Option<SloClass> {
        match b {
            0 => Some(SloClass::Interactive),
            1 => Some(SloClass::Standard),
            2 => Some(SloClass::Batch),
            _ => None,
        }
    }
}

/// A complete request descriptor as submitted by a frontend: everything
/// the cluster needs to admit, schedule and place one generation. This
/// is the one struct threaded from the `GEN` line (or the DES workload
/// generator) down to Algorithm 3 placement — layers must not decompose
/// it back into loose `(prompt, max_new)` tuples.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Unique id.
    pub id: RequestId,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Generation cap (including the prefill's first token).
    pub max_new: u32,
    /// SLO class; `Standard` for legacy clients that do not annotate.
    pub class: SloClass,
    /// Optional completion deadline, milliseconds after arrival. Only
    /// meaningful to the deadline-aware decode placement policy.
    pub deadline_ms: Option<f64>,
}

impl JobSpec {
    /// A standard-class spec with no deadline (legacy `(prompt, max_new)`
    /// submissions map onto exactly this).
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new: u32) -> Self {
        JobSpec {
            id,
            prompt,
            max_new,
            class: SloClass::default(),
            deadline_ms: None,
        }
    }

    /// Set the SLO class.
    pub fn with_class(mut self, class: SloClass) -> Self {
        self.class = class;
        self
    }

    /// Set the completion deadline in milliseconds after arrival.
    pub fn with_deadline_ms(mut self, deadline_ms: f64) -> Self {
        self.deadline_ms = Some(deadline_ms);
        self
    }
}

/// Identifies one DP-Attention unit: `(instance, local dp rank)`.
///
/// The paper's §3.1 point: in DP+EP deployments the atomic scheduling unit
/// is the DP-Attention group *inside* an instance, not the instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DpUnitId {
    /// Index of the inference instance in its pool.
    pub instance: u32,
    /// DP rank within the instance.
    pub dp: u32,
}

impl DpUnitId {
    /// Convenience constructor.
    pub fn new(instance: u32, dp: u32) -> Self {
        DpUnitId { instance, dp }
    }
}

impl std::fmt::Display for DpUnitId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "i{}d{}", self.instance, self.dp)
    }
}

/// A request as the scheduler sees it.
#[derive(Debug, Clone)]
pub struct Request {
    /// Unique id.
    pub id: RequestId,
    /// Prompt length in tokens (the paper's `L(r)`).
    pub input_tokens: u32,
    /// Number of tokens to generate (known in simulation; a cap in real
    /// serving).
    pub output_tokens: u32,
    /// Arrival timestamp at the scheduler frontend, seconds.
    pub arrival: f64,
    /// Consecutive allocation cycles this request failed to place
    /// (Algorithm 2 phase 3; compared against `N_limit`).
    pub wait_cycles: u32,
    /// Shared-prefix group for cache-aware scheduling (None = unique).
    pub prefix_group: Option<u64>,
    /// Length of the shared prefix in tokens (0 when no group).
    pub prefix_len: u32,
    /// SLO class (batch-formation order, shed priority).
    pub class: SloClass,
    /// Absolute completion deadline on the scheduler clock, seconds
    /// (`arrival + deadline_ms / 1000`). `None` = no deadline.
    pub deadline: Option<f64>,
}

impl Request {
    /// A plain request with no shared prefix.
    pub fn new(id: RequestId, input_tokens: u32, output_tokens: u32, arrival: f64) -> Self {
        Request {
            id,
            input_tokens,
            output_tokens,
            arrival,
            wait_cycles: 0,
            prefix_group: None,
            prefix_len: 0,
            class: SloClass::default(),
            deadline: None,
        }
    }

    /// Attach a shared prefix group (for cache-aware allocation).
    pub fn with_prefix(mut self, group: u64, prefix_len: u32) -> Self {
        assert!(prefix_len <= self.input_tokens);
        self.prefix_group = Some(group);
        self.prefix_len = prefix_len;
        self
    }

    /// Attach an SLO class.
    pub fn with_class(mut self, class: SloClass) -> Self {
        self.class = class;
        self
    }

    /// Attach an absolute completion deadline (scheduler clock, seconds).
    pub fn with_deadline(mut self, deadline: f64) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Total sequence length once fully decoded (used by Algorithm 3's
    /// fill-the-valley pre-sort).
    pub fn total_len(&self) -> u32 {
        self.input_tokens + self.output_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dp_unit_display_and_ord() {
        let a = DpUnitId::new(0, 1);
        let b = DpUnitId::new(1, 0);
        assert!(a < b);
        assert_eq!(a.to_string(), "i0d1");
    }

    #[test]
    fn request_total_len() {
        let r = Request::new(1, 100, 28, 0.0);
        assert_eq!(r.total_len(), 128);
    }

    #[test]
    #[should_panic]
    fn prefix_longer_than_input_rejected() {
        let _ = Request::new(1, 10, 1, 0.0).with_prefix(7, 11);
    }

    #[test]
    fn slo_class_round_trips_names_and_wire_bytes() {
        for c in SloClass::ALL {
            assert_eq!(SloClass::parse(c.name()), Some(c));
            assert_eq!(SloClass::from_wire(c.to_wire()), Some(c));
        }
        assert_eq!(SloClass::parse("premium"), None);
        assert_eq!(SloClass::from_wire(3), None);
    }

    #[test]
    fn slo_class_ranks_order_by_latency_sensitivity() {
        assert!(SloClass::Interactive.rank() < SloClass::Standard.rank());
        assert!(SloClass::Standard.rank() < SloClass::Batch.rank());
        assert_eq!(SloClass::default(), SloClass::Standard);
    }

    #[test]
    fn job_spec_defaults_match_legacy_submissions() {
        let spec = JobSpec::new(3, vec![1, 2], 8);
        assert_eq!(spec.class, SloClass::Standard);
        assert_eq!(spec.deadline_ms, None);
        let spec = spec.with_class(SloClass::Batch).with_deadline_ms(750.0);
        assert_eq!(spec.class, SloClass::Batch);
        assert_eq!(spec.deadline_ms, Some(750.0));
    }

    #[test]
    fn request_class_and_deadline_builders() {
        let r = Request::new(1, 100, 28, 2.0)
            .with_class(SloClass::Interactive)
            .with_deadline(2.5);
        assert_eq!(r.class, SloClass::Interactive);
        assert_eq!(r.deadline, Some(2.5));
        let plain = Request::new(2, 10, 1, 0.0);
        assert_eq!(plain.class, SloClass::Standard);
        assert_eq!(plain.deadline, None);
    }
}
