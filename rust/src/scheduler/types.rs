//! Shared request/identifier types used across scheduling policies, the
//! simulator and the real engine.

/// Globally unique request identifier.
pub type RequestId = u64;

/// Identifies one DP-Attention unit: `(instance, local dp rank)`.
///
/// The paper's §3.1 point: in DP+EP deployments the atomic scheduling unit
/// is the DP-Attention group *inside* an instance, not the instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DpUnitId {
    /// Index of the inference instance in its pool.
    pub instance: u32,
    /// DP rank within the instance.
    pub dp: u32,
}

impl DpUnitId {
    /// Convenience constructor.
    pub fn new(instance: u32, dp: u32) -> Self {
        DpUnitId { instance, dp }
    }
}

impl std::fmt::Display for DpUnitId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "i{}d{}", self.instance, self.dp)
    }
}

/// A request as the scheduler sees it.
#[derive(Debug, Clone)]
pub struct Request {
    /// Unique id.
    pub id: RequestId,
    /// Prompt length in tokens (the paper's `L(r)`).
    pub input_tokens: u32,
    /// Number of tokens to generate (known in simulation; a cap in real
    /// serving).
    pub output_tokens: u32,
    /// Arrival timestamp at the scheduler frontend, seconds.
    pub arrival: f64,
    /// Consecutive allocation cycles this request failed to place
    /// (Algorithm 2 phase 3; compared against `N_limit`).
    pub wait_cycles: u32,
    /// Shared-prefix group for cache-aware scheduling (None = unique).
    pub prefix_group: Option<u64>,
    /// Length of the shared prefix in tokens (0 when no group).
    pub prefix_len: u32,
}

impl Request {
    /// A plain request with no shared prefix.
    pub fn new(id: RequestId, input_tokens: u32, output_tokens: u32, arrival: f64) -> Self {
        Request {
            id,
            input_tokens,
            output_tokens,
            arrival,
            wait_cycles: 0,
            prefix_group: None,
            prefix_len: 0,
        }
    }

    /// Attach a shared prefix group (for cache-aware allocation).
    pub fn with_prefix(mut self, group: u64, prefix_len: u32) -> Self {
        assert!(prefix_len <= self.input_tokens);
        self.prefix_group = Some(group);
        self.prefix_len = prefix_len;
        self
    }

    /// Total sequence length once fully decoded (used by Algorithm 3's
    /// fill-the-valley pre-sort).
    pub fn total_len(&self) -> u32 {
        self.input_tokens + self.output_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dp_unit_display_and_ord() {
        let a = DpUnitId::new(0, 1);
        let b = DpUnitId::new(1, 0);
        assert!(a < b);
        assert_eq!(a.to_string(), "i0d1");
    }

    #[test]
    fn request_total_len() {
        let r = Request::new(1, 100, 28, 0.0);
        assert_eq!(r.total_len(), 128);
    }

    #[test]
    #[should_panic]
    fn prefix_longer_than_input_rejected() {
        let _ = Request::new(1, 10, 1, 0.0).with_prefix(7, 11);
    }
}
