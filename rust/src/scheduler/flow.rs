//! Overload protection / flow control (Algorithm 2, phase 3).
//!
//! When PBAA reports requests that exceeded `N_limit` waiting cycles, the
//! flow controller decides between throttling (shed a fraction of new
//! admissions for a cool-down window) and outright rejection, and exposes
//! an admission check for the frontend.

use super::types::Request;

/// Flow-control policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlowPolicy {
    /// Reject the overloaded requests themselves, admit everything else.
    RejectOverloaded,
    /// Additionally shed a fraction of *new* admissions for a cool-down
    /// period after each overload event (paper's "Throttle").
    Throttle,
}

/// Flow controller state.
#[derive(Debug, Clone)]
pub struct FlowController {
    policy: FlowPolicy,
    /// Fraction of new requests shed while throttling (0..1).
    pub shed_fraction: f64,
    /// Cool-down duration in seconds after an overload event.
    pub cooldown: f64,
    throttle_until: f64,
    /// Monotone counter used to deterministically shed every k-th request.
    admit_counter: u64,
    /// Total rejected requests (overload + shed).
    rejected: u64,
}

impl FlowController {
    /// New controller.
    pub fn new(policy: FlowPolicy) -> Self {
        FlowController {
            policy,
            shed_fraction: 0.25,
            cooldown: 2.0,
            throttle_until: -1.0,
            admit_counter: 0,
            rejected: 0,
        }
    }

    /// Total requests rejected so far.
    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    /// Whether throttling is active at `now`.
    pub fn throttling(&self, now: f64) -> bool {
        self.policy == FlowPolicy::Throttle && now < self.throttle_until
    }

    /// Handle PBAA's overloaded set at time `now`; returns the requests to
    /// reject upstream (all of them, under both policies — they already
    /// waited `N_limit` cycles).
    pub fn on_overload(&mut self, now: f64, overloaded: Vec<Request>) -> Vec<Request> {
        if !overloaded.is_empty() && self.policy == FlowPolicy::Throttle {
            self.throttle_until = now + self.cooldown;
        }
        self.rejected += overloaded.len() as u64;
        overloaded
    }

    /// Admission check for a new arrival at `now`. Deterministic shedding:
    /// while throttling, every ⌈1/shed_fraction⌉-th request is refused.
    pub fn admit(&mut self, now: f64) -> bool {
        if !self.throttling(now) {
            return true;
        }
        self.admit_counter += 1;
        let period = (1.0 / self.shed_fraction).round().max(1.0) as u64;
        if self.admit_counter % period == 0 {
            self.rejected += 1;
            false
        } else {
            true
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn r(id: u64) -> Request {
        Request::new(id, 100, 10, 0.0)
    }

    #[test]
    fn reject_policy_never_throttles() {
        let mut f = FlowController::new(FlowPolicy::RejectOverloaded);
        let rejected = f.on_overload(1.0, vec![r(1), r(2)]);
        assert_eq!(rejected.len(), 2);
        assert_eq!(f.rejected(), 2);
        assert!(!f.throttling(1.1));
        assert!(f.admit(1.1));
    }

    #[test]
    fn throttle_sheds_fraction_during_cooldown() {
        let mut f = FlowController::new(FlowPolicy::Throttle);
        f.shed_fraction = 0.5;
        f.on_overload(10.0, vec![r(1)]);
        assert!(f.throttling(10.5));
        let admitted = (0..10).filter(|_| f.admit(10.5)).count();
        assert_eq!(admitted, 5, "50% shed");
        // After cooldown everything is admitted again.
        assert!(!f.throttling(12.5));
        assert!(f.admit(12.5));
    }

    #[test]
    fn empty_overload_does_not_arm_throttle() {
        let mut f = FlowController::new(FlowPolicy::Throttle);
        f.on_overload(10.0, vec![]);
        assert!(!f.throttling(10.1));
    }
}
